"""L1 performance estimators (VMEM footprint, MXU utilization) and the
oc-tile selection policy — the structural-perf contract of DESIGN.md §5."""

import numpy as np
import pytest

from compile.kernels import mm2im, ref


def test_vmem_accounts_every_operand():
    p = ref.TconvProblem(8, 8, 64, 5, 32, 2)
    v = mm2im.vmem_bytes(p, oc_tile=16)
    assert v["x"] == 8 * 8 * 64 * 4
    assert v["w"] == 5 * 64 * 5 * 16 * 4
    assert v["g"] == (8 * 5) * 16 * 4
    assert v["out_row"] == 16 * 16 * 4
    assert v["total"] == sum(val for k, val in v.items() if k != "total")


def test_vmem_fits_tpu_budget_for_all_table2_layers():
    layers = [
        ref.TconvProblem(4, 4, 1024, 5, 512, 2),
        ref.TconvProblem(8, 8, 512, 5, 256, 2),
        ref.TconvProblem(16, 16, 256, 5, 128, 2),
        ref.TconvProblem(64, 64, 128, 3, 64, 2),
        ref.TconvProblem(256, 256, 32, 9, 3, 2),
    ]
    for p in layers:
        t = mm2im._pick_oc_tile(p.oc)
        assert mm2im.vmem_bytes(p, t)["total"] < 16 * 1024 * 1024, str(p)


def test_mxu_utilization_bounded_and_monotone_in_tile():
    p = ref.TconvProblem(64, 64, 128, 3, 64, 2)
    utils = [mm2im.mxu_utilization(p, t)["weighted"] for t in (8, 16, 32, 64)]
    assert all(0.0 < u <= 1.0 for u in utils)
    assert utils == sorted(utils), "larger tiles must not reduce MXU feed"


def test_pick_oc_tile_is_largest_divisor_leq_128():
    assert mm2im._pick_oc_tile(512) == 128
    assert mm2im._pick_oc_tile(64) == 64
    assert mm2im._pick_oc_tile(48) == 16
    assert mm2im._pick_oc_tile(3) == 1
    assert mm2im._pick_oc_tile(21) == 1


@pytest.mark.parametrize("oc,tile", [(16, 8), (16, 16), (32, 4)])
def test_kernel_correct_at_every_legal_tile(oc, tile):
    rng = np.random.default_rng(0)
    p = ref.TconvProblem(4, 4, 8, 3, oc, 2)
    x = rng.standard_normal((4, 4, 8)).astype(np.float32)
    w = rng.standard_normal((oc, 3, 3, 8)).astype(np.float32)
    got = np.asarray(mm2im.mm2im(x, w, None, 2, oc_tile=tile))
    want = np.asarray(ref.tconv_ref(x, w, None, 2))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
