"""Properties of the compute/output maps (Algorithm 2) and tiling schedule
(Algorithm 1) — the software mirrors the rust `tconv::maps` module must
match bit-for-bit."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

problems = st.tuples(
    st.integers(1, 8), st.integers(1, 8), st.integers(1, 8),
    st.integers(1, 7), st.integers(1, 8), st.integers(1, 3),
).map(lambda t: ref.TconvProblem(*t))


def test_fig2_worked_example():
    """Paper §III-A: tconv(2,2,2,3,2,1) => D_o=40, M*N=72, D_r=0.55;
    storage efficiency 2.25x (skip dropped) and 9x (direct accumulate)."""
    p = ref.TconvProblem(2, 2, 2, 3, 2, 1)
    d_o, d_r = ref.drop_stats(p)
    assert (p.m * p.n) == 72
    assert d_o == 40
    assert abs(d_r - 40 / 72) < 1e-12
    kept = p.m * p.n - d_o
    assert p.m * p.n / kept == pytest.approx(2.25)
    assert p.m * p.n / (p.oh * p.ow * p.oc) == pytest.approx(9.0)


@settings(max_examples=120, deadline=None)
@given(p=problems)
def test_omap_indices_in_bounds(p):
    omap = ref.output_map(p)
    assert omap.shape == (p.m, p.ks * p.ks)
    valid = omap[omap >= 0]
    if valid.size:
        assert valid.max() < p.oh * p.ow
    assert omap.min() >= -1


@settings(max_examples=120, deadline=None)
@given(p=problems)
def test_omap_covers_every_output(p):
    """Every final output receives at least one partial (TCONV with
    Oh = S*Ih and pad = (Ks-S)//2 is surjective onto the cropped window)
    whenever Ks >= S; with Ks < S the uncovered zero-gap outputs exist."""
    omap = ref.output_map(p)
    covered = np.zeros(p.oh * p.ow, bool)
    covered[omap[omap >= 0]] = True
    if p.ks >= p.stride:
        assert covered.all()


@settings(max_examples=100, deadline=None)
@given(p=problems)
def test_overlap_counts_match_direct_contributions(p):
    """The multiset of omap targets == brute-force contribution counts."""
    omap = ref.output_map(p)
    counts = np.zeros(p.oh * p.ow, np.int64)
    for v in omap[omap >= 0]:
        counts[v] += 1
    brute = np.zeros((p.oh, p.ow), np.int64)
    for ih in range(p.ih):
        for iw in range(p.iw):
            for kh in range(p.ks):
                for kw in range(p.ks):
                    oh = ih * p.stride - p.pad_top + kh
                    ow = iw * p.stride - p.pad_left + kw
                    if 0 <= oh < p.oh and 0 <= ow < p.ow:
                        brute[oh, ow] += 1
    np.testing.assert_array_equal(counts.reshape(p.oh, p.ow), brute)


@settings(max_examples=100, deadline=None)
@given(p=problems)
def test_row_schedule_exactly_the_contributing_rows(p):
    idx, khs, valid, r = ref.row_schedule(p)
    assert r <= (p.ks + p.stride - 1) // p.stride
    for h in range(p.oh):
        got = {(int(idx[h, s]), int(khs[h, s])) for s in range(r) if valid[h, s]}
        want = {
            (ihr, h + p.pad_top - ihr * p.stride)
            for ihr in range(p.ih)
            if 0 <= h + p.pad_top - ihr * p.stride < p.ks
        }
        assert got == want


@settings(max_examples=100, deadline=None)
@given(p=problems)
def test_i_end_row_monotone_nondecreasing(p):
    """Algorithm 1 streams input rows forward only; i_end_row must be
    non-decreasing or the dynamic input loader would rewind."""
    ends = ref.i_end_row(p)
    seen = -1
    for e in ends:
        if e >= 0:
            assert e >= seen
            seen = e


@settings(max_examples=100, deadline=None)
@given(p=problems)
def test_scatter_matrix_is_partial_permutation(p):
    """G rows are one-hot or zero; zero rows == width-cropped taps."""
    g = ref.width_scatter_matrix(p)
    sums = g.sum(axis=1)
    assert set(np.unique(sums)) <= {0.0, 1.0}
    zero_rows = int((sums == 0).sum())
    brute = sum(
        1
        for iw in range(p.iw)
        for kw in range(p.ks)
        if not (0 <= iw * p.stride - p.pad_left + kw < p.ow)
    )
    assert zero_rows == brute


@settings(max_examples=60, deadline=None)
@given(p=problems)
def test_drop_rate_in_unit_interval_and_consistent(p):
    d_o, d_r = ref.drop_stats(p)
    assert 0 <= d_o <= p.m * p.n
    assert 0.0 <= d_r < 1.0
    assert d_o % p.oc == 0  # drops replicate across the Oc axis


def test_stride_reduces_drop_rate():
    """Paper §V-B: higher stride => lower drop rate (same other dims)."""
    for ks in (3, 5, 7):
        for ih in (7, 9, 11):
            _, d1 = ref.drop_stats(ref.TconvProblem(ih, ih, 32, ks, 32, 1))
            _, d2 = ref.drop_stats(ref.TconvProblem(ih, ih, 32, ks, 32, 2))
            assert d2 < d1


def test_kernel_size_increases_drop_rate():
    """Paper §V-B: larger Ks => higher drop rate."""
    for s in (1, 2):
        for ih in (7, 9, 11):
            rates = [
                ref.drop_stats(ref.TconvProblem(ih, ih, 32, ks, 32, s))[1]
                for ks in (3, 5, 7)
            ]
            assert rates == sorted(rates)
