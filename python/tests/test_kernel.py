"""Kernel-vs-oracle correctness: the CORE L1 signal.

The MM2IM Pallas kernel must agree with (a) the direct TCONV reference and
(b) the IOM matmul+col2im reference, across shapes, strides, kernel sizes,
Oc tilings and dtypes. Hypothesis sweeps the shape space; the parametrized
grid pins the paper's own configurations.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mm2im, ref


def _rand(problem: ref.TconvProblem, seed: int = 0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((problem.ih, problem.iw, problem.ic)), jnp.float32)
    w = jnp.asarray(
        rng.standard_normal((problem.oc, problem.ks, problem.ks, problem.ic)), jnp.float32
    )
    b = jnp.asarray(rng.standard_normal((problem.oc,)), jnp.float32)
    return x, w, b


def _assert_matches(problem: ref.TconvProblem, seed: int = 0, oc_tile=None):
    x, w, b = _rand(problem, seed)
    want = np.asarray(ref.tconv_ref(x, w, b, problem.stride))
    got = np.asarray(mm2im.mm2im(x, w, b, problem.stride, oc_tile=oc_tile))
    assert got.shape == (problem.oh, problem.ow, problem.oc)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# --- paper configurations ----------------------------------------------------

PAPER_GRID = [
    ref.TconvProblem(ih, ih, ic, ks, oc, s)
    for oc in (16, 32)
    for ks in (3, 5, 7)
    for ih in (7, 9)
    for ic in (32, 64)
    for s in (1, 2)
]


@pytest.mark.parametrize("problem", PAPER_GRID, ids=str)
def test_kernel_matches_reference_paper_grid(problem):
    _assert_matches(problem)


@pytest.mark.parametrize(
    "problem",
    [
        ref.TconvProblem(2, 2, 2, 3, 2, 1),  # the Fig. 2 worked example
        ref.TconvProblem(4, 4, 1024, 5, 8, 1),  # DCGAN_1-like depth (Oc cut)
        ref.TconvProblem(1, 1, 21, 4, 21, 4),  # FCN: Ks == S, zero padding
        ref.TconvProblem(4, 4, 4, 2, 4, 2),  # Ks == S
        ref.TconvProblem(3, 3, 4, 2, 4, 3),  # Ks < S (zero-stuffed gaps)
        ref.TconvProblem(5, 3, 7, 5, 3, 2),  # non-square, odd channels
        ref.TconvProblem(1, 1, 1, 1, 1, 1),  # degenerate 1x1
    ],
    ids=str,
)
def test_kernel_matches_reference_edges(problem):
    _assert_matches(problem)


def test_kernel_matches_iom_oracle():
    p = ref.TconvProblem(5, 5, 8, 5, 4, 2)
    x, w, b = _rand(p, 3)
    np.testing.assert_allclose(
        np.asarray(mm2im.mm2im(x, w, b, p.stride)),
        np.asarray(ref.tconv_iom(x, w, b, p.stride)),
        rtol=1e-4,
        atol=1e-4,
    )


@pytest.mark.parametrize("oc_tile", [1, 2, 4, 8, 16])
def test_oc_tiling_invariance(oc_tile):
    """Grid-axis-1 tiling (the paper's X-PM parallelism) must not change
    numerics."""
    p = ref.TconvProblem(4, 4, 8, 5, 16, 2)
    _assert_matches(p, seed=7, oc_tile=oc_tile)


def test_int8_int32_accumulator_contract():
    """int8 x int8 -> int32 exact accumulation — the contract shared with
    the rust CPU baseline and the simulator CUs."""
    p = ref.TconvProblem(5, 5, 16, 5, 8, 2)
    rng = np.random.default_rng(11)
    x = rng.integers(-128, 128, (p.ih, p.iw, p.ic), dtype=np.int8)
    w = rng.integers(-128, 128, (p.oc, p.ks, p.ks, p.ic), dtype=np.int8)
    want = ref.tconv_ref_int32(x, w, p.stride)
    got = np.asarray(
        mm2im.mm2im(jnp.asarray(x), jnp.asarray(w), None, p.stride, acc_dtype=jnp.int32)
    )
    assert got.dtype == np.int32
    np.testing.assert_array_equal(got, want)


def test_pack_weights_layout():
    """pack_weights must be the exact inverse of the kernel's reshape."""
    p = ref.TconvProblem(3, 3, 4, 3, 8, 2)
    _, w, _ = _rand(p, 5)
    packed = mm2im.pack_weights(w, oc_tile=4)
    assert packed.shape == (p.ks, p.ic, 2 * p.ks * 4)
    # tile 0 of filter row kh, reshaped to [kw, oc_tile], must equal
    # w[0:4, kh, :, :] transposed.
    for kh in range(p.ks):
        tile0 = np.asarray(packed[kh, :, : p.ks * 4]).reshape(p.ic, p.ks, 4)
        want = np.transpose(np.asarray(w[0:4, kh, :, :]), (2, 1, 0))  # [ic, kw, oc]
        np.testing.assert_array_equal(tile0, want)


def test_bias_is_applied_once_per_output():
    p = ref.TconvProblem(4, 4, 4, 5, 4, 2)
    x, w, _ = _rand(p, 9)
    b = jnp.asarray(np.full((p.oc,), 100.0), jnp.float32)
    without = np.asarray(mm2im.mm2im(x, w, None, p.stride))
    with_b = np.asarray(mm2im.mm2im(x, w, b, p.stride))
    np.testing.assert_allclose(with_b - without, 100.0, rtol=0, atol=1e-3)


# --- hypothesis sweeps --------------------------------------------------------

shape_strategy = st.tuples(
    st.integers(1, 6),  # ih
    st.integers(1, 6),  # iw
    st.integers(1, 12),  # ic
    st.integers(1, 7),  # ks
    st.integers(1, 9),  # oc
    st.integers(1, 3),  # stride
)


@settings(max_examples=60, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**31 - 1))
def test_kernel_matches_reference_hypothesis(shape, seed):
    p = ref.TconvProblem(*shape)
    _assert_matches(p, seed=seed)


@settings(max_examples=25, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**31 - 1))
def test_kernel_int8_hypothesis(shape, seed):
    p = ref.TconvProblem(*shape)
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (p.ih, p.iw, p.ic), dtype=np.int8)
    w = rng.integers(-128, 128, (p.oc, p.ks, p.ks, p.ic), dtype=np.int8)
    want = ref.tconv_ref_int32(x, w, p.stride)
    got = np.asarray(
        mm2im.mm2im(jnp.asarray(x), jnp.asarray(w), None, p.stride, acc_dtype=jnp.int32)
    )
    np.testing.assert_array_equal(got, want)


def test_zero_insertion_cross_oracle():
    """Independent oracle: TCONV == conv(zero-stuffed input, flipped filter)
    — the paper's 'Zero-Insertion' method (§II-A). Validates that all our
    aligned oracles are not wrong together."""
    p = ref.TconvProblem(4, 5, 3, 5, 2, 2)
    rng = np.random.default_rng(21)
    x = rng.standard_normal((p.ih, p.iw, p.ic)).astype(np.float32)
    w = rng.standard_normal((p.oc, p.ks, p.ks, p.ic)).astype(np.float32)

    up_h = (p.ih - 1) * p.stride + 1
    up_w = (p.iw - 1) * p.stride + 1
    up = np.zeros((up_h, up_w, p.ic), np.float32)
    up[:: p.stride, :: p.stride] = x
    lo_h, lo_w = p.ks - 1 - p.pad_top, p.ks - 1 - p.pad_left
    padded = np.pad(
        up,
        (
            (lo_h, p.oh + p.pad_top - up_h),
            (lo_w, p.ow + p.pad_left - up_w),
            (0, 0),
        ),
    )
    out = np.zeros((p.oh, p.ow, p.oc), np.float32)
    wf = w[:, ::-1, ::-1, :]  # flipped kernel -> correlation
    for oh in range(p.oh):
        for ow_ in range(p.ow):
            patch = padded[oh : oh + p.ks, ow_ : ow_ + p.ks, :]
            out[oh, ow_] = np.einsum("hwc,ohwc->o", patch, wf)

    got = np.asarray(mm2im.mm2im(jnp.asarray(x), jnp.asarray(w), None, p.stride))
    np.testing.assert_allclose(got, out, rtol=1e-4, atol=1e-4)
