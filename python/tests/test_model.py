"""L2 model graph tests: DCGAN generator shapes, determinism, and the
artifact manifest contract the rust runtime depends on."""

import json
import pathlib

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

ARTIFACT_DIR = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_dcgan_generator_shapes():
    params = model.init_dcgan_params(seed=0)
    z = jnp.zeros((model.DCGAN_LATENT,), jnp.float32)
    img = model.dcgan_generator(z, params)
    assert img.shape == (28, 28, 1)
    assert model.dcgan_output_shapes() == [(7, 7, 128), (14, 14, 64), (28, 28, 1)]


def test_dcgan_generator_output_range():
    params = model.init_dcgan_params(seed=0)
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.standard_normal(model.DCGAN_LATENT), jnp.float32)
    img = np.asarray(model.dcgan_generator(z, params))
    assert np.all(img <= 1.0) and np.all(img >= -1.0)  # tanh head
    assert np.isfinite(img).all()


def test_dcgan_params_deterministic():
    a = model.init_dcgan_params(seed=0)
    b = model.init_dcgan_params(seed=0)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    c = model.init_dcgan_params(seed=1)
    assert any(
        not np.array_equal(np.asarray(pa), np.asarray(pc)) for pa, pc in zip(a, c)
    )


def test_dcgan_layer_stack_matches_reference_chain():
    """The generator must equal hand-chaining tconv_ref through the stack."""
    params = model.init_dcgan_params(seed=0)
    rng = np.random.default_rng(5)
    z = jnp.asarray(rng.standard_normal(model.DCGAN_LATENT), jnp.float32)

    it = iter(params)
    dense_w, dense_b = next(it), next(it)
    h = model.leaky_relu(z @ dense_w + dense_b).reshape(7, 7, 256)
    for spec in model.DCGAN_SPECS:
        w, b = next(it), next(it)
        h = ref.tconv_ref(h, w, b, spec.stride)
        if spec.activation == "leaky":
            scale, shift = next(it), next(it)
            h = model.leaky_relu(h * scale[None, None, :] + shift[None, None, :])
        else:
            h = jnp.tanh(h)

    got = np.asarray(model.dcgan_generator(z, params))
    np.testing.assert_allclose(got, np.asarray(h), rtol=2e-3, atol=2e-3)


def test_single_tconv_fn_contract():
    prob = ref.TconvProblem(5, 5, 8, 5, 4, 2)
    fn, specs = model.single_tconv(prob)
    assert [tuple(s.shape) for s in specs] == [(5, 5, 8), (4, 5, 5, 8), (4,)]
    rng = np.random.default_rng(0)
    args = [jnp.asarray(rng.standard_normal(s.shape), jnp.float32) for s in specs]
    (out,) = fn(*args)
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(ref.tconv_ref(args[0], args[1], args[2], prob.stride)),
        rtol=1e-4,
        atol=1e-4,
    )


@pytest.mark.skipif(not ARTIFACT_DIR.exists(), reason="run `make artifacts` first")
def test_manifest_matches_artifacts_on_disk():
    manifest = json.loads((ARTIFACT_DIR / "manifest.json").read_text())
    assert "model.hlo.txt" in manifest["artifacts"]
    assert "dcgan_gen.hlo.txt" in manifest["artifacts"]
    for name, meta in manifest["artifacts"].items():
        path = ARTIFACT_DIR / name
        assert path.exists(), name
        head = path.read_text()[:200]
        assert "HloModule" in head, f"{name} is not HLO text"
        assert meta["returns_tuple"] is True
        if meta["kind"] == "tconv":
            p = meta["problem"]
            x, w, b = meta["args"]
            assert x["shape"] == [p["ih"], p["iw"], p["ic"]]
            assert w["shape"] == [p["oc"], p["ks"], p["ks"], p["ic"]]
            assert b["shape"] == [p["oc"]]


@pytest.mark.skipif(not ARTIFACT_DIR.exists(), reason="run `make artifacts` first")
def test_dcgan_artifact_param_count():
    manifest = json.loads((ARTIFACT_DIR / "manifest.json").read_text())
    meta = manifest["artifacts"]["dcgan_gen.hlo.txt"]
    params = model.init_dcgan_params(seed=meta["param_seed"])
    assert len(meta["args"]) == 1 + len(params)
    for spec_json, p in zip(meta["args"][1:], params):
        assert tuple(spec_json["shape"]) == tuple(p.shape)
