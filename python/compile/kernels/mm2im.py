"""MM2IM Pallas kernel — the paper's fused MatMul + col2IM hot spot on TPU.

Hardware adaptation (DESIGN.md §5). The paper's FPGA design skips cropped
partials with per-element cmap checks and muxes survivors into output
buffers with the omap. Branchy per-element logic is hostile to the MXU, so
the same insight is re-expressed as dense algebra:

  * grid axis 0 walks **output rows** h (Algorithm 1's inner loop) — output
    rows that exist are the only ones scheduled, so the height-axis crop is
    structural (never computed);
  * per output row, each contributing input row (at most R = ceil(Ks/S))
    is one MXU matmul  x_row[Iw, Ic] @ w_kh[Ic, Ks*Oc_t]  — the PE-array
    dot products of all PMs in one systolic pass (weight-stationary: the
    weight block's index_map is constant along the h axis, so it stays
    resident in VMEM like the PM-local filter buffers);
  * the width-axis col2im (omap + overlapping-sum accumulation) is a second
    MXU matmul with the constant one-hot scatter matrix G[Iw*Ks, Ow]:
    cropped partials hit an all-zero G row and vanish — the cmap skip —
    while overlapping partials sum inside the contraction — the out-muxer;
  * grid axis 1 tiles Oc, the paper's X-PM parallelism.

The kernel is lowered with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); on a real TPU the same BlockSpecs express the HBM->VMEM
schedule that the paper implemented with the Row Buffer / Dynamic Input
Loader. VMEM/MXU estimates: `vmem_bytes()` / `mxu_utilization()` below.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref


def _mm2im_kernel(
    idx_ref,  # [1, R] int32 — input-row index per slot (this output row)
    khs_ref,  # [1, R] int32 — filter-row index per slot
    val_ref,  # [1, R] int32 — slot validity
    x_ref,    # [Ih, Iw, Ic] — full input resident in VMEM
    w_ref,    # [Ks, Ic, Ks*Oc_t] — filter rows (Oc-tiled), weight-stationary
    g_ref,    # [Iw*Ks, Ow] — one-hot width scatter (cmap+omap as algebra)
    b_ref,    # [1, Oc_t] — bias tile
    o_ref,    # [1, Ow, Oc_t] — one output row tile
    *,
    r_slots: int,
    acc_dtype,
):
    iw_ks, ow = g_ref.shape
    oc_t = o_ref.shape[2]
    acc = jnp.zeros((ow, oc_t), dtype=acc_dtype)
    for r in range(r_slots):  # static unroll: R = ceil(Ks/S) slots
        ihr = idx_ref[0, r]
        kh = khs_ref[0, r]
        valid = val_ref[0, r].astype(acc_dtype)
        x_row = pl.load(x_ref, (pl.dslice(ihr, 1), slice(None), slice(None)))[0]
        w_kh = pl.load(w_ref, (pl.dslice(kh, 1), slice(None), slice(None)))[0]
        # MXU pass 1: input row x all surviving weight columns.
        part = jax.lax.dot(
            x_row.astype(acc_dtype), w_kh.astype(acc_dtype),
            preferred_element_type=acc_dtype,
        )  # [Iw, Ks*Oc_t]
        part = part.reshape(iw_ks, oc_t)  # [(iw, kw), oc]
        # MXU pass 2: col2im scatter-accumulate (G^T @ part); invalid slots
        # multiply to zero instead of branching.
        acc = acc + valid * jax.lax.dot(
            g_ref[...].astype(acc_dtype).T, part,
            preferred_element_type=acc_dtype,
        )
    acc = acc + b_ref[0].astype(acc_dtype)[None, :]
    o_ref[0] = acc.astype(o_ref.dtype)


def _pick_oc_tile(oc: int) -> int:
    for t in (128, 64, 32, 16, 8, 4, 2, 1):
        if oc % t == 0:
            return min(t, oc)
    return oc


@functools.partial(
    jax.jit, static_argnames=("stride", "oc_tile", "interpret", "acc_dtype")
)
def _mm2im_call(x, w_packed, g, bias, idx, khs, val, *, stride, oc_tile,
                interpret, acc_dtype):
    ih, iw, ic = x.shape
    ks = w_packed.shape[0]
    oc = w_packed.shape[2] // ks
    p = ref.TconvProblem(ih, iw, ic, ks, oc, stride)
    r_slots = idx.shape[1]
    n_oc_tiles = oc // oc_tile

    kernel = functools.partial(_mm2im_kernel, r_slots=r_slots, acc_dtype=acc_dtype)
    out_dtype = jnp.dtype(acc_dtype) if jnp.issubdtype(acc_dtype, jnp.integer) else x.dtype

    return pl.pallas_call(
        kernel,
        grid=(p.oh, n_oc_tiles),
        in_specs=[
            pl.BlockSpec((1, r_slots), lambda h, c: (h, 0)),
            pl.BlockSpec((1, r_slots), lambda h, c: (h, 0)),
            pl.BlockSpec((1, r_slots), lambda h, c: (h, 0)),
            # Whole input resident; rows are dynamically sliced in-kernel
            # (the Row Buffer). index_map constant => loaded once.
            pl.BlockSpec((ih, iw, ic), lambda h, c: (0, 0, 0)),
            # Weight-stationary along h; tiled along oc (grid axis 1 = PMs).
            pl.BlockSpec((ks, ic, ks * oc_tile), lambda h, c: (0, 0, c)),
            pl.BlockSpec((iw * ks, p.ow), lambda h, c: (0, 0)),
            pl.BlockSpec((1, oc_tile), lambda h, c: (0, c)),
        ],
        out_specs=pl.BlockSpec((1, p.ow, oc_tile), lambda h, c: (h, 0, c)),
        out_shape=jax.ShapeDtypeStruct((p.oh, p.ow, oc), out_dtype),
        interpret=interpret,
    )(idx, khs, val, x, w_packed, g, bias)


def pack_weights(w: jnp.ndarray, oc_tile: int) -> jnp.ndarray:
    """[Oc, Ks, Ks, Ic] -> [Ks, Ic, n_tiles * Ks * oc_tile].

    Layout: for filter row kh, the [Ic, Ks*oc_tile] tile `c` holds columns
    ordered (kw, oc_within_tile) for output channels c*oc_tile..(c+1)*oc_tile,
    matching the kernel's reshape to [(iw, kw), oc].
    """
    oc, ks, _, ic = w.shape
    assert oc % oc_tile == 0, (oc, oc_tile)
    n_tiles = oc // oc_tile
    # -> [ks(kh), ic, n_tiles, ks(kw), oc_tile]
    wt = jnp.transpose(w.reshape(n_tiles, oc_tile, ks, ks, ic), (2, 4, 0, 3, 1))
    return wt.reshape(ks, ic, n_tiles * ks * oc_tile)


def mm2im(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None,
    stride: int,
    *,
    oc_tile: int | None = None,
    interpret: bool = True,
    acc_dtype=jnp.float32,
) -> jnp.ndarray:
    """TCONV via the MM2IM Pallas kernel.

    x: [Ih, Iw, Ic]; w: [Oc, Ks, Ks, Ic]; b: [Oc] or None; returns
    [S*Ih, S*Iw, Oc]. For the int8 path pass int8 x/w with
    acc_dtype=jnp.int32 (returns the raw int32 accumulators, the contract
    shared with the rust simulator's compute units).
    """
    ih, iw, ic = x.shape
    oc, ks, _, _ = w.shape
    p = ref.TconvProblem(ih, iw, ic, ks, oc, stride)
    oc_tile = oc_tile or _pick_oc_tile(oc)
    idx, khs, val, _ = ref.row_schedule(p)
    g = jnp.asarray(ref.width_scatter_matrix(p, dtype=np.float32))
    if jnp.issubdtype(jnp.dtype(acc_dtype), jnp.integer):
        g = g.astype(jnp.int32)
        x = x.astype(jnp.int32) if x.dtype == jnp.int8 else x
        w = w.astype(jnp.int32) if w.dtype == jnp.int8 else w
    if b is None:
        b = jnp.zeros((oc,), dtype=acc_dtype)
    w_packed = pack_weights(w, oc_tile)
    return _mm2im_call(
        x, w_packed, g, jnp.asarray(b).reshape(1, oc),
        jnp.asarray(idx), jnp.asarray(khs), jnp.asarray(val),
        stride=stride, oc_tile=oc_tile, interpret=interpret,
        acc_dtype=jnp.dtype(acc_dtype),
    )


# ----------------------------------------------------------------------------
# Roofline / footprint estimators (real-TPU numbers are estimated, not
# measured — interpret=True runs on CPU).
# ----------------------------------------------------------------------------

def vmem_bytes(p: ref.TconvProblem, oc_tile: int, dtype_bytes: int = 4) -> dict:
    """Per-grid-step VMEM residency of each operand block."""
    blocks = {
        "x": p.ih * p.iw * p.ic * dtype_bytes,
        "w": p.ks * p.ic * p.ks * oc_tile * dtype_bytes,
        "g": p.iw * p.ks * p.ow * dtype_bytes,
        "out_row": p.ow * oc_tile * dtype_bytes,
        "sched": 3 * ((p.ks + p.stride - 1) // p.stride) * 4,
    }
    blocks["total"] = sum(blocks.values())
    return blocks


def mxu_utilization(p: ref.TconvProblem, oc_tile: int, mxu: int = 128) -> dict:
    """Fraction of MXU lanes fed by each matmul in the kernel.

    Pass 1 is [Iw, Ic] @ [Ic, Ks*oc_tile]; pass 2 is [Ow, Iw*Ks] @
    [Iw*Ks, oc_tile]. Utilization = prod(min(dim, mxu)/mxu-padded dims).
    """
    def util(m, k, n):
        pads = 1.0
        for d in (m, k, n):
            pads *= d / (((d + mxu - 1) // mxu) * mxu)
        return pads

    u1 = util(p.iw, p.ic, p.ks * oc_tile)
    u2 = util(p.ow, p.iw * p.ks, oc_tile)
    macs1 = p.iw * p.ic * p.ks * oc_tile
    macs2 = p.ow * p.iw * p.ks * oc_tile
    return {
        "pass1_matmul": u1,
        "pass2_scatter": u2,
        "weighted": (u1 * macs1 + u2 * macs2) / (macs1 + macs2),
    }
