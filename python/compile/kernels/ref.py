"""Pure-jnp/numpy correctness oracles for the MM2IM kernel.

Normative TCONV semantics (DESIGN.md §4, TFLite TransposeConv, NHWC):

    out(Oh, Ow, Oc) = tconv(Ih, Iw, Ic, Ks, Oc, S)
    Oh = S * Ih,  Ow = S * Iw
    pad_total = max(Ks - S, 0), pad_top = pad_left = pad_total // 2

Input pixel (ih, iw) with filter tap (kh, kw) contributes
    x[ih, iw, :] . w[oc, kh, kw, :]
to output (ih*S - pad_top + kh, iw*S - pad_left + kw); out-of-bounds
contributions are the *cropped* (ineffectual) partials of the IOM method.

Everything here is loop-level-obvious and used only at build/test time.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TconvProblem:
    """Mirror of rust `tconv::TconvProblem` (Eq. 1 of the paper)."""

    ih: int
    iw: int
    ic: int
    ks: int
    oc: int
    stride: int

    @property
    def oh(self) -> int:
        return self.stride * self.ih

    @property
    def ow(self) -> int:
        return self.stride * self.iw

    @property
    def pad_total(self) -> int:
        return max(self.ks - self.stride, 0)

    @property
    def pad_top(self) -> int:
        return self.pad_total // 2

    @property
    def pad_left(self) -> int:
        return self.pad_total // 2

    # MatMul view of the IOM method (Eq. 2): [M, K] @ [K, N].
    @property
    def m(self) -> int:
        return self.ih * self.iw

    @property
    def k(self) -> int:
        return self.ic

    @property
    def n(self) -> int:
        return self.ks * self.ks * self.oc

    @property
    def macs(self) -> int:
        """Total MAC count of the unskipped IOM MatMul (M*N*K)."""
        return self.m * self.n * self.k

    @property
    def full_h(self) -> int:
        """Uncropped (padded) IOM output height: (Ih-1)*S + Ks."""
        return (self.ih - 1) * self.stride + self.ks

    @property
    def full_w(self) -> int:
        return (self.iw - 1) * self.stride + self.ks


def tconv_ref(x: jnp.ndarray, w: jnp.ndarray, b, stride: int) -> jnp.ndarray:
    """Direct TCONV. x: [Ih, Iw, Ic], w: [Oc, Ks, Ks, Ic], b: [Oc] -> [Oh, Ow, Oc].

    Computes the full padded output then crops — the literal picture of
    Fig. 2 in the paper (gray squares = cropped perimeter).
    """
    ih, iw, ic = x.shape
    oc, ks, _, _ = w.shape
    p = TconvProblem(ih, iw, ic, ks, oc, stride)
    acc_dtype = jnp.promote_types(x.dtype, jnp.float32)
    # When Ks < S the uncropped footprint is smaller than the Oh x Ow
    # output window: the zero-gap rows/cols past the last contribution are
    # genuine zeros of the TCONV, so allocate the larger of the two.
    fh = max(p.full_h, p.pad_top + p.oh)
    fw = max(p.full_w, p.pad_left + p.ow)
    full = jnp.zeros((fh, fw, oc), dtype=acc_dtype)
    for kh in range(ks):
        for kw in range(ks):
            contrib = jnp.einsum("hwc,oc->hwo", x.astype(acc_dtype), w[:, kh, kw, :].astype(acc_dtype))
            full = full.at[
                kh : kh + (ih - 1) * stride + 1 : stride,
                kw : kw + (iw - 1) * stride + 1 : stride,
                :,
            ].add(contrib)
    out = full[p.pad_top : p.pad_top + p.oh, p.pad_left : p.pad_left + p.ow, :]
    if b is not None:
        out = out + jnp.asarray(b, acc_dtype)[None, None, :]
    return out


def tconv_ref_int32(x_q: np.ndarray, w_q: np.ndarray, stride: int) -> np.ndarray:
    """Int8 x int8 -> int32 accumulator direct TCONV (no requantization).

    This is the bit-exact accumulator contract shared with the rust CPU
    baseline and the accelerator simulator's compute units.
    """
    assert x_q.dtype == np.int8 and w_q.dtype == np.int8
    out = np.asarray(
        tconv_ref(
            jnp.asarray(x_q.astype(np.float64)),
            jnp.asarray(w_q.astype(np.float64)),
            None,
            stride,
        )
    )
    assert np.all(np.abs(out) < 2**52)  # exact in f64
    return out.astype(np.int32)


def output_map(p: TconvProblem) -> np.ndarray:
    """omap[M, Ks*Ks] -> flat output index (oh*Ow + ow) or -1 if cropped.

    Software mirror of the MM2IM Mapper (Algorithm 2). Row-major
    row_id = ih*Iw + iw (the paper's listing swaps div/mod; see DESIGN.md §4).
    """
    omap = np.full((p.m, p.ks * p.ks), -1, dtype=np.int64)
    for row_id in range(p.m):
        h_pad = -p.pad_top + p.stride * (row_id // p.iw)
        w_pad = -p.pad_left + p.stride * (row_id % p.iw)
        col = 0
        for kh in range(p.ks):
            for kw in range(p.ks):
                oh = kh + h_pad
                ow = kw + w_pad
                if 0 <= oh < p.oh and 0 <= ow < p.ow:
                    omap[row_id, col] = oh * p.ow + ow
                col += 1
    return omap


def drop_stats(p: TconvProblem) -> tuple[int, float]:
    """(dropped outputs D_o, drop rate D_r = D_o / (M*N)) — §III-A.1."""
    omap = output_map(p)
    dropped_taps = int((omap < 0).sum())
    d_o = dropped_taps * p.oc  # each tap spans Oc MatMul columns
    return d_o, d_o / (p.m * p.n)


def weight_matrix(w: jnp.ndarray) -> jnp.ndarray:
    """W_T of Eq. 2: [Oc, Ks, Ks, Ic] -> [K=Ic, N=(kh, kw, oc)]."""
    oc, ks, _, ic = w.shape
    return jnp.transpose(w, (3, 1, 2, 0)).reshape(ic, ks * ks * oc)


def iom_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """The MatMul of Eq. 2: [M, K] @ [K, N] with N ordered (kh, kw, oc)."""
    ih, iw, ic = x.shape
    xm = x.reshape(ih * iw, ic)
    return xm @ weight_matrix(w)


def col2im(partials, p: TconvProblem, b=None) -> jnp.ndarray:
    """col2IM: accumulate MatMul partials [M, Ks*Ks*Oc] into [Oh, Ow, Oc]."""
    omap = output_map(p)
    part = np.asarray(partials).reshape(p.m, p.ks * p.ks, p.oc)
    out = np.zeros((p.oh * p.ow, p.oc), dtype=part.dtype)
    for m in range(p.m):
        for t in range(p.ks * p.ks):
            o = omap[m, t]
            if o >= 0:
                out[o] += part[m, t]
    out = out.reshape(p.oh, p.ow, p.oc)
    if b is not None:
        out = out + np.asarray(b)[None, None, :]
    return jnp.asarray(out)


def tconv_iom(x: jnp.ndarray, w: jnp.ndarray, b, stride: int) -> jnp.ndarray:
    """Full IOM method (Eq. 2): col2im(mm(I, W_T)). Oracle for the kernel."""
    ih, iw, ic = x.shape
    oc, ks, _, _ = w.shape
    p = TconvProblem(ih, iw, ic, ks, oc, stride)
    return col2im(iom_matmul(x, w), p, b)


def width_scatter_matrix(p: TconvProblem, dtype=np.float32) -> np.ndarray:
    """G[Iw*Ks, Ow]: the one-hot width-axis col2im scatter (DESIGN.md §5).

    Row (iw*Ks + kw) is one-hot at column (iw*S - pad_left + kw) when that
    column is in range, else all-zero (a cropped partial — the TPU analogue
    of the paper's cmap skip).
    """
    g = np.zeros((p.iw * p.ks, p.ow), dtype=dtype)
    for iw in range(p.iw):
        for kw in range(p.ks):
            ow = iw * p.stride - p.pad_left + kw
            if 0 <= ow < p.ow:
                g[iw * p.ks + kw, ow] = 1
    return g


def row_schedule(p: TconvProblem) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Static per-output-row input schedule (Algorithm 1's i_end_row).

    Returns (idx[Oh, R], khs[Oh, R], valid[Oh, R], R) where R = max number of
    contributing input rows per output row; slot r of output row h reads
    input row idx[h, r] with filter row khs[h, r] when valid[h, r] == 1.
    """
    rows: list[list[tuple[int, int]]] = []
    for h in range(p.oh):
        contrib = []
        for ihr in range(p.ih):
            kh = h + p.pad_top - ihr * p.stride
            if 0 <= kh < p.ks:
                contrib.append((ihr, kh))
        rows.append(contrib)
    r_max = max((len(c) for c in rows), default=1) or 1
    idx = np.zeros((p.oh, r_max), dtype=np.int32)
    khs = np.zeros((p.oh, r_max), dtype=np.int32)
    valid = np.zeros((p.oh, r_max), dtype=np.int32)
    for h, contrib in enumerate(rows):
        for r, (ihr, kh) in enumerate(contrib):
            idx[h, r] = ihr
            khs[h, r] = kh
            valid[h, r] = 1
    return idx, khs, valid, r_max


def i_end_row(p: TconvProblem) -> np.ndarray:
    """Algorithm 1's i_end_row: last input row needed for each output row."""
    idx, _, valid, _ = row_schedule(p)
    ends = np.where(valid.any(axis=1), (idx * valid).max(axis=1), -1)
    return ends.astype(np.int32)


def quantize_sym(x: np.ndarray, bits: int = 8) -> tuple[np.ndarray, float]:
    """Symmetric per-tensor int8 quantization (weights-style)."""
    amax = float(np.abs(x).max()) or 1.0
    scale = amax / (2 ** (bits - 1) - 1)
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return q, scale
