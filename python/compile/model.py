"""L2 — JAX compute graphs built on the MM2IM Pallas kernel.

Defines the TCONV layer forward plus the DCGAN generator (the
TensorFlow-tutorial variant used in the paper's Table IV) so the whole
generator lowers into a single HLO module. These are *build-time* graphs:
`aot.py` lowers them once to HLO text; the rust runtime executes the
artifacts and the rust model executor cross-validates against them.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import mm2im, ref


def tconv_layer(x, w, b, stride: int, *, interpret: bool = True):
    """One TCONV layer via the MM2IM kernel. x [Ih,Iw,Ic], w [Oc,Ks,Ks,Ic]."""
    return mm2im.mm2im(x, w, b, stride, interpret=interpret)


def leaky_relu(x, alpha: float = 0.3):
    return jnp.where(x >= 0, x, alpha * x)


@dataclasses.dataclass(frozen=True)
class TconvSpec:
    oc: int
    ks: int
    stride: int
    activation: str  # "leaky" | "tanh" | "none"


# TF-tutorial DCGAN generator (Table IV footnote 2): 100 -> 7*7*256 dense,
# then tconv(128,5,1), tconv(64,5,2), tconv(1,5,2) with tanh.
DCGAN_SPECS: tuple[TconvSpec, ...] = (
    TconvSpec(128, 5, 1, "leaky"),
    TconvSpec(64, 5, 2, "leaky"),
    TconvSpec(1, 5, 2, "tanh"),
)
DCGAN_LATENT = 100
DCGAN_SEED_HW = 7
DCGAN_SEED_C = 256


def init_dcgan_params(seed: int = 0) -> list[jnp.ndarray]:
    """Deterministic synthetic parameters (DESIGN.md §8: weights are

    synthetic; every latency/drop-rate result is shape-dependent only).
    Returned flat list order is the artifact argument order after z:
    [dense_w, dense_b, (w_i, b_i, scale_i, shift_i) per tconv layer...]
    with the last layer omitting scale/shift (tanh straight after bias).
    """
    rng = np.random.default_rng(seed)
    hw, c = DCGAN_SEED_HW, DCGAN_SEED_C

    def arr(*shape, scale=0.05):
        return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)

    params: list[jnp.ndarray] = [arr(DCGAN_LATENT, hw * hw * c), arr(hw * hw * c, scale=0.01)]
    ic = c
    for i, spec in enumerate(DCGAN_SPECS):
        params.append(arr(spec.oc, spec.ks, spec.ks, ic, scale=0.08))
        params.append(arr(spec.oc, scale=0.01))
        if spec.activation == "leaky":  # inference-mode batchnorm = affine
            params.append(jnp.asarray(1.0 + rng.standard_normal(spec.oc) * 0.02, jnp.float32))
            params.append(jnp.asarray(rng.standard_normal(spec.oc) * 0.02, jnp.float32))
        ic = spec.oc
    return params


def dcgan_generator(z: jnp.ndarray, params: Sequence[jnp.ndarray], *, interpret: bool = True):
    """z: [latent] -> image [28, 28, 1] in [-1, 1]."""
    it = iter(params)
    dense_w, dense_b = next(it), next(it)
    h = z @ dense_w + dense_b
    h = leaky_relu(h).reshape(DCGAN_SEED_HW, DCGAN_SEED_HW, DCGAN_SEED_C)
    for spec in DCGAN_SPECS:
        w, b = next(it), next(it)
        h = tconv_layer(h, w, b, spec.stride, interpret=interpret)
        if spec.activation == "leaky":
            scale, shift = next(it), next(it)
            h = leaky_relu(h * scale[None, None, :] + shift[None, None, :])
        elif spec.activation == "tanh":
            h = jnp.tanh(h)
    return h


def dcgan_output_shapes() -> list[tuple[int, int, int]]:
    """Feature-map shape after each tconv layer (for cross-layer tests)."""
    shapes = []
    h = w = DCGAN_SEED_HW
    for spec in DCGAN_SPECS:
        h, w = h * spec.stride, w * spec.stride
        shapes.append((h, w, spec.oc))
    return shapes


def single_tconv(problem: ref.TconvProblem, *, interpret: bool = True):
    """(fn, example_args) for a single-layer TCONV artifact."""

    def fn(x, w, b):
        return (tconv_layer(x, w, b, problem.stride, interpret=interpret),)

    specs = (
        jax.ShapeDtypeStruct((problem.ih, problem.iw, problem.ic), jnp.float32),
        jax.ShapeDtypeStruct((problem.oc, problem.ks, problem.ks, problem.ic), jnp.float32),
        jax.ShapeDtypeStruct((problem.oc,), jnp.float32),
    )
    return fn, specs
