"""AOT pipeline: lower the L2 graphs to HLO **text** artifacts.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the rust `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/load_hlo/.

Run once at build time (`make artifacts`); python never runs on the rust
request path. Emits next to --out:

  model.hlo.txt        canonical single TCONV layer (the Makefile target)
  tconv_<name>.hlo.txt additional layer configs the rust tests exercise
  dcgan_gen.hlo.txt    full DCGAN generator (z[100] -> [28,28,1])
  manifest.json        argument shapes/dtypes + problem params + seeds
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Canonical layer configs exported for the rust runtime's numerics tests.
# (name, problem). Kept small so `make artifacts` stays fast; the rust
# simulator covers the full 261-problem sweep without artifacts.
TCONV_ARTIFACTS: list[tuple[str, ref.TconvProblem]] = [
    ("k5s2", ref.TconvProblem(ih=7, iw=7, ic=32, ks=5, oc=16, stride=2)),
    ("k3s1", ref.TconvProblem(ih=9, iw=9, ic=16, ks=3, oc=8, stride=1)),
    ("k4s2", ref.TconvProblem(ih=8, iw=8, ic=16, ks=4, oc=8, stride=2)),
]


def _spec_json(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def build(out_path: pathlib.Path) -> dict:
    out_dir = out_path.parent
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest: dict = {"artifacts": {}}

    # --- single TCONV layers -------------------------------------------------
    for i, (name, prob) in enumerate(TCONV_ARTIFACTS):
        fn, specs = model.single_tconv(prob)
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        path = out_path if i == 0 else out_dir / f"tconv_{name}.hlo.txt"
        path.write_text(text)
        manifest["artifacts"][path.name] = {
            "kind": "tconv",
            "name": name,
            "problem": {
                "ih": prob.ih, "iw": prob.iw, "ic": prob.ic,
                "ks": prob.ks, "oc": prob.oc, "stride": prob.stride,
            },
            "args": [_spec_json(s) for s in specs],
            "returns_tuple": True,
        }
        print(f"wrote {path} ({len(text)} chars)")

    # --- DCGAN generator ------------------------------------------------------
    params = model.init_dcgan_params(seed=0)
    z_spec = jax.ShapeDtypeStruct((model.DCGAN_LATENT,), jnp.float32)
    p_specs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]

    def gen_fn(z, *ps):
        return (model.dcgan_generator(z, ps),)

    text = to_hlo_text(jax.jit(gen_fn).lower(z_spec, *p_specs))
    gen_path = out_dir / "dcgan_gen.hlo.txt"
    gen_path.write_text(text)
    manifest["artifacts"][gen_path.name] = {
        "kind": "dcgan_generator",
        "param_seed": 0,
        "latent": model.DCGAN_LATENT,
        "args": [_spec_json(z_spec)] + [_spec_json(s) for s in p_specs],
        "returns_tuple": True,
    }
    print(f"wrote {gen_path} ({len(text)} chars)")

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt")
    args = ap.parse_args()
    build(pathlib.Path(args.out))


if __name__ == "__main__":
    main()
