//! The full §V-B synthetic sweep as a runnable example: all 261 TCONV
//! problems through the simulated accelerator with per-problem rows
//! (drop rate, latency, speedup) and the Fig. 6/7 summary statistics.
//!
//! Run: `cargo run --release --example sweep261 [-- --limit 20]`

use mm2im::accel::AccelConfig;
use mm2im::bench::harness::run_problem;
use mm2im::bench::workloads::sweep261;
use mm2im::util::cli::Args;
use mm2im::util::stats;
use mm2im::util::table::{f2, ms, pct, Table};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let entries = sweep261();
    let limit = args.usize_or("limit", entries.len());
    let cfg = AccelConfig::default();

    let mut t = Table::new(
        "261-problem TCONV sweep (Figs. 6/7 data)",
        &["#", "problem", "drop", "acc ms", "cpu 2T ms", "speedup", "GOPs", "util"],
    );
    let mut speedups = Vec::new();
    let mut drops = Vec::new();
    for (i, e) in entries.iter().take(limit).enumerate() {
        let r = run_problem(&e.problem, &cfg, 1);
        speedups.push(r.speedup_2t());
        drops.push(r.drop.d_r);
        t.row(&[
            i.to_string(),
            e.problem.to_string(),
            pct(r.drop.d_r),
            ms(r.acc_seconds),
            ms(r.cpu2_seconds),
            f2(r.speedup_2t()),
            f2(r.gops),
            pct(r.utilization),
        ]);
    }
    t.print();
    println!(
        "\n{} problems: speedup mean {:.2}x / geomean {:.2}x / median {:.2}x / min {:.2}x / max {:.2}x",
        speedups.len(),
        stats::mean(&speedups),
        stats::geomean(&speedups),
        stats::median(&speedups),
        stats::min(&speedups),
        stats::max(&speedups)
    );
    println!("drop rate mean {} / max {} (paper Fig. 7 peaks ~45% at Ks=7, Ih=7, S=1)", pct(stats::mean(&drops)), pct(stats::max(&drops)));
}
