//! Inference service demo: the L3 coordinator serving batched DCGAN
//! generation requests across a *heterogeneous* shard fleet (simulated
//! MM2IM instances with different X/UF instantiations), driven through
//! the typed request API: priority classes, a deadline, a real tensor
//! payload (zero-copy, `Arc`-shared), and a ticket cancellation.
//!
//! Even-indexed shards run the paper instantiation (X=8, UF=16);
//! odd-indexed shards run a narrow-array, deep-unroll variant
//! (X=4, UF=32). Outputs are byte-identical regardless of which shard
//! serves a request — configs change cycles, never numerics.
//!
//! The demo ends with a *warm restart*: the first server flushes its
//! compiled-plan cache to a `driver::persist` snapshot on `finish`, and a
//! second, freshly spawned server preloads it and serves the same traffic
//! with few (single-config fleets: zero) plan compiles.
//!
//! Run: `cargo run --release --example serve [-- --requests 16 --shards 2
//! --workers-per-shard 2]`

use mm2im::accel::AccelConfig;
use mm2im::bench::harness::latency_by_class;
use mm2im::coordinator::{Outcome, Priority, Request, Server};
use mm2im::model::zoo;
use mm2im::telemetry::triage;
use mm2im::tensor::Tensor;
use mm2im::util::cli::Args;
use mm2im::util::rng::Pcg32;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let requests = args.usize_or("requests", 16);
    let shards = args.usize_or("shards", 2).max(1);
    let workers_per_shard = args.usize_or("workers-per-shard", 2);
    // Heterogeneous fleet: alternate the paper instantiation with a
    // narrow/deep variant.
    let shard_accels: Vec<AccelConfig> = (0..shards)
        .map(|i| {
            let mut cfg = AccelConfig::default();
            if i % 2 == 1 {
                cfg.x_pms = 4;
                cfg.uf = 32;
            }
            cfg
        })
        .collect();
    let g = Arc::new(zoo::dcgan_tf(0));
    // Compiled plans persist across restarts: the server flushes its plan
    // cache here on finish, and the second server below preloads it.
    let plan_store = std::env::temp_dir().join("mm2im_serve_plans.bin");
    let _ = std::fs::remove_file(&plan_store);

    println!(
        "serving DCGAN generation: {requests} requests across {shards} heterogeneous shards x {workers_per_shard} workers"
    );
    let mut server = Server::builder()
        .graph(g.clone())
        .workers_per_shard(workers_per_shard)
        .queue_capacity(args.usize_or("queue", 16))
        .max_batch(args.usize_or("batch", 4))
        .shard_fleet(shard_accels.clone())
        .plan_store(&plan_store)
        .start()
        .expect("valid server config");

    // Mixed-class seeded traffic: every 4th request is latency-sensitive,
    // the rest carry a generous deadline (no request should miss it).
    for seed in 0..requests as u64 {
        let req = if seed % 4 == 0 {
            Request::seed(seed).priority(Priority::High)
        } else {
            Request::seed(seed).deadline(Duration::from_secs(60))
        };
        server.submit(req).expect("seeded requests always validate");
    }
    // One *real* input payload: the tensor is shared into the server
    // (Arc bump) and spliced zero-copy into the instruction streams.
    let mut rng = Pcg32::new(1234);
    let payload = Arc::new(Tensor::<i8>::random(&g.input_shape, &mut rng));
    let payload_ticket =
        server.submit(Request::tensor(payload).priority(Priority::High)).expect("shape matches");
    // And one background request we change our mind about.
    let doomed = server
        .submit(Request::seed(u64::MAX).priority(Priority::Low))
        .expect("seeded requests always validate");
    let cancelled = doomed.cancel();

    // Live introspection: a consistent snapshot of the server's
    // telemetry tree, taken mid-serve without stopping the workers. The
    // exactly-once ledger (served + cancelled + expired + failed +
    // in-flight == submitted) holds on *every* snapshot, which the
    // built-in triage rules check.
    let live = server.inspect();
    println!(
        "  live snapshot   : {} submitted, {} served, {:.0} in flight (epoch {})",
        live.counter("fleet/submitted").expect("registered at spawn"),
        live.counter("fleet/served").expect("registered at spawn"),
        live.gauge("fleet/in_flight").expect("registered at spawn"),
        live.epoch()
    );
    let mid_serve = triage::evaluate(&triage::default_rules(), &live);
    assert!(mid_serve.healthy(), "mid-serve triage must stay green:\n{mid_serve}");

    let telem = server.telemetry();
    let (responses, stats) = server.finish();
    assert_eq!(responses.len(), requests + 2);
    let payload_response =
        responses.iter().find(|r| r.id == payload_ticket.id()).expect("ticket resolves");
    assert_eq!(payload_response.outcome, Outcome::Ok);
    assert!(payload_response.seed().is_none(), "real payloads carry no seed");

    println!("  throughput      : {:.1} images/s (host)", stats.throughput_rps);
    println!(
        "  latency p50/p95 : {:.1} / {:.1} ms (incl. queue wait)",
        stats.p50_latency_s * 1e3,
        stats.p95_latency_s * 1e3
    );
    for c in latency_by_class(&responses) {
        println!(
            "    {:<6} class  : {} served, p50 {:.1} ms, p95 {:.1} ms",
            c.priority.label(),
            c.requests,
            c.p50_s * 1e3,
            c.p95_s * 1e3
        );
    }
    println!(
        "  outcomes        : {} ok, {} cancelled, {} deadline-expired",
        stats.requests, stats.cancelled, stats.deadline_expired
    );
    if cancelled {
        println!("                    (the Low-priority ticket was cancelled while queued)");
    }
    println!(
        "  mean modeled    : {:.1} ms/image on the serving shard's config",
        stats.modeled_mean_s * 1e3
    );
    println!(
        "  plan cache      : {:.0}% hits ({} compiles for {} plan lookups)",
        stats.cache_hit_rate() * 100.0,
        stats.cache_misses,
        stats.cache_hits + stats.cache_misses
    );
    println!(
        "  weight loads    : {:.0}% amortized ({} performed, {} skipped, {} per-request equivalent)",
        stats.weight_load_hit_rate() * 100.0,
        stats.weight_loads,
        stats.weight_loads_skipped,
        stats.weight_loads_equiv
    );
    println!(
        "  placement       : {} decisions, {} cross-batch resident hits",
        stats.placements.len(),
        stats.cross_batch_resident_hits
    );
    println!("  mean batch size : {:.2}", stats.mean_batch_size);
    for (i, (u, fp)) in
        stats.shard_utilization.iter().zip(&stats.shard_config_fps).enumerate()
    {
        println!(
            "  shard {i}         : util {:>3.0}%, {} requests, config {fp:#018x}",
            u * 100.0,
            stats.shard_requests[i]
        );
    }
    // The final snapshot triages green too, and the legacy stats struct
    // is exactly its projection.
    let report = triage::evaluate(&triage::default_rules(), &telem.snapshot());
    assert!(report.healthy(), "final triage must be green:\n{report}");
    println!("  triage          : all rules green (ledger, quarantine, queue saturation)");
    println!("  all outputs deterministic by request seed (or payload bytes)");

    // ── Warm restart ────────────────────────────────────────────────────
    // `finish` above flushed every compiled plan to the snapshot. A brand
    // new server on the same fleet preloads it at startup, so its workers
    // find their plans already resident. A heterogeneous fleet only
    // recompiles plans for configs the first run never exercised; with a
    // single config the warm run compiles *nothing* (the property
    // `tests/persistence.rs` pins exactly).
    println!("\nwarm restart from {}", plan_store.display());
    let mut warm = Server::builder()
        .graph(g)
        .workers_per_shard(workers_per_shard)
        .queue_capacity(args.usize_or("queue", 16))
        .max_batch(args.usize_or("batch", 4))
        .shard_fleet(shard_accels)
        .plan_store(&plan_store)
        .start()
        .expect("valid server config");
    for seed in 0..requests as u64 {
        warm.submit(Request::seed(seed)).expect("seeded requests always validate");
    }
    let (warm_responses, warm_stats) = warm.finish();
    assert_eq!(warm_responses.len(), requests);
    assert!(
        warm_stats.plans_preloaded > 0,
        "snapshot written by the first run must preload into the second"
    );
    assert!(
        warm_stats.cache_misses <= stats.cache_misses,
        "a preloaded server never compiles more than a cold one"
    );
    println!(
        "  plans preloaded : {} (cold run compiled {}, warm run compiled {})",
        warm_stats.plans_preloaded, stats.cache_misses, warm_stats.cache_misses
    );
    for cold in responses.iter().filter(|r| r.id < requests as u64) {
        let rewarmed =
            warm_responses.iter().find(|r| r.id == cold.id).expect("same seeds resubmitted");
        assert_eq!(
            cold.output_tensor().data(),
            rewarmed.output_tensor().data(),
            "warm-restarted outputs stay byte-identical (seed {})",
            cold.id
        );
    }
    println!("  outputs         : byte-identical to the cold run for all {requests} seeds");
    let _ = std::fs::remove_file(&plan_store);
}
