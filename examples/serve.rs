//! Inference service demo: the L3 coordinator serving batched DCGAN
//! generation requests across shards (simulated MM2IM accelerator
//! instances), with every worker resolving layer programs through one
//! shared compiled-plan cache.
//!
//! Run: `cargo run --release --example serve [-- --requests 16 --shards 2
//! --workers-per-shard 2]`

use mm2im::coordinator::{Server, ServerConfig};
use mm2im::model::zoo;
use mm2im::util::cli::Args;
use std::sync::Arc;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let requests = args.usize_or("requests", 16);
    let config = ServerConfig {
        shards: args.usize_or("shards", 2),
        workers_per_shard: args.usize_or("workers-per-shard", 2),
        queue_capacity: args.usize_or("queue", 16),
        max_batch: args.usize_or("batch", 4),
        ..ServerConfig::default()
    };
    let g = Arc::new(zoo::dcgan_tf(0));

    println!(
        "serving DCGAN generation: {requests} requests across {} shards x {} workers",
        config.shards, config.workers_per_shard
    );
    let mut server = Server::start(g, config);
    let seeds: Vec<u64> = (0..requests as u64).collect();
    server.submit_many(&seeds);
    let (responses, stats) = server.finish();
    assert_eq!(stats.requests, requests);
    assert_eq!(responses.len(), requests);

    println!("  throughput      : {:.1} images/s (host)", stats.throughput_rps);
    println!(
        "  latency p50/p95 : {:.1} / {:.1} ms (incl. queue wait)",
        stats.p50_latency_s * 1e3,
        stats.p95_latency_s * 1e3
    );
    println!("  mean modeled    : {:.1} ms/image on PYNQ-Z1 (ACC + CPU 1T)", stats.modeled_mean_s * 1e3);
    println!(
        "  plan cache      : {:.0}% hits ({} compiles for {} plan lookups)",
        stats.cache_hit_rate() * 100.0,
        stats.cache_misses,
        stats.cache_hits + stats.cache_misses
    );
    println!(
        "  weight loads    : {:.0}% amortized by layer batching ({} performed / {} per-request equivalent)",
        stats.weight_load_hit_rate() * 100.0,
        stats.weight_loads,
        stats.weight_loads_equiv
    );
    println!("  mean batch size : {:.2}", stats.mean_batch_size);
    for (i, u) in stats.shard_utilization.iter().enumerate() {
        println!("  shard {i} util    : {:.0}%", u * 100.0);
    }
    println!("  all outputs deterministic by request seed");
}
