//! Inference service demo: the L3 coordinator serving batched DCGAN
//! generation requests over worker threads, each offloading TCONV layers
//! to its own simulated MM2IM accelerator instance.
//!
//! Run: `cargo run --release --example serve [-- --requests 16 --workers 4]`

use mm2im::accel::AccelConfig;
use mm2im::coordinator::{summarize, Server};
use mm2im::driver::Delegate;
use mm2im::model::executor::{Executor, RunConfig};
use mm2im::model::zoo;
use mm2im::util::cli::Args;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let requests = args.usize_or("requests", 16);
    let workers = args.usize_or("workers", 4);
    let g = Arc::new(zoo::dcgan_tf(0));
    let cfg = AccelConfig::default();

    println!("serving DCGAN generation: {requests} requests across {workers} workers");
    let cfg2 = cfg.clone();
    let mut server = Server::start(
        g,
        workers,
        move || Executor::new(Delegate::new(cfg2.clone(), 1, true)),
        RunConfig::AccPlusCpu { threads: 1 },
        cfg,
    );
    let t0 = Instant::now();
    for seed in 0..requests as u64 {
        server.submit(seed);
    }
    let responses = server.drain();
    let stats = summarize(&responses, t0.elapsed().as_secs_f64());
    assert_eq!(stats.requests, requests);
    println!("  throughput      : {:.1} images/s (host)", stats.throughput_rps);
    println!("  mean host wall  : {:.1} ms/image", stats.wall_mean_s * 1e3);
    println!("  mean modeled    : {:.1} ms/image on PYNQ-Z1 (ACC + CPU 1T)", stats.modeled_mean_s * 1e3);
    println!("  all outputs deterministic by request seed");
}
