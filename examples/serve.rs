//! Inference service demo: the L3 coordinator serving batched DCGAN
//! generation requests across a *heterogeneous* shard fleet (simulated
//! MM2IM instances with different X/UF instantiations), with every
//! worker resolving layer programs through one shared compiled-plan
//! cache and batches routed by the modeled-latency, weight-aware
//! placement scorer.
//!
//! Even-indexed shards run the paper instantiation (X=8, UF=16);
//! odd-indexed shards run a narrow-array, deep-unroll variant
//! (X=4, UF=32). Outputs are byte-identical regardless of which shard
//! serves a request — configs change cycles, never numerics.
//!
//! Run: `cargo run --release --example serve [-- --requests 16 --shards 2
//! --workers-per-shard 2]`

use mm2im::accel::AccelConfig;
use mm2im::coordinator::{Server, ServerConfig};
use mm2im::model::zoo;
use mm2im::util::cli::Args;
use std::sync::Arc;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let requests = args.usize_or("requests", 16);
    let shards = args.usize_or("shards", 2).max(1);
    // Heterogeneous fleet: alternate the paper instantiation with a
    // narrow/deep variant.
    let shard_accels: Vec<AccelConfig> = (0..shards)
        .map(|i| {
            let mut cfg = AccelConfig::default();
            if i % 2 == 1 {
                cfg.x_pms = 4;
                cfg.uf = 32;
            }
            cfg
        })
        .collect();
    let config = ServerConfig {
        workers_per_shard: args.usize_or("workers-per-shard", 2),
        queue_capacity: args.usize_or("queue", 16),
        max_batch: args.usize_or("batch", 4),
        shard_accels,
        ..ServerConfig::default()
    };
    let g = Arc::new(zoo::dcgan_tf(0));

    println!(
        "serving DCGAN generation: {requests} requests across {shards} heterogeneous shards x {} workers",
        config.workers_per_shard
    );
    let mut server = Server::start(g, config);
    let seeds: Vec<u64> = (0..requests as u64).collect();
    server.submit_many(&seeds);
    let (responses, stats) = server.finish();
    assert_eq!(stats.requests, requests);
    assert_eq!(responses.len(), requests);

    println!("  throughput      : {:.1} images/s (host)", stats.throughput_rps);
    println!(
        "  latency p50/p95 : {:.1} / {:.1} ms (incl. queue wait)",
        stats.p50_latency_s * 1e3,
        stats.p95_latency_s * 1e3
    );
    println!(
        "  mean modeled    : {:.1} ms/image on the serving shard's config",
        stats.modeled_mean_s * 1e3
    );
    println!(
        "  plan cache      : {:.0}% hits ({} compiles for {} plan lookups)",
        stats.cache_hit_rate() * 100.0,
        stats.cache_misses,
        stats.cache_hits + stats.cache_misses
    );
    println!(
        "  weight loads    : {:.0}% amortized ({} performed, {} skipped, {} per-request equivalent)",
        stats.weight_load_hit_rate() * 100.0,
        stats.weight_loads,
        stats.weight_loads_skipped,
        stats.weight_loads_equiv
    );
    println!(
        "  placement       : {} decisions, {} cross-batch resident hits",
        stats.placements.len(),
        stats.cross_batch_resident_hits
    );
    println!("  mean batch size : {:.2}", stats.mean_batch_size);
    for (i, (u, fp)) in
        stats.shard_utilization.iter().zip(&stats.shard_config_fps).enumerate()
    {
        println!(
            "  shard {i}         : util {:>3.0}%, {} requests, config {fp:#018x}",
            u * 100.0,
            stats.shard_requests[i]
        );
    }
    println!("  all outputs deterministic by request seed");
}
