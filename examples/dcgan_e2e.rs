//! End-to-end driver (DESIGN.md deliverable): generate a batch of images
//! with the DCGAN generator through the full stack — int8 model graph,
//! TFLite-style delegate, Algorithm-1 host driver, micro-ISA stream,
//! cycle-level MM2IM accelerator — verify every image bit-exactly against
//! the CPU-only baseline, and report the paper's Table IV metrics.
//!
//! Writes the first generated image as ASCII-art + PGM to /tmp.
//!
//! Run: `cargo run --release --example dcgan_e2e [-- --batch 16]`

use mm2im::accel::AccelConfig;
use mm2im::driver::Delegate;
use mm2im::model::executor::{Executor, RunConfig};
use mm2im::model::zoo;
use mm2im::tensor::Tensor;
use mm2im::util::cli::Args;
use mm2im::util::rng::Pcg32;
use mm2im::util::table::{f2, ms, Table};
use std::time::Instant;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let batch = args.usize_or("batch", 8);
    let g = zoo::dcgan_tf(args.u64_or("model-seed", 0));
    let cfg = AccelConfig::default();
    let acc = Executor::new(Delegate::new(cfg.clone(), 2, true));
    let cpu = Executor::new(Delegate::new(cfg.clone(), 1, false));

    println!("DCGAN generator (TF-tutorial variant): z[100] -> [28,28,1], {} TCONV layers", g.tconv_layers().len());
    println!("generating {batch} images through the accelerator...\n");

    let t0 = Instant::now();
    let mut first_image: Option<Tensor<i8>> = None;
    let mut acc_run = None;
    for i in 0..batch {
        let mut rng = Pcg32::new(1000 + i as u64);
        let z = Tensor::<i8>::random(&g.input_shape, &mut rng);
        let run_a = acc.run(&g, &z);
        let run_c = cpu.run(&g, &z);
        assert_eq!(run_a.output.data(), run_c.output.data(), "image {i}: ACC != CPU");
        if first_image.is_none() {
            first_image = Some(run_a.output.clone());
            acc_run = Some(run_a);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("all {batch} images verified bit-exact vs CPU baseline (host wall {wall:.2}s)\n");

    // Table IV style report from one run's records.
    let run = acc_run.unwrap();
    let mut t = Table::new("modeled PYNQ-Z1 per-image latency/energy (Table IV)", &["configuration", "TCONV ms", "overall ms", "energy J"]);
    for (label, rc) in [
        ("CPU 1T", RunConfig::Cpu { threads: 1 }),
        ("ACC + CPU 1T", RunConfig::AccPlusCpu { threads: 1 }),
        ("CPU 2T", RunConfig::Cpu { threads: 2 }),
        ("ACC + CPU 2T", RunConfig::AccPlusCpu { threads: 2 }),
    ] {
        let tb = run.modeled(rc, &cfg);
        t.row(&[label.into(), ms(tb.tconv_s), ms(tb.total_s()), format!("{:.4}", tb.energy_j)]);
    }
    t.print();
    let cpu1 = run.modeled(RunConfig::Cpu { threads: 1 }, &cfg);
    let acc1 = run.modeled(RunConfig::AccPlusCpu { threads: 1 }, &cfg);
    println!("\nTCONV speedup {}x | overall {}x | energy reduction {}x",
        f2(cpu1.tconv_s / acc1.tconv_s), f2(cpu1.total_s() / acc1.total_s()), f2(cpu1.energy_j / acc1.energy_j));

    // render + save the first image
    let img = first_image.unwrap();
    let scale = run.output_scale;
    let ramp = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    println!("\nfirst generated image (28x28, tanh output in [-1,1]):");
    for y in 0..28 {
        let mut line = String::new();
        for x in 0..28 {
            let v = img.at3(y, x, 0) as f32 * scale; // [-1, 1]
            let idx = (((v + 1.0) / 2.0) * (ramp.len() - 1) as f32).round() as usize;
            line.push(ramp[idx.min(ramp.len() - 1)]);
        }
        println!("  {line}");
    }
    let mut pgm = String::from("P2\n28 28\n255\n");
    for y in 0..28 {
        for x in 0..28 {
            let v = img.at3(y, x, 0) as f32 * scale;
            pgm.push_str(&format!("{} ", (((v + 1.0) / 2.0) * 255.0).round() as u8));
        }
        pgm.push('\n');
    }
    std::fs::write("/tmp/dcgan_e2e.pgm", pgm).expect("write pgm");
    println!("\nsaved /tmp/dcgan_e2e.pgm");
}
