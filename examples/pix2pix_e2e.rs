//! pix2pix U-Net generator end to end: image-to-image translation
//! through the delegate (7 TCONV layers at size 256 — the paper's
//! Table IV workload). Verifies ACC == CPU numerics and reports all four
//! Table IV configurations.
//!
//! Run: `cargo run --release --example pix2pix_e2e [-- --size 128 --width 32]`
//! (size 256 / width 64 = the paper's full model; ~1-2 min of host time)

use mm2im::accel::AccelConfig;
use mm2im::driver::Delegate;
use mm2im::model::executor::{Executor, RunConfig};
use mm2im::model::zoo;
use mm2im::tensor::Tensor;
use mm2im::util::cli::Args;
use mm2im::util::rng::Pcg32;
use mm2im::util::table::{f2, ms, Table};
use std::time::Instant;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let size = args.usize_or("size", 128);
    let width = args.usize_or("width", 32);
    let g = zoo::pix2pix(size, width, args.u64_or("model-seed", 0));

    let convs = g.layers.iter().filter(|l| matches!(l, mm2im::model::Layer::Conv { .. })).count();
    println!("pix2pix U-Net generator: {size}x{size}x3 -> {size}x{size}x3");
    println!("  {} encoder convs + {} decoder TCONVs ({} TCONV GOPs)\n", convs, g.tconv_layers().len(), g.tconv_ops() as f64 / 1e9);

    let cfg = AccelConfig::default();
    let mut rng = Pcg32::new(9);
    let input = Tensor::<i8>::random(&g.input_shape, &mut rng);

    let t0 = Instant::now();
    let acc_run = Executor::new(Delegate::new(cfg.clone(), 2, true)).run(&g, &input);
    let t_acc = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let cpu_run = Executor::new(Delegate::new(cfg.clone(), 2, false)).run(&g, &input);
    let t_cpu = t1.elapsed().as_secs_f64();
    assert_eq!(acc_run.output.data(), cpu_run.output.data(), "ACC != CPU");
    println!("translated image verified bit-exact vs CPU baseline");
    println!("(host wall: accelerated-path {t_acc:.2}s, cpu-path {t_cpu:.2}s)\n");

    let mut t = Table::new(&format!("pix2pix_{size} modeled PYNQ-Z1 (Table IV)"), &["configuration", "TCONV ms", "overall ms", "energy J"]);
    for (label, rc) in [
        ("CPU 1T", RunConfig::Cpu { threads: 1 }),
        ("ACC + CPU 1T", RunConfig::AccPlusCpu { threads: 1 }),
        ("CPU 2T", RunConfig::Cpu { threads: 2 }),
        ("ACC + CPU 2T", RunConfig::AccPlusCpu { threads: 2 }),
    ] {
        let tb = acc_run.modeled(rc, &cfg);
        t.row(&[label.into(), ms(tb.tconv_s), ms(tb.total_s()), format!("{:.3}", tb.energy_j)]);
    }
    t.print();
    let cpu1 = acc_run.modeled(RunConfig::Cpu { threads: 1 }, &cfg);
    let acc1 = acc_run.modeled(RunConfig::AccPlusCpu { threads: 1 }, &cfg);
    let cpu2 = acc_run.modeled(RunConfig::Cpu { threads: 2 }, &cfg);
    let acc2 = acc_run.modeled(RunConfig::AccPlusCpu { threads: 2 }, &cfg);
    println!("\nTCONV speedup (1T) {}x (paper 3.0x) | overall (2T) {}x (paper 2.3x)",
        f2(cpu1.tconv_s / acc1.tconv_s), f2(cpu2.total_s() / acc2.total_s()));
}
