//! Quickstart: the paper's Fig. 2 worked example end to end.
//!
//! 1. Builds tconv(2,2,2,3,2,1) and prints the IOM inefficiency numbers
//!    from §III-A (D_o = 40, D_r = 0.55, 2.25x / 9x storage gains).
//! 2. Prints the compute/output maps the MM2IM Mapper generates.
//! 3. Runs the layer through the full stack — host driver (Algorithm 1)
//!    -> micro-ISA stream -> cycle-level accelerator — and checks the
//!    result bit-exactly against the direct reference.
//!
//! Run: `cargo run --release --example quickstart`

use mm2im::accel::isa::OutMode;
use mm2im::accel::mapper::Mapper;
use mm2im::accel::{Accelerator, AccelConfig};
use mm2im::driver::instructions::build_layer_stream;
use mm2im::tconv::metrics::DropStats;
use mm2im::tconv::{reference, TconvProblem};
use mm2im::tensor::Tensor;
use mm2im::util::rng::Pcg32;

fn main() {
    let p = TconvProblem::new(2, 2, 2, 3, 2, 1);
    println!("== the Fig. 2 worked example: {p} ==\n");
    println!("MatMul view (Eq. 2): M={} N={} K={} -> {} partials, {} MACs", p.m(), p.n(), p.k(), p.p_outs(), p.macs());

    let s = DropStats::compute(&p);
    println!("\n§III-A inefficiency metrics:");
    println!("  dropped outputs D_o          : {} (paper: 40)", s.d_o);
    println!("  drop rate D_r                : {:.3} (paper: 0.55)", s.d_r);
    println!("  storage gain (skip dropped)  : {:.2}x (paper: 2.25x)", s.storage_gain_skip);
    println!("  storage gain (direct accum)  : {:.2}x (paper: 9x)", s.storage_gain_accumulate);

    println!("\nMM2IM Mapper output (cmap col -> omap index) per MatMul row:");
    let mapper = Mapper::configure(&p);
    for row in 0..p.m() {
        let entries = mapper.matmul_row_entries(row);
        let fmt: Vec<String> = entries.iter().map(|(c, o)| format!("{c}->{o}")).collect();
        println!("  row {row} (pixel {},{}): {}", row / p.iw, row % p.iw, fmt.join(" "));
    }

    println!("\n== running through the full accelerator ==");
    let mut rng = Pcg32::new(42);
    let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
    let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
    let bias = vec![5i32, -5];
    let cfg = AccelConfig::default();
    let stream = build_layer_stream(&p, &x, &w, &bias, None, &cfg, OutMode::Raw32);
    println!("driver emitted {} instructions (Algorithm 1)", stream.len());
    let result = Accelerator::new(cfg.clone()).execute(&stream).expect("execute");
    let want = reference::direct_i32(&p, &x, &w, Some(&bias));
    assert_eq!(result.raw.data(), want.data(), "accelerator must match reference");
    println!("accelerator output == direct reference (bit-exact)");
    println!("\ncycle report:");
    println!("  total cycles    : {}", result.report.total_cycles);
    println!("  CU compute/load : {} / {}", result.report.pm.cu_compute, result.report.pm.cu_load);
    println!("  mapper          : {}", result.report.mapper);
    println!("  AXI w/in/out    : {} / {} / {}", result.report.axi_weights, result.report.axi_inputs, result.report.axi_outputs);
    println!("  modeled latency : {:.1} us at {} MHz", result.report.seconds(&cfg) * 1e6, cfg.freq_hz / 1e6);
    println!("\nquickstart OK");
}
