//! §V-F — performance model validation: the analytical model (Eq. 3/4 +
//! overlap) vs the cycle-level simulator across the 261-problem sweep.
//! Paper: "the model estimates the actual performance within 10%"; the
//! mapper-optimization delta is predicted "within 1%".

use mm2im::accel::isa::OutMode;
use mm2im::accel::{Accelerator, AccelConfig};
use mm2im::bench::workloads::sweep261;
use mm2im::driver::instructions::build_layer_stream;
use mm2im::perf_model;
use mm2im::tensor::Tensor;
use mm2im::util::rng::Pcg32;
use mm2im::util::stats;
use mm2im::util::table::{f2, pct, Table};

fn simulate(p: &mm2im::tconv::TconvProblem, cfg: &AccelConfig, seed: u64) -> u64 {
    let mut rng = Pcg32::new(seed);
    let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
    let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
    let stream = build_layer_stream(p, &x, &w, &vec![0; p.oc], None, cfg, OutMode::Raw32);
    Accelerator::new(cfg.clone()).execute(&stream).unwrap().report.total_cycles
}

fn main() {
    let cfg = AccelConfig::default();
    let mut errs = Vec::new();
    let mut worst: (f64, String) = (0.0, String::new());
    for e in sweep261() {
        let sim = simulate(&e.problem, &cfg, 1) as f64;
        let est = perf_model::estimate(&e.problem, &cfg).t_total as f64;
        let err = ((est - sim) / sim).abs();
        if err > worst.0 {
            worst = (err, e.problem.to_string());
        }
        errs.push(err * 100.0);
    }
    let mut t = Table::new("§V-F — analytical model vs simulator (261 problems)", &["metric", "value"]);
    t.row(&["mean abs error".into(), pct(stats::mean(&errs) / 100.0)]);
    t.row(&["median abs error".into(), pct(stats::median(&errs) / 100.0)]);
    t.row(&["p95-ish max error".into(), pct(stats::max(&errs) / 100.0)]);
    t.row(&["worst problem".into(), worst.1.clone()]);
    t.print();
    println!("\npaper: within 10% on average — ours mean {:.1}%", stats::mean(&errs));

    // Mapper-optimization delta prediction (the "within 1%" claim):
    // predicted improvement (model) vs actual improvement (simulator)
    // from enabling the MM2IM Mapper.
    let mut deltas = Vec::new();
    let mut no_map = cfg.clone();
    no_map.mapper_enabled = false;
    for e in sweep261().iter().step_by(13) {
        let p = e.problem;
        let sim_on = simulate(&p, &cfg, 1) as f64;
        let sim_off = simulate(&p, &no_map, 1) as f64;
        let est_on = perf_model::estimate(&p, &cfg).t_total as f64;
        let est_off = perf_model::estimate(&p, &no_map).t_total as f64;
        let actual_gain = sim_off / sim_on;
        let predicted_gain = est_off / est_on;
        deltas.push(((predicted_gain - actual_gain) / actual_gain).abs() * 100.0);
    }
    println!(
        "mapper-optimization delta: predicted vs actual improvement deviates {:.2}% on average (paper: within 1%)",
        stats::mean(&deltas)
    );
    assert!(stats::mean(&errs) < 10.0, "model must stay within the paper's 10% band");
}
