//! Design-space ablation: scale the PM array (X) and the unroll factor
//! (UF) — "these parameters could be scaled to meet performance demands
//! and resource constraints" (§IV). Reports speedup + resource cost per
//! configuration and flags which fit the PYNQ-Z1.

use mm2im::accel::{resources, AccelConfig};
use mm2im::bench::harness::run_problem;
use mm2im::tconv::TconvProblem;
use mm2im::util::stats;
use mm2im::util::table::{f2, Table};

fn main() {
    let probes = [
        TconvProblem::square(7, 64, 5, 16, 2),
        TconvProblem::square(9, 128, 5, 32, 2),
        TconvProblem::square(11, 256, 3, 64, 1),
        TconvProblem::square(8, 512, 5, 64, 2),
    ];
    let mut t = Table::new(
        "Scaling ablation — X (PMs) and UF (MACs/CU)",
        &["X", "UF", "peak GOPs", "DSP", "BRAM %", "fits?", "mean speedup vs CPU 2T"],
    );
    for (x, uf) in [(1usize, 16usize), (2, 16), (4, 16), (8, 8), (8, 16), (8, 32), (16, 16)] {
        let mut cfg = AccelConfig::default();
        cfg.x_pms = x;
        cfg.uf = uf;
        let res = resources::estimate(&cfg);
        let speedups: Vec<f64> = probes
            .iter()
            .map(|p| run_problem(p, &cfg, 1).speedup_2t())
            .collect();
        t.row(&[
            x.to_string(),
            uf.to_string(),
            f2(cfg.peak_gops()),
            res.dsp.to_string(),
            f2(res.bram_pct()),
            if res.fits() { "yes".into() } else { "NO".into() },
            f2(stats::mean(&speedups)),
        ]);
    }
    t.print();
    println!("\nthe paper's instantiation (X=8, UF=16) is the largest configuration that fits the PYNQ-Z1");
}
