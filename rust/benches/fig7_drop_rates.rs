//! Fig. 7 — drop rate (% cropped outputs) for the 261 benchmarked TCONV
//! problems, grouped as the paper plots them (per Oc/Ks/Ih bucket, swept
//! over Ic and S).

use mm2im::bench::workloads::{group_label, sweep261};
use mm2im::tconv::metrics::DropStats;
use mm2im::util::stats;
use mm2im::util::table::{pct, Table};
use std::collections::BTreeMap;

fn main() {
    let mut groups: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    let mut by_stride: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
    let mut by_ks: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
    let mut by_ih: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
    for e in sweep261().iter().filter(|e| e.group == "grid216") {
        let d = DropStats::compute(&e.problem).d_r;
        groups.entry(group_label(&e.problem)).or_default().push(d);
        by_stride.entry(e.problem.stride).or_default().push(d);
        by_ks.entry(e.problem.ks).or_default().push(d);
        by_ih.entry(e.problem.ih).or_default().push(d);
    }
    let mut t = Table::new(
        "Fig. 7 — drop rate per problem group (mean over Ic x S)",
        &["group (oc_ks_ih)", "mean", "min", "max"],
    );
    for (g, v) in &groups {
        t.row(&[g.clone(), pct(stats::mean(v)), pct(stats::min(v)), pct(stats::max(v))]);
    }
    t.print();

    let mut s = Table::new("Fig. 7 takeaways — marginals", &["dimension", "value", "mean drop"]);
    for (k, v) in &by_ks {
        s.row(&["Ks".into(), k.to_string(), pct(stats::mean(v))]);
    }
    for (k, v) in &by_ih {
        s.row(&["Ih".into(), k.to_string(), pct(stats::mean(v))]);
    }
    for (k, v) in &by_stride {
        s.row(&["S".into(), k.to_string(), pct(stats::mean(v))]);
    }
    s.print();
    println!("\npaper: Ks raises drop rate; higher Ih and S lower it.");
}
