//! Ablation (§III-C third insight): remove the MM2IM Mapper and stream
//! omap/cmap over AXI instead. The paper's performance model attributed
//! "up to 35% of end-to-end latency" to this transfer, motivating the
//! hardware mapper.

use mm2im::accel::isa::OutMode;
use mm2im::accel::{Accelerator, AccelConfig};
use mm2im::bench::workloads::sweep261;
use mm2im::driver::instructions::build_layer_stream;
use mm2im::tensor::Tensor;
use mm2im::util::rng::Pcg32;
use mm2im::util::stats;
use mm2im::util::table::{f2, pct, Table};

fn main() {
    let with = AccelConfig::default();
    let mut without = AccelConfig::default();
    without.mapper_enabled = false;

    let mut shares = Vec::new();
    let mut slowdowns = Vec::new();
    let mut worst: (f64, String) = (0.0, String::new());
    for e in sweep261().iter().step_by(3) {
        let p = e.problem;
        let mut rng = Pcg32::new(1);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let s1 = build_layer_stream(&p, &x, &w, &vec![0; p.oc], None, &with, OutMode::Raw32);
        let s2 = build_layer_stream(&p, &x, &w, &vec![0; p.oc], None, &without, OutMode::Raw32);
        let on = Accelerator::new(with.clone()).execute(&s1).unwrap().report;
        let off = Accelerator::new(without.clone()).execute(&s2).unwrap().report;
        assert!(off.traffic.omap_bytes > 0);
        let share = off.axi_omap as f64 / off.total_cycles as f64;
        let slowdown = off.total_cycles as f64 / on.total_cycles as f64;
        if share > worst.0 {
            worst = (share, p.to_string());
        }
        shares.push(share);
        slowdowns.push(slowdown);
    }
    let mut t = Table::new(
        "Mapper ablation — omap transfer cost without the MM2IM Mapper",
        &["metric", "value"],
    );
    t.row(&["mean omap share of latency".into(), pct(stats::mean(&shares))]);
    t.row(&["max omap share of latency".into(), pct(stats::max(&shares))]);
    t.row(&["worst problem".into(), worst.1.clone()]);
    t.row(&["mean slowdown without mapper".into(), format!("{}x", f2(stats::mean(&slowdowns)))]);
    t.row(&["max slowdown without mapper".into(), format!("{}x", f2(stats::max(&slowdowns)))]);
    t.print();
    println!("\npaper (§III-C): omap transfers were up to 35% of T_total before the Mapper was added");
    println!("(ours peaks lower — our packed 4-byte map records are tighter than the paper's —");
    println!(" but the direction and the Ic/Ks-dependence match: small-Ic problems suffer most)");
}
