//! Fig. 6 — MM2IM speedup normalized to dual-thread CPU execution across
//! the 261 TCONV problems (full numerics + cycle model per problem).
//!
//! Prints per-group speedups plus the paper's takeaway marginals
//! (Ic, Ih, Ks, Oc, S trends) and the overall average vs the 1.9x claim.

use mm2im::accel::AccelConfig;
use mm2im::bench::harness::run_problem;
use mm2im::bench::workloads::{group_label, sweep261};
use mm2im::util::stats;
use mm2im::util::table::{f2, Table};
use std::collections::BTreeMap;

fn main() {
    let cfg = AccelConfig::default();
    let entries = sweep261();
    let mut all = Vec::new();
    let mut groups: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    let mut marg: BTreeMap<(&str, usize), Vec<f64>> = BTreeMap::new();
    for e in &entries {
        let r = run_problem(&e.problem, &cfg, 1);
        let s = r.speedup_2t();
        all.push(s);
        if e.group == "grid216" {
            groups.entry(group_label(&e.problem)).or_default().push(s);
            let p = e.problem;
            for (dim, v) in [("Ic", p.ic), ("Ih", p.ih), ("Ks", p.ks), ("Oc", p.oc), ("S", p.stride)] {
                marg.entry((dim, v)).or_default().push(s);
            }
        }
    }

    let mut t = Table::new(
        "Fig. 6 — speedup vs CPU 2T per problem group (mean over Ic x S)",
        &["group (oc_ks_ih)", "mean", "min", "max"],
    );
    for (g, v) in &groups {
        t.row(&[g.clone(), f2(stats::mean(v)), f2(stats::min(v)), f2(stats::max(v))]);
    }
    t.print();

    let mut m = Table::new("Fig. 6 takeaways — marginal mean speedups", &["dim", "value", "mean speedup"]);
    for ((dim, v), xs) in &marg {
        m.row(&[dim.to_string(), v.to_string(), f2(stats::mean(xs))]);
    }
    m.print();

    let s1: Vec<f64> = marg.get(&("S", 1)).cloned().unwrap_or_default();
    let s2: Vec<f64> = marg.get(&("S", 2)).cloned().unwrap_or_default();
    println!(
        "\nALL 261: mean {:.2}x | geomean {:.2}x | median {:.2}x   (paper: avg 1.9x)",
        stats::mean(&all),
        stats::geomean(&all),
        stats::median(&all)
    );
    println!(
        "stride-2 mean / stride-1 mean = {:.2} (paper: stride-2 speedups are ~54% of stride-1)",
        stats::mean(&s2) / stats::mean(&s1)
    );
}
