//! Table II — performance evaluation on generative-model layers:
//! latency, speedup (vs CPU 1T), GOPs and GOPs/W per layer, side by side
//! with the paper's measured numbers.

use mm2im::accel::AccelConfig;
use mm2im::bench::harness::run_problem;
use mm2im::model::zoo;
use mm2im::util::stats;
use mm2im::util::table::{f2, ms, Table};

fn main() {
    let cfg = AccelConfig::default();
    let mut t = Table::new(
        "Table II — generative model layers (ours vs paper)",
        &[
            "layer", "OPs", "lat ms", "paper", "cpu1T ms", "paper", "speedup", "paper",
            "GOPs", "paper", "GOPs/W", "paper",
        ],
    );
    let mut our_speedups = Vec::new();
    let mut our_gops = Vec::new();
    let mut our_gpw = Vec::new();
    for row in zoo::table2_layers() {
        let r = run_problem(&row.problem, &cfg, 1);
        our_speedups.push(r.speedup_1t());
        our_gops.push(r.gops);
        our_gpw.push(r.gops_per_watt);
        t.row(&[
            row.name.to_string(),
            format!("{}M", row.problem.ops() / 1_000_000),
            ms(r.acc_seconds),
            f2(row.paper_acc_ms),
            ms(r.cpu1_seconds),
            f2(row.paper_cpu_ms),
            f2(r.speedup_1t()),
            f2(row.paper_speedup),
            f2(r.gops),
            f2(row.paper_gops),
            f2(r.gops_per_watt),
            f2(row.paper_gops_w),
        ]);
    }
    t.print();
    println!(
        "\nours: avg speedup {:.2}x (paper 2.8x) | avg GOPs {:.2} (paper 5.5) | avg GOPs/W {:.2} (paper 14.9)",
        stats::mean(&our_speedups),
        stats::mean(&our_gops),
        stats::mean(&our_gpw)
    );
    println!("known deviations: StyleTransfer_1/2 run faster in our simulator (EXPERIMENTS.md §Calibration)");
}
