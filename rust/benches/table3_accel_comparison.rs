//! Table III — comparison with state-of-the-art TCONV accelerators.
//!
//! Related-work rows are constants quoted from the paper (their
//! artifacts are unavailable); the "Ours" column is regenerated from the
//! resource model and the best achieved throughput across the Table II
//! layer set (the paper reports its best observed performance).

use mm2im::accel::{resources, AccelConfig};
use mm2im::bench::harness::run_problem;
use mm2im::model::zoo;
use mm2im::util::table::{f2, Table};

struct Related {
    source: &'static str,
    fpga: &'static str,
    mhz: u32,
    precision: &'static str,
    dsp: u32,
    gops: f64,
}

fn main() {
    let related = [
        Related { source: "Zhang et al. [6]", fpga: "ZYNQ 7Z020", mhz: 100, precision: "12-bit", dsp: 209, gops: 2.6 },
        Related { source: "Liu et al. [18]", fpga: "ZC706 XC7Z045", mhz: 200, precision: "16-bit", dsp: 640, gops: 29.0 },
        Related { source: "Di et al. [19]", fpga: "ZC706 XC7Z045", mhz: 167, precision: "16-bit", dsp: 603, gops: 236.9 },
        Related { source: "Chang et al. [8]", fpga: "Kintex-7 XC7K410T", mhz: 130, precision: "13-bit", dsp: 1512, gops: 2691.0 },
    ];

    let cfg = AccelConfig::default();
    let res = resources::estimate(&cfg);
    // Best achieved throughput across the evaluated layers (+ sustained
    // peak on the most accelerator-friendly shape, as vendors report).
    let mut best_gops: f64 = 0.0;
    let mut best_layer = String::new();
    for row in zoo::table2_layers() {
        let r = run_problem(&row.problem, &cfg, 1);
        if r.gops > best_gops {
            best_gops = r.gops;
            best_layer = row.name.to_string();
        }
    }

    let mut t = Table::new(
        "Table III — state-of-the-art comparison",
        &["source", "FPGA", "MHz", "precision", "DSP", "GOPs", "GOPs/DSP"],
    );
    for r in &related {
        t.row(&[
            r.source.into(),
            r.fpga.into(),
            r.mhz.to_string(),
            r.precision.into(),
            r.dsp.to_string(),
            f2(r.gops),
            f2(r.gops / r.dsp as f64),
        ]);
    }
    t.row(&[
        "Ours (MM2IM)".into(),
        "PYNQ Z1 (simulated)".into(),
        "200".into(),
        "8-bit".into(),
        res.dsp.to_string(),
        f2(best_gops),
        f2(best_gops / res.dsp as f64),
    ]);
    t.print();

    let ours_gops_dsp = best_gops / res.dsp as f64;
    let next_best = related.iter().map(|r| r.gops / r.dsp as f64).fold(0.0, f64::max);
    println!("\nbest layer: {best_layer} at {best_gops:.2} GOPs");
    println!(
        "GOPs/DSP: ours {ours_gops_dsp:.2} vs next best {next_best:.2} -> {:.2}x",
        ours_gops_dsp / next_best
    );
    println!(
        "peak GOPs/DSP (architecture bound): {:.2}",
        cfg.peak_gops() / res.dsp as f64
    );
    println!("REPRODUCTION NOTE: the paper's 'Ours' GOPs/DSP cell (3.51) is not derivable");
    println!("from its own row (23.0 GOPs / 49 DSP = 0.47); under consistent arithmetic the");
    println!(">= 2x-over-next-best claim does not hold for any achievable GOPs on this design");
    println!("(peak is 51.2 GOPs -> 1.04 GOPs/DSP). See EXPERIMENTS.md (Table III).");
    println!(
        "resources: {} DSP ({:.0}%), {} LUT ({:.0}%), {} FF ({:.0}%), {:.1} Mb BRAM ({:.0}%)",
        res.dsp, res.dsp_pct(), res.lut, res.lut_pct(), res.ff, res.ff_pct(),
        res.bram_bits as f64 / 1e6, res.bram_pct()
    );
    println!("paper 'Ours' column: 49 DSP (22%), 42K LUT (79%), 49K FF (46%), 99% BRAM, 23.0 GOPs, 3.51 GOPs/DSP");
}
