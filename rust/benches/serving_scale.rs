//! Serving-scale bench: what the compiled-plan cache, sharding,
//! weight-reuse layer batching, and the SLO-class request API buy.
//!
//! 1. Stream-production amortization: per-request cost of compiling a
//!    layer program from scratch vs instantiating the cached plan
//!    (byte-identical outputs verified inside the harness helper).
//! 2. End-to-end serve runs of the DCGAN generator across shard counts,
//!    reporting throughput, latency percentiles, cache hit rate and
//!    per-shard utilization from `ServeStats`.
//! 3. Layer batching on same-layer traffic: identical request sets served
//!    with batching disabled (`max_batch 1`) vs enabled, reporting the
//!    modeled (simulated-cycle) per-request latency, the **wall-clock
//!    requests/sec** (where the zero-copy instruction streams and the
//!    fused GEMM+col2IM engine land), and the weight-load hit rate.
//! 4. Priority traffic: a half-High/half-Low request set queued up front,
//!    p50/p95 client latency split by class — the priority-seeded batch
//!    scheduler must serve the High class with a strictly lower p95
//!    (asserted), since High requests seed batches first within the
//!    bounded-inversion window.
//! 5. Heterogeneous fleet (X=8/UF=16 next to X=4/UF=32 shards): the
//!    modeled-latency, weight-aware placement scorer vs route-blind
//!    round-robin — on same-layer traffic the scorer must strictly
//!    reduce total weight loads (asserted), and on mixed DCGAN/pix2pix
//!    traffic the placement spread and cross-batch resident hits are
//!    reported.
//! 6. Warm restart: the same DCGAN traffic served cold (compiling every
//!    plan, flushing the cache to a `driver::persist` snapshot on
//!    finish) and then by a restarted server over the same plan store —
//!    the warm run must preload every plan and compile **zero**
//!    (asserted), reporting both runs' compile counts and wall clock.
//!
//! Run: `cargo bench --bench serving_scale [-- --requests 24]`

use mm2im::bench::harness::{compile_amortization, latency_by_class};
use mm2im::bench::workloads::{hetero_fleet, mixed_traffic};
use mm2im::coordinator::{PlacementPolicy, Priority, Request, Server, ServeStats};
use mm2im::model::zoo;
use mm2im::tconv::TconvProblem;
use mm2im::util::cli::Args;
use std::sync::Arc;

fn policy_name(p: PlacementPolicy) -> &'static str {
    match p {
        PlacementPolicy::Modeled { .. } => "scored   ",
        PlacementPolicy::RoundRobin => "roundrobin",
    }
}

fn print_fleet_stats(policy: PlacementPolicy, stats: &ServeStats) {
    let spread = stats
        .shard_requests
        .iter()
        .map(|r| r.to_string())
        .collect::<Vec<_>>()
        .join("/");
    println!(
        "{}: modeled {:.2} ms/req, weight loads {} ({} skipped, {} cross-batch hits), \
         shard requests [{spread}]",
        policy_name(policy),
        stats.modeled_mean_s * 1e3,
        stats.weight_loads,
        stats.weight_loads_skipped,
        stats.cross_batch_resident_hits,
    );
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let requests = args.usize_or("requests", 24);

    println!("== stream production: fresh compile vs cached plan ==");
    let cfg = mm2im::accel::AccelConfig::default();
    for p in [
        TconvProblem::square(7, 64, 5, 16, 2),   // sweep mid-size
        TconvProblem::square(7, 256, 5, 64, 2),  // filter-heavy
        TconvProblem::square(14, 64, 5, 1, 2),   // DCGAN head
    ] {
        let r = compile_amortization(&p, &cfg, requests.max(2), 7);
        assert!(r.outputs_identical);
        println!(
            "{p}: fresh {:.1} us/req, cached {:.1} us/req ({:.1}x; {} compile / {} hits)",
            r.fresh_stream_s / r.requests as f64 * 1e6,
            r.cached_stream_s / r.requests as f64 * 1e6,
            r.stream_speedup(),
            r.cache.misses,
            r.cache.hits,
        );
    }

    println!("\n== sharded serving: DCGAN generator, {requests} requests ==");
    let mut baseline = None;
    for shards in [1usize, 2, 4] {
        let mut server = Server::builder()
            .graph(Arc::new(zoo::dcgan_tf(0)))
            .shards(shards)
            .workers_per_shard(1)
            .queue_capacity(16)
            .max_batch(4)
            .start()
            .expect("valid config");
        server.submit_many((0..requests as u64).map(Request::seed)).expect("submit");
        // The tree outlives `finish`; the widest configuration's final
        // snapshot becomes the bench's baseline artifact below.
        let telem = server.telemetry();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), requests);
        baseline = Some(telem.snapshot());
        let util = stats
            .shard_utilization
            .iter()
            .map(|u| format!("{:.0}%", u * 100.0))
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "shards {shards}: {:.1} req/s, p50 {:.0} ms, p95 {:.0} ms, cache {:.0}% hits ({} compiles), util [{util}]",
            stats.throughput_rps,
            stats.p50_latency_s * 1e3,
            stats.p95_latency_s * 1e3,
            stats.cache_hit_rate() * 100.0,
            stats.cache_misses,
        );
    }
    // Baseline artifact: the 4-shard run's full telemetry snapshot, in the
    // stable JSON schema `repro stats` consumes. CI archives it so the bench
    // trajectory accumulates comparable dumps over time.
    let snap = baseline.expect("loop above always runs");
    std::fs::write("BENCH_serving.json", snap.to_json()).expect("writable working directory");
    println!("baseline artifact: BENCH_serving.json ({} metrics)", snap.iter().count());

    println!("\n== layer batching: same-layer traffic, {requests} requests ==");
    let mut unbatched_ms = None;
    for max_batch in [1usize, 4, 8] {
        let mut server = Server::builder()
            .graph(Arc::new(zoo::dcgan_tf(0)))
            .shards(1)
            .workers_per_shard(1)
            .queue_capacity(requests.max(1))
            .max_batch(max_batch)
            .start()
            .expect("valid config");
        // Queue everything up front so the scheduler can form full
        // batches — the same-layer steady state of hot serving traffic.
        server.pause();
        for s in 0..requests as u64 {
            server.try_submit(Request::seed(s)).expect("capacity sized to the burst");
        }
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), requests);
        let modeled_ms = stats.modeled_mean_s * 1e3;
        let speedup = match unbatched_ms {
            None => {
                unbatched_ms = Some(modeled_ms);
                1.0
            }
            Some(base) => base / modeled_ms,
        };
        println!(
            "max_batch {max_batch}: modeled {modeled_ms:.2} ms/req ({speedup:.2}x), \
             wall-clock {:.1} req/s, \
             weight loads {} / {} per-request equiv ({:.0}% amortized), mean batch {:.1}",
            stats.throughput_rps,
            stats.weight_loads,
            stats.weight_loads_equiv,
            stats.weight_load_hit_rate() * 100.0,
            stats.mean_batch_size,
        );
    }

    // ---- priority traffic: p95 latency split by class -----------------------
    // Half the requests are High, half Low, interleaved and queued up
    // front on one worker. The priority-seeded scheduler serves every
    // High batch before the first Low one (the Low class stays within
    // the bounded-inversion window), so High p95 must come in strictly
    // below Low p95 — queue wait dominates client latency here.
    println!("\n== priority traffic: {requests} requests, half High / half Low ==");
    let server_batch = 4usize;
    let mut server = Server::builder()
        .graph(Arc::new(zoo::dcgan_tf(0)))
        .shards(1)
        .workers_per_shard(1)
        .queue_capacity(requests.max(2))
        .max_batch(server_batch)
        .group_window(requests.max(2))
        .start()
        .expect("valid config");
    server.pause();
    for s in 0..requests as u64 {
        let class = if s % 2 == 0 { Priority::Low } else { Priority::High };
        server.try_submit(Request::seed(s).priority(class)).expect("capacity sized");
    }
    server.resume();
    let (responses, stats) = server.finish();
    assert_eq!(responses.len(), requests);
    let split = latency_by_class(&responses);
    for c in &split {
        println!(
            "class {:<6}: {} served, p50 {:.1} ms, p95 {:.1} ms",
            c.priority.label(),
            c.requests,
            c.p50_s * 1e3,
            c.p95_s * 1e3
        );
    }
    let high = split.iter().find(|c| c.priority == Priority::High);
    let low = split.iter().find(|c| c.priority == Priority::Low);
    match (high, low) {
        // The inversion assert needs enough traffic that the classes
        // land in different batches (default --requests 24 does).
        (Some(high), Some(low)) if requests > 2 * server_batch => {
            assert!(
                high.p95_s < low.p95_s,
                "priority scheduling must cut the High class's p95: high {:.3} ms vs low {:.3} ms",
                high.p95_s * 1e3,
                low.p95_s * 1e3
            );
            println!(
                "high-priority p95 is {:.1}x below low ({} batches total)",
                low.p95_s / high.p95_s.max(1e-12),
                stats.batches
            );
        }
        _ => println!(
            "(skipping the High-vs-Low p95 assert: {requests} requests is too few to \
             separate the classes into distinct batches)"
        ),
    }

    // ---- heterogeneous fleet: same-layer traffic ---------------------------
    // One single-TCONV model, every batch identical: the scorer should
    // park the traffic on the modeled-fastest shard and ride the
    // resident filter set; round-robin reloads on every shard it visits.
    println!("\n== heterogeneous fleet (X8/UF16 + X4/UF32): same-layer traffic ==");
    let serve_fleet = |graphs: Vec<Arc<mm2im::model::graph::Graph>>,
                       traffic: &[(usize, u64)],
                       policy: PlacementPolicy| {
        let mut server = Server::builder()
            .graphs(graphs)
            .workers_per_shard(1)
            .queue_capacity(traffic.len().max(1))
            .max_batch(4)
            .shard_fleet(hetero_fleet())
            .placement(policy)
            .start()
            .expect("valid config");
        server.pause();
        for &(graph, seed) in traffic {
            server.try_submit(Request::seed(seed).graph(graph)).expect("capacity sized");
        }
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), traffic.len());
        stats
    };

    let same_layer: Vec<(usize, u64)> = (0..requests as u64).map(|s| (0, s)).collect();
    let fsrcnn = || Arc::new(zoo::fsrcnn(8, 0));
    let rr = serve_fleet(vec![fsrcnn()], &same_layer, PlacementPolicy::RoundRobin);
    print_fleet_stats(PlacementPolicy::RoundRobin, &rr);
    let scored_policy = PlacementPolicy::Modeled { tolerance: 0.0 };
    let scored = serve_fleet(vec![fsrcnn()], &same_layer, scored_policy);
    print_fleet_stats(scored_policy, &scored);
    assert!(
        scored.weight_loads < rr.weight_loads,
        "weight-aware placement must strictly reduce weight loads on same-layer \
         traffic: scored {} vs round-robin {}",
        scored.weight_loads,
        rr.weight_loads
    );
    println!(
        "scorer eliminates {} of {} round-robin weight loads",
        rr.weight_loads - scored.weight_loads,
        rr.weight_loads
    );

    // ---- heterogeneous fleet: mixed-model traffic --------------------------
    println!("\n== heterogeneous fleet: mixed DCGAN + pix2pix traffic ==");
    let traffic = mixed_traffic(2, requests, 42);
    for policy in [PlacementPolicy::RoundRobin, PlacementPolicy::Modeled { tolerance: 0.05 }] {
        let graphs = vec![Arc::new(zoo::dcgan_tf(0)), Arc::new(zoo::pix2pix(16, 4, 0))];
        let stats = serve_fleet(graphs, &traffic, policy);
        print_fleet_stats(policy, &stats);
    }

    // ---- warm restart: plan-store snapshot vs recompiling the zoo ----------
    // A cold server compiles every TCONV plan and flushes the cache to a
    // snapshot on finish; a restarted server over the same store must
    // preload them all and serve the identical traffic with ZERO compiles
    // (asserted — the `driver::persist` contract, pinned structurally in
    // tests/persistence.rs). Wall-clock includes server start, so the
    // delta is what a restarted shard's first requests stop paying.
    println!("\n== warm restart: DCGAN, {requests} requests, plan-store snapshot ==");
    let store = std::env::temp_dir().join(format!("mm2im_bench_plans_{}.bin", std::process::id()));
    let _ = std::fs::remove_file(&store);
    let serve_with_store = || {
        let t0 = std::time::Instant::now();
        let mut server = Server::builder()
            .graph(Arc::new(zoo::dcgan_tf(0)))
            .shards(1)
            .workers_per_shard(1)
            .queue_capacity(requests.max(1))
            .max_batch(4)
            .plan_store(&store)
            .start()
            .expect("valid config");
        server.submit_many((0..requests as u64).map(Request::seed)).expect("submit");
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), requests);
        (stats, t0.elapsed().as_secs_f64())
    };
    let (cold, cold_s) = serve_with_store();
    let (warm, warm_s) = serve_with_store();
    assert_eq!(warm.cache_misses, 0, "a warm restart must not compile a single plan");
    assert_eq!(warm.plans_preloaded, cold.cache_misses, "every cold compile preloads");
    println!(
        "cold : {} compiles, {} preloaded, {:.1} req/s ({:.0} ms total)",
        cold.cache_misses,
        cold.plans_preloaded,
        cold.throughput_rps,
        cold_s * 1e3
    );
    println!(
        "warm : {} compiles, {} preloaded, {:.1} req/s ({:.0} ms total)",
        warm.cache_misses,
        warm.plans_preloaded,
        warm.throughput_rps,
        warm_s * 1e3
    );
    let _ = std::fs::remove_file(&store);
}
