//! Serving-scale bench: what the compiled-plan cache, sharding, and
//! weight-reuse layer batching buy.
//!
//! 1. Stream-production amortization: per-request cost of compiling a
//!    layer program from scratch vs instantiating the cached plan
//!    (byte-identical outputs verified inside the harness helper).
//! 2. End-to-end serve runs of the DCGAN generator across shard counts,
//!    reporting throughput, latency percentiles, cache hit rate and
//!    per-shard utilization from `ServeStats`.
//! 3. Layer batching on same-layer traffic: identical request sets served
//!    with batching disabled (`max_batch 1`) vs enabled, reporting the
//!    modeled (simulated-cycle) per-request latency and the weight-load
//!    hit rate — the per-request cost drops because one
//!    `Configure`/`LoadWeights` prologue per tile serves the whole batch.
//!
//! Run: `cargo bench --bench serving_scale [-- --requests 24]`

use mm2im::bench::harness::compile_amortization;
use mm2im::coordinator::{Server, ServerConfig};
use mm2im::model::zoo;
use mm2im::tconv::TconvProblem;
use mm2im::util::cli::Args;
use std::sync::Arc;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let requests = args.usize_or("requests", 24);

    println!("== stream production: fresh compile vs cached plan ==");
    let cfg = mm2im::accel::AccelConfig::default();
    for p in [
        TconvProblem::square(7, 64, 5, 16, 2),   // sweep mid-size
        TconvProblem::square(7, 256, 5, 64, 2),  // filter-heavy
        TconvProblem::square(14, 64, 5, 1, 2),   // DCGAN head
    ] {
        let r = compile_amortization(&p, &cfg, requests.max(2), 7);
        assert!(r.outputs_identical);
        println!(
            "{p}: fresh {:.1} us/req, cached {:.1} us/req ({:.1}x; {} compile / {} hits)",
            r.fresh_stream_s / r.requests as f64 * 1e6,
            r.cached_stream_s / r.requests as f64 * 1e6,
            r.stream_speedup(),
            r.cache.misses,
            r.cache.hits,
        );
    }

    println!("\n== sharded serving: DCGAN generator, {requests} requests ==");
    for shards in [1usize, 2, 4] {
        let g = Arc::new(zoo::dcgan_tf(0));
        let config = ServerConfig {
            shards,
            workers_per_shard: 1,
            queue_capacity: 16,
            max_batch: 4,
            ..ServerConfig::default()
        };
        let mut server = Server::start(g, config);
        let seeds: Vec<u64> = (0..requests as u64).collect();
        server.submit_many(&seeds);
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), requests);
        let util = stats
            .shard_utilization
            .iter()
            .map(|u| format!("{:.0}%", u * 100.0))
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "shards {shards}: {:.1} req/s, p50 {:.0} ms, p95 {:.0} ms, cache {:.0}% hits ({} compiles), util [{util}]",
            stats.throughput_rps,
            stats.p50_latency_s * 1e3,
            stats.p95_latency_s * 1e3,
            stats.cache_hit_rate() * 100.0,
            stats.cache_misses,
        );
    }

    println!("\n== layer batching: same-layer traffic, {requests} requests ==");
    let mut unbatched_ms = None;
    for max_batch in [1usize, 4, 8] {
        let g = Arc::new(zoo::dcgan_tf(0));
        let config = ServerConfig {
            shards: 1,
            workers_per_shard: 1,
            queue_capacity: requests.max(1),
            max_batch,
            ..ServerConfig::default()
        };
        let mut server = Server::start(g, config);
        // Queue everything up front so the scheduler can form full
        // batches — the same-layer steady state of hot serving traffic.
        server.pause();
        let seeds: Vec<u64> = (0..requests as u64).collect();
        for &s in &seeds {
            server.submit(s);
        }
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), requests);
        let modeled_ms = stats.modeled_mean_s * 1e3;
        let speedup = match unbatched_ms {
            None => {
                unbatched_ms = Some(modeled_ms);
                1.0
            }
            Some(base) => base / modeled_ms,
        };
        println!(
            "max_batch {max_batch}: modeled {modeled_ms:.2} ms/req ({speedup:.2}x), \
             weight loads {} / {} per-request equiv ({:.0}% amortized), mean batch {:.1}",
            stats.weight_loads,
            stats.weight_loads_equiv,
            stats.weight_load_hit_rate() * 100.0,
            stats.mean_batch_size,
        );
    }
}
