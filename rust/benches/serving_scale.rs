//! Serving-scale bench: what the compiled-plan cache, sharding, and
//! weight-reuse layer batching buy.
//!
//! 1. Stream-production amortization: per-request cost of compiling a
//!    layer program from scratch vs instantiating the cached plan
//!    (byte-identical outputs verified inside the harness helper).
//! 2. End-to-end serve runs of the DCGAN generator across shard counts,
//!    reporting throughput, latency percentiles, cache hit rate and
//!    per-shard utilization from `ServeStats`.
//! 3. Layer batching on same-layer traffic: identical request sets served
//!    with batching disabled (`max_batch 1`) vs enabled, reporting the
//!    modeled (simulated-cycle) per-request latency, the **wall-clock
//!    requests/sec** (where the zero-copy instruction streams and the
//!    fused GEMM+col2IM engine land), and the weight-load hit rate — the
//!    per-request cost drops because one `Configure`/`LoadWeights`
//!    prologue per tile serves the whole batch.
//! 4. Heterogeneous fleet (X=8/UF=16 next to X=4/UF=32 shards): the
//!    modeled-latency, weight-aware placement scorer vs route-blind
//!    round-robin — on same-layer traffic the scorer must strictly
//!    reduce total weight loads (asserted), and on mixed DCGAN/pix2pix
//!    traffic the placement spread and cross-batch resident hits are
//!    reported.
//!
//! Run: `cargo bench --bench serving_scale [-- --requests 24]`

use mm2im::bench::harness::compile_amortization;
use mm2im::bench::workloads::{hetero_fleet, mixed_traffic};
use mm2im::coordinator::{PlacementPolicy, Server, ServeStats, ServerConfig};
use mm2im::model::zoo;
use mm2im::tconv::TconvProblem;
use mm2im::util::cli::Args;
use std::sync::Arc;

fn policy_name(p: PlacementPolicy) -> &'static str {
    match p {
        PlacementPolicy::Modeled { .. } => "scored   ",
        PlacementPolicy::RoundRobin => "roundrobin",
    }
}

fn print_fleet_stats(policy: PlacementPolicy, stats: &ServeStats) {
    let spread = stats
        .shard_requests
        .iter()
        .map(|r| r.to_string())
        .collect::<Vec<_>>()
        .join("/");
    println!(
        "{}: modeled {:.2} ms/req, weight loads {} ({} skipped, {} cross-batch hits), \
         shard requests [{spread}]",
        policy_name(policy),
        stats.modeled_mean_s * 1e3,
        stats.weight_loads,
        stats.weight_loads_skipped,
        stats.cross_batch_resident_hits,
    );
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let requests = args.usize_or("requests", 24);

    println!("== stream production: fresh compile vs cached plan ==");
    let cfg = mm2im::accel::AccelConfig::default();
    for p in [
        TconvProblem::square(7, 64, 5, 16, 2),   // sweep mid-size
        TconvProblem::square(7, 256, 5, 64, 2),  // filter-heavy
        TconvProblem::square(14, 64, 5, 1, 2),   // DCGAN head
    ] {
        let r = compile_amortization(&p, &cfg, requests.max(2), 7);
        assert!(r.outputs_identical);
        println!(
            "{p}: fresh {:.1} us/req, cached {:.1} us/req ({:.1}x; {} compile / {} hits)",
            r.fresh_stream_s / r.requests as f64 * 1e6,
            r.cached_stream_s / r.requests as f64 * 1e6,
            r.stream_speedup(),
            r.cache.misses,
            r.cache.hits,
        );
    }

    println!("\n== sharded serving: DCGAN generator, {requests} requests ==");
    for shards in [1usize, 2, 4] {
        let g = Arc::new(zoo::dcgan_tf(0));
        let config = ServerConfig {
            shards,
            workers_per_shard: 1,
            queue_capacity: 16,
            max_batch: 4,
            ..ServerConfig::default()
        };
        let mut server = Server::start(g, config);
        let seeds: Vec<u64> = (0..requests as u64).collect();
        server.submit_many(&seeds);
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), requests);
        let util = stats
            .shard_utilization
            .iter()
            .map(|u| format!("{:.0}%", u * 100.0))
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "shards {shards}: {:.1} req/s, p50 {:.0} ms, p95 {:.0} ms, cache {:.0}% hits ({} compiles), util [{util}]",
            stats.throughput_rps,
            stats.p50_latency_s * 1e3,
            stats.p95_latency_s * 1e3,
            stats.cache_hit_rate() * 100.0,
            stats.cache_misses,
        );
    }

    println!("\n== layer batching: same-layer traffic, {requests} requests ==");
    let mut unbatched_ms = None;
    for max_batch in [1usize, 4, 8] {
        let g = Arc::new(zoo::dcgan_tf(0));
        let config = ServerConfig {
            shards: 1,
            workers_per_shard: 1,
            queue_capacity: requests.max(1),
            max_batch,
            ..ServerConfig::default()
        };
        let mut server = Server::start(g, config);
        // Queue everything up front so the scheduler can form full
        // batches — the same-layer steady state of hot serving traffic.
        server.pause();
        let seeds: Vec<u64> = (0..requests as u64).collect();
        for &s in &seeds {
            server.submit(s);
        }
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), requests);
        let modeled_ms = stats.modeled_mean_s * 1e3;
        let speedup = match unbatched_ms {
            None => {
                unbatched_ms = Some(modeled_ms);
                1.0
            }
            Some(base) => base / modeled_ms,
        };
        println!(
            "max_batch {max_batch}: modeled {modeled_ms:.2} ms/req ({speedup:.2}x), \
             wall-clock {:.1} req/s, \
             weight loads {} / {} per-request equiv ({:.0}% amortized), mean batch {:.1}",
            stats.throughput_rps,
            stats.weight_loads,
            stats.weight_loads_equiv,
            stats.weight_load_hit_rate() * 100.0,
            stats.mean_batch_size,
        );
    }

    // ---- heterogeneous fleet: same-layer traffic ---------------------------
    // One single-TCONV model, every batch identical: the scorer should
    // park the traffic on the modeled-fastest shard and ride the
    // resident filter set; round-robin reloads on every shard it visits.
    println!("\n== heterogeneous fleet (X8/UF16 + X4/UF32): same-layer traffic ==");
    let serve_fleet = |graphs: Vec<Arc<mm2im::model::graph::Graph>>,
                       traffic: &[(usize, u64)],
                       policy: PlacementPolicy| {
        let config = ServerConfig {
            workers_per_shard: 1,
            queue_capacity: traffic.len().max(1),
            max_batch: 4,
            shard_accels: hetero_fleet(),
            placement: policy,
            ..ServerConfig::default()
        };
        let mut server = Server::start_multi(graphs, config);
        server.pause();
        for &(graph, seed) in traffic {
            server.submit_to(graph, seed);
        }
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), traffic.len());
        stats
    };

    let same_layer: Vec<(usize, u64)> = (0..requests as u64).map(|s| (0, s)).collect();
    let fsrcnn = || Arc::new(zoo::fsrcnn(8, 0));
    let rr = serve_fleet(vec![fsrcnn()], &same_layer, PlacementPolicy::RoundRobin);
    print_fleet_stats(PlacementPolicy::RoundRobin, &rr);
    let scored_policy = PlacementPolicy::Modeled { tolerance: 0.0 };
    let scored = serve_fleet(vec![fsrcnn()], &same_layer, scored_policy);
    print_fleet_stats(scored_policy, &scored);
    assert!(
        scored.weight_loads < rr.weight_loads,
        "weight-aware placement must strictly reduce weight loads on same-layer \
         traffic: scored {} vs round-robin {}",
        scored.weight_loads,
        rr.weight_loads
    );
    println!(
        "scorer eliminates {} of {} round-robin weight loads",
        rr.weight_loads - scored.weight_loads,
        rr.weight_loads
    );

    // ---- heterogeneous fleet: mixed-model traffic --------------------------
    println!("\n== heterogeneous fleet: mixed DCGAN + pix2pix traffic ==");
    let traffic = mixed_traffic(2, requests, 42);
    for policy in [PlacementPolicy::RoundRobin, PlacementPolicy::Modeled { tolerance: 0.05 }] {
        let graphs = vec![Arc::new(zoo::dcgan_tf(0)), Arc::new(zoo::pix2pix(16, 4, 0))];
        let stats = serve_fleet(graphs, &traffic, policy);
        print_fleet_stats(policy, &stats);
    }
}
