//! Hot-path micro-benchmarks (criterion stand-in; the offline image has
//! no criterion crate — `util::timer` provides warmup + median timing).
//!
//! These measure *host* wall-clock of the L3 hot paths — the int8 GEMMs,
//! the map generation, the full simulator, and the fused-vs-scalar
//! execution engine matchup — for the §Perf optimization loop. Modeled
//! PYNQ latencies are unaffected by host speed.
//!
//! The engine section **asserts** (not eyeballs) that the fused
//! GEMM+col2IM engine beats the legacy scalar path on the large-`Ic`
//! Table-II layers, and the kernel-matrix section asserts the SIMD
//! GEMM kernel beats the forced-scalar oracle there too; record
//! refreshed numbers in docs/EXPERIMENTS.md §Perf.

use mm2im::accel::isa::OutMode;
use mm2im::accel::mapper::Mapper;
use mm2im::accel::{Accelerator, AccelConfig, ExecEngine};
use mm2im::cpu::{baseline, gemm};
use mm2im::driver::instructions::{build_layer_stream, compile_layer};
use mm2im::tconv::maps::OutputMap;
use mm2im::tconv::TconvProblem;
use mm2im::tensor::Tensor;
use mm2im::util::rng::Pcg32;
use mm2im::util::timer::bench_auto;

fn main() {
    let mut rng = Pcg32::new(1);

    // --- int8 GEMM (the CPU baseline's MatMul core) -------------------------
    for (m, n, k) in [(64usize, 6400usize, 512usize), (256, 1600, 128), (1024, 288, 64)] {
        let mut a = vec![0i8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        for threads in [1usize, 2] {
            let mut c = vec![0i32; m * n];
            let r = bench_auto(0.6, || {
                c.iter_mut().for_each(|v| *v = 0);
                gemm::gemm_i8_i32(m, n, k, &a, &b, &mut c, threads);
            });
            let gmacs = (m * n * k) as f64 / 1e9;
            println!(
                "gemm_i8 {m}x{n}x{k} t{threads}: {} -> {:.2} GMAC/s",
                r,
                gmacs / r.median_s
            );
        }
    }

    // --- map generation (Algorithm 2, software + hardware mirror) -----------
    let p = TconvProblem::square(128, 64, 3, 32, 2);
    let r = bench_auto(0.5, || OutputMap::build(&p));
    println!("OutputMap::build {p}: {r}");
    let mapper = Mapper::configure(&p);
    let cfg = AccelConfig::default();
    let r = bench_auto(0.5, || {
        let mut total = 0usize;
        for h in 0..p.oh() {
            for (ihr, kh) in mapper.contributing_rows(h) {
                total += mapper.row_maps(ihr, kh, &cfg).taps.len();
            }
        }
        total
    });
    println!("Mapper::row_maps full layer {p}: {r}");

    // --- CPU baseline TCONV end-to-end --------------------------------------
    let p = TconvProblem::square(16, 256, 5, 128, 2);
    let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
    let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
    let wm = baseline::pack_weight_matrix_i8(&p, &w);
    for threads in [1usize, 2, 4] {
        let r = bench_auto(1.0, || baseline::tconv_i32_prepacked(&p, &x, &wm, None, threads));
        let gmacs = p.macs() as f64 / 1e9;
        println!("cpu tconv {p} t{threads}: {} -> {:.2} GMAC/s", r, gmacs / r.median_s);
    }

    // --- full simulator throughput ------------------------------------------
    let p = TconvProblem::square(9, 128, 5, 32, 2);
    let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
    let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
    let stream = build_layer_stream(&p, &x, &w, &vec![0; p.oc], None, &cfg, OutMode::Raw32);
    let r = bench_auto(1.0, || {
        Accelerator::new(cfg.clone()).execute(&stream).unwrap().report.total_cycles
    });
    let sim_macs = p.macs() as f64 / 1e9;
    println!(
        "simulator {p}: {} -> {:.2} modeled-GMAC/s host throughput",
        r,
        sim_macs / r.median_s
    );

    // --- fused engine vs legacy scalar path (§Perf tentpole) ----------------
    // Persistent instances (serving steady state: weights resident after
    // the first stream, repack amortized away); identical zero-copy
    // streams; the only variable is the Schedule compute path. The
    // fused engine must be strictly faster on the large-Ic layers — the
    // regime the paper's speedup grows in (§V-B takeaway ii).
    println!();
    let scalar_cfg = AccelConfig { exec_engine: ExecEngine::Scalar, ..AccelConfig::default() };
    for (name, p) in [
        ("DCGAN_1 (Ic=1024)", TconvProblem::square(4, 1024, 5, 512, 2)),
        ("DCGAN_2 (Ic=512)", TconvProblem::square(8, 512, 5, 256, 2)),
        ("DCGAN_3 (Ic=256)", TconvProblem::square(16, 256, 5, 128, 2)),
        ("FSRCNN (Ic=32)", TconvProblem::square(32, 32, 9, 2, 2)),
    ] {
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let plan = compile_layer(&p, &w, &vec![0; p.oc], None, &cfg, OutMode::Raw32);
        let stream = plan.instantiate(&x);
        let mut fused_acc = Accelerator::new(cfg.clone());
        let fused = bench_auto(0.8, || {
            fused_acc.run_stream(&stream).unwrap().report.total_cycles
        });
        let mut scalar_acc = Accelerator::new(scalar_cfg.clone());
        let scalar = bench_auto(0.8, || {
            scalar_acc.run_stream(&stream).unwrap().report.total_cycles
        });
        let speedup = scalar.median_s / fused.median_s;
        println!(
            "engine {name} {p}: fused {:.3} ms vs scalar {:.3} ms -> {speedup:.2}x",
            fused.median_s * 1e3,
            scalar.median_s * 1e3,
        );
        if p.ic >= 256 {
            assert!(
                fused.median_s < scalar.median_s,
                "{name}: fused engine must beat the scalar path on Ic >= 256 \
                 (fused {:.4} ms vs scalar {:.4} ms)",
                fused.median_s * 1e3,
                scalar.median_s * 1e3,
            );
        }
    }

    // --- NT kernel matrix: scalar vs SIMD vs SIMD + threads (§Perf) ---------
    // Same Table-II layers, fused engine throughout; the variables are
    // the GEMM microkernel (forced-scalar oracle vs detected SIMD) and
    // the host lane count (1 vs auto; the pass-size gate is forced open
    // in the threaded leg so every pass exercises the fan-out — the
    // stride-2 zoo layers sit below the default gate). On a CPU with a
    // SIMD path, SIMD must be strictly faster than the scalar oracle
    // wherever Ic >= 256 — the regime where the dot products are long
    // enough for lane width to dominate (§V-B takeaway ii, host
    // edition). Record refreshed numbers in docs/EXPERIMENTS.md §Perf.
    println!();
    let detected = gemm::detect_kernel();
    let threaded_cfg =
        AccelConfig { host_threads: 0, host_parallel_min_macs: 0, ..AccelConfig::default() };
    println!(
        "NT kernel matrix (detected kernel: {detected}, auto threads: {})",
        threaded_cfg.resolved_host_threads()
    );
    for (name, p) in [
        ("DCGAN_1 (Ic=1024)", TconvProblem::square(4, 1024, 5, 512, 2)),
        ("DCGAN_2 (Ic=512)", TconvProblem::square(8, 512, 5, 256, 2)),
        ("DCGAN_3 (Ic=256)", TconvProblem::square(16, 256, 5, 128, 2)),
        ("FSRCNN (Ic=32)", TconvProblem::square(32, 32, 9, 2, 2)),
    ] {
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let plan = compile_layer(&p, &w, &vec![0; p.oc], None, &cfg, OutMode::Raw32);
        let stream = plan.instantiate(&x);

        gemm::force_nt_kernel(Some(gemm::GemmKernel::Scalar));
        let mut acc = Accelerator::new(cfg.clone());
        let scalar_k = bench_auto(0.6, || acc.run_stream(&stream).unwrap().report.total_cycles);

        gemm::force_nt_kernel(None);
        let mut acc = Accelerator::new(cfg.clone());
        let simd_k = bench_auto(0.6, || acc.run_stream(&stream).unwrap().report.total_cycles);

        let mut acc = Accelerator::new(threaded_cfg.clone());
        let simd_mt = bench_auto(0.6, || acc.run_stream(&stream).unwrap().report.total_cycles);

        println!(
            "kernel {name} {p}: scalar {:.3} ms | {detected} {:.3} ms ({:.2}x) | \
             {detected}+threads {:.3} ms ({:.2}x)",
            scalar_k.median_s * 1e3,
            simd_k.median_s * 1e3,
            scalar_k.median_s / simd_k.median_s,
            simd_mt.median_s * 1e3,
            scalar_k.median_s / simd_mt.median_s,
        );
        if p.ic >= 256 && detected != gemm::GemmKernel::Scalar {
            assert!(
                simd_k.median_s < scalar_k.median_s,
                "{name}: the {detected} kernel must beat the scalar oracle on Ic >= 256 \
                 ({detected} {:.4} ms vs scalar {:.4} ms)",
                simd_k.median_s * 1e3,
                scalar_k.median_s * 1e3,
            );
        }
    }
}
