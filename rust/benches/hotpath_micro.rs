//! Hot-path micro-benchmarks (criterion stand-in; the offline image has
//! no criterion crate — `util::timer` provides warmup + median timing).
//!
//! These measure *host* wall-clock of the three L3 hot paths — the int8
//! GEMM, the map generation, and the full simulator — for the §Perf
//! optimization loop. Modeled PYNQ latencies are unaffected by host speed.

use mm2im::accel::isa::OutMode;
use mm2im::accel::mapper::Mapper;
use mm2im::accel::{Accelerator, AccelConfig};
use mm2im::cpu::{baseline, gemm};
use mm2im::driver::instructions::build_layer_stream;
use mm2im::tconv::maps::OutputMap;
use mm2im::tconv::TconvProblem;
use mm2im::tensor::Tensor;
use mm2im::util::rng::Pcg32;
use mm2im::util::timer::bench_auto;

fn main() {
    let mut rng = Pcg32::new(1);

    // --- int8 GEMM (the CPU baseline's MatMul core) -------------------------
    for (m, n, k) in [(64usize, 6400usize, 512usize), (256, 1600, 128), (1024, 288, 64)] {
        let mut a = vec![0i8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_i8(&mut a);
        rng.fill_i8(&mut b);
        for threads in [1usize, 2] {
            let mut c = vec![0i32; m * n];
            let r = bench_auto(0.6, || {
                c.iter_mut().for_each(|v| *v = 0);
                gemm::gemm_i8_i32(m, n, k, &a, &b, &mut c, threads);
            });
            let gmacs = (m * n * k) as f64 / 1e9;
            println!(
                "gemm_i8 {m}x{n}x{k} t{threads}: {} -> {:.2} GMAC/s",
                r,
                gmacs / r.median_s
            );
        }
    }

    // --- map generation (Algorithm 2, software + hardware mirror) -----------
    let p = TconvProblem::square(128, 64, 3, 32, 2);
    let r = bench_auto(0.5, || OutputMap::build(&p));
    println!("OutputMap::build {p}: {r}");
    let mapper = Mapper::configure(&p);
    let cfg = AccelConfig::default();
    let r = bench_auto(0.5, || {
        let mut total = 0usize;
        for h in 0..p.oh() {
            for (ihr, kh) in mapper.contributing_rows(h) {
                total += mapper.row_maps(ihr, kh, &cfg).taps.len();
            }
        }
        total
    });
    println!("Mapper::row_maps full layer {p}: {r}");

    // --- CPU baseline TCONV end-to-end --------------------------------------
    let p = TconvProblem::square(16, 256, 5, 128, 2);
    let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
    let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
    let wm = baseline::pack_weight_matrix_i8(&p, &w);
    for threads in [1usize, 2, 4] {
        let r = bench_auto(1.0, || baseline::tconv_i32_prepacked(&p, &x, &wm, None, threads));
        let gmacs = p.macs() as f64 / 1e9;
        println!("cpu tconv {p} t{threads}: {} -> {:.2} GMAC/s", r, gmacs / r.median_s);
    }

    // --- full simulator throughput ------------------------------------------
    let p = TconvProblem::square(9, 128, 5, 32, 2);
    let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
    let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
    let stream = build_layer_stream(&p, &x, &w, &vec![0; p.oc], None, &cfg, OutMode::Raw32);
    let r = bench_auto(1.0, || {
        Accelerator::new(cfg.clone()).execute(&stream).unwrap().report.total_cycles
    });
    let sim_macs = p.macs() as f64 / 1e9;
    println!(
        "simulator {p}: {} -> {:.2} modeled-GMAC/s host throughput",
        r,
        sim_macs / r.median_s
    );
}
