//! Fig. 1 — Percentage of cropped outputs for the TCONV problems of
//! well-known generative models (the Table II layer set).
//!
//! Regenerates the figure's series as a table: drop rate per layer plus
//! the wasted-MAC count that motivates MM2IM.

use mm2im::model::zoo;
use mm2im::tconv::metrics::DropStats;
use mm2im::util::table::{pct, Table};

fn main() {
    let mut t = Table::new(
        "Fig. 1 — cropped outputs across generative-model TCONV layers",
        &["layer", "problem", "cropped %", "D_o", "wasted MACs"],
    );
    let mut max_rate: (f64, &str) = (0.0, "");
    for row in zoo::table2_layers() {
        let s = DropStats::compute(&row.problem);
        if s.d_r > max_rate.0 {
            max_rate = (s.d_r, row.name);
        }
        t.row(&[
            row.name.to_string(),
            row.problem.to_string(),
            pct(s.d_r),
            s.d_o.to_string(),
            s.skipped_macs.to_string(),
        ]);
    }
    t.print();
    println!("\nhighest drop rate: {} at {}", max_rate.1, pct(max_rate.0));
    println!("paper (§II-A): up to 28% ineffectual computation for DCGAN layers");
}
