//! Table IV — end-to-end GAN inference (DCGAN + pix2pix) across the four
//! configurations: CPU 1T, ACC+CPU 1T, CPU 2T, ACC+CPU 2T. One numerics
//! pass per model (accelerated, verified bit-exact against CPU-only),
//! then the Table IV rows are modeled from the per-layer records.
//!
//! pix2pix runs at 128x128 by default (SWEEP_SIZE=256 for the paper's
//! full resolution; numerics cost grows ~4x).

use mm2im::accel::AccelConfig;
use mm2im::driver::Delegate;
use mm2im::model::executor::{Executor, RunConfig};
use mm2im::model::zoo;
use mm2im::tensor::Tensor;
use mm2im::util::rng::Pcg32;
use mm2im::util::table::{f2, ms, Table};

fn paper_row(model: &str, config: &str) -> Option<(f64, f64, f64)> {
    // (TCONV ms, overall ms, energy J/pic) from Table IV.
    match (model, config) {
        ("dcgan", "CPU 1T") => Some((38.0, 49.0, 7.9)),
        ("dcgan", "ACC + CPU 1T") => Some((15.0, 21.0, 4.3)),
        ("dcgan", "CPU 2T") => Some((24.0, 28.0, 6.5)),
        ("dcgan", "ACC + CPU 2T") => Some((16.0, 20.0, 4.3)),
        ("pix2pix", "CPU 1T") => Some((2737.0, 5238.0, 9.8)),
        ("pix2pix", "ACC + CPU 1T") => Some((922.0, 3360.0, 7.9)),
        ("pix2pix", "CPU 2T") => Some((1532.0, 2886.0, 5.9)),
        ("pix2pix", "ACC + CPU 2T") => Some((926.0, 2266.0, 6.2)),
        _ => None,
    }
}

fn run_model(name: &str, g: &mm2im::model::Graph) {
    let cfg = AccelConfig::default();
    let mut rng = Pcg32::new(7);
    let input = Tensor::<i8>::random(&g.input_shape, &mut rng);

    // numerics: accelerated pass + CPU-only pass, must agree (§V-E)
    let acc_run = Executor::new(Delegate::new(cfg.clone(), 2, true)).run(g, &input);
    let cpu_run = Executor::new(Delegate::new(cfg.clone(), 1, false)).run(g, &input);
    assert_eq!(acc_run.output.data(), cpu_run.output.data(), "{name}: ACC != CPU");
    println!("{name}: accelerator output verified bit-exact against CPU baseline");

    let configs = [
        ("CPU 1T", RunConfig::Cpu { threads: 1 }),
        ("ACC + CPU 1T", RunConfig::AccPlusCpu { threads: 1 }),
        ("CPU 2T", RunConfig::Cpu { threads: 2 }),
        ("ACC + CPU 2T", RunConfig::AccPlusCpu { threads: 2 }),
    ];
    let base = acc_run.modeled(RunConfig::Cpu { threads: 1 }, &cfg);
    let mut t = Table::new(
        &format!("Table IV — {name} (ours, modeled PYNQ-Z1; paper values in parens)"),
        &["configuration", "TCONV ms", "x", "overall ms", "x", "energy J", "x", "paper (tconv/overall/J)"],
    );
    for (label, rc) in configs {
        let tb = acc_run.modeled(rc, &cfg);
        let paper = paper_row(name, label)
            .map(|(a, b, c)| format!("{a:.0} / {b:.0} / {c:.1}"))
            .unwrap_or_default();
        t.row(&[
            label.into(),
            ms(tb.tconv_s),
            f2(base.tconv_s / tb.tconv_s),
            ms(tb.total_s()),
            f2(base.total_s() / tb.total_s()),
            format!("{:.3}", tb.energy_j),
            f2(base.energy_j / tb.energy_j),
            paper,
        ]);
    }
    t.print();
}

fn main() {
    run_model("dcgan", &zoo::dcgan_tf(0));
    let size: usize = std::env::var("SWEEP_SIZE").ok().and_then(|s| s.parse().ok()).unwrap_or(128);
    run_model("pix2pix", &zoo::pix2pix(size, 64.min(size / 4), 0));
    println!("\npaper claims: up to 3x TCONV speedup, 2.4x overall, 2.4x energy reduction");
}
