//! Hierarchical live-introspection tree (mist-os Inspect-style) for the
//! serving stack.
//!
//! A [`Tree`] is a registry of metrics addressed by `/`-separated paths
//! (`fleet/shard/1/exec_failures`, `classes/High/served`, `cache/hits`,
//! `plans/0x1234/compiles`, …). Recording is lock-light: registration
//! returns a cheap cloneable handle ([`Counter`], [`Gauge`], [`Text`],
//! [`Histogram`], [`Ring`]) backed by atomics (or a tiny mutex for the
//! non-scalar kinds), so hot paths never touch the registry again.
//!
//! # Consistency
//!
//! Multi-metric invariants (the serving ledger `served + cancelled +
//! deadline_expired + failed + in_flight == submitted`) are kept
//! observable at *every* instant with a seqlock-style generation
//! counter, the same trick the Inspect VMO format uses: writers wrap a
//! group of updates in [`Tree::txn`], which bumps the generation to odd
//! before and even after; [`Tree::snapshot`] retries until it reads the
//! same even generation on both sides of its copy (and falls back to
//! briefly excluding writers after a bounded number of attempts).
//! Individual handle bumps outside a transaction are atomic but only
//! individually so — group anything that must be seen together.
//!
//! # Snapshots, queries, serialization
//!
//! [`Snapshot`] is an immutable copy: typed path queries
//! ([`Snapshot::counter`], [`Snapshot::gauge`], …) return
//! [`QueryError`] — never panic — on missing paths or kind mismatches;
//! [`Snapshot::diff`] compares two snapshots counter-by-counter; and
//! [`Snapshot::to_json`] / [`Snapshot::from_json`] give a stable
//! (sorted-key, canonically-numbered) JSON form that round-trips
//! byte-for-byte, which is what `serve --stats-json` writes and
//! `repro stats` reads back. Declarative health rules over snapshots
//! live in [`triage`].

pub mod triage;

use crate::util::json::Value;
use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Recover a mutex guard even if a previous holder panicked (telemetry
/// must stay readable while the coordinator is unwinding a worker).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            m.clear_poison();
            poisoned.into_inner()
        }
    }
}

/// A monotonically increasing `u64` metric.
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add `n` to the counter.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::SeqCst);
    }

    /// Add 1 to the counter.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::SeqCst)
    }
}

/// An `f64` metric that can move in either direction (stored as bits in
/// an atomic word; `add` is a CAS loop).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Set the gauge to `v`.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::SeqCst);
    }

    /// Add `v` (may be negative) to the gauge.
    pub fn add(&self, v: f64) {
        let _ = self.0.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |bits| {
            Some((f64::from_bits(bits) + v).to_bits())
        });
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::SeqCst))
    }
}

/// A small string metric (shard health labels, config fingerprints).
#[derive(Clone, Debug, Default)]
pub struct Text(Arc<Mutex<String>>);

impl Text {
    /// Replace the text.
    pub fn set(&self, v: impl Into<String>) {
        *lock(&self.0) = v.into();
    }

    /// Current text.
    pub fn get(&self) -> String {
        lock(&self.0).clone()
    }
}

/// Fixed-bucket histogram: `bounds[i]` is the inclusive upper edge of
/// bucket `i`, with one extra overflow bucket past the last bound.
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Arc<Vec<f64>>,
    counts: Arc<Vec<AtomicU64>>,
    sum: Gauge,
    count: Counter,
}

/// Default latency bucket upper edges, in seconds (half-decade steps
/// from 1 us to 10 s; an overflow bucket catches the rest).
pub const LATENCY_BUCKETS_S: [f64; 12] =
    [1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 10.0];

impl Histogram {
    fn new(bounds: &[f64]) -> Self {
        let counts = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Self {
            bounds: Arc::new(bounds.to_vec()),
            counts: Arc::new(counts),
            sum: Gauge::default(),
            count: Counter::default(),
        }
    }

    /// Record one observation.
    pub fn record(&self, v: f64) {
        let idx = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[idx].fetch_add(1, Ordering::SeqCst);
        self.sum.add(v);
        self.count.inc();
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.count.get()
    }

    fn snap(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            bounds: self.bounds.as_ref().clone(),
            counts: self.counts.iter().map(|c| c.load(Ordering::SeqCst)).collect(),
            sum: self.sum.get(),
            count: self.count.get(),
        }
    }
}

/// A bounded ring of structured samples ([`Value`]s): the latency
/// window the percentile projection reads, and the placement decision
/// log. Pushing past capacity evicts the oldest entry.
#[derive(Clone, Debug)]
pub struct Ring {
    cap: usize,
    items: Arc<Mutex<VecDeque<Value>>>,
}

impl Ring {
    fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), items: Arc::new(Mutex::new(VecDeque::new())) }
    }

    /// Append a sample, evicting the oldest once `cap` is reached.
    pub fn push(&self, v: Value) {
        let mut items = lock(&self.items);
        if items.len() == self.cap {
            items.pop_front();
        }
        items.push_back(v);
    }

    /// Samples currently held (oldest first).
    pub fn items(&self) -> Vec<Value> {
        lock(&self.items).iter().cloned().collect()
    }

    /// Capacity of the window.
    pub fn cap(&self) -> usize {
        self.cap
    }
}

/// One registered metric (the registry's value type; handles clone out).
#[derive(Clone, Debug)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Text(Text),
    Histogram(Histogram),
    Ring(Ring),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Text(_) => "text",
            Metric::Histogram(_) => "histogram",
            Metric::Ring(_) => "ring",
        }
    }
}

/// The metric tree. Share it as `Arc<Tree>`; every registration returns
/// a handle that records without touching the registry again.
#[derive(Debug, Default)]
pub struct Tree {
    registry: Mutex<BTreeMap<String, Metric>>,
    /// Seqlock generation: odd while a [`Tree::txn`] is applying.
    epoch: AtomicU64,
    /// Serializes transactions (and the snapshot fallback path).
    txn_lock: Mutex<()>,
}

/// Panics on structurally invalid paths (empty segments, a segment
/// named `type` — reserved by the JSON leaf encoding).
fn validate_path(path: &str) {
    assert!(!path.is_empty(), "telemetry path must not be empty");
    for seg in path.split('/') {
        assert!(!seg.is_empty(), "telemetry path {path:?} has an empty segment");
        assert!(seg != "type", "telemetry path {path:?} uses the reserved segment name 'type'");
    }
}

impl Tree {
    /// An empty tree.
    pub fn new() -> Self {
        Self::default()
    }

    fn register<F>(&self, path: &str, make: F) -> Metric
    where
        F: FnOnce() -> Metric,
    {
        validate_path(path);
        let mut reg = lock(&self.registry);
        if let Some(existing) = reg.get(path) {
            return existing.clone();
        }
        // A leaf cannot also be an interior node: reject registrations
        // where one path extends the other at a `/` boundary.
        for existing in reg.keys() {
            let conflict = existing.strip_prefix(path).is_some_and(|r| r.starts_with('/'))
                || path.strip_prefix(existing.as_str()).is_some_and(|r| r.starts_with('/'));
            assert!(!conflict, "telemetry path {path:?} conflicts with existing {existing:?}");
        }
        let metric = make();
        reg.insert(path.to_string(), metric.clone());
        metric
    }

    /// Register (or re-open) a counter at `path`.
    ///
    /// # Panics
    /// If `path` is already registered as a different metric kind, or
    /// structurally conflicts with an existing path.
    pub fn counter(&self, path: &str) -> Counter {
        match self.register(path, || Metric::Counter(Counter::default())) {
            Metric::Counter(c) => c,
            other => panic!("telemetry path {path:?} is a {}, not a counter", other.kind()),
        }
    }

    /// Register (or re-open) a gauge at `path` (panics on kind conflict).
    pub fn gauge(&self, path: &str) -> Gauge {
        match self.register(path, || Metric::Gauge(Gauge::default())) {
            Metric::Gauge(g) => g,
            other => panic!("telemetry path {path:?} is a {}, not a gauge", other.kind()),
        }
    }

    /// Register (or re-open) a text metric at `path` (panics on kind
    /// conflict).
    pub fn text(&self, path: &str) -> Text {
        match self.register(path, || Metric::Text(Text::default())) {
            Metric::Text(t) => t,
            other => panic!("telemetry path {path:?} is a {}, not text", other.kind()),
        }
    }

    /// Register (or re-open) a histogram at `path` with the given bucket
    /// upper edges (panics on kind conflict; `bounds` of an existing
    /// histogram are kept).
    pub fn histogram(&self, path: &str, bounds: &[f64]) -> Histogram {
        match self.register(path, || Metric::Histogram(Histogram::new(bounds))) {
            Metric::Histogram(h) => h,
            other => panic!("telemetry path {path:?} is a {}, not a histogram", other.kind()),
        }
    }

    /// Register (or re-open) a ring of capacity `cap` at `path` (panics
    /// on kind conflict; the capacity of an existing ring is kept).
    pub fn ring(&self, path: &str, cap: usize) -> Ring {
        match self.register(path, || Metric::Ring(Ring::new(cap))) {
            Metric::Ring(r) => r,
            other => panic!("telemetry path {path:?} is a {}, not a ring", other.kind()),
        }
    }

    /// A registration view rooted at `prefix` (purely a naming
    /// convenience — `tree.node("fleet/shard/0").counter("requests")`
    /// registers `fleet/shard/0/requests`).
    pub fn node(&self, prefix: &str) -> Node<'_> {
        validate_path(prefix);
        Node { tree: self, prefix: prefix.to_string() }
    }

    /// Run `f` as one observable transaction: no snapshot will ever see
    /// a strict subset of its updates.
    pub fn txn<R>(&self, f: impl FnOnce() -> R) -> R {
        let _guard = lock(&self.txn_lock);
        self.epoch.fetch_add(1, Ordering::SeqCst);
        let out = f();
        self.epoch.fetch_add(1, Ordering::SeqCst);
        out
    }

    fn read_all(&self) -> BTreeMap<String, SnapValue> {
        let reg = lock(&self.registry);
        reg.iter()
            .map(|(path, metric)| {
                let v = match metric {
                    Metric::Counter(c) => SnapValue::Counter(c.get()),
                    Metric::Gauge(g) => SnapValue::Gauge(g.get()),
                    Metric::Text(t) => SnapValue::Text(t.get()),
                    Metric::Histogram(h) => SnapValue::Histogram(h.snap()),
                    Metric::Ring(r) => SnapValue::Ring(r.items()),
                };
                (path.clone(), v)
            })
            .collect()
    }

    /// A consistent copy of every metric: retries the seqlock read until
    /// a stable even generation brackets the copy, then (after a bounded
    /// number of attempts under heavy write pressure) briefly excludes
    /// transactions and reads directly. Never blocks metric recording
    /// outside transactions.
    pub fn snapshot(&self) -> Snapshot {
        for _ in 0..64 {
            let before = self.epoch.load(Ordering::SeqCst);
            if before % 2 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let values = self.read_all();
            let after = self.epoch.load(Ordering::SeqCst);
            if before == after {
                return Snapshot { epoch: after, values };
            }
        }
        let _guard = lock(&self.txn_lock);
        Snapshot { epoch: self.epoch.load(Ordering::SeqCst), values: self.read_all() }
    }
}

/// Registration view rooted at a path prefix — see [`Tree::node`].
pub struct Node<'a> {
    tree: &'a Tree,
    prefix: String,
}

impl Node<'_> {
    fn path(&self, name: &str) -> String {
        format!("{}/{name}", self.prefix)
    }

    /// A child view one level deeper.
    pub fn child(&self, name: &str) -> Node<'_> {
        Node { tree: self.tree, prefix: self.path(name) }
    }

    /// Register a counter under this node.
    pub fn counter(&self, name: &str) -> Counter {
        self.tree.counter(&self.path(name))
    }

    /// Register a gauge under this node.
    pub fn gauge(&self, name: &str) -> Gauge {
        self.tree.gauge(&self.path(name))
    }

    /// Register a text metric under this node.
    pub fn text(&self, name: &str) -> Text {
        self.tree.text(&self.path(name))
    }

    /// Register a histogram under this node.
    pub fn histogram(&self, name: &str, bounds: &[f64]) -> Histogram {
        self.tree.histogram(&self.path(name), bounds)
    }

    /// Register a ring under this node.
    pub fn ring(&self, name: &str, cap: usize) -> Ring {
        self.tree.ring(&self.path(name), cap)
    }
}

/// Frozen histogram contents inside a [`Snapshot`].
#[derive(Clone, Debug, PartialEq)]
pub struct HistogramSnapshot {
    /// Inclusive bucket upper edges.
    pub bounds: Vec<f64>,
    /// Per-bucket counts; one longer than `bounds` (overflow last).
    pub counts: Vec<u64>,
    /// Sum of all recorded observations.
    pub sum: f64,
    /// Number of observations.
    pub count: u64,
}

/// One frozen metric value inside a [`Snapshot`].
#[derive(Clone, Debug, PartialEq)]
pub enum SnapValue {
    /// A [`Counter`] reading.
    Counter(u64),
    /// A [`Gauge`] reading.
    Gauge(f64),
    /// A [`Text`] reading.
    Text(String),
    /// A [`Histogram`] reading.
    Histogram(HistogramSnapshot),
    /// A [`Ring`] reading (oldest first).
    Ring(Vec<Value>),
}

impl SnapValue {
    /// The metric kind name (matches the JSON `type` tag).
    pub fn kind(&self) -> &'static str {
        match self {
            SnapValue::Counter(_) => "counter",
            SnapValue::Gauge(_) => "gauge",
            SnapValue::Text(_) => "text",
            SnapValue::Histogram(_) => "histogram",
            SnapValue::Ring(_) => "ring",
        }
    }
}

/// A typed path-query failure — the error side of every [`Snapshot`]
/// accessor (queries never panic).
#[derive(Clone, Debug, PartialEq)]
pub enum QueryError {
    /// No metric is registered at the path.
    Missing(String),
    /// The path exists but holds a different metric kind.
    Kind {
        /// The queried path.
        path: String,
        /// The kind the accessor wanted.
        want: &'static str,
        /// The kind actually registered.
        got: &'static str,
    },
}

impl std::fmt::Display for QueryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueryError::Missing(path) => write!(f, "no metric at {path:?}"),
            QueryError::Kind { path, want, got } => {
                write!(f, "metric at {path:?} is a {got}, not a {want}")
            }
        }
    }
}

impl std::error::Error for QueryError {}

/// The earlier/later readings of one counter across a
/// [`Snapshot::diff`].
#[derive(Clone, Debug, PartialEq)]
pub struct CounterDelta {
    /// Counter path.
    pub path: String,
    /// Reading in the earlier snapshot.
    pub earlier: u64,
    /// Reading in the later snapshot.
    pub later: u64,
}

impl CounterDelta {
    /// `later - earlier` (negative only if the counter contract was
    /// violated — [`Snapshot::diff`] monotonicity tests pin this ≥ 0).
    pub fn delta(&self) -> i128 {
        self.later as i128 - self.earlier as i128
    }
}

/// An immutable, internally consistent copy of a [`Tree`].
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    epoch: u64,
    values: BTreeMap<String, SnapValue>,
}

impl Snapshot {
    /// The seqlock generation the snapshot was taken at.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// All `(path, value)` pairs in path order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &SnapValue)> {
        self.values.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// The value at `path`, whatever its kind.
    pub fn get(&self, path: &str) -> Result<&SnapValue, QueryError> {
        self.values.get(path).ok_or_else(|| QueryError::Missing(path.to_string()))
    }

    fn kinded<T>(
        &self,
        path: &str,
        want: &'static str,
        extract: impl Fn(&SnapValue) -> Option<T>,
    ) -> Result<T, QueryError> {
        let v = self.get(path)?;
        extract(v).ok_or_else(|| QueryError::Kind { path: path.to_string(), want, got: v.kind() })
    }

    /// The counter at `path`.
    pub fn counter(&self, path: &str) -> Result<u64, QueryError> {
        self.kinded(path, "counter", |v| match v {
            SnapValue::Counter(c) => Some(*c),
            _ => None,
        })
    }

    /// The gauge at `path`.
    pub fn gauge(&self, path: &str) -> Result<f64, QueryError> {
        self.kinded(path, "gauge", |v| match v {
            SnapValue::Gauge(g) => Some(*g),
            _ => None,
        })
    }

    /// The text at `path`.
    pub fn text(&self, path: &str) -> Result<String, QueryError> {
        self.kinded(path, "text", |v| match v {
            SnapValue::Text(t) => Some(t.clone()),
            _ => None,
        })
    }

    /// The histogram at `path`.
    pub fn histogram(&self, path: &str) -> Result<HistogramSnapshot, QueryError> {
        self.kinded(path, "histogram", |v| match v {
            SnapValue::Histogram(h) => Some(h.clone()),
            _ => None,
        })
    }

    /// The ring contents at `path` (oldest first).
    pub fn ring(&self, path: &str) -> Result<Vec<Value>, QueryError> {
        self.kinded(path, "ring", |v| match v {
            SnapValue::Ring(r) => Some(r.clone()),
            _ => None,
        })
    }

    /// The path as a number: counters widen to `f64`, gauges read
    /// directly. This is the accessor [`triage`] expressions use.
    pub fn num(&self, path: &str) -> Result<f64, QueryError> {
        let v = self.get(path)?;
        match v {
            SnapValue::Counter(c) => Ok(*c as f64),
            SnapValue::Gauge(g) => Ok(*g),
            other => Err(QueryError::Kind {
                path: path.to_string(),
                want: "counter or gauge",
                got: other.kind(),
            }),
        }
    }

    /// Per-counter readings across two snapshots of the same tree, for
    /// every path that is a counter in both (path order).
    pub fn diff(&self, earlier: &Snapshot) -> Vec<CounterDelta> {
        self.values
            .iter()
            .filter_map(|(path, v)| match (v, earlier.values.get(path)) {
                (SnapValue::Counter(later), Some(SnapValue::Counter(e))) => Some(CounterDelta {
                    path: path.clone(),
                    earlier: *e,
                    later: *later,
                }),
                _ => None,
            })
            .collect()
    }

    fn leaf_json(v: &SnapValue) -> Value {
        let mut obj = BTreeMap::new();
        obj.insert("type".to_string(), Value::Str(v.kind().to_string()));
        match v {
            SnapValue::Counter(c) => {
                obj.insert("value".to_string(), Value::Num(*c as f64));
            }
            SnapValue::Gauge(g) => {
                obj.insert("value".to_string(), Value::Num(*g));
            }
            SnapValue::Text(t) => {
                obj.insert("value".to_string(), Value::Str(t.clone()));
            }
            SnapValue::Histogram(h) => {
                let bounds = h.bounds.iter().map(|&b| Value::Num(b)).collect();
                let counts = h.counts.iter().map(|&c| Value::Num(c as f64)).collect();
                obj.insert("bounds".to_string(), Value::Arr(bounds));
                obj.insert("counts".to_string(), Value::Arr(counts));
                obj.insert("sum".to_string(), Value::Num(h.sum));
                obj.insert("count".to_string(), Value::Num(h.count as f64));
            }
            SnapValue::Ring(items) => {
                obj.insert("items".to_string(), Value::Arr(items.clone()));
            }
        }
        Value::Obj(obj)
    }

    /// The snapshot as a [`Value`] tree: `{"epoch": N, "tree": {...}}`
    /// with one nested object per path segment and type-tagged leaves.
    pub fn to_value(&self) -> Value {
        let mut root: BTreeMap<String, Value> = BTreeMap::new();
        for (path, v) in &self.values {
            let mut segs: Vec<&str> = path.split('/').collect();
            let leaf_name = segs.pop().expect("validated non-empty path");
            let mut cursor = &mut root;
            for seg in segs {
                let entry = cursor
                    .entry(seg.to_string())
                    .or_insert_with(|| Value::Obj(BTreeMap::new()));
                cursor = match entry {
                    Value::Obj(m) => m,
                    _ => unreachable!("registration rejects leaf/node path conflicts"),
                };
            }
            cursor.insert(leaf_name.to_string(), Self::leaf_json(v));
        }
        let mut top = BTreeMap::new();
        top.insert("epoch".to_string(), Value::Num(self.epoch as f64));
        top.insert("tree".to_string(), Value::Obj(root));
        Value::Obj(top)
    }

    /// Stable JSON: sorted keys, canonical number formatting — the same
    /// input always serializes to the same bytes, and
    /// `from_json(to_json(s)).to_json() == to_json(s)`.
    pub fn to_json(&self) -> String {
        self.to_value().to_json()
    }

    fn leaf_from_json(
        path: &str,
        kind: &str,
        obj: &BTreeMap<String, Value>,
    ) -> Result<SnapValue, String> {
        let field = |name: &str| {
            obj.get(name).ok_or_else(|| format!("{path}: {kind} leaf missing {name:?}"))
        };
        let num = |name: &str| {
            field(name)?.as_f64().ok_or_else(|| format!("{path}: {name:?} must be a number"))
        };
        match kind {
            "counter" => Ok(SnapValue::Counter(num("value")? as u64)),
            "gauge" => Ok(SnapValue::Gauge(num("value")?)),
            "text" => match field("value")? {
                Value::Str(s) => Ok(SnapValue::Text(s.clone())),
                _ => Err(format!("{path}: text value must be a string")),
            },
            "histogram" => {
                let nums = |name: &str| -> Result<Vec<f64>, String> {
                    match field(name)? {
                        Value::Arr(a) => a
                            .iter()
                            .map(|v| {
                                v.as_f64().ok_or_else(|| format!("{path}: non-numeric {name}"))
                            })
                            .collect(),
                        _ => Err(format!("{path}: {name:?} must be an array")),
                    }
                };
                Ok(SnapValue::Histogram(HistogramSnapshot {
                    bounds: nums("bounds")?,
                    counts: nums("counts")?.into_iter().map(|c| c as u64).collect(),
                    sum: num("sum")?,
                    count: num("count")? as u64,
                }))
            }
            "ring" => match field("items")? {
                Value::Arr(items) => Ok(SnapValue::Ring(items.clone())),
                _ => Err(format!("{path}: ring items must be an array")),
            },
            other => Err(format!("{path}: unknown metric kind {other:?}")),
        }
    }

    fn walk(
        prefix: &str,
        obj: &BTreeMap<String, Value>,
        out: &mut BTreeMap<String, SnapValue>,
    ) -> Result<(), String> {
        for (name, v) in obj {
            let path = if prefix.is_empty() { name.clone() } else { format!("{prefix}/{name}") };
            match v {
                Value::Obj(m) => match m.get("type").and_then(Value::as_str) {
                    Some(kind) => {
                        out.insert(path.clone(), Self::leaf_from_json(&path, kind, m)?);
                    }
                    None => Self::walk(&path, m, out)?,
                },
                _ => return Err(format!("{path}: expected an object")),
            }
        }
        Ok(())
    }

    /// Parse a snapshot dump produced by [`Snapshot::to_json`].
    pub fn from_json(s: &str) -> Result<Snapshot, String> {
        let top = crate::util::json::parse(s).map_err(|e| e.to_string())?;
        let top = match &top {
            Value::Obj(m) => m,
            _ => return Err("snapshot dump must be a JSON object".to_string()),
        };
        let epoch = top
            .get("epoch")
            .and_then(Value::as_f64)
            .ok_or_else(|| "snapshot dump missing numeric \"epoch\"".to_string())?
            as u64;
        let tree = match top.get("tree") {
            Some(Value::Obj(m)) => m,
            _ => return Err("snapshot dump missing \"tree\" object".to_string()),
        };
        let mut values = BTreeMap::new();
        Self::walk("", tree, &mut values)?;
        Ok(Snapshot { epoch, values })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn handles_record_and_snapshot_reads() {
        let tree = Tree::new();
        let c = tree.counter("fleet/served");
        let g = tree.gauge("fleet/uptime_s");
        let t = tree.text("fleet/shard/0/health");
        let h = tree.histogram("fleet/latency_hist", &LATENCY_BUCKETS_S);
        let r = tree.ring("fleet/latency_window", 4);
        c.add(3);
        g.set(1.5);
        t.set("healthy");
        h.record(2e-4);
        r.push(Value::Num(0.25));

        let snap = tree.snapshot();
        assert_eq!(snap.counter("fleet/served"), Ok(3));
        assert_eq!(snap.gauge("fleet/uptime_s"), Ok(1.5));
        assert_eq!(snap.text("fleet/shard/0/health"), Ok("healthy".to_string()));
        let hist = snap.histogram("fleet/latency_hist").unwrap();
        assert_eq!(hist.count, 1);
        assert_eq!(hist.counts.iter().sum::<u64>(), 1);
        assert_eq!(snap.ring("fleet/latency_window").unwrap(), vec![Value::Num(0.25)]);

        // Re-opening a path returns the same underlying metric.
        tree.counter("fleet/served").inc();
        assert_eq!(tree.snapshot().counter("fleet/served"), Ok(4));
    }

    #[test]
    fn path_queries_fail_typed_never_panic() {
        let tree = Tree::new();
        tree.counter("fleet/served");
        let snap = tree.snapshot();
        assert_eq!(snap.counter("fleet/nope"), Err(QueryError::Missing("fleet/nope".into())));
        assert_eq!(
            snap.gauge("fleet/served"),
            Err(QueryError::Kind { path: "fleet/served".into(), want: "gauge", got: "counter" })
        );
        assert_eq!(snap.num("fleet/served"), Ok(0.0), "counters widen to f64");
        assert_eq!(snap.num("fleet/nope"), Err(QueryError::Missing("fleet/nope".into())));
    }

    #[test]
    #[should_panic(expected = "conflicts")]
    fn leaf_cannot_shadow_interior_node() {
        let tree = Tree::new();
        tree.counter("fleet/shard/0/requests");
        tree.counter("fleet/shard");
    }

    #[test]
    #[should_panic(expected = "not a counter")]
    fn kind_conflicts_panic_at_registration() {
        let tree = Tree::new();
        tree.gauge("fleet/uptime_s");
        tree.counter("fleet/uptime_s");
    }

    #[test]
    fn ring_evicts_oldest_at_capacity() {
        let tree = Tree::new();
        let r = tree.ring("window", 3);
        for i in 0..5 {
            r.push(Value::Num(i as f64));
        }
        let items = tree.snapshot().ring("window").unwrap();
        assert_eq!(items, vec![Value::Num(2.0), Value::Num(3.0), Value::Num(4.0)]);
    }

    #[test]
    fn diff_reports_counter_deltas() {
        let tree = Tree::new();
        let a = tree.counter("a");
        let b = tree.counter("b");
        let first = tree.snapshot();
        a.add(2);
        b.add(5);
        let second = tree.snapshot();
        let deltas = second.diff(&first);
        assert_eq!(deltas.len(), 2);
        assert_eq!(deltas[0].path, "a");
        assert_eq!(deltas[0].delta(), 2);
        assert_eq!(deltas[1].delta(), 5);
        assert!(deltas.iter().all(|d| d.delta() >= 0), "counters are monotone");
    }

    /// The seqlock contract: a snapshot taken while a writer thread is
    /// moving value between two counters inside `txn` never observes a
    /// half-applied transfer.
    #[test]
    fn snapshots_never_observe_partial_transactions() {
        let tree = Arc::new(Tree::new());
        let a = tree.counter("ledger/a");
        let b = tree.counter("ledger/b");
        let stop = Arc::new(AtomicBool::new(false));
        let writer = {
            let tree = Arc::clone(&tree);
            let (a, b, stop) = (a.clone(), b.clone(), Arc::clone(&stop));
            std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    // Both sides move together: a + b stays even.
                    tree.txn(|| {
                        a.inc();
                        b.inc();
                    });
                }
            })
        };
        for _ in 0..500 {
            let snap = tree.snapshot();
            let sum = snap.counter("ledger/a").unwrap() + snap.counter("ledger/b").unwrap();
            assert_eq!(sum % 2, 0, "observed a torn transaction");
        }
        stop.store(true, Ordering::SeqCst);
        writer.join().unwrap();
    }

    #[test]
    fn json_round_trip_is_stable() {
        let tree = Tree::new();
        tree.counter("fleet/served").add(7);
        tree.gauge("fleet/uptime_s").set(0.125);
        tree.gauge("fleet/tiny").set(1e-7);
        tree.text("fleet/shard/0/config_fp").set("0x00ab");
        tree.histogram("fleet/latency_hist", &[1e-3, 1.0]).record(0.5);
        let ring = tree.ring("fleet/placements", 8);
        let mut entry = BTreeMap::new();
        entry.insert("shard".to_string(), Value::Num(1.0));
        entry.insert("hit".to_string(), Value::Bool(true));
        ring.push(Value::Obj(entry));

        let snap = tree.snapshot();
        let json = snap.to_json();
        let parsed = Snapshot::from_json(&json).expect("round trip parses");
        assert_eq!(parsed, snap);
        assert_eq!(parsed.to_json(), json, "serialization must be stable");
        // Serialization is deterministic call to call.
        assert_eq!(tree.snapshot().to_json(), json);
    }

    #[test]
    fn from_json_rejects_malformed_dumps() {
        assert!(Snapshot::from_json("[]").is_err());
        assert!(Snapshot::from_json("{\"epoch\":1}").is_err());
        assert!(Snapshot::from_json("{\"epoch\":1,\"tree\":{\"x\":{\"type\":\"nope\"}}}").is_err());
        assert!(Snapshot::from_json("not json").is_err());
    }
}
