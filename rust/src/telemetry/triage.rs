//! Declarative health rules over telemetry snapshots (mist-os
//! triage-style).
//!
//! A [`Rule`] is a named boolean expression over [`Snapshot`] paths
//! that *fires* when the expression is true — rules state the unhealthy
//! condition, so a quiet report is a healthy fleet. The grammar (full
//! table in `docs/architecture.md`):
//!
//! ```text
//! expr  := and ( "||" and )*
//! and   := cmp ( "&&" cmp )*
//! cmp   := sum ( ("==" | "!=" | "<=" | ">=" | "<" | ">") sum )?
//! sum   := prod ( ("+" | "-") prod )*
//! prod  := atom ( ("*" | "/") atom )*
//! atom  := number | path | "(" expr ")"
//! ```
//!
//! Paths (`fleet/served`, `cache/hits`, …) read counters and gauges via
//! [`Snapshot::num`]; booleans are 1.0/0.0; division by zero evaluates
//! to 0 so rate rules degrade gracefully on empty denominators. Because
//! `/` also separates path segments, surround the *division* operator
//! with spaces (`a / b`), as every example here does. A rule
//! whose expression names a path the snapshot does not carry reports
//! [`Verdict::Missing`] — typed, never a panic, and never silently
//! "passing" ([`Report::worst`] treats it as a `Warning`).
//!
//! [`default_rules`] ships the serving invariants: the exactly-once
//! ledger (always-on, `Error`), quarantined-majority (`Error`), and
//! queue-saturation (`Warning`).

use super::{QueryError, Snapshot};
use std::fmt;

/// How bad a fired rule is.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Degraded but serving.
    Warning,
    /// An invariant is broken or the fleet is effectively down.
    Error,
}

impl Severity {
    /// Lowercase label (`warning` / `error`).
    pub fn label(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// A named, parsed health rule. Build with [`Rule::new`]; evaluate a
/// batch with [`evaluate`].
#[derive(Clone, Debug)]
pub struct Rule {
    name: String,
    expr_src: String,
    expr: Expr,
    severity: Severity,
    note: String,
}

impl Rule {
    /// Parse `expr` and build a rule that fires (at `severity`) when it
    /// evaluates true. `note` is the operator-facing explanation.
    pub fn new(
        name: impl Into<String>,
        expr: &str,
        severity: Severity,
        note: impl Into<String>,
    ) -> Result<Self, String> {
        Ok(Self {
            name: name.into(),
            expr_src: expr.to_string(),
            expr: parse_expr(expr)?,
            severity,
            note: note.into(),
        })
    }

    /// The rule's name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The source text of the expression.
    pub fn expr(&self) -> &str {
        &self.expr_src
    }

    /// The severity the rule fires at.
    pub fn severity(&self) -> Severity {
        self.severity
    }

    /// The operator-facing explanation.
    pub fn note(&self) -> &str {
        &self.note
    }
}

/// The outcome of evaluating one rule against one snapshot.
#[derive(Clone, Debug, PartialEq)]
pub enum Verdict {
    /// The unhealthy condition is absent.
    Pass,
    /// The rule fired: the condition holds, at the rule's severity.
    Fire,
    /// The expression named a path the snapshot does not carry (or of a
    /// non-numeric kind) — reported, not panicked.
    Missing(String),
}

/// One rule's evaluation inside a [`Report`].
#[derive(Clone, Debug)]
pub struct Evaluation {
    /// Rule name.
    pub name: String,
    /// Rule severity (applies when the verdict is [`Verdict::Fire`]).
    pub severity: Severity,
    /// What happened.
    pub verdict: Verdict,
    /// The rule's explanation (from [`Rule::note`]).
    pub note: String,
}

/// The result of [`evaluate`]: per-rule verdicts plus rollups.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// One entry per rule, in input order.
    pub evaluations: Vec<Evaluation>,
}

impl Report {
    /// The most severe problem in the report: `Error` if any error-level
    /// rule fired, else `Warning` if a warning fired *or any rule could
    /// not be evaluated*, else `None` (healthy).
    pub fn worst(&self) -> Option<Severity> {
        let mut worst = None;
        for e in &self.evaluations {
            let sev = match &e.verdict {
                Verdict::Pass => continue,
                Verdict::Fire => e.severity,
                Verdict::Missing(_) => Severity::Warning,
            };
            worst = Some(worst.map_or(sev, |w: Severity| w.max(sev)));
        }
        worst
    }

    /// True when no rule fired and every rule evaluated.
    pub fn healthy(&self) -> bool {
        self.worst().is_none()
    }
}

impl fmt::Display for Report {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for e in &self.evaluations {
            let (tag, detail) = match &e.verdict {
                Verdict::Pass => ("ok   ", String::new()),
                Verdict::Fire => (
                    match e.severity {
                        Severity::Warning => "WARN ",
                        Severity::Error => "ERROR",
                    },
                    format!(" — {}", e.note),
                ),
                Verdict::Missing(path) => ("MISS ", format!(" — no metric at {path:?}")),
            };
            writeln!(f, "[{tag}] {}{detail}", e.name)?;
        }
        Ok(())
    }
}

/// Evaluate every rule against `snap`.
pub fn evaluate(rules: &[Rule], snap: &Snapshot) -> Report {
    let evaluations = rules
        .iter()
        .map(|r| {
            let verdict = match r.expr.eval(snap) {
                Ok(v) => {
                    if v != 0.0 {
                        Verdict::Fire
                    } else {
                        Verdict::Pass
                    }
                }
                Err(QueryError::Missing(path)) => Verdict::Missing(path),
                Err(QueryError::Kind { path, .. }) => Verdict::Missing(path),
            };
            Evaluation {
                name: r.name.clone(),
                severity: r.severity,
                verdict,
                note: r.note.clone(),
            }
        })
        .collect();
    Report { evaluations }
}

/// The serving stack's built-in rules. The ledger identity is the
/// always-on invariant: with the live `fleet/in_flight` gauge in the
/// sum it must hold on *every* snapshot, mid-serve included, and at
/// quiescence (`in_flight == 0`) it reduces to the four-term form
/// `served + cancelled + deadline_expired + failed == submitted`.
pub fn default_rules() -> Vec<Rule> {
    vec![
        Rule::new(
            "ledger_identity",
            "fleet/served + fleet/cancelled + fleet/deadline_expired + fleet/failed \
             + fleet/in_flight != fleet/submitted",
            Severity::Error,
            "exactly-once ledger out of balance: some request resolved zero or twice",
        )
        .expect("built-in rule parses"),
        Rule::new(
            "quarantined_majority",
            "fleet/quarantined_now / fleet/shards > 0.5",
            Severity::Error,
            "more than half the fleet is quarantined",
        )
        .expect("built-in rule parses"),
        Rule::new(
            "queue_saturation",
            "fleet/queue_full / (fleet/submitted + fleet/queue_full) > 0.2",
            Severity::Warning,
            "over 20% of non-blocking submissions bounced off a full queue",
        )
        .expect("built-in rule parses"),
    ]
}

// ---------------------------------------------------------------------------
// Expression parser/evaluator.

#[derive(Clone, Debug)]
enum Expr {
    Num(f64),
    Path(String),
    Binary(Op, Box<Expr>, Box<Expr>),
}

#[derive(Clone, Copy, Debug)]
enum Op {
    Or,
    And,
    Eq,
    Ne,
    Le,
    Ge,
    Lt,
    Gt,
    Add,
    Sub,
    Mul,
    Div,
}

impl Expr {
    fn eval(&self, snap: &Snapshot) -> Result<f64, QueryError> {
        Ok(match self {
            Expr::Num(n) => *n,
            Expr::Path(p) => snap.num(p)?,
            Expr::Binary(op, l, r) => {
                let (l, r) = (l.eval(snap)?, r.eval(snap)?);
                let b = |cond: bool| {
                    if cond {
                        1.0
                    } else {
                        0.0
                    }
                };
                match op {
                    Op::Or => b(l != 0.0 || r != 0.0),
                    Op::And => b(l != 0.0 && r != 0.0),
                    Op::Eq => b(l == r),
                    Op::Ne => b(l != r),
                    Op::Le => b(l <= r),
                    Op::Ge => b(l >= r),
                    Op::Lt => b(l < r),
                    Op::Gt => b(l > r),
                    Op::Add => l + r,
                    Op::Sub => l - r,
                    Op::Mul => l * r,
                    // Rate rules over empty denominators read as 0, not
                    // inf/NaN (documented in the module grammar).
                    Op::Div => {
                        if r == 0.0 {
                            0.0
                        } else {
                            l / r
                        }
                    }
                }
            }
        })
    }
}

#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Num(f64),
    Path(String),
    Op(&'static str),
    LParen,
    RParen,
}

fn tokenize(src: &str) -> Result<Vec<Tok>, String> {
    let b = src.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match c {
            b' ' | b'\t' | b'\n' => i += 1,
            b'(' => {
                toks.push(Tok::LParen);
                i += 1;
            }
            b')' => {
                toks.push(Tok::RParen);
                i += 1;
            }
            b'|' | b'&' | b'=' | b'!' | b'<' | b'>' => {
                let two = &src[i..(i + 2).min(src.len())];
                let op = match two {
                    "||" | "&&" | "==" | "!=" | "<=" | ">=" => two,
                    _ if c == b'<' => "<",
                    _ if c == b'>' => ">",
                    _ => return Err(format!("bad operator at byte {i} in {src:?}")),
                };
                toks.push(Tok::Op(match op {
                    "||" => "||",
                    "&&" => "&&",
                    "==" => "==",
                    "!=" => "!=",
                    "<=" => "<=",
                    ">=" => ">=",
                    "<" => "<",
                    _ => ">",
                }));
                i += op.len();
            }
            b'+' | b'-' | b'*' | b'/' => {
                toks.push(Tok::Op(match c {
                    b'+' => "+",
                    b'-' => "-",
                    b'*' => "*",
                    _ => "/",
                }));
                i += 1;
            }
            b'0'..=b'9' | b'.' => {
                let start = i;
                while i < b.len() && matches!(b[i], b'0'..=b'9' | b'.' | b'e' | b'E') {
                    i += 1;
                }
                let text = &src[start..i];
                toks.push(Tok::Num(
                    text.parse::<f64>().map_err(|_| format!("bad number {text:?}"))?,
                ));
            }
            c if c.is_ascii_alphabetic() || c == b'_' => {
                let start = i;
                while i < b.len()
                    && (b[i].is_ascii_alphanumeric() || matches!(b[i], b'_' | b'/' | b'.' | b'-'))
                {
                    i += 1;
                }
                toks.push(Tok::Path(src[start..i].to_string()));
            }
            _ => return Err(format!("unexpected byte {:?} at {i} in {src:?}", c as char)),
        }
    }
    Ok(toks)
}

struct RuleParser {
    toks: Vec<Tok>,
    i: usize,
}

impl RuleParser {
    fn peek_op(&self) -> Option<&'static str> {
        match self.toks.get(self.i) {
            Some(Tok::Op(o)) => Some(o),
            _ => None,
        }
    }

    fn or(&mut self) -> Result<Expr, String> {
        let mut e = self.and()?;
        while self.peek_op() == Some("||") {
            self.i += 1;
            e = Expr::Binary(Op::Or, Box::new(e), Box::new(self.and()?));
        }
        Ok(e)
    }

    fn and(&mut self) -> Result<Expr, String> {
        let mut e = self.cmp()?;
        while self.peek_op() == Some("&&") {
            self.i += 1;
            e = Expr::Binary(Op::And, Box::new(e), Box::new(self.cmp()?));
        }
        Ok(e)
    }

    fn cmp(&mut self) -> Result<Expr, String> {
        let e = self.sum()?;
        let op = match self.peek_op() {
            Some("==") => Op::Eq,
            Some("!=") => Op::Ne,
            Some("<=") => Op::Le,
            Some(">=") => Op::Ge,
            Some("<") => Op::Lt,
            Some(">") => Op::Gt,
            _ => return Ok(e),
        };
        self.i += 1;
        Ok(Expr::Binary(op, Box::new(e), Box::new(self.sum()?)))
    }

    fn sum(&mut self) -> Result<Expr, String> {
        let mut e = self.prod()?;
        loop {
            let op = match self.peek_op() {
                Some("+") => Op::Add,
                Some("-") => Op::Sub,
                _ => return Ok(e),
            };
            self.i += 1;
            e = Expr::Binary(op, Box::new(e), Box::new(self.prod()?));
        }
    }

    fn prod(&mut self) -> Result<Expr, String> {
        let mut e = self.atom()?;
        loop {
            let op = match self.peek_op() {
                Some("*") => Op::Mul,
                Some("/") => Op::Div,
                _ => return Ok(e),
            };
            self.i += 1;
            e = Expr::Binary(op, Box::new(e), Box::new(self.atom()?));
        }
    }

    fn atom(&mut self) -> Result<Expr, String> {
        match self.toks.get(self.i).cloned() {
            Some(Tok::Num(n)) => {
                self.i += 1;
                Ok(Expr::Num(n))
            }
            Some(Tok::Path(p)) => {
                self.i += 1;
                Ok(Expr::Path(p))
            }
            Some(Tok::LParen) => {
                self.i += 1;
                let e = self.or()?;
                match self.toks.get(self.i) {
                    Some(Tok::RParen) => {
                        self.i += 1;
                        Ok(e)
                    }
                    _ => Err("unclosed '('".to_string()),
                }
            }
            other => Err(format!("expected a number, path, or '(', got {other:?}")),
        }
    }
}

fn parse_expr(src: &str) -> Result<Expr, String> {
    let mut p = RuleParser { toks: tokenize(src)?, i: 0 };
    let e = p.or()?;
    if p.i != p.toks.len() {
        return Err(format!("trailing tokens in rule expression {src:?}"));
    }
    Ok(e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Tree;

    fn snap_with(vals: &[(&str, u64)]) -> Snapshot {
        let tree = Tree::new();
        for (path, v) in vals {
            tree.counter(path).add(*v);
        }
        tree.snapshot()
    }

    #[test]
    fn expressions_evaluate_with_precedence() {
        let snap = snap_with(&[("a", 2), ("b", 3), ("c", 12)]);
        let fired = |expr: &str| {
            let rule = Rule::new("t", expr, Severity::Warning, "").expect("parses");
            matches!(evaluate(&[rule], &snap).evaluations[0].verdict, Verdict::Fire)
        };
        assert!(fired("a + b * 2 == 8"));
        assert!(fired("(a + b) * 2 == 10"));
        assert!(fired("c / a / b == 2"));
        assert!(fired("a < b && b < c"));
        assert!(fired("a > b || c >= 12"));
        assert!(!fired("a != 2"));
        // Division by zero reads as 0, so rate rules stay quiet on
        // empty denominators.
        assert!(!fired("a / (b - 3) > 0.5"));
    }

    #[test]
    fn missing_paths_are_typed_not_panics() {
        let snap = snap_with(&[("fleet/served", 1)]);
        let rule =
            Rule::new("m", "fleet/served + fleet/ghost > 0", Severity::Error, "").expect("parses");
        let report = evaluate(&[rule], &snap);
        assert_eq!(report.evaluations[0].verdict, Verdict::Missing("fleet/ghost".to_string()));
        assert_eq!(report.worst(), Some(Severity::Warning), "missing is surfaced, not ignored");
    }

    #[test]
    fn bad_expressions_fail_to_parse() {
        assert!(Rule::new("x", "a +", Severity::Warning, "").is_err());
        assert!(Rule::new("x", "(a", Severity::Warning, "").is_err());
        assert!(Rule::new("x", "a ? b", Severity::Warning, "").is_err());
        assert!(Rule::new("x", "a b", Severity::Warning, "").is_err());
    }

    #[test]
    fn default_rules_pass_on_a_balanced_ledger_and_fire_on_imbalance() {
        let balanced = snap_with(&[
            ("fleet/served", 8),
            ("fleet/cancelled", 1),
            ("fleet/deadline_expired", 1),
            ("fleet/failed", 2),
            ("fleet/in_flight", 0),
            ("fleet/submitted", 12),
            ("fleet/quarantined_now", 0),
            ("fleet/shards", 2),
            ("fleet/queue_full", 0),
        ]);
        let report = evaluate(&default_rules(), &balanced);
        assert!(report.healthy(), "{report}");

        let torn = snap_with(&[
            ("fleet/served", 7),
            ("fleet/cancelled", 0),
            ("fleet/deadline_expired", 0),
            ("fleet/failed", 0),
            ("fleet/in_flight", 0),
            ("fleet/submitted", 12),
            ("fleet/quarantined_now", 2),
            ("fleet/shards", 2),
            ("fleet/queue_full", 9),
        ]);
        let report = evaluate(&default_rules(), &torn);
        assert_eq!(report.worst(), Some(Severity::Error));
        let fired: Vec<&str> = report
            .evaluations
            .iter()
            .filter(|e| e.verdict == Verdict::Fire)
            .map(|e| e.name.as_str())
            .collect();
        assert_eq!(fired, vec!["ledger_identity", "quarantined_majority", "queue_saturation"]);
    }
}
