//! Wall-clock micro-bench harness (criterion stand-in, offline image).
//!
//! Measures a closure with warmup, reports min/median/mean over N samples.
//! Used by the hot-path benches; simulation results never depend on it —
//! modeled cycles are deterministic.

use std::time::Instant;

/// Timing summary of one measured closure.
#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    /// Measured samples (excluding warmup).
    pub samples: usize,
    /// Fastest sample, seconds.
    pub min_s: f64,
    /// Median sample, seconds.
    pub median_s: f64,
    /// Mean sample, seconds.
    pub mean_s: f64,
}

impl BenchResult {
    /// Items per second at the median sample time.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.median_s
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "min {:.3}ms / median {:.3}ms / mean {:.3}ms over {} samples",
            self.min_s * 1e3,
            self.median_s * 1e3,
            self.mean_s * 1e3,
            self.samples
        )
    }
}

/// Run `f` `samples` times after `warmup` runs; `f`'s return value is
/// black-boxed to keep the optimizer honest.
pub fn bench<T>(warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(samples > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchResult {
        samples,
        min_s: times[0],
        median_s: times[times.len() / 2],
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
    }
}

/// Auto-scale the sample count so a bench takes roughly `budget_s` seconds.
pub fn bench_auto<T>(budget_s: f64, mut f: impl FnMut() -> T) -> BenchResult {
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let samples = ((budget_s / once) as usize).clamp(3, 1000);
    bench(samples.min(10) / 3 + 1, samples, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let r = bench(1, 5, || (0..1000u64).sum::<u64>());
        assert!(r.min_s >= 0.0);
        assert!(r.median_s >= r.min_s);
        assert_eq!(r.samples, 5);
    }

    #[test]
    fn auto_scales() {
        let r = bench_auto(0.01, || (0..100u64).sum::<u64>());
        assert!(r.samples >= 3);
    }
}
