//! Minimal FNV-1a accumulator, shared by the compiled-plan cache key
//! fingerprints (`driver::plan::PlanKey`, `accel::AccelConfig::
//! fingerprint`). One definition so the constants cannot drift.

/// 64-bit FNV-1a state.
pub struct Fnv(u64);

impl Fnv {
    /// The standard FNV-1a 64-bit offset basis.
    pub const BASIS: u64 = 0xcbf2_9ce4_8422_2325;
    /// Shared alternate basis for the second digest of every dual-FNV
    /// fingerprint in the crate (plan keys, tensor fingerprints,
    /// resident-weight signatures) — one constant so the pairs stay
    /// comparable across layers.
    pub const ALT_BASIS: u64 = 0x9e37_79b9_7f4a_7c15;
    const PRIME: u64 = 0x100_0000_01b3;

    /// Accumulator starting at the standard basis.
    pub fn new() -> Self {
        Self(Self::BASIS)
    }

    /// Alternate starting state, for a second statistically-independent
    /// fingerprint over the same byte stream.
    pub fn with_basis(basis: u64) -> Self {
        Self(basis)
    }

    /// Absorb one byte.
    pub fn byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(Self::PRIME);
    }

    /// Absorb a u64 as eight little-endian bytes.
    pub fn word(&mut self, w: u64) {
        for b in w.to_le_bytes() {
            self.byte(b);
        }
    }

    /// The current 64-bit digest.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vector_and_sensitivity() {
        // FNV-1a("a") = 0xaf63dc4c8601ec8c
        let mut h = Fnv::new();
        h.byte(b'a');
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        // One-byte difference changes the digest.
        let mut h1 = Fnv::new();
        let mut h2 = Fnv::new();
        h1.word(1);
        h2.word(2);
        assert_ne!(h1.finish(), h2.finish());
        // Distinct bases give independent digests for the same stream.
        let mut b2 = Fnv::with_basis(0x9e37_79b9_7f4a_7c15);
        b2.word(1);
        assert_ne!(h1.finish(), b2.finish());
    }
}
