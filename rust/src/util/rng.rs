//! Deterministic PRNG (PCG-XSH-RR 32) — seeds are part of every
//! experiment's identity, so results in EXPERIMENTS.md are reproducible
//! bit-for-bit.

/// PCG32: small, fast, statistically solid, and fully deterministic.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seeded generator on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Seeded generator on an explicit stream (independent sequences for
    /// the same seed).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next 32 uniform bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniform bits (two draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias.
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u32) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f32() + f32::MIN_POSITIVE).min(1.0 - f32::EPSILON);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Uniform i8 across the full range (TFLite tensor stand-in).
    pub fn i8(&mut self) -> i8 {
        (self.next_u32() & 0xff) as u8 as i8
    }

    /// Fill `buf` with uniform i8 values.
    pub fn fill_i8(&mut self, buf: &mut [i8]) {
        for v in buf.iter_mut() {
            *v = self.i8();
        }
    }

    /// Fill `buf` with normal samples scaled by `scale`.
    pub fn fill_normal(&mut self, buf: &mut [f32], scale: f32) {
        for v in buf.iter_mut() {
            *v = self.normal() * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_bounds_and_hits_all_values() {
        let mut rng = Pcg32::new(7);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_unit_interval() {
        let mut rng = Pcg32::new(3);
        for _ in 0..1000 {
            let v = rng.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut rng = Pcg32::new(11);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
