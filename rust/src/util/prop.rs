//! Miniature property-test runner (proptest is not vendored in this image).
//!
//! `check(name, iters, |g| { ... })` runs the closure against `iters`
//! deterministically-seeded random cases. On failure it re-runs with the
//! failing case isolated and panics with the case seed so the exact input
//! can be replayed (`PROP_SEED=<seed>` env). No shrinking — failing seeds
//! are printed instead, which is enough at this input scale.

use crate::util::rng::Pcg32;

/// Per-case input generator handed to the property body.
pub struct Gen {
    /// The case's deterministic entropy source.
    pub rng: Pcg32,
    /// Seed identifying this case; printed on failure.
    pub case_seed: u64,
}

impl Gen {
    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    /// Uniform i8 across the full range.
    pub fn i8(&mut self) -> i8 {
        self.rng.i8()
    }

    /// Standard-normal f32.
    pub fn f32(&mut self) -> f32 {
        self.rng.normal()
    }

    /// `n` uniform i8 values.
    pub fn vec_i8(&mut self, n: usize) -> Vec<i8> {
        let mut v = vec![0i8; n];
        self.rng.fill_i8(&mut v);
        v
    }

    /// `n` normal f32 values scaled by `scale`.
    pub fn vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        let mut v = vec![0f32; n];
        self.rng.fill_normal(&mut v, scale);
        v
    }

    /// Uniformly pick one element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.range(0, xs.len() - 1)]
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }
}

/// Run `body` against `iters` random cases. Honors `PROP_SEED` to replay a
/// single failing case.
pub fn check(name: &str, iters: u64, mut body: impl FnMut(&mut Gen)) {
    if let Ok(seed) = std::env::var("PROP_SEED") {
        let seed: u64 = seed.parse().expect("PROP_SEED must be u64");
        let mut g = Gen { rng: Pcg32::with_stream(seed, 0x9e37), case_seed: seed };
        body(&mut g);
        return;
    }
    for case in 0..iters {
        let case_seed = fxhash(name) ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen { rng: Pcg32::with_stream(case_seed, 0x9e37), case_seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case}/{iters} \
                 (replay with PROP_SEED={case_seed}): {msg}"
            );
        }
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("add-commutes", 50, |g| {
            let a = g.int(0, 1000);
            let b = g.int(0, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always-fails", 3, |_| panic!("boom"));
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("PROP_SEED="), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn cases_are_deterministic() {
        let mut seen = Vec::new();
        check("det", 5, |g| seen.push(g.int(0, 1_000_000)));
        let mut again = Vec::new();
        check("det", 5, |g| again.push(g.int(0, 1_000_000)));
        assert_eq!(seen, again);
    }
}
