//! Summary statistics used by the benchmark harness and EXPERIMENTS.md.

/// Arithmetic mean (NaN for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean — the right average for speedup ratios (paper's "average
/// speedup" claims are arithmetic; we report both).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Median (NaN for empty input).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Minimum (infinity for empty input).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum (negative infinity for empty input).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Sample standard deviation (0 below two samples).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Mean absolute percentage error — used by perf-model validation (§V-F).
pub fn mape(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    mean(
        &actual
            .iter()
            .zip(predicted)
            .map(|(a, p)| ((a - p) / a).abs() * 100.0)
            .collect::<Vec<_>>(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 4.0);
    }

    #[test]
    fn median_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn mape_zero_when_exact() {
        assert_eq!(mape(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mape(&[100.0], &[90.0]) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_nan() {
        assert!(mean(&[]).is_nan());
        assert!(median(&[]).is_nan());
    }
}
