//! Tiny argv parser for the `repro` CLI: `repro <command> [--key value]
//! [--key=value] [--flag] [positional...]`.

use std::collections::BTreeMap;

/// Parsed argv: `repro <command> [--key value] [--flag] [positional...]`.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-option token (the subcommand).
    pub command: Option<String>,
    /// Non-option tokens after the command.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` pairs.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` tokens.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse an explicit token stream (argv minus the binary name).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(body.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(body.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// True when `--name` was passed as a bare flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Option value for `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    /// Option value for `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// `--name` parsed as usize, or `default`. Panics on non-integers.
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    /// `--name` parsed as u64, or `default`. Panics on non-integers.
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    /// `--name` parsed as f64, or `default`. Panics on non-numbers.
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_options_flags_positionals() {
        // NB: a bare `--flag` followed by a non-flag token consumes it as
        // a value (`--flag v`); standalone flags go last or use `--k=v`.
        let a = parse("sweep extra1 extra2 --threads 2 --x=8 --verbose");
        assert_eq!(a.command.as_deref(), Some("sweep"));
        assert_eq!(a.get("threads"), Some("2"));
        assert_eq!(a.usize_or("x", 0), 8);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.usize_or("threads", 2), 2);
        assert_eq!(a.get_or("model", "dcgan"), "dcgan");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn double_dash_before_double_dash_is_flag() {
        let a = parse("x --a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
