//! Markdown-ish table printer — every paper table/figure bench prints its
//! rows through this so `cargo bench` output can be diffed against
//! EXPERIMENTS.md directly.

/// A titled table accumulated row by row, rendered as markdown.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    /// Append one row (arity must match the headers).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Rows appended so far.
    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    /// Render to an aligned markdown table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:>w$} |", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helper shared by the benches: two decimals.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format helper: one decimal.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

/// Format helper: fraction as a percentage with one decimal.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Format helper: seconds rendered as milliseconds, two decimals.
pub fn ms(seconds: f64) -> String {
    format!("{:.2}", seconds * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("T", &["name", "v"]);
        t.row(&["a".into(), "1.00".into()]);
        t.row(&["longer".into(), "2.5".into()]);
        let r = t.render();
        assert!(r.contains("## T"));
        assert!(r.contains("|   name |    v |"));
        assert!(r.contains("| longer |  2.5 |"));
        assert_eq!(t.rows(), 2);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn format_helpers() {
        assert_eq!(f2(1.234), "1.23");
        assert_eq!(pct(0.5551), "55.5%");
        assert_eq!(ms(0.04626), "46.26");
    }
}
