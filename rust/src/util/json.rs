//! Minimal JSON reader/writer — enough to parse `artifacts/manifest.json`
//! (objects, arrays, strings, numbers, bools, null; UTF-8; `\uXXXX`
//! escapes outside the BMP are rejected rather than mangled) and to
//! serialize telemetry snapshots stably ([`Value::to_json`]: sorted
//! keys via [`BTreeMap`], canonical number formatting, so equal values
//! always produce identical bytes).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Object member lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric payload as a non-negative integer, when exact.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The member map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// The boolean payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize to compact, *stable* JSON: object keys come out in
    /// [`BTreeMap`] order and numbers in a canonical form (integers in
    /// `[-2^53, 2^53]` as plain integers, everything else via Rust's
    /// shortest-round-trip `{:?}` — both re-parse to the same `f64`).
    /// Non-finite numbers, which JSON cannot carry, serialize as
    /// `null`. `parse(v.to_json())` always succeeds, and
    /// `parse(s).to_json()` is a fixed point for any `s` this writer
    /// produced.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write_json(&mut out);
        out
    }

    fn write_json(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(*n, out),
            Value::Str(s) => write_str(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_json(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write_json(out);
                }
                out.push('}');
            }
        }
    }
}

/// Canonical number form (see [`Value::to_json`]).
fn write_num(n: f64, out: &mut String) {
    const EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
    if !n.is_finite() {
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() <= EXACT {
        // `{:?}` would print "1.0"; JSON integers are cleaner and
        // canonical ("-0" normalizes to "0").
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n:?}"));
    }
}

/// Escaped, quoted string (control chars as `\u00XX`).
fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse failure with its byte offset.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset of the failure in the input.
    pub at: usize,
    /// Human-readable cause.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (rejects trailing data).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { at: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.i += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.i = self.i.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte 0x{c:02x}"))),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        if (0xD800..0xE000).contains(&code) {
                            return Err(self.err("surrogate pairs unsupported"));
                        }
                        s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble multi-byte UTF-8 (input is valid &str).
                    let start = self.i - 1;
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| {
                        self.err("invalid utf-8")
                    })?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| ParseError { at: start, msg: format!("bad number '{text}'") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested_manifest_shape() {
        let v = parse(
            r#"{"artifacts": {"model.hlo.txt": {"kind": "tconv",
                "problem": {"ih": 7, "stride": 2},
                "args": [{"shape": [7, 7, 32], "dtype": "float32"}],
                "returns_tuple": true}}}"#,
        )
        .unwrap();
        let meta = v.get("artifacts").unwrap().get("model.hlo.txt").unwrap();
        assert_eq!(meta.get("kind").unwrap().as_str(), Some("tconv"));
        assert_eq!(meta.get("problem").unwrap().get("ih").unwrap().as_usize(), Some(7));
        let args = meta.get("args").unwrap().as_arr().unwrap();
        let shape: Vec<usize> = args[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| s.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![7, 7, 32]);
        assert_eq!(meta.get("returns_tuple").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn writer_is_stable_and_round_trips() {
        let src = r#"{"a":[1,2.5,true,null],"b":{"c":"x\ny","d":1e-7},"z":-0.125}"#;
        let v = parse(src).unwrap();
        let out = v.to_json();
        // Canonical form re-parses to the same value...
        assert_eq!(parse(&out).unwrap(), v);
        // ...and is a fixed point of parse -> write.
        assert_eq!(parse(&out).unwrap().to_json(), out);
        // Integers print as integers, fractions via shortest round-trip.
        assert_eq!(Value::Num(3.0).to_json(), "3");
        assert_eq!(Value::Num(-0.0).to_json(), "0");
        assert_eq!(Value::Num(0.1).to_json(), "0.1");
        assert_eq!(Value::Num(1e-7).to_json(), "1e-7");
        assert_eq!(Value::Num(f64::NAN).to_json(), "null");
        assert_eq!(Value::Str("q\"\\\u{1}".into()).to_json(), "\"q\\\"\\\\\\u0001\"");
    }

    #[test]
    fn parses_unicode_escapes_and_utf8() {
        assert_eq!(parse("\"\\u00e9\"").unwrap(), Value::Str("é".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
        assert!(parse("\"\\ud834\"").is_err());
    }
}
