//! Self-contained utility substrate.
//!
//! The build image is fully offline with only the `xla` crate's dependency
//! closure vendored, so the usual ecosystem crates (clap, serde, rand,
//! criterion, proptest) are re-implemented here at the scale this project
//! needs: a deterministic PRNG, a JSON reader for the artifact manifest, a
//! flag parser for the CLI, a table printer for the paper-figure benches, a
//! wall-clock bench timer, and a miniature property-test runner.

pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;
