//! Dense row-major tensors (NHWC convention for feature maps).
//!
//! The element buffer is `Arc`-shared with copy-on-write semantics:
//! cloning a tensor (or taking [`Tensor::shared_data`]) bumps a
//! reference count instead of copying bytes, and any mutation through
//! [`Tensor::data_mut`] / [`Tensor::set3`] detaches the buffer first.
//! This is what lets the driver splice input rows into instruction
//! streams as zero-copy [`crate::accel::isa::RowSlice`]s. The hot paths
//! (GEMM, simulator) work on raw slices; `Tensor` is the typed container
//! at module boundaries.

use crate::util::hash::Fnv;
use crate::util::rng::Pcg32;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// Lazily-computed dual-FNV digest of a tensor's element bytes, plus a
/// compute counter for the memoization regression tests. Clones share the
/// cell (same buffer, same digest); any mutation detaches to a fresh one.
#[derive(Debug, Default)]
struct FpCell {
    fp: OnceLock<(u64, u64)>,
    computes: AtomicU64,
}

/// Dense row-major tensor: a shape plus its `Arc`-shared flat element
/// buffer (copy-on-write — see the [module docs](self)).
#[derive(Clone, Debug)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Arc<Vec<T>>,
    fp: Arc<FpCell>,
}

impl<T: PartialEq> PartialEq for Tensor<T> {
    fn eq(&self, other: &Self) -> bool {
        self.shape == other.shape && self.data == other.data
    }
}

impl<T: Copy + Default> Tensor<T> {
    /// All-default (zero) tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let numel = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: Arc::new(vec![T::default(); numel]),
            fp: Arc::default(),
        }
    }

    /// Wrap an existing buffer; length must match the shape's product.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Self { shape: shape.to_vec(), data: Arc::new(data), fp: Arc::default() }
    }

    /// Build from a flat-index function.
    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> T) -> Self {
        let numel = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: Arc::new((0..numel).map(&mut f).collect()),
            fp: Arc::default(),
        }
    }

    /// Detach the fingerprint cell ahead of a mutation: a computed digest
    /// would go stale, and a cell shared with clones must not observe the
    /// new bytes. A private, never-computed cell can be kept as-is.
    fn invalidate_fp(&mut self) {
        if self.fp.fp.get().is_some() || Arc::strong_count(&self.fp) > 1 {
            self.fp = Arc::default();
        }
    }

    /// The tensor's shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Flat element buffer (row-major).
    pub fn data(&self) -> &[T] {
        self.data.as_slice()
    }

    /// Shared handle to the flat buffer: an `Arc` bump, never a byte
    /// copy. Mutation through [`Tensor::data_mut`] / [`Tensor::set3`]
    /// detaches the tensor (copy-on-write), so a handle taken here keeps
    /// observing the bytes as they were at the time of the call.
    pub fn shared_data(&self) -> Arc<Vec<T>> {
        Arc::clone(&self.data)
    }

    /// Mutable flat element buffer. Detaches the buffer when it is
    /// shared (copy-on-write) and invalidates any memoized fingerprint —
    /// see [`Tensor::fingerprint`].
    pub fn data_mut(&mut self) -> &mut [T] {
        self.invalidate_fp();
        Arc::make_mut(&mut self.data).as_mut_slice()
    }

    /// Consume into the flat buffer (copies only if the buffer is still
    /// shared with another tensor or row slice).
    pub fn into_vec(self) -> Vec<T> {
        Arc::try_unwrap(self.data).unwrap_or_else(|shared| (*shared).clone())
    }

    /// Flat index of [h, w, c] in a rank-3 NHWC (no batch) tensor.
    #[inline]
    pub fn idx3(&self, h: usize, w: usize, c: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 3);
        (h * self.shape[1] + w) * self.shape[2] + c
    }

    /// Element at [h, w, c] of a rank-3 tensor.
    #[inline]
    pub fn at3(&self, h: usize, w: usize, c: usize) -> T {
        self.data[self.idx3(h, w, c)]
    }

    /// Write element [h, w, c] of a rank-3 tensor (copy-on-write).
    #[inline]
    pub fn set3(&mut self, h: usize, w: usize, c: usize, v: T) {
        self.invalidate_fp();
        let i = self.idx3(h, w, c);
        Arc::make_mut(&mut self.data)[i] = v;
    }

    /// Flat index of [o, kh, kw, c] in a rank-4 OHWI weight tensor.
    #[inline]
    pub fn idx4(&self, o: usize, kh: usize, kw: usize, c: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 4);
        ((o * self.shape[1] + kh) * self.shape[2] + kw) * self.shape[3] + c
    }

    /// Element at [o, kh, kw, c] of a rank-4 weight tensor.
    #[inline]
    pub fn at4(&self, o: usize, kh: usize, kw: usize, c: usize) -> T {
        self.data[self.idx4(o, kh, kw, c)]
    }

    /// Reinterpret under a new shape with the same element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }
}

impl Tensor<f32> {
    /// Tensor of normal samples scaled by `scale`.
    pub fn random_normal(shape: &[usize], scale: f32, rng: &mut Pcg32) -> Self {
        let mut t = Self::zeros(shape);
        rng.fill_normal(t.data_mut(), scale);
        t
    }

    /// Largest elementwise absolute difference (shapes must match).
    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl Tensor<i8> {
    /// Tensor of uniform int8 values (TFLite tensor stand-in).
    pub fn random(shape: &[usize], rng: &mut Pcg32) -> Self {
        let mut t = Self::zeros(shape);
        rng.fill_i8(t.data_mut());
        t
    }

    /// Dual-basis FNV-1a digest of the element bytes, **memoized per
    /// buffer lifetime**: the first call pays the O(numel) pass, later
    /// calls (including on clones, which share the cell) return the
    /// cached pair. Mutation through [`Tensor::data_mut`]/[`Tensor::set3`]
    /// detaches the cell, so the next call re-digests the new bytes. This
    /// is what lets `driver::plan::PlanKey` stop re-hashing the full
    /// weight tensor on every cache lookup.
    pub fn fingerprint(&self) -> (u64, u64) {
        *self.fp.fp.get_or_init(|| {
            self.fp.computes.fetch_add(1, Ordering::Relaxed);
            let mut fp = Fnv::new();
            let mut fp2 = Fnv::with_basis(Fnv::ALT_BASIS);
            for &b in self.data.iter() {
                fp.byte(b as u8);
                fp2.byte(b as u8);
            }
            (fp.finish(), fp2.finish())
        })
    }

    /// How many times this buffer's fingerprint has actually been
    /// computed (0 before the first [`Tensor::fingerprint`] call, 1 for
    /// the rest of the buffer's lifetime). Regression hook for the
    /// one-hash-per-layer-per-graph-lifetime guarantee.
    pub fn fingerprint_computes(&self) -> u64 {
        self.fp.computes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_is_row_major() {
        let t = Tensor::from_fn(&[2, 3, 4], |i| i as i32);
        assert_eq!(t.at3(0, 0, 0), 0);
        assert_eq!(t.at3(0, 0, 3), 3);
        assert_eq!(t.at3(0, 1, 0), 4);
        assert_eq!(t.at3(1, 0, 0), 12);
        assert_eq!(t.at3(1, 2, 3), 23);
    }

    #[test]
    fn idx4_matches_nested_loops() {
        let t: Tensor<i8> = Tensor::zeros(&[3, 2, 2, 5]);
        let mut flat = 0;
        for o in 0..3 {
            for kh in 0..2 {
                for kw in 0..2 {
                    for c in 0..5 {
                        assert_eq!(t.idx4(o, kh, kw, c), flat);
                        flat += 1;
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn from_vec_validates_shape() {
        let _ = Tensor::from_vec(&[2, 2], vec![1i32; 5]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6).collect::<Vec<i32>>());
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.shape(), &[3, 2]);
    }

    #[test]
    fn random_deterministic_by_seed() {
        let mut r1 = Pcg32::new(5);
        let mut r2 = Pcg32::new(5);
        let a = Tensor::<i8>::random(&[4, 4, 4], &mut r1);
        let b = Tensor::<i8>::random(&[4, 4, 4], &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::from_vec(&[2], vec![1.0f32, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.5f32, 1.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }

    /// Clones and shared handles alias the same buffer (zero-copy);
    /// mutation detaches the mutated tensor only (copy-on-write).
    #[test]
    fn clone_shares_buffer_and_mutation_detaches() {
        let mut rng = Pcg32::new(21);
        let t = Tensor::<i8>::random(&[2, 3, 4], &mut rng);
        let c = t.clone();
        assert!(Arc::ptr_eq(&t.shared_data(), &c.shared_data()), "clone must not copy");
        let handle = t.shared_data();

        let mut m = t.clone();
        m.data_mut()[0] = m.data()[0].wrapping_add(1);
        // The mutated clone detached; the original and the handle still
        // alias the unmodified bytes.
        assert!(!Arc::ptr_eq(&m.shared_data(), &handle));
        assert!(Arc::ptr_eq(&t.shared_data(), &handle));
        assert_eq!(handle[0], c.data()[0]);
        assert_ne!(m.data()[0], c.data()[0]);
    }

    #[test]
    fn fingerprint_memoized_once_and_shared_by_clones() {
        let mut rng = Pcg32::new(9);
        let t = Tensor::<i8>::random(&[4, 4, 4], &mut rng);
        assert_eq!(t.fingerprint_computes(), 0, "lazy until first query");
        let fp = t.fingerprint();
        assert_eq!(t.fingerprint_computes(), 1);
        assert_eq!(t.fingerprint(), fp, "stable across calls");
        assert_eq!(t.fingerprint_computes(), 1, "second call hits the memo");
        // Clones share the buffer, hence the digest and the memo.
        let c = t.clone();
        assert_eq!(c.fingerprint(), fp);
        assert_eq!(c.fingerprint_computes(), 1, "clone reuses the cell");
        // The two bases are independent digests.
        assert_ne!(fp.0, fp.1);
    }

    #[test]
    fn fingerprint_invalidated_by_mutation_not_by_reshape() {
        let mut rng = Pcg32::new(10);
        let mut t = Tensor::<i8>::random(&[2, 2, 4], &mut rng);
        let fp = t.fingerprint();
        // Reshape does not touch the bytes: digest survives.
        let r = t.clone().reshape(&[4, 4]);
        assert_eq!(r.fingerprint(), fp);
        // Mutating detaches the memo and changes the digest.
        t.data_mut()[0] = t.data()[0].wrapping_add(1);
        assert_ne!(t.fingerprint(), fp);
        // The clone made before the mutation still sees the old digest.
        assert_eq!(r.fingerprint(), fp);
        // set3 invalidates too.
        let mut u = Tensor::<i8>::random(&[2, 2, 4], &mut rng);
        let before = u.fingerprint();
        let flipped = u.at3(1, 1, 1).wrapping_add(1);
        u.set3(1, 1, 1, flipped);
        assert_ne!(u.fingerprint(), before);
    }
}
