//! TFLite-style 8-bit quantization.
//!
//! The paper integrates MM2IM as a TFLite delegate operating on int8
//! tensors; the PPU inside each Accumulation Unit performs the requantize
//! step. This module reproduces TFLite's exact fixed-point arithmetic
//! (`MultiplyByQuantizedMultiplier`: saturating rounding doubling high-mul
//! + rounding right shift) so CPU baseline, simulator PPU, and any future
//! RTL agree bit-for-bit.

/// Asymmetric per-tensor quantization: `real = scale * (q - zero_point)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    /// Real value of one quantization step.
    pub scale: f32,
    /// Quantized value representing real 0.
    pub zero_point: i32,
}

impl QuantParams {
    /// Choose parameters covering `[min, max]` (TFLite's ChooseQuantizationParams).
    pub fn from_range(min: f32, max: f32) -> Self {
        let min = min.min(0.0);
        let max = max.max(0.0);
        if min == max {
            return Self { scale: 1.0, zero_point: 0 };
        }
        let scale = (max - min) / 255.0;
        let zp = (-128.0 - min / scale).round().clamp(-128.0, 127.0) as i32;
        Self { scale, zero_point: zp }
    }

    /// Symmetric (weights-style): zero_point = 0, range clamped to ±127.
    pub fn symmetric(max_abs: f32) -> Self {
        let m = if max_abs > 0.0 { max_abs } else { 1.0 };
        Self { scale: m / 127.0, zero_point: 0 }
    }

    /// Real -> int8 with round-to-nearest and saturation.
    pub fn quantize(&self, x: f32) -> i8 {
        ((x / self.scale).round() as i32 + self.zero_point).clamp(-128, 127) as i8
    }

    /// Int8 -> real.
    pub fn dequantize(&self, q: i8) -> f32 {
        self.scale * (q as i32 - self.zero_point) as f32
    }

    /// Quantize a whole slice.
    pub fn quantize_slice(&self, xs: &[f32]) -> Vec<i8> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Dequantize a whole slice.
    pub fn dequantize_slice(&self, qs: &[i8]) -> Vec<f32> {
        qs.iter().map(|&q| self.dequantize(q)).collect()
    }
}

/// TFLite's fixed-point representation of a positive real multiplier < 1:
/// `real ≈ m * 2^shift / 2^31` with `m` in `[2^30, 2^31)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantizedMultiplier {
    /// Fixed-point mantissa in `[2^30, 2^31)`.
    pub m: i32,
    /// Power-of-two exponent (positive = left shift).
    pub shift: i32,
}

impl QuantizedMultiplier {
    /// `QuantizeMultiplier` from TFLite (handles any positive real).
    pub fn from_real(real: f64) -> Self {
        assert!(real > 0.0, "multiplier must be positive, got {real}");
        let (frac, mut exp) = frexp(real);
        let mut m = (frac * (1i64 << 31) as f64).round() as i64;
        if m == 1i64 << 31 {
            m /= 2;
            exp += 1;
        }
        Self { m: m as i32, shift: exp }
    }

    /// The real multiplier this fixed-point pair encodes.
    pub fn to_real(self) -> f64 {
        self.m as f64 / (1i64 << 31) as f64 * 2f64.powi(self.shift)
    }

    /// `MultiplyByQuantizedMultiplier(x)` — TFLite reference semantics.
    #[inline]
    pub fn apply(self, x: i32) -> i32 {
        let left = self.shift.max(0);
        let right = (-self.shift).max(0);
        // x * 2^left with saturation (TFLite uses i32 shifts; inputs in the
        // requant path never overflow because real multipliers are < 1 for
        // the layers we run, but saturate anyway for safety).
        let shifted = (x as i64) << left;
        let shifted = shifted.clamp(i32::MIN as i64, i32::MAX as i64) as i32;
        rounding_right_shift(saturating_rounding_doubling_high_mul(shifted, self.m), right)
    }
}

/// gemmlowp `SaturatingRoundingDoublingHighMul`.
#[inline]
pub fn saturating_rounding_doubling_high_mul(a: i32, b: i32) -> i32 {
    if a == i32::MIN && b == i32::MIN {
        return i32::MAX; // the single overflow case
    }
    let ab = a as i64 * b as i64;
    let nudge = if ab >= 0 { 1i64 << 30 } else { 1 - (1i64 << 30) };
    // gemmlowp divides (C++ semantics: truncation toward zero), which
    // differs from an arithmetic shift for negative products.
    ((ab + nudge) / (1i64 << 31)) as i32
}

/// gemmlowp `RoundingDivideByPOT` (round-half-away-from-zero).
#[inline]
pub fn rounding_right_shift(x: i32, exponent: i32) -> i32 {
    debug_assert!((0..=31).contains(&exponent));
    if exponent == 0 {
        return x;
    }
    let mask = (1i64 << exponent) - 1;
    let remainder = (x as i64) & mask;
    let threshold = (mask >> 1) + if x < 0 { 1 } else { 0 };
    ((x as i64 >> exponent) + if remainder > threshold { 1 } else { 0 }) as i32
}

/// Requantize one int32 accumulator to int8 (the PPU's core op):
/// `clamp(zp_out + mbqm(acc))`.
#[inline]
pub fn requantize(acc: i32, mult: QuantizedMultiplier, zp_out: i32) -> i8 {
    (mult.apply(acc) + zp_out).clamp(-128, 127) as i8
}

/// Per-channel requant params for a TCONV/conv layer:
/// `real_multiplier[oc] = input_scale * weight_scale[oc] / output_scale`.
#[derive(Clone, Debug)]
pub struct PerChannel {
    /// One fixed-point multiplier per output channel.
    pub mults: Vec<QuantizedMultiplier>,
    /// Output zero point shared by all channels.
    pub zp_out: i32,
}

impl PerChannel {
    /// Derive the per-channel multipliers from layer scales.
    pub fn new(input_scale: f32, weight_scales: &[f32], output: QuantParams) -> Self {
        Self {
            mults: weight_scales
                .iter()
                .map(|&ws| {
                    QuantizedMultiplier::from_real(input_scale as f64 * ws as f64 / output.scale as f64)
                })
                .collect(),
            zp_out: output.zero_point,
        }
    }

    /// Requantize one accumulator with channel `oc`'s multiplier.
    #[inline]
    pub fn requantize(&self, acc: i32, oc: usize) -> i8 {
        requantize(acc, self.mults[oc], self.zp_out)
    }
}

/// `frexp` for positive finite doubles: returns (frac in [0.5, 1), exp).
fn frexp(x: f64) -> (f64, i32) {
    debug_assert!(x > 0.0 && x.is_finite());
    let bits = x.to_bits();
    let raw_exp = ((bits >> 52) & 0x7ff) as i32;
    if raw_exp == 0 {
        // subnormal: normalize by scaling up
        let scaled = x * 2f64.powi(64);
        let (f, e) = frexp(scaled);
        return (f, e - 64);
    }
    let exp = raw_exp - 1022;
    let frac = f64::from_bits((bits & !(0x7ffu64 << 52)) | (1022u64 << 52));
    (frac, exp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frexp_roundtrip() {
        for &x in &[1.0, 0.5, 0.75, 3.141, 1e-9, 1e9] {
            let (f, e) = frexp(x);
            assert!((0.5..1.0).contains(&f), "{x} -> frac {f}");
            assert!((f * 2f64.powi(e) - x).abs() <= x * 1e-15);
        }
        // min subnormal: 2^-1074 == 0.5 * 2^-1073 exactly (powi would
        // underflow, so check the pair directly).
        assert_eq!(frexp(f64::from_bits(1)), (0.5, -1073));
    }

    #[test]
    fn quantized_multiplier_roundtrip() {
        for &real in &[0.25, 0.0003, 0.99, 1.0, 1.7, 123.456] {
            let qm = QuantizedMultiplier::from_real(real);
            assert!(
                (qm.to_real() - real).abs() / real < 1e-9,
                "{real} -> {qm:?} -> {}",
                qm.to_real()
            );
            assert!(qm.m >= 1 << 30 || qm.m == i32::MAX);
        }
    }

    #[test]
    fn srdhm_matches_gemmlowp_vectors() {
        // Hand-computed gemmlowp semantics: result = round(a*b / 2^31).
        assert_eq!(saturating_rounding_doubling_high_mul(1 << 30, 1 << 30), 1 << 29);
        assert_eq!(saturating_rounding_doubling_high_mul(i32::MIN, i32::MIN), i32::MAX);
        assert_eq!(saturating_rounding_doubling_high_mul(0, 12345), 0);
        // rounding: a*b = 3 * 2^29 = 1.5 * 2^30 -> 2^30 is 0.5ulp -> rounds to 1
        assert_eq!(saturating_rounding_doubling_high_mul(3, 1 << 29), 1);
        assert_eq!(saturating_rounding_doubling_high_mul(-3, 1 << 29), -1);
    }

    #[test]
    fn rounding_right_shift_half_away_from_zero() {
        assert_eq!(rounding_right_shift(5, 1), 3); // 2.5 -> 3
        assert_eq!(rounding_right_shift(-5, 1), -3); // -2.5 -> -3 (away from zero: -3? gemmlowp: -2)
        assert_eq!(rounding_right_shift(4, 1), 2);
        assert_eq!(rounding_right_shift(7, 2), 2); // 1.75 -> 2
        assert_eq!(rounding_right_shift(6, 2), 2); // 1.5 -> 2
        assert_eq!(rounding_right_shift(-6, 2), -2); // -1.5 -> -2 (toward even? gemmlowp: -1?)
        assert_eq!(rounding_right_shift(100, 0), 100);
    }

    #[test]
    fn requantize_tracks_real_arithmetic() {
        // For a random set of accumulators and multipliers the fixed-point
        // result must be within 1 LSB of the real-valued computation.
        let mut rng = crate::util::rng::Pcg32::new(9);
        for _ in 0..500 {
            let acc = rng.next_u32() as i32 % 100_000;
            let real = 0.5e-3 + rng.f32() as f64 * 0.01;
            let qm = QuantizedMultiplier::from_real(real);
            let got = requantize(acc, qm, -3);
            let want = ((acc as f64 * real).round() as i32 - 3).clamp(-128, 127) as i8;
            assert!(
                (got as i32 - want as i32).abs() <= 1,
                "acc={acc} real={real} got={got} want={want}"
            );
        }
    }

    #[test]
    fn quant_params_roundtrip_within_one_lsb() {
        let qp = QuantParams::from_range(-6.2, 5.1);
        for i in 0..100 {
            let x = -6.2 + (i as f32) * 0.113;
            let err = (qp.dequantize(qp.quantize(x)) - x).abs();
            assert!(err <= qp.scale * 0.5 + 1e-6, "x={x} err={err}");
        }
        // zero must be exactly representable (TFLite invariant)
        assert_eq!(qp.dequantize(qp.quantize(0.0)), 0.0);
    }

    #[test]
    fn symmetric_weights_zero_point_zero() {
        let qp = QuantParams::symmetric(3.3);
        assert_eq!(qp.zero_point, 0);
        assert_eq!(qp.quantize(3.3), 127);
        assert_eq!(qp.quantize(-3.3), -127);
    }

    #[test]
    fn degenerate_range() {
        let qp = QuantParams::from_range(0.0, 0.0);
        assert_eq!(qp.quantize(0.0), 0);
    }

    #[test]
    fn per_channel_requant() {
        let pc = PerChannel::new(
            0.05,
            &[0.01, 0.02],
            QuantParams { scale: 0.1, zero_point: 3 },
        );
        // channel 0: real mult 0.005 -> acc 1000 -> 5 + 3 = 8
        assert_eq!(pc.requantize(1000, 0), 8);
        // channel 1: real mult 0.01 -> acc 1000 -> 10 + 3 = 13
        assert_eq!(pc.requantize(1000, 1), 13);
        // saturation
        assert_eq!(pc.requantize(10_000_000, 1), 127);
        assert_eq!(pc.requantize(-10_000_000, 1), -128);
    }
}
