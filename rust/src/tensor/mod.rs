//! Tensor + TFLite-style quantization substrate.

pub mod quant;
#[allow(clippy::module_inception)]
pub mod tensor;

pub use quant::{QuantParams, QuantizedMultiplier};
pub use tensor::Tensor;
