//! Tensor + TFLite-style quantization substrate.

pub mod quant;
pub mod tensor;

pub use quant::{QuantParams, QuantizedMultiplier};
pub use tensor::Tensor;
