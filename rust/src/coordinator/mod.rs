//! Production-style serving subsystem: batched, sharded inference over
//! the model executor with a shared compiled-plan cache
//! (`examples/serve.rs`, `repro serve`).
//!
//! The paper amortizes mapping work in hardware (maps generated once per
//! row, §IV-E); this layer applies the same principle to orchestration:
//!
//! * **Compile once, serve many** — every worker's delegate resolves
//!   TCONV layer programs through one [`PlanCache`] shared across the
//!   server, so each distinct layer compiles exactly once per process
//!   regardless of request count (hit/miss counters surface in
//!   [`ServeStats`]).
//! * **Sharding** — workers are grouped into shards, each standing for
//!   one simulated MM2IM accelerator instance; per-shard utilization is
//!   reported so load imbalance is visible.
//! * **Batching** — a worker drains up to [`ServerConfig::max_batch`]
//!   same-graph requests per queue round-trip, amortizing lock traffic
//!   and keeping a shard's plan/weight state hot.
//! * **Async submission with backpressure** — the request queue is
//!   bounded ([`ServerConfig::queue_capacity`]): [`Server::submit`]
//!   blocks when full, [`Server::try_submit`] refuses, [`Server::poll`]
//!   collects finished responses without closing, and
//!   [`Server::finish`]/[`Server::drain`] close and join.

use crate::accel::AccelConfig;
use crate::driver::PlanCache;
use crate::model::executor::{Executor, RunConfig};
use crate::model::graph::Graph;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// One generation request: a seed for the latent/input tensor.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: u64,
    pub seed: u64,
    enqueued: Instant,
}

/// Completed response with measured host wall-clock and modeled
/// PYNQ-Z1 latency for the configured device.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub seed: u64,
    /// Shard (simulated accelerator instance) that served the request.
    pub shard: usize,
    pub output: Tensor<i8>,
    /// Seconds spent waiting in the bounded queue.
    pub queue_seconds: f64,
    /// Host wall-clock seconds of the numerics pass.
    pub wall_seconds: f64,
    /// Modeled end-to-end seconds on the PYNQ-Z1 testbed.
    pub modeled_seconds: f64,
}

impl Response {
    /// Queue wait + execution: the latency a client observes.
    pub fn latency_seconds(&self) -> f64 {
        self.queue_seconds + self.wall_seconds
    }
}

/// Server topology and policy.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Simulated accelerator instances (worker groups). >= 1.
    pub shards: usize,
    /// Worker threads per shard. >= 1.
    pub workers_per_shard: usize,
    /// Bounded request-queue capacity; `submit` blocks and `try_submit`
    /// refuses once `queue_capacity` requests are waiting.
    pub queue_capacity: usize,
    /// Max same-graph requests one worker drains per queue round-trip.
    pub max_batch: usize,
    /// Compiled plans the shared cache may hold (>= distinct TCONV
    /// layers of the graph to avoid thrash).
    pub plan_cache_capacity: usize,
    /// CPU threads per worker for non-offloaded layers.
    pub cpu_threads: usize,
    /// Offload TCONV layers to the simulated accelerator.
    pub use_accelerator: bool,
    /// Device configuration used for modeled latency.
    pub run_config: RunConfig,
    pub accel: AccelConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            shards: 1,
            workers_per_shard: 1,
            queue_capacity: 64,
            max_batch: 4,
            plan_cache_capacity: 64,
            cpu_threads: 1,
            use_accelerator: true,
            run_config: RunConfig::AccPlusCpu { threads: 1 },
            accel: AccelConfig::default(),
        }
    }
}

impl ServerConfig {
    pub fn workers(&self) -> usize {
        self.shards.max(1) * self.workers_per_shard.max(1)
    }
}

struct State {
    pending: VecDeque<Request>,
    done: Vec<Response>,
    closed: bool,
    /// While true, workers leave the queue untouched (maintenance /
    /// deterministic backpressure tests). Closing overrides pausing.
    paused: bool,
}

/// Latency samples kept for percentile reporting; older samples rotate
/// out ring-buffer style so a long-lived server's memory stays bounded.
const LATENCY_WINDOW: usize = 65_536;

/// Running aggregates, independent of `poll` draining `done`.
#[derive(Default)]
struct Metrics {
    /// Most recent `LATENCY_WINDOW` request latencies (queue + run).
    latencies_s: Vec<f64>,
    /// Next ring slot once the window is full.
    latency_slot: usize,
    /// Total requests served over the server's lifetime.
    served: u64,
    wall_total_s: f64,
    modeled_total_s: f64,
    batches: u64,
}

impl Metrics {
    fn record_latency(&mut self, v: f64) {
        self.served += 1;
        if self.latencies_s.len() < LATENCY_WINDOW {
            self.latencies_s.push(v);
        } else {
            self.latencies_s[self.latency_slot] = v;
            self.latency_slot = (self.latency_slot + 1) % LATENCY_WINDOW;
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct ShardStat {
    busy_s: f64,
    requests: u64,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for work or close.
    work_cv: Condvar,
    /// Submitters wait here for queue space.
    space_cv: Condvar,
    metrics: Mutex<Metrics>,
    shards: Mutex<Vec<ShardStat>>,
}

/// Batched, sharded inference server for one model graph.
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    cache: Arc<PlanCache>,
    config: ServerConfig,
    submitted: u64,
    started: Instant,
}

impl Server {
    /// Spawn `config.workers()` threads over `config.shards` shards; each
    /// worker owns an executor whose delegate shares the server-wide plan
    /// cache.
    pub fn start(graph: Arc<Graph>, config: ServerConfig) -> Self {
        if matches!(config.run_config, RunConfig::AccPlusCpu { .. }) {
            assert!(
                config.use_accelerator,
                "AccPlusCpu modeling requires use_accelerator (no cycle reports otherwise)"
            );
        }
        // Normalize the topology once; `submit` reads the stored config,
        // so a zero queue capacity must be clamped here or backpressure
        // would block forever.
        let mut config = config;
        config.queue_capacity = config.queue_capacity.max(1);
        let shards = config.shards.max(1);
        let workers_per_shard = config.workers_per_shard.max(1);
        let max_batch = config.max_batch.max(1);
        let cache = PlanCache::shared(config.plan_cache_capacity.max(1));
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                pending: VecDeque::new(),
                done: Vec::new(),
                closed: false,
                paused: false,
            }),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            metrics: Mutex::new(Metrics::default()),
            shards: Mutex::new(vec![ShardStat::default(); shards]),
        });

        let mut handles = Vec::with_capacity(shards * workers_per_shard);
        for worker_idx in 0..shards * workers_per_shard {
            let shard = worker_idx % shards;
            let shared = shared.clone();
            let graph = graph.clone();
            let cache = cache.clone();
            let cfg = config.clone();
            handles.push(std::thread::spawn(move || {
                let exec = Executor::with_shared_cache(
                    cfg.accel.clone(),
                    cfg.cpu_threads,
                    cfg.use_accelerator,
                    cache,
                );
                worker_loop(&shared, &graph, &exec, &cfg, shard, max_batch);
            }));
        }
        Self { shared, workers: handles, cache, config, submitted: 0, started: Instant::now() }
    }

    /// Enqueue one request, blocking while the queue is at capacity
    /// (backpressure). Returns the request id (submission order).
    ///
    /// Caution: while the server is [`Server::pause`]d, nothing drains
    /// the queue, so a blocking submit past `queue_capacity` would wait
    /// until `resume` — which this same thread can then never call. Use
    /// [`Server::try_submit`] when submitting to a paused server.
    pub fn submit(&mut self, seed: u64) -> u64 {
        let id = self.next_id();
        let mut st = self.shared.state.lock().unwrap();
        while st.pending.len() >= self.config.queue_capacity {
            st = self.shared.space_cv.wait(st).unwrap();
        }
        st.pending.push_back(Request { id, seed, enqueued: Instant::now() });
        drop(st);
        self.shared.work_cv.notify_one();
        id
    }

    /// Non-blocking submit: `None` when the queue is full.
    pub fn try_submit(&mut self, seed: u64) -> Option<u64> {
        let shared = self.shared.clone();
        let mut st = shared.state.lock().unwrap();
        if st.pending.len() >= self.config.queue_capacity {
            return None;
        }
        let id = self.next_id();
        st.pending.push_back(Request { id, seed, enqueued: Instant::now() });
        drop(st);
        shared.work_cv.notify_one();
        Some(id)
    }

    /// Blocking bulk submission; returns the ids in seed order.
    pub fn submit_many(&mut self, seeds: &[u64]) -> Vec<u64> {
        seeds.iter().map(|&s| self.submit(s)).collect()
    }

    /// Collect responses completed so far (sorted by id) without closing
    /// the queue.
    pub fn poll(&mut self) -> Vec<Response> {
        let mut out = std::mem::take(&mut self.shared.state.lock().unwrap().done);
        out.sort_by_key(|r| r.id);
        out
    }

    /// Stop workers from taking new work (in-flight batches complete).
    /// While paused, prefer [`Server::try_submit`] over the blocking
    /// [`Server::submit`] — see the caution there.
    pub fn pause(&mut self) {
        self.shared.state.lock().unwrap().paused = true;
    }

    /// Resume a paused server.
    pub fn resume(&mut self) {
        self.shared.state.lock().unwrap().paused = false;
        self.shared.work_cv.notify_all();
    }

    /// Requests currently waiting in the queue.
    pub fn queued(&self) -> usize {
        self.shared.state.lock().unwrap().pending.len()
    }

    /// Close the queue, serve everything still pending, and collect the
    /// remaining responses (sorted by id) — responses already taken by
    /// `poll` are not repeated.
    pub fn drain(self) -> Vec<Response> {
        self.finish().0
    }

    /// `drain` plus the server-lifetime statistics: plan-cache counters,
    /// per-shard utilization, and latency percentiles (computed over the
    /// most recent 65 536 requests — see [`ServeStats`]).
    pub fn finish(self) -> (Vec<Response>, ServeStats) {
        let Server { shared, workers, cache, config, submitted, started } = self;
        {
            let mut st = shared.state.lock().unwrap();
            st.closed = true;
        }
        shared.work_cv.notify_all();
        for h in workers {
            h.join().expect("worker panicked");
        }
        let mut done = std::mem::take(&mut shared.state.lock().unwrap().done);
        done.sort_by_key(|r| r.id);

        let elapsed_s = started.elapsed().as_secs_f64();
        let m = shared.metrics.lock().unwrap();
        let mut lat = m.latencies_s.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let served = m.served as usize;
        let cache_stats = cache.stats();
        let shard_stats = shared.shards.lock().unwrap();
        let per_slot = elapsed_s.max(1e-9) * config.workers_per_shard.max(1) as f64;
        let stats = ServeStats {
            requests: served,
            submitted,
            wall_total_s: m.wall_total_s,
            wall_mean_s: m.wall_total_s / served.max(1) as f64,
            modeled_mean_s: m.modeled_total_s / served.max(1) as f64,
            throughput_rps: served as f64 / elapsed_s.max(1e-9),
            p50_latency_s: percentile(&lat, 0.50),
            p95_latency_s: percentile(&lat, 0.95),
            cache_hits: cache_stats.hits,
            cache_misses: cache_stats.misses,
            batches: m.batches,
            mean_batch_size: served as f64 / m.batches.max(1) as f64,
            shard_utilization: shard_stats.iter().map(|s| s.busy_s / per_slot).collect(),
            shard_requests: shard_stats.iter().map(|s| s.requests).collect(),
        };
        (done, stats)
    }

    fn next_id(&mut self) -> u64 {
        let id = self.submitted;
        self.submitted += 1;
        id
    }
}

fn worker_loop(
    shared: &Shared,
    graph: &Graph,
    exec: &Executor,
    cfg: &ServerConfig,
    shard: usize,
    max_batch: usize,
) {
    loop {
        let batch: Vec<Request> = {
            let mut st = shared.state.lock().unwrap();
            loop {
                let can_take = !st.pending.is_empty() && (!st.paused || st.closed);
                if can_take {
                    let n = st.pending.len().min(max_batch);
                    break st.pending.drain(..n).collect();
                }
                if st.closed && st.pending.is_empty() {
                    return;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        shared.space_cv.notify_all();

        let n = batch.len();
        let t_batch = Instant::now();
        let mut responses = Vec::with_capacity(n);
        let mut latencies = Vec::with_capacity(n);
        let mut wall_sum = 0.0;
        let mut modeled_sum = 0.0;
        for req in batch {
            let queue_seconds = req.enqueued.elapsed().as_secs_f64();
            let mut rng = Pcg32::new(req.seed);
            let input = Tensor::<i8>::random(&graph.input_shape, &mut rng);
            let t0 = Instant::now();
            let run = exec.run(graph, &input);
            let wall_seconds = t0.elapsed().as_secs_f64();
            let modeled_seconds = run.modeled(cfg.run_config, &cfg.accel).total_s();
            wall_sum += wall_seconds;
            modeled_sum += modeled_seconds;
            latencies.push(queue_seconds + wall_seconds);
            responses.push(Response {
                id: req.id,
                seed: req.seed,
                shard,
                output: run.output,
                queue_seconds,
                wall_seconds,
                modeled_seconds,
            });
        }
        let busy_s = t_batch.elapsed().as_secs_f64();

        shared.state.lock().unwrap().done.extend(responses);
        {
            let mut m = shared.metrics.lock().unwrap();
            for v in latencies {
                m.record_latency(v);
            }
            m.wall_total_s += wall_sum;
            m.modeled_total_s += modeled_sum;
            m.batches += 1;
        }
        {
            let mut sh = shared.shards.lock().unwrap();
            sh[shard].busy_s += busy_s;
            sh[shard].requests += n as u64;
        }
    }
}

/// Serve-run summary. Latency percentiles cover queue wait + execution
/// (a 65 536-request recency window bounds memory on very long runs);
/// `shard_utilization[i]` is shard i's busy time over the run, normalized
/// per worker slot (1.0 = that shard's workers never idled).
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Requests actually served.
    pub requests: usize,
    /// Requests submitted over the server's lifetime.
    pub submitted: u64,
    pub wall_total_s: f64,
    pub wall_mean_s: f64,
    pub modeled_mean_s: f64,
    pub throughput_rps: f64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    /// Compiled-plan cache counters across all workers.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Worker queue round-trips; `mean_batch_size` = requests / batches.
    pub batches: u64,
    pub mean_batch_size: f64,
    pub shard_utilization: Vec<f64>,
    pub shard_requests: Vec<u64>,
}

impl ServeStats {
    /// Fraction of plan lookups served from cache (0 when none).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Summary over an explicit response set (e.g. one `poll` window).
/// Cache and shard fields are zero/empty here — those are server-lifetime
/// numbers reported by [`Server::finish`].
pub fn summarize(responses: &[Response], elapsed_s: f64) -> ServeStats {
    let n = responses.len().max(1);
    let wall_total: f64 = responses.iter().map(|r| r.wall_seconds).sum();
    let modeled: f64 = responses.iter().map(|r| r.modeled_seconds).sum();
    let mut lat: Vec<f64> = responses.iter().map(Response::latency_seconds).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ServeStats {
        requests: responses.len(),
        submitted: responses.len() as u64,
        wall_total_s: wall_total,
        wall_mean_s: wall_total / n as f64,
        modeled_mean_s: modeled / n as f64,
        throughput_rps: responses.len() as f64 / elapsed_s.max(1e-9),
        p50_latency_s: percentile(&lat, 0.50),
        p95_latency_s: percentile(&lat, 0.95),
        cache_hits: 0,
        cache_misses: 0,
        batches: 0,
        mean_batch_size: 0.0,
        shard_utilization: Vec::new(),
        shard_requests: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::Delegate;
    use crate::model::graph::Layer;
    use crate::model::zoo;

    fn tiny_graph() -> Arc<Graph> {
        Arc::new(zoo::pix2pix(8, 2, 0))
    }

    fn tiny_config(shards: usize, workers_per_shard: usize) -> ServerConfig {
        ServerConfig {
            shards,
            workers_per_shard,
            queue_capacity: 16,
            max_batch: 2,
            ..ServerConfig::default()
        }
    }

    #[test]
    fn serves_all_requests_deterministically() {
        let g = tiny_graph();
        let mut server = Server::start(g.clone(), tiny_config(2, 1));
        for seed in 0..6 {
            server.submit(seed);
        }
        let responses = server.drain();
        assert_eq!(responses.len(), 6);
        assert_eq!(responses.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4, 5]);

        // Same seeds on a different topology => identical outputs
        // (end-to-end determinism, independent of sharding).
        let mut server2 = Server::start(g, tiny_config(1, 1));
        for seed in 0..6 {
            server2.submit(seed);
        }
        let responses2 = server2.drain();
        for (a, b) in responses.iter().zip(&responses2) {
            assert_eq!(a.output.data(), b.output.data());
        }
    }

    #[test]
    fn stats_cover_latency_cache_and_shards() {
        let g = tiny_graph();
        let mut server = Server::start(g, tiny_config(2, 1));
        for seed in 0..8 {
            server.submit(seed);
        }
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 8);
        assert_eq!(stats.requests, 8);
        assert_eq!(stats.submitted, 8);
        assert!(stats.wall_mean_s > 0.0);
        assert!(stats.modeled_mean_s > 0.0);
        assert!(stats.throughput_rps > 0.0);
        assert!(stats.p50_latency_s > 0.0);
        assert!(stats.p95_latency_s >= stats.p50_latency_s);
        assert_eq!(stats.shard_utilization.len(), 2);
        assert_eq!(stats.shard_requests.iter().sum::<u64>(), 8);
        assert!(stats.batches >= 4, "8 requests at max_batch 2 need >= 4 batches");
        // Every request after the first hits the shared plan cache.
        assert!(stats.cache_hits > 0);
        assert!(stats.cache_misses > 0);
        assert!(stats.cache_hit_rate() > 0.0 && stats.cache_hit_rate() < 1.0);
    }

    /// The acceptance criterion for the plan cache: N >= 2 requests for
    /// the same graph compile each TCONV layer exactly once, and the
    /// outputs are byte-identical to the uncached path.
    #[test]
    fn plan_cache_compiles_each_layer_once_across_requests() {
        let g = tiny_graph();
        let tconv_layers =
            g.layers.iter().filter(|l| matches!(l, Layer::Tconv { .. })).count() as u64;
        assert!(tconv_layers >= 2, "graph should exercise several layers");

        // Single worker => strictly sequential => exact counters.
        let mut server = Server::start(g.clone(), tiny_config(1, 1));
        let n_requests = 4u64;
        for seed in 0..n_requests {
            server.submit(seed);
        }
        let (responses, stats) = server.finish();
        assert_eq!(stats.cache_misses, tconv_layers, "each layer compiled exactly once");
        assert_eq!(stats.cache_hits, (n_requests - 1) * tconv_layers);

        // Byte-identical to the uncached executor on every request.
        let uncached = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
        for r in &responses {
            let mut rng = Pcg32::new(r.seed);
            let input = Tensor::<i8>::random(&g.input_shape, &mut rng);
            let want = uncached.run(&g, &input);
            assert_eq!(r.output.data(), want.output.data(), "seed {}", r.seed);
        }
    }

    #[test]
    fn poll_and_drain_return_each_response_exactly_once() {
        let g = tiny_graph();
        let mut server = Server::start(g, tiny_config(2, 2));
        let ids = server.submit_many(&(0..10u64).collect::<Vec<_>>());
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
        let mut seen = Vec::new();
        // Poll a few windows while work is in flight...
        for _ in 0..3 {
            seen.extend(server.poll().into_iter().map(|r| r.id));
            std::thread::yield_now();
        }
        // ...then close; drain returns only the remainder, sorted.
        let rest = server.drain();
        assert!(rest.windows(2).all(|w| w[0].id < w[1].id), "drain sorted by id");
        seen.extend(rest.iter().map(|r| r.id));
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn bounded_queue_refuses_when_paused_and_full() {
        let g = tiny_graph();
        let cfg = ServerConfig { queue_capacity: 3, ..tiny_config(1, 1) };
        let mut server = Server::start(g, cfg);
        server.pause();
        for seed in 0..3 {
            assert!(server.try_submit(seed).is_some());
        }
        assert_eq!(server.queued(), 3);
        assert_eq!(server.try_submit(99), None, "backpressure engaged");
        server.resume();
        let responses = server.drain();
        assert_eq!(responses.len(), 3);
    }
}
