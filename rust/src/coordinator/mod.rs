//! Production-style serving subsystem: layer-batched, sharded inference
//! over the model executor with a shared compiled-plan cache,
//! shard-persistent accelerators, and modeled-latency placement across a
//! (possibly heterogeneous) shard fleet (`examples/serve.rs`,
//! `repro serve`).
//!
//! The paper amortizes mapping work in hardware (maps generated once per
//! row, §IV-E); this layer applies the same principle to orchestration.
//! The full request path is documented in `docs/architecture.md`; in
//! brief:
//!
//! * **Compile once, serve many** — every worker's delegate resolves
//!   TCONV layer programs through one [`PlanCache`] shared across the
//!   server, so each distinct layer compiles exactly once per process
//!   *per backend config* (plan keys fingerprint the full
//!   [`AccelConfig`], so plans never cross backends; hit/miss counters
//!   surface in [`ServeStats`]).
//! * **Heterogeneous sharding with persistent accelerators** — workers
//!   are grouped into shards; each shard owns one persistent simulated
//!   MM2IM instance built from *its own* [`AccelConfig`]
//!   ([`ServerConfig::shard_accels`]), because no single `(X, UF)`
//!   instantiation wins across all 261 sweep configurations (§V-B).
//!   Outputs are byte-identical regardless of which shard serves a
//!   request — configs change cycles, never numerics.
//! * **Modeled-latency, weight-aware placement** — each batch is scored
//!   against every shard using the memoized
//!   [`perf_model`](crate::perf_model) estimate for that shard's config,
//!   minus a resident-weight bonus when the shard's accelerator already
//!   holds the batch's first filter set (so the PR-2 `LoadWeights` skip
//!   fires *across* consecutive batches). Among shards within the
//!   scorer's tolerance of the minimum, the smallest backlog wins — see
//!   [`placement`]. Decisions are recorded in
//!   [`ServeStats::placements`].
//! * **Weight-reuse layer batching** — a worker forms batches of
//!   *same-graph* requests (see [scheduling](#batch-scheduling-and-fairness)) and executes them with
//!   `Executor::run_batch`: each TCONV layer runs once for the whole
//!   batch, paying one `Configure`/`LoadWeights` prologue per tile
//!   instead of one per request (GANAX-style decoupled access/execute;
//!   the amortization surfaces as [`ServeStats::weight_load_hit_rate`]).
//! * **Async submission with backpressure** — the request queue is
//!   bounded ([`ServerConfig::queue_capacity`]): [`Server::submit`]
//!   blocks when full, [`Server::try_submit`] refuses, [`Server::poll`]
//!   collects finished responses without closing, and
//!   [`Server::finish`]/[`Server::drain`] close and join.
//!
//! # Batch scheduling and fairness
//!
//! A worker forms a batch by taking the queue's **head** request and then
//! pulling up to [`ServerConfig::max_batch`] requests *of the same
//! group* (same graph, hence same layer/`PlanKey` chain) from the first
//! [`ServerConfig::group_window`] queued entries; other groups keep
//! their queue positions. Because the batch group is always the oldest
//! waiting request's group, a hot layer group can never starve the
//! others: any request reaches the head after at most the batches needed
//! to serve the requests queued before it, and out-of-order pulls are
//! bounded by `group_window`. Placement then routes the formed batch to
//! a shard (any idle worker may place; only the target shard's workers
//! execute), so head-of-line fairness and shard choice stay independent
//! concerns.

pub mod placement;

use crate::accel::{AccelConfig, WeightSetSig};
use crate::driver::{Delegate, PlanCache};
use crate::model::executor::{Executor, RunConfig};
use crate::model::graph::Graph;
use crate::perf_model::EstimateCache;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;
use placement::PlacementTable;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

pub use placement::{PlacementDecision, PlacementPolicy};

/// One generation request: a seed for the latent/input tensor of one of
/// the server's graphs.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    /// Submission-order id.
    pub id: u64,
    /// Seed deriving the input tensor.
    pub seed: u64,
    /// Index into the server's graph list (the batching group).
    pub graph: usize,
    enqueued: Instant,
}

/// Completed response with measured host wall-clock and modeled
/// PYNQ-Z1 latency for the shard's device configuration.
#[derive(Clone, Debug)]
pub struct Response {
    /// Submission-order id.
    pub id: u64,
    /// Seed the input tensor was derived from.
    pub seed: u64,
    /// Graph (batching group) the request targeted.
    pub graph: usize,
    /// Shard (simulated accelerator instance) that served the request.
    pub shard: usize,
    /// Final int8 output tensor.
    pub output: Tensor<i8>,
    /// Seconds spent waiting in the bounded queue.
    pub queue_seconds: f64,
    /// Host wall-clock seconds of the numerics pass (amortized share of
    /// the batch the request rode in).
    pub wall_seconds: f64,
    /// Modeled end-to-end seconds on the PYNQ-Z1 testbed for the
    /// serving shard's config (amortized share of the batch).
    pub modeled_seconds: f64,
}

impl Response {
    /// Queue wait + execution: the latency a client observes.
    pub fn latency_seconds(&self) -> f64 {
        self.queue_seconds + self.wall_seconds
    }
}

/// Server topology and policy.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Simulated accelerator instances (worker groups). >= 1. Ignored
    /// when [`ServerConfig::shard_accels`] is non-empty (its length
    /// defines the fleet).
    pub shards: usize,
    /// Worker threads per shard. >= 1.
    pub workers_per_shard: usize,
    /// Bounded request-queue capacity; `submit` blocks and `try_submit`
    /// refuses once `queue_capacity` requests are waiting (un-routed
    /// *plus* routed-but-unserved, so placement cannot turn the bound
    /// into unbounded per-shard backlogs).
    pub queue_capacity: usize,
    /// Max same-group requests one worker batches per queue round-trip
    /// (the layer-batching width).
    pub max_batch: usize,
    /// How deep past the queue head the batch scheduler may scan for
    /// same-group requests (the fairness bound on out-of-order pulls —
    /// see the [module docs](self#batch-scheduling-and-fairness)).
    pub group_window: usize,
    /// Compiled plans the shared cache may hold (>= distinct TCONV
    /// layers x distinct shard configs to avoid thrash).
    pub plan_cache_capacity: usize,
    /// CPU threads per worker for non-offloaded layers.
    pub cpu_threads: usize,
    /// Offload TCONV layers to the simulated accelerator.
    pub use_accelerator: bool,
    /// Device configuration used for modeled latency.
    pub run_config: RunConfig,
    /// Accelerator configuration shared by every shard of a homogeneous
    /// fleet (ignored when [`ServerConfig::shard_accels`] is set).
    pub accel: AccelConfig,
    /// Heterogeneous fleet: one [`AccelConfig`] per shard. Empty (the
    /// default) means `shards` copies of [`ServerConfig::accel`].
    pub shard_accels: Vec<AccelConfig>,
    /// How batches are routed to shards (modeled-latency scorer by
    /// default; round-robin as the route-blind baseline). CPU-only
    /// servers (`use_accelerator: false`) always route round-robin —
    /// accelerator latency estimates and resident-weight bonuses
    /// describe hardware those servers never touch.
    pub placement: PlacementPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            shards: 1,
            workers_per_shard: 1,
            queue_capacity: 64,
            max_batch: 4,
            group_window: 64,
            plan_cache_capacity: 64,
            cpu_threads: 1,
            use_accelerator: true,
            run_config: RunConfig::AccPlusCpu { threads: 1 },
            accel: AccelConfig::default(),
            shard_accels: Vec::new(),
            placement: PlacementPolicy::default(),
        }
    }
}

impl ServerConfig {
    /// Shards the fleet resolves to: `shard_accels.len()` when set,
    /// else [`ServerConfig::shards`] (clamped to >= 1).
    pub fn shard_count(&self) -> usize {
        if self.shard_accels.is_empty() {
            self.shards.max(1)
        } else {
            self.shard_accels.len()
        }
    }

    /// The fleet's per-shard configs: [`ServerConfig::shard_accels`]
    /// verbatim when set, else [`ServerConfig::shard_count`] copies of
    /// [`ServerConfig::accel`].
    pub fn shard_configs(&self) -> Vec<AccelConfig> {
        if self.shard_accels.is_empty() {
            vec![self.accel.clone(); self.shard_count()]
        } else {
            self.shard_accels.clone()
        }
    }

    /// Total worker threads the server spawns.
    pub fn workers(&self) -> usize {
        self.shard_count() * self.workers_per_shard.max(1)
    }
}

struct State {
    /// Requests not yet grouped or routed (the bounded client queue).
    pending: VecDeque<Request>,
    /// Batches already routed, per target shard, awaiting that shard's
    /// workers. Any idle worker may *place*; only the target executes.
    placed: Vec<VecDeque<Vec<Request>>>,
    /// Requests sitting in `placed` queues (routed, not yet picked up
    /// for execution). Counted against `queue_capacity` so placement
    /// cannot launder the bounded queue into unbounded per-shard
    /// backlogs: `submit` blocks on `pending + staged`.
    staged: usize,
    done: Vec<Response>,
    closed: bool,
    /// While true, workers leave the queues untouched (maintenance /
    /// deterministic backpressure tests). Closing overrides pausing.
    paused: bool,
    /// Requests routed to each shard and not yet completed (the
    /// scorer's tie-breaker).
    backlog: Vec<u64>,
    /// Predicted resident filter-set signature per shard: what the
    /// shard's accelerator BRAM will hold once its placed batches
    /// execute. Exact for single-worker shards executing in placement
    /// order; a best-effort heuristic beyond that.
    resident: Vec<Option<WeightSetSig>>,
    /// Round-robin cursor for [`PlacementPolicy::RoundRobin`].
    rr_next: usize,
    /// Most recent routing decisions (ring-buffered at
    /// [`PLACEMENT_WINDOW`] so a long-lived server's memory stays
    /// bounded), in placement order while under the window.
    placements: Vec<PlacementDecision>,
    /// Next ring slot once the placement window is full.
    placement_slot: usize,
}

impl State {
    /// Record a routing decision, rotating the oldest out once the
    /// window is full (mirrors the latency window).
    fn record_placement(&mut self, d: PlacementDecision) {
        if self.placements.len() < PLACEMENT_WINDOW {
            self.placements.push(d);
        } else {
            self.placements[self.placement_slot] = d;
            self.placement_slot = (self.placement_slot + 1) % PLACEMENT_WINDOW;
        }
    }
}

/// Latency samples kept for percentile reporting; older samples rotate
/// out ring-buffer style so a long-lived server's memory stays bounded.
const LATENCY_WINDOW: usize = 65_536;

/// Placement decisions kept in [`ServeStats::placements`]; older
/// decisions rotate out so a long-lived server's memory stays bounded.
const PLACEMENT_WINDOW: usize = 65_536;

/// Running aggregates, independent of `poll` draining `done`.
#[derive(Default)]
struct Metrics {
    /// Most recent `LATENCY_WINDOW` request latencies (queue + run).
    latencies_s: Vec<f64>,
    /// Next ring slot once the window is full.
    latency_slot: usize,
    /// Total requests served over the server's lifetime.
    served: u64,
    wall_total_s: f64,
    modeled_total_s: f64,
    batches: u64,
    /// Weight loads actually performed across all layer executions.
    weight_loads: u64,
    /// Weight loads elided because the filter set was already resident.
    weight_loads_skipped: u64,
    /// Weight loads a per-request replay would have performed.
    weight_loads_equiv: u64,
    /// Batches whose *first* TCONV stream skipped its weight load — the
    /// cross-batch resident hits the placement scorer steers toward.
    cross_batch_resident_hits: u64,
}

impl Metrics {
    fn record_latency(&mut self, v: f64) {
        self.served += 1;
        if self.latencies_s.len() < LATENCY_WINDOW {
            self.latencies_s.push(v);
        } else {
            self.latencies_s[self.latency_slot] = v;
            self.latency_slot = (self.latency_slot + 1) % LATENCY_WINDOW;
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct ShardStat {
    busy_s: f64,
    requests: u64,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for work or close.
    work_cv: Condvar,
    /// Submitters wait here for queue space.
    space_cv: Condvar,
    metrics: Mutex<Metrics>,
    shards: Mutex<Vec<ShardStat>>,
}

/// Layer-batched, sharded inference server over one or more model
/// graphs, with modeled-latency placement across a possibly
/// heterogeneous shard fleet.
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    cache: Arc<PlanCache>,
    graphs: Vec<Arc<Graph>>,
    config: ServerConfig,
    shard_cfgs: Vec<AccelConfig>,
    submitted: u64,
    started: Instant,
}

impl Server {
    /// Single-graph server: every request targets `graph` (group 0).
    pub fn start(graph: Arc<Graph>, config: ServerConfig) -> Self {
        Self::start_multi(vec![graph], config)
    }

    /// Spawn `config.workers()` threads over the shard fleet; each
    /// worker owns an executor whose delegate shares the server-wide plan
    /// cache *and its shard's persistent accelerator*, built from that
    /// shard's own [`AccelConfig`] (so BRAM/weight state survives across
    /// the shard's batches and heterogeneous fleets are possible).
    /// Requests are grouped for layer batching by their graph index and
    /// routed to shards by [`ServerConfig::placement`]; the placement
    /// table (modeled latencies + weight signatures per `(graph, shard)`
    /// pair) is precomputed here so the dispatch path stays cheap.
    pub fn start_multi(graphs: Vec<Arc<Graph>>, config: ServerConfig) -> Self {
        assert!(!graphs.is_empty(), "server needs at least one graph");
        if matches!(config.run_config, RunConfig::AccPlusCpu { .. }) {
            assert!(
                config.use_accelerator,
                "AccPlusCpu modeling requires use_accelerator (no cycle reports otherwise)"
            );
        }
        // Normalize the topology once; `submit` reads the stored config,
        // so a zero queue capacity must be clamped here or backpressure
        // would block forever.
        let mut config = config;
        config.queue_capacity = config.queue_capacity.max(1);
        config.group_window = config.group_window.max(1);
        let shard_cfgs = config.shard_configs();
        let shards = shard_cfgs.len();
        config.shards = shards;
        let workers_per_shard = config.workers_per_shard.max(1);
        let cache = PlanCache::shared(config.plan_cache_capacity.max(1));
        // Score inputs for the placement table are memoized per (layer
        // geometry, config) — graphs sharing layer shapes across the
        // fleet pay the analytical walk once.
        let estimates = EstimateCache::new();
        let table = Arc::new(PlacementTable::build(&graphs, &shard_cfgs, &estimates));
        // One persistent accelerator per shard, built from the shard's
        // own config and shared by its workers.
        let shard_accels: Vec<_> = shard_cfgs.iter().map(Delegate::shared_accelerator).collect();
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                pending: VecDeque::new(),
                placed: vec![VecDeque::new(); shards],
                staged: 0,
                done: Vec::new(),
                closed: false,
                paused: false,
                backlog: vec![0; shards],
                resident: vec![None; shards],
                rr_next: 0,
                placements: Vec::new(),
                placement_slot: 0,
            }),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            metrics: Mutex::new(Metrics::default()),
            shards: Mutex::new(vec![ShardStat::default(); shards]),
        });

        let mut handles = Vec::with_capacity(shards * workers_per_shard);
        for worker_idx in 0..shards * workers_per_shard {
            let shard = worker_idx % shards;
            let shard_cfg = shard_cfgs[shard].clone();
            let shared = shared.clone();
            let graphs = graphs.clone();
            let cache = cache.clone();
            let accel = shard_accels[shard].clone();
            let cfg = config.clone();
            let table = table.clone();
            handles.push(std::thread::spawn(move || {
                let exec = Executor::with_shared_accelerator(
                    shard_cfg.clone(),
                    cfg.cpu_threads,
                    cfg.use_accelerator,
                    cache,
                    accel,
                );
                worker_loop(&shared, &graphs, &exec, &cfg, shard, &shard_cfg, &table);
            }));
        }
        Self {
            shared,
            workers: handles,
            cache,
            graphs,
            config,
            shard_cfgs,
            submitted: 0,
            started: Instant::now(),
        }
    }

    /// Enqueue one request for graph 0, blocking while the queue is at
    /// capacity (backpressure). Returns the request id (submission
    /// order).
    ///
    /// Caution: while the server is [`Server::pause`]d, nothing drains
    /// the queue, so a blocking submit past `queue_capacity` would wait
    /// until `resume` — which this same thread can then never call. Use
    /// [`Server::try_submit`] when submitting to a paused server.
    pub fn submit(&mut self, seed: u64) -> u64 {
        self.submit_to(0, seed)
    }

    /// Enqueue one request for graph `graph` (blocking backpressure, see
    /// [`Server::submit`]).
    pub fn submit_to(&mut self, graph: usize, seed: u64) -> u64 {
        assert!(graph < self.graphs.len(), "graph {graph} out of range");
        let id = self.next_id();
        let mut st = self.shared.state.lock().unwrap();
        while st.pending.len() + st.staged >= self.config.queue_capacity {
            st = self.shared.space_cv.wait(st).unwrap();
        }
        st.pending.push_back(Request { id, seed, graph, enqueued: Instant::now() });
        drop(st);
        self.shared.work_cv.notify_one();
        id
    }

    /// Non-blocking submit for graph 0: `None` when the queue is full.
    pub fn try_submit(&mut self, seed: u64) -> Option<u64> {
        self.try_submit_to(0, seed)
    }

    /// Non-blocking submit for graph `graph`: `None` when the queue is
    /// full.
    pub fn try_submit_to(&mut self, graph: usize, seed: u64) -> Option<u64> {
        assert!(graph < self.graphs.len(), "graph {graph} out of range");
        let shared = self.shared.clone();
        let mut st = shared.state.lock().unwrap();
        if st.pending.len() + st.staged >= self.config.queue_capacity {
            return None;
        }
        let id = self.next_id();
        st.pending.push_back(Request { id, seed, graph, enqueued: Instant::now() });
        drop(st);
        shared.work_cv.notify_one();
        Some(id)
    }

    /// Blocking bulk submission to graph 0; returns the ids in seed
    /// order.
    pub fn submit_many(&mut self, seeds: &[u64]) -> Vec<u64> {
        seeds.iter().map(|&s| self.submit(s)).collect()
    }

    /// Collect responses completed so far (sorted by id) without closing
    /// the queue.
    pub fn poll(&mut self) -> Vec<Response> {
        let mut out = std::mem::take(&mut self.shared.state.lock().unwrap().done);
        out.sort_by_key(|r| r.id);
        out
    }

    /// Stop workers from taking new work (in-flight batches complete).
    /// While paused, prefer [`Server::try_submit`] over the blocking
    /// [`Server::submit`] — see the caution there.
    pub fn pause(&mut self) {
        self.shared.state.lock().unwrap().paused = true;
    }

    /// Resume a paused server.
    pub fn resume(&mut self) {
        self.shared.state.lock().unwrap().paused = false;
        self.shared.work_cv.notify_all();
    }

    /// Requests waiting in the bounded client queue, before routing.
    /// Routed-but-unserved batches are not counted here (they left the
    /// queue at placement time) but still occupy `queue_capacity` for
    /// backpressure purposes.
    pub fn queued(&self) -> usize {
        self.shared.state.lock().unwrap().pending.len()
    }

    /// Close the queue, serve everything still pending, and collect the
    /// remaining responses (sorted by id) — responses already taken by
    /// `poll` are not repeated.
    pub fn drain(self) -> Vec<Response> {
        self.finish().0
    }

    /// `drain` plus the server-lifetime statistics: plan-cache counters,
    /// weight-load amortization, placement decisions, per-shard
    /// utilization, and latency percentiles (computed over the most
    /// recent 65 536 requests — see [`ServeStats`]).
    pub fn finish(self) -> (Vec<Response>, ServeStats) {
        let Server { shared, workers, cache, graphs: _, config, shard_cfgs, submitted, started } =
            self;
        {
            let mut st = shared.state.lock().unwrap();
            st.closed = true;
        }
        shared.work_cv.notify_all();
        for h in workers {
            h.join().expect("worker panicked");
        }
        let (mut done, placements) = {
            let mut st = shared.state.lock().unwrap();
            debug_assert!(st.backlog.iter().all(|&b| b == 0), "backlog must drain");
            debug_assert_eq!(st.staged, 0, "no batch may be left staged after join");
            (std::mem::take(&mut st.done), std::mem::take(&mut st.placements))
        };
        done.sort_by_key(|r| r.id);

        let elapsed_s = started.elapsed().as_secs_f64();
        let m = shared.metrics.lock().unwrap();
        let mut lat = m.latencies_s.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let served = m.served as usize;
        let cache_stats = cache.stats();
        let shard_stats = shared.shards.lock().unwrap();
        let per_slot = elapsed_s.max(1e-9) * config.workers_per_shard.max(1) as f64;
        let stats = ServeStats {
            requests: served,
            submitted,
            wall_total_s: m.wall_total_s,
            wall_mean_s: m.wall_total_s / served.max(1) as f64,
            modeled_mean_s: m.modeled_total_s / served.max(1) as f64,
            throughput_rps: served as f64 / elapsed_s.max(1e-9),
            p50_latency_s: percentile(&lat, 0.50),
            p95_latency_s: percentile(&lat, 0.95),
            cache_hits: cache_stats.hits,
            cache_misses: cache_stats.misses,
            batches: m.batches,
            mean_batch_size: served as f64 / m.batches.max(1) as f64,
            weight_loads: m.weight_loads,
            weight_loads_skipped: m.weight_loads_skipped,
            weight_loads_equiv: m.weight_loads_equiv,
            cross_batch_resident_hits: m.cross_batch_resident_hits,
            shard_utilization: shard_stats.iter().map(|s| s.busy_s / per_slot).collect(),
            shard_requests: shard_stats.iter().map(|s| s.requests).collect(),
            shard_config_fps: shard_cfgs.iter().map(AccelConfig::fingerprint).collect(),
            placements,
        };
        (done, stats)
    }

    fn next_id(&mut self) -> u64 {
        let id = self.submitted;
        self.submitted += 1;
        id
    }
}

/// Form one batch from the queue: the head request picks the group, then
/// up to `max_batch` same-group requests are pulled from the first
/// `window` queued entries (others keep their positions). Head-of-line
/// group selection is the starvation bound: the oldest waiting request
/// always defines the next batch.
fn take_group(pending: &mut VecDeque<Request>, max_batch: usize, window: usize) -> Vec<Request> {
    let group = pending.front().expect("take_group on empty queue").graph;
    let mut batch = Vec::with_capacity(max_batch.min(pending.len()));
    let mut i = 0;
    let mut scanned = 0;
    while i < pending.len() && batch.len() < max_batch && scanned < window {
        if pending[i].graph == group {
            batch.push(pending.remove(i).expect("index in range"));
        } else {
            i += 1;
        }
        scanned += 1;
    }
    batch
}

fn worker_loop(
    shared: &Shared,
    graphs: &[Arc<Graph>],
    exec: &Executor,
    cfg: &ServerConfig,
    shard: usize,
    shard_cfg: &AccelConfig,
    table: &PlacementTable,
) {
    let max_batch = cfg.max_batch.max(1);
    // CPU-only fleets never touch an accelerator: modeled accelerator
    // latencies and resident bonuses would be fiction, so fall back to
    // round-robin and leave the resident shadows untouched.
    let policy = if cfg.use_accelerator { cfg.placement } else { PlacementPolicy::RoundRobin };
    loop {
        let batch: Vec<Request> = {
            let mut st = shared.state.lock().unwrap();
            loop {
                let active = !st.paused || st.closed;
                if active {
                    // 1) Work already routed to this shard.
                    if let Some(batch) = st.placed[shard].pop_front() {
                        st.staged -= batch.len();
                        shared.space_cv.notify_all();
                        break batch;
                    }
                    // 2) Route new work: form the head-of-line batch and
                    // score it against every shard. Any worker places;
                    // only the target shard executes.
                    if !st.pending.is_empty() {
                        let batch = take_group(&mut st.pending, max_batch, cfg.group_window);
                        shared.space_cv.notify_all();
                        let graph = batch[0].graph;
                        let shards = st.placed.len();
                        let (target, scores_s, resident_hit_predicted) = match policy {
                            PlacementPolicy::Modeled { tolerance } => {
                                table.choose(graph, &st.resident, &st.backlog, tolerance)
                            }
                            PlacementPolicy::RoundRobin => {
                                let t = st.rr_next % shards;
                                st.rr_next = st.rr_next.wrapping_add(1);
                                let (scores, hits) = table.score_all(graph, &st.resident);
                                (t, scores, hits[t])
                            }
                        };
                        st.backlog[target] += batch.len() as u64;
                        // A graph with no TCONV layers never touches the
                        // accelerator: the shard's resident set survives
                        // it, so only overwrite the shadow with a real
                        // signature (and not at all on CPU-only fleets).
                        if cfg.use_accelerator {
                            if let Some(sig) = table.last_sig(graph, target) {
                                st.resident[target] = Some(sig);
                            }
                        }
                        st.record_placement(PlacementDecision {
                            graph,
                            requests: batch.len(),
                            shard: target,
                            scores_s,
                            resident_hit_predicted,
                        });
                        if target == shard {
                            break batch;
                        }
                        st.staged += batch.len();
                        st.placed[target].push_back(batch);
                        shared.work_cv.notify_all();
                        continue;
                    }
                }
                if st.closed && st.pending.is_empty() && st.placed[shard].is_empty() {
                    return;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };

        let n = batch.len();
        let graph = &graphs[batch[0].graph];
        let t_batch = Instant::now();
        let queue_seconds: Vec<f64> =
            batch.iter().map(|r| r.enqueued.elapsed().as_secs_f64()).collect();
        let inputs: Vec<Tensor<i8>> = batch
            .iter()
            .map(|r| {
                let mut rng = Pcg32::new(r.seed);
                Tensor::<i8>::random(&graph.input_shape, &mut rng)
            })
            .collect();

        // Layer-batched execution: every TCONV layer runs once for the
        // whole (same-graph) batch on the shard's persistent accelerator.
        let t0 = Instant::now();
        let run = exec.run_batch(graph, &inputs);
        let wall_batch = t0.elapsed().as_secs_f64();
        let modeled_batch = run.modeled(cfg.run_config, shard_cfg).total_s();
        let wl = run.weight_load_counters();
        let cross_batch_hit = run.first_layer_resident_hit();
        // Amortized per-request shares.
        let wall_each = wall_batch / n as f64;
        let modeled_each = modeled_batch / n as f64;

        let mut responses = Vec::with_capacity(n);
        let mut latencies = Vec::with_capacity(n);
        for ((req, output), queue_s) in batch.iter().zip(run.outputs).zip(&queue_seconds) {
            // A response is delivered only when its whole batch finishes:
            // client-observed latency counts the full batch wall time,
            // while `wall_seconds` carries the amortized per-request share.
            latencies.push(queue_s + wall_batch);
            responses.push(Response {
                id: req.id,
                seed: req.seed,
                graph: req.graph,
                shard,
                output,
                queue_seconds: *queue_s,
                wall_seconds: wall_each,
                modeled_seconds: modeled_each,
            });
        }
        let busy_s = t_batch.elapsed().as_secs_f64();

        {
            let mut st = shared.state.lock().unwrap();
            st.done.extend(responses);
            st.backlog[shard] -= n as u64;
        }
        {
            let mut m = shared.metrics.lock().unwrap();
            for v in latencies {
                m.record_latency(v);
            }
            m.wall_total_s += wall_batch;
            m.modeled_total_s += modeled_batch;
            m.batches += 1;
            m.weight_loads += wl.performed;
            m.weight_loads_skipped += wl.skipped;
            m.weight_loads_equiv += wl.equivalent;
            if cross_batch_hit {
                m.cross_batch_resident_hits += 1;
            }
        }
        {
            let mut sh = shared.shards.lock().unwrap();
            sh[shard].busy_s += busy_s;
            sh[shard].requests += n as u64;
        }
    }
}

/// Serve-run summary. Latency percentiles cover queue wait + execution
/// (a 65 536-request recency window bounds memory on very long runs);
/// `shard_utilization[i]` is shard i's busy time over the run, normalized
/// per worker slot (1.0 = that shard's workers never idled).
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Requests actually served.
    pub requests: usize,
    /// Requests submitted over the server's lifetime.
    pub submitted: u64,
    /// Total host wall-clock seconds spent in numerics passes.
    pub wall_total_s: f64,
    /// Mean per-request host wall-clock seconds (amortized over batches).
    pub wall_mean_s: f64,
    /// Mean per-request modeled PYNQ-Z1 seconds (amortized over batches,
    /// on each serving shard's own config).
    pub modeled_mean_s: f64,
    /// Served requests per host wall-clock second.
    pub throughput_rps: f64,
    /// Median client-observed latency (queue wait + execution).
    pub p50_latency_s: f64,
    /// 95th-percentile client-observed latency.
    pub p95_latency_s: f64,
    /// Compiled-plan cache hits across all workers.
    pub cache_hits: u64,
    /// Compiled-plan cache misses (= compilations) across all workers.
    pub cache_misses: u64,
    /// Worker queue round-trips; `mean_batch_size` = requests / batches.
    pub batches: u64,
    /// Mean layer-batch width achieved by the group scheduler.
    pub mean_batch_size: f64,
    /// `LoadWeights` transfers actually performed across all layer
    /// executions (batched prologues + resident-skip elisions reduce
    /// this).
    pub weight_loads: u64,
    /// `LoadWeights` elided because the filter set was already resident
    /// in PM BRAM (within-batch and cross-batch skips).
    pub weight_loads_skipped: u64,
    /// `LoadWeights` transfers a per-request replay would have performed
    /// (requests x tiles per TCONV execution).
    pub weight_loads_equiv: u64,
    /// Batches whose first TCONV stream skipped its weight load because
    /// the previous batch on that shard left the same filter set
    /// resident — the cross-batch hits weight-aware placement creates.
    pub cross_batch_resident_hits: u64,
    /// Per-shard busy fraction (1.0 = that shard's workers never idled).
    pub shard_utilization: Vec<f64>,
    /// Requests served per shard.
    pub shard_requests: Vec<u64>,
    /// [`AccelConfig::fingerprint`] of each shard's accelerator — equal
    /// entries mean a homogeneous fleet.
    pub shard_config_fps: Vec<u64>,
    /// Batch-routing decisions (scores are modeled seconds per shard
    /// with the resident bonus applied), in placement order while under
    /// the 65 536-decision recency window; older decisions rotate out so
    /// a long-lived server's memory stays bounded.
    pub placements: Vec<PlacementDecision>,
}

impl ServeStats {
    /// Fraction of plan lookups served from cache (0 when none).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Fraction of per-request-equivalent weight loads that batching and
    /// resident-weight reuse eliminated (0 for per-request traffic, 1 -
    /// 1/N for full same-layer batches of width N, higher when
    /// cross-batch resident skips fire).
    pub fn weight_load_hit_rate(&self) -> f64 {
        if self.weight_loads_equiv == 0 {
            0.0
        } else {
            1.0 - self.weight_loads as f64 / self.weight_loads_equiv as f64
        }
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Summary over an explicit response set (e.g. one `poll` window).
/// Cache, shard, and placement fields are zero/empty here — those are
/// server-lifetime numbers reported by [`Server::finish`].
pub fn summarize(responses: &[Response], elapsed_s: f64) -> ServeStats {
    let n = responses.len().max(1);
    let wall_total: f64 = responses.iter().map(|r| r.wall_seconds).sum();
    let modeled: f64 = responses.iter().map(|r| r.modeled_seconds).sum();
    let mut lat: Vec<f64> = responses.iter().map(Response::latency_seconds).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ServeStats {
        requests: responses.len(),
        submitted: responses.len() as u64,
        wall_total_s: wall_total,
        wall_mean_s: wall_total / n as f64,
        modeled_mean_s: modeled / n as f64,
        throughput_rps: responses.len() as f64 / elapsed_s.max(1e-9),
        p50_latency_s: percentile(&lat, 0.50),
        p95_latency_s: percentile(&lat, 0.95),
        cache_hits: 0,
        cache_misses: 0,
        batches: 0,
        mean_batch_size: 0.0,
        weight_loads: 0,
        weight_loads_skipped: 0,
        weight_loads_equiv: 0,
        cross_batch_resident_hits: 0,
        shard_utilization: Vec::new(),
        shard_requests: Vec::new(),
        shard_config_fps: Vec::new(),
        placements: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::Delegate;
    use crate::model::graph::Layer;
    use crate::model::zoo;

    fn tiny_graph() -> Arc<Graph> {
        Arc::new(zoo::pix2pix(8, 2, 0))
    }

    fn tiny_config(shards: usize, workers_per_shard: usize) -> ServerConfig {
        ServerConfig {
            shards,
            workers_per_shard,
            queue_capacity: 16,
            max_batch: 2,
            ..ServerConfig::default()
        }
    }

    #[test]
    fn serves_all_requests_deterministically() {
        let g = tiny_graph();
        let mut server = Server::start(g.clone(), tiny_config(2, 1));
        for seed in 0..6 {
            server.submit(seed);
        }
        let responses = server.drain();
        assert_eq!(responses.len(), 6);
        assert_eq!(responses.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4, 5]);

        // Same seeds on a different topology => identical outputs
        // (end-to-end determinism, independent of sharding).
        let mut server2 = Server::start(g, tiny_config(1, 1));
        for seed in 0..6 {
            server2.submit(seed);
        }
        let responses2 = server2.drain();
        for (a, b) in responses.iter().zip(&responses2) {
            assert_eq!(a.output.data(), b.output.data());
        }
    }

    #[test]
    fn stats_cover_latency_cache_weights_shards_and_placements() {
        let g = tiny_graph();
        let mut server = Server::start(g, tiny_config(2, 1));
        for seed in 0..8 {
            server.submit(seed);
        }
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 8);
        assert_eq!(stats.requests, 8);
        assert_eq!(stats.submitted, 8);
        assert!(stats.wall_mean_s > 0.0);
        assert!(stats.modeled_mean_s > 0.0);
        assert!(stats.throughput_rps > 0.0);
        assert!(stats.p50_latency_s > 0.0);
        assert!(stats.p95_latency_s >= stats.p50_latency_s);
        assert_eq!(stats.shard_utilization.len(), 2);
        assert_eq!(stats.shard_requests.iter().sum::<u64>(), 8);
        assert!(stats.batches >= 4, "8 requests at max_batch 2 need >= 4 batches");
        // A homogeneous default fleet: identical config fingerprints,
        // and one recorded decision per batch with one score per shard.
        assert_eq!(stats.shard_config_fps, vec![AccelConfig::default().fingerprint(); 2]);
        assert_eq!(stats.placements.len(), stats.batches as usize);
        assert_eq!(
            stats.placements.iter().map(|d| d.requests as u64).sum::<u64>(),
            8,
            "placements cover every request exactly once"
        );
        assert!(stats.placements.iter().all(|d| d.scores_s.len() == 2));
        // Plans are looked up once per (batch, layer); each layer
        // compiled once, everything else hit.
        assert!(stats.cache_hits > 0);
        assert!(stats.cache_misses > 0);
        assert!(stats.cache_hit_rate() > 0.0 && stats.cache_hit_rate() < 1.0);
        // Weight-load accounting is present and consistent.
        assert!(stats.weight_loads > 0);
        assert!(stats.weight_loads_equiv >= stats.weight_loads);
        let rate = stats.weight_load_hit_rate();
        assert!((0.0..1.0).contains(&rate), "hit rate {rate}");
    }

    /// The plan-cache acceptance criterion, batching-aware: N requests
    /// for the same graph compile each TCONV layer exactly once and look
    /// plans up once per (batch, layer); outputs are byte-identical to
    /// the uncached path. (The placement table compiles its signature
    /// plans *outside* the shared cache, so these counters stay exact.)
    #[test]
    fn plan_cache_compiles_each_layer_once_across_requests() {
        let g = tiny_graph();
        let tconv_layers =
            g.layers.iter().filter(|l| matches!(l, Layer::Tconv { .. })).count() as u64;
        assert!(tconv_layers >= 2, "graph should exercise several layers");

        // Single worker + pre-filled queue => deterministic batching:
        // 4 requests at max_batch 2 form exactly 2 batches.
        let mut server = Server::start(g.clone(), tiny_config(1, 1));
        server.pause();
        let n_requests = 4u64;
        for seed in 0..n_requests {
            server.submit(seed);
        }
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(stats.batches, 2, "4 queued requests at max_batch 2");
        assert_eq!(stats.cache_misses, tconv_layers, "each layer compiled exactly once");
        assert_eq!(stats.cache_hits, (stats.batches - 1) * tconv_layers);
        // A full same-layer batch of 2 halves the weight loads.
        assert_eq!(stats.weight_loads_equiv, 2 * stats.weight_loads);
        assert!((stats.weight_load_hit_rate() - 0.5).abs() < 1e-12);

        // Byte-identical to the uncached executor on every request.
        let uncached = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
        for r in &responses {
            let mut rng = Pcg32::new(r.seed);
            let input = Tensor::<i8>::random(&g.input_shape, &mut rng);
            let want = uncached.run(&g, &input);
            assert_eq!(r.output.data(), want.output.data(), "seed {}", r.seed);
        }
    }

    #[test]
    fn multi_graph_requests_group_by_graph_and_stay_correct() {
        // Two graphs with different weights (and layer chains / PlanKeys).
        let g0 = Arc::new(zoo::pix2pix(8, 2, 0));
        let g1 = Arc::new(zoo::pix2pix(8, 2, 7));
        let mut server = Server::start_multi(vec![g0.clone(), g1.clone()], tiny_config(1, 1));
        server.pause();
        // Interleaved submission; the scheduler regroups by graph.
        for seed in 0..6u64 {
            server.submit_to((seed % 2) as usize, seed);
        }
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 6);

        // Outputs byte-identical to per-request runs on the right graph.
        let reference = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
        for r in &responses {
            let g = if r.graph == 0 { &g0 } else { &g1 };
            let mut rng = Pcg32::new(r.seed);
            let input = Tensor::<i8>::random(&g.input_shape, &mut rng);
            let want = reference.run(g, &input);
            assert_eq!(r.output.data(), want.output.data(), "id {} graph {}", r.id, r.graph);
        }
        // Batches never mix groups, so 3 same-graph requests at
        // max_batch 2 make 2 batches per graph.
        assert_eq!(stats.batches, 4);
    }

    #[test]
    fn head_of_line_group_defines_each_batch() {
        // Queue: [g1, g0, g0] with one worker, max_batch 2. The head (g1)
        // forms a singleton batch even though two g0 requests could fill
        // a batch — that is the starvation bound.
        let g0 = Arc::new(zoo::pix2pix(8, 2, 0));
        let g1 = Arc::new(zoo::pix2pix(8, 2, 7));
        let mut server = Server::start_multi(vec![g0, g1], tiny_config(1, 1));
        server.pause();
        server.submit_to(1, 10);
        server.submit_to(0, 11);
        server.submit_to(0, 12);
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 3);
        assert_eq!(stats.batches, 2, "one singleton (head group) + one pair");
        assert!((stats.mean_batch_size - 1.5).abs() < 1e-12);
    }

    #[test]
    fn group_window_bounds_out_of_order_pulls() {
        let mut pending: VecDeque<Request> = VecDeque::new();
        let mk = |id: u64, graph: usize| Request { id, seed: id, graph, enqueued: Instant::now() };
        // g0 at positions 0, 2, 4; g1 at 1, 3.
        for (i, g) in [0usize, 1, 0, 1, 0].iter().enumerate() {
            pending.push_back(mk(i as u64, *g));
        }
        // Window 3: scans positions 0..3 only — picks g0 ids 0 and 2, the
        // g0 at original position 4 stays put.
        let batch = take_group(&mut pending, 8, 3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(pending.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3, 4]);
        // Unbounded window takes the rest of the head group.
        let batch = take_group(&mut pending, 8, usize::MAX);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(pending.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4]);
        // max_batch caps the pull.
        let batch = take_group(&mut pending, 1, usize::MAX);
        assert_eq!(batch.len(), 1);
        assert!(pending.is_empty());
    }

    #[test]
    fn poll_and_drain_return_each_response_exactly_once() {
        let g = tiny_graph();
        let mut server = Server::start(g, tiny_config(2, 2));
        let ids = server.submit_many(&(0..10u64).collect::<Vec<_>>());
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
        let mut seen = Vec::new();
        // Poll a few windows while work is in flight...
        for _ in 0..3 {
            seen.extend(server.poll().into_iter().map(|r| r.id));
            std::thread::yield_now();
        }
        // ...then close; drain returns only the remainder, sorted.
        let rest = server.drain();
        assert!(rest.windows(2).all(|w| w[0].id < w[1].id), "drain sorted by id");
        seen.extend(rest.iter().map(|r| r.id));
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn bounded_queue_refuses_when_paused_and_full() {
        let g = tiny_graph();
        let cfg = ServerConfig { queue_capacity: 3, ..tiny_config(1, 1) };
        let mut server = Server::start(g, cfg);
        server.pause();
        for seed in 0..3 {
            assert!(server.try_submit(seed).is_some());
        }
        assert_eq!(server.queued(), 3);
        assert_eq!(server.try_submit(99), None, "backpressure engaged");
        server.resume();
        let responses = server.drain();
        assert_eq!(responses.len(), 3);
    }

    /// A heterogeneous fleet built from `shard_accels` serves correctly,
    /// reports per-shard fingerprints, and every modeled placement
    /// decision lands within the scorer's tolerance of the minimum.
    #[test]
    fn heterogeneous_fleet_serves_and_respects_tolerance() {
        let g = tiny_graph();
        let mut small = AccelConfig::default();
        small.x_pms = 4;
        small.uf = 32;
        let tolerance = 0.05;
        let config = ServerConfig {
            workers_per_shard: 1,
            queue_capacity: 16,
            max_batch: 2,
            shard_accels: vec![AccelConfig::default(), small.clone()],
            placement: PlacementPolicy::Modeled { tolerance },
            ..ServerConfig::default()
        };
        let mut server = Server::start(g.clone(), config);
        for seed in 0..6 {
            server.submit(seed);
        }
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 6);
        assert_eq!(
            stats.shard_config_fps,
            vec![AccelConfig::default().fingerprint(), small.fingerprint()]
        );
        assert_ne!(stats.shard_config_fps[0], stats.shard_config_fps[1]);
        // Every decision picked a shard within tolerance of the min.
        assert!(!stats.placements.is_empty());
        for d in &stats.placements {
            let min = d.scores_s.iter().copied().fold(f64::INFINITY, f64::min);
            assert!(
                d.scores_s[d.shard] <= min * (1.0 + tolerance) + 1e-12,
                "decision outside tolerance: {d:?}"
            );
        }
        // Outputs byte-identical to the default-config reference,
        // whichever shard config served them.
        let reference = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
        for r in &responses {
            let mut rng = Pcg32::new(r.seed);
            let input = Tensor::<i8>::random(&g.input_shape, &mut rng);
            let want = reference.run(&g, &input);
            assert_eq!(r.output.data(), want.output.data(), "seed {}", r.seed);
        }
    }

    /// Round-robin routing alternates shards strictly — the route-blind
    /// baseline the benches compare the scorer against.
    #[test]
    fn round_robin_alternates_shards() {
        let g = tiny_graph();
        let config = ServerConfig {
            shards: 2,
            workers_per_shard: 1,
            queue_capacity: 16,
            max_batch: 1,
            placement: PlacementPolicy::RoundRobin,
            ..ServerConfig::default()
        };
        let mut server = Server::start(g, config);
        server.pause();
        for seed in 0..4 {
            server.submit(seed);
        }
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 4);
        let shards: Vec<usize> = stats.placements.iter().map(|d| d.shard).collect();
        assert_eq!(shards, vec![0, 1, 0, 1], "round-robin placement order");
    }
}
