//! Inference service: a threaded request loop over the model executor —
//! the "serve GAN images" front of the stack (`examples/serve.rs`).
//!
//! The paper's contribution is the accelerator itself, so this L3 service
//! is intentionally a thin coordinator (DESIGN.md: "if the contribution
//! lives at the accelerator level, L3 is a thin driver"): a bounded
//! request queue, N worker threads each owning an `Executor`, and
//! end-to-end latency/throughput metrics.

use crate::model::executor::{Executor, RunConfig};
use crate::model::graph::Graph;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// One generation request: a seed for the latent/input tensor.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: u64,
    pub seed: u64,
}

/// Completed response with measured host wall-clock and modeled
/// PYNQ-Z1 latency for the configured device.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub output: Tensor<i8>,
    pub wall_seconds: f64,
    pub modeled_seconds: f64,
}

struct Queue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
}

struct QueueInner {
    pending: VecDeque<Request>,
    done: Vec<Response>,
    closed: bool,
}

/// Thread-pool inference server for one model graph.
pub struct Server {
    queue: Arc<Queue>,
    workers: Vec<std::thread::JoinHandle<()>>,
    submitted: u64,
}

impl Server {
    /// Spawn `workers` threads, each with its own executor built by
    /// `make_executor` (delegates are cheap to clone via config).
    pub fn start(
        graph: Arc<Graph>,
        workers: usize,
        make_executor: impl Fn() -> Executor + Send + Sync + 'static,
        run_config: RunConfig,
        acc_cfg: crate::accel::AccelConfig,
    ) -> Self {
        let queue = Arc::new(Queue {
            inner: Mutex::new(QueueInner {
                pending: VecDeque::new(),
                done: Vec::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        });
        let make_executor = Arc::new(make_executor);
        let mut handles = Vec::new();
        for _ in 0..workers.max(1) {
            let q = queue.clone();
            let g = graph.clone();
            let mk = make_executor.clone();
            let acc_cfg = acc_cfg.clone();
            handles.push(std::thread::spawn(move || {
                let exec = mk();
                loop {
                    let req = {
                        let mut inner = q.inner.lock().unwrap();
                        loop {
                            if let Some(r) = inner.pending.pop_front() {
                                break Some(r);
                            }
                            if inner.closed {
                                break None;
                            }
                            inner = q.cv.wait(inner).unwrap();
                        }
                    };
                    let Some(req) = req else { return };
                    let mut rng = Pcg32::new(req.seed);
                    let input = Tensor::<i8>::random(&g.input_shape, &mut rng);
                    let t0 = Instant::now();
                    let run = exec.run(&g, &input);
                    let wall = t0.elapsed().as_secs_f64();
                    let modeled = run.modeled(run_config, &acc_cfg).total_s();
                    let resp = Response {
                        id: req.id,
                        output: run.output,
                        wall_seconds: wall,
                        modeled_seconds: modeled,
                    };
                    let mut inner = q.inner.lock().unwrap();
                    inner.done.push(resp);
                    q.cv.notify_all();
                }
            }));
        }
        Self { queue, workers: handles, submitted: 0 }
    }

    pub fn submit(&mut self, seed: u64) -> u64 {
        let id = self.submitted;
        self.submitted += 1;
        let mut inner = self.queue.inner.lock().unwrap();
        inner.pending.push_back(Request { id, seed });
        self.queue.cv.notify_all();
        id
    }

    /// Close the queue and collect all responses (sorted by id).
    pub fn drain(self) -> Vec<Response> {
        {
            let mut inner = self.queue.inner.lock().unwrap();
            inner.closed = true;
            self.queue.cv.notify_all();
        }
        for h in self.workers {
            h.join().expect("worker panicked");
        }
        let mut done = std::mem::take(&mut self.queue.inner.lock().unwrap().done);
        done.sort_by_key(|r| r.id);
        done
    }
}

/// Batch summary for the serving example / coordinator metrics.
#[derive(Debug, Clone, Copy)]
pub struct ServeStats {
    pub requests: usize,
    pub wall_total_s: f64,
    pub wall_mean_s: f64,
    pub modeled_mean_s: f64,
    pub throughput_rps: f64,
}

pub fn summarize(responses: &[Response], elapsed_s: f64) -> ServeStats {
    let n = responses.len().max(1);
    let wall_total: f64 = responses.iter().map(|r| r.wall_seconds).sum();
    let modeled: f64 = responses.iter().map(|r| r.modeled_seconds).sum();
    ServeStats {
        requests: responses.len(),
        wall_total_s: wall_total,
        wall_mean_s: wall_total / n as f64,
        modeled_mean_s: modeled / n as f64,
        throughput_rps: responses.len() as f64 / elapsed_s.max(1e-9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::AccelConfig;
    use crate::driver::Delegate;
    use crate::model::zoo;

    fn tiny_graph() -> Arc<Graph> {
        Arc::new(zoo::pix2pix(8, 2, 0))
    }

    #[test]
    fn serves_all_requests_deterministically() {
        let g = tiny_graph();
        let mut server = Server::start(
            g.clone(),
            2,
            || Executor::new(Delegate::new(AccelConfig::default(), 1, true)),
            RunConfig::AccPlusCpu { threads: 1 },
            AccelConfig::default(),
        );
        for seed in 0..6 {
            server.submit(seed);
        }
        let responses = server.drain();
        assert_eq!(responses.len(), 6);
        assert_eq!(responses.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4, 5]);

        // Same seeds again => identical outputs (end-to-end determinism).
        let mut server2 = Server::start(
            g,
            1,
            || Executor::new(Delegate::new(AccelConfig::default(), 1, true)),
            RunConfig::AccPlusCpu { threads: 1 },
            AccelConfig::default(),
        );
        for seed in 0..6 {
            server2.submit(seed);
        }
        let responses2 = server2.drain();
        for (a, b) in responses.iter().zip(&responses2) {
            assert_eq!(a.output.data(), b.output.data());
        }
    }

    #[test]
    fn stats_summarize() {
        let g = tiny_graph();
        let mut server = Server::start(
            g,
            2,
            || Executor::new(Delegate::new(AccelConfig::default(), 1, false)),
            RunConfig::Cpu { threads: 1 },
            AccelConfig::default(),
        );
        let t0 = Instant::now();
        for seed in 0..4 {
            server.submit(seed);
        }
        let responses = server.drain();
        let stats = summarize(&responses, t0.elapsed().as_secs_f64());
        assert_eq!(stats.requests, 4);
        assert!(stats.wall_mean_s > 0.0);
        assert!(stats.modeled_mean_s > 0.0);
        assert!(stats.throughput_rps > 0.0);
    }
}
