//! Production-style serving subsystem: layer-batched, sharded inference
//! over the model executor with a shared compiled-plan cache,
//! shard-persistent accelerators, and modeled-latency placement across a
//! (possibly heterogeneous) shard fleet (`examples/serve.rs`,
//! `repro serve`).
//!
//! The paper amortizes mapping work in hardware (maps generated once per
//! row, §IV-E); this layer applies the same principle to orchestration.
//! The full request path is documented in `docs/architecture.md`; in
//! brief:
//!
//! * **Typed requests** — a [`Request`] carries an [`InputSource`]
//!   (`Seed` for reproduction workloads, `Tensor` for real payloads —
//!   zero-copy via the `Arc`-backed tensor storage), a target graph, and
//!   a [`Class`] (priority + optional deadline). A [`RequestBuilder`]
//!   composes them; [`Server::submit`] validates the target graph and
//!   tensor shape up front and returns a [`Ticket`] or a typed
//!   [`SubmitError`] — never an untyped `Option` or a panic.
//! * **Tickets and outcomes** — every submitted request resolves to
//!   exactly one [`Response`] whose [`Outcome`] is `Ok`, `Cancelled`
//!   ([`Ticket::cancel`] removed it while still queued),
//!   `DeadlineExpired` (its deadline lapsed before batch formation) or
//!   `Failed` (execution faults exhausted the retry budget — see
//!   [supervision](#fault-model-and-supervision)).
//! * **Compile once, serve many** — every worker's delegate resolves
//!   TCONV layer programs through one [`PlanCache`] shared across the
//!   server, so each distinct layer compiles exactly once per process
//!   *per backend config*.
//! * **Heterogeneous sharding with persistent accelerators** — workers
//!   are grouped into shards; each shard owns one persistent simulated
//!   MM2IM instance built from *its own* [`AccelConfig`]. Outputs are
//!   byte-identical regardless of which shard serves a request.
//! * **Modeled-latency, weight-aware placement** — each batch is scored
//!   against every shard using the memoized
//!   [`perf_model`](crate::perf_model) estimate for that shard's config,
//!   minus a resident-weight bonus — see [`placement`].
//! * **Weight-reuse layer batching, across graphs** — a worker forms
//!   batches of *chain-mate* requests (see
//!   [scheduling](#batch-scheduling-priorities-and-fairness)) and
//!   executes them with `Executor::run_batch` /
//!   `Executor::run_batch_multi`: each TCONV layer runs once for the
//!   whole batch. Under the default [`BatchGrouping::PlanChain`] policy
//!   the batch group is the graph's memoized
//!   [`GraphKey`](crate::driver::plan::GraphKey) — the
//!   weight-independent digest of its compiled `PlanKey` chain, computed
//!   once at registration — so two graphs with identical layer shapes
//!   but different weights batch *together*, sharing one `Configure` and
//!   row schedule per tile and paying one `LoadWeights` per
//!   (tile, variant). [`BatchGrouping::GraphIdentity`] restores the old
//!   graph-index grouping (the comparison baseline).
//! * **Async submission with backpressure** — the request queue is
//!   bounded: [`Server::submit`] blocks when full, [`Server::try_submit`]
//!   returns [`SubmitError::QueueFull`], [`Server::poll`] collects
//!   finished responses without closing, and
//!   [`Server::finish`]/[`Server::drain`] close and join (idempotently
//!   with respect to tickets already cancelled — cancelled requests were
//!   resolved at cancel time and are never re-delivered).
//!
//! Servers are built with [`Server::builder`]; [`ServerConfig`] is the
//! builder's validated product (its fields are private — the builder is
//! the only way to deviate from [`ServerConfig::default`]).
//!
//! # Batch scheduling, priorities and fairness
//!
//! A worker forms a batch by scanning the first
//! [`ServerBuilder::group_window`] queued entries. First, every scanned
//! request whose deadline already lapsed is dropped (resolved as
//! [`Outcome::DeadlineExpired`] — deadlines are enforced at batch
//! formation; a request that made it into a batch always completes).
//! Then a **seed** request picks the batch group (graph): the most
//! urgent [`Priority`] present in the window, oldest first. Up to
//! [`ServerBuilder::max_batch`] same-group requests among the scanned
//! entries join the seed; scanned requests left behind are *passed
//! over*, and a request passed over `group_window` times is promoted
//! above every priority class — the next batch formed while it is in
//! the window must take it as seed.
//!
//! **Bounded inversion**: a queued request is passed over at most
//! `group_window` times before it is *promoted*, regardless of its
//! priority — within the scan window, every batch formation either
//! takes the request or increments its pass-over count, and after
//! `group_window` increments the aging promotion lifts it above every
//! class. Promoted requests then seed strictly oldest-first, one per
//! batch formation, so a promoted request is passed over only by older
//! promoted requests: with `k` simultaneously promoted window entries
//! (`k < group_window` by construction) the worst case is
//! `group_window + k - 1` pass-overs total — bounded by
//! `2·group_window`, and exactly `group_window` in the common
//! single-promotion case (pinned by a scheduler-level test). The
//! uniform-priority case degenerates to the original head-of-line
//! argument: the oldest waiting request always seeds the batch, so a
//! hot graph can never starve the others and out-of-order pulls are
//! bounded by `group_window`. Placement then routes the formed batch to
//! a shard (any idle worker may place; only the target shard's workers
//! execute), so fairness and shard choice stay independent concerns.
//!
//! # Fault model and supervision
//!
//! Serving survives four failure classes, injectable deterministically
//! through [`crate::accel::fault`] (the `MM2IM_FAULT_SPEC` env var —
//! read by default at [`ServerBuilder::start`] — or an explicit
//! [`ServerBuilder::fault_plan`]):
//!
//! * **Transient execution faults** and **corrupt transfers** surface
//!   as typed [`ExecError`]s from the executor. Faults fire at stream
//!   *boundaries* — before any instruction of the stream executes — so
//!   a failed batch produced no output and requeueing it wholesale can
//!   never double-serve a request.
//! * **Stalls** are latency spikes, not failures: the stream executes
//!   normally after the injected sleep and the batch completes.
//! * **Shard death** panics inside batch execution. The worker contains
//!   it with `catch_unwind`, requeues the batch exactly like a typed
//!   error, and the health machine below quarantines the shard.
//! * **Worker aborts** (`abort=W@K`) panic *outside* the supervised
//!   region, killing the worker thread itself. [`Server::finish`]
//!   captures the panic as [`ServeError::WorkerFailed`] (in
//!   [`ServeStats::worker_failures`]) instead of propagating it, and
//!   resolves requests stranded on the dead worker's shard as
//!   [`Outcome::Failed`] — completed responses still drain normally.
//!
//! **Retry budget and exactly-once.** A failed batch's requests are
//! requeued at the queue head with their attempt counters bumped; a
//! request whose attempts exceed [`ServerBuilder::retry_budget`]
//! resolves as [`Outcome::Failed`] instead of requeueing. Because only
//! output-free batches are ever retried (the executor's error
//! contract), every admitted id still resolves exactly once, and the
//! ledger extends additively:
//! `served + cancelled + deadline_expired + failed == submitted`.
//!
//! **Shard health.** Each shard runs a three-state machine driven by
//! consecutive batch failures on its accelerator:
//!
//! ```text
//!            failure                 quarantine_after consecutive
//! Healthy ────────────▶ Degraded ─────────────────────▶ Quarantined
//!    ▲                     │                                 │
//!    └──────── success ◀───┘          recovery probe ────────┘
//! ```
//!
//! Quarantined shards take no placements (either policy) until one of
//! their workers' recovery probes succeeds; while *every* shard is
//! quarantined, placement falls back to the full fleet so the queue
//! cannot deadlock — requests then burn retry budget instead of
//! waiting forever. All coordinator locks are poison-tolerant (one
//! `lock_recover` helper): a worker that panics while holding one
//! cannot take `poll`/`finish`/cancel observability down with it.
//!
//! # Observability
//!
//! Everything the server counts records into one hierarchical
//! [`telemetry::Tree`] (`fleet/…`, `fleet/shard/<i>/…`,
//! `classes/<class>/…`, `cache/`, `plans/<fp>/…`, `faults/…` — the
//! node layout table lives in `docs/architecture.md`).
//! [`Server::inspect`] takes a consistent [`telemetry::Snapshot`]
//! mid-serve without stopping workers; ledger transitions are grouped
//! in seqlock transactions so the five-term mid-serve identity
//! `served + cancelled + deadline_expired + failed + in_flight ==
//! submitted` holds at *every* snapshot, not just at quiescence. The
//! legacy [`ServeStats`] struct survives API-compatibly as a pure
//! projection of a final snapshot ([`ServeStats::from_snapshot`] — the
//! exact struct [`Server::finish`] returns), and
//! [`crate::telemetry::triage`] evaluates declarative health rules
//! (the ledger identity chief among them) over any snapshot or dump.

pub mod placement;

use crate::accel::{AccelConfig, ExecError, FaultPlan, WeightSetSig};
use crate::driver::persist;
use crate::driver::plan::GraphKey;
use crate::driver::{Delegate, PlanCache};
use crate::model::executor::{Executor, RunConfig};
use crate::model::graph::Graph;
use crate::perf_model::EstimateCache;
use crate::telemetry::{self, Counter, Gauge, Histogram, Ring, Snapshot, Text, Tree};
use crate::tensor::Tensor;
use crate::util::json::Value;
use crate::util::rng::Pcg32;
use placement::PlacementTable;
use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

pub use placement::{PlacementDecision, PlacementPolicy};

/// Poison-tolerant lock acquisition. A worker that panics (an injected
/// shard death escaping `catch_unwind` is impossible, but an injected
/// *worker abort* panics while holding the state lock by design) poisons
/// the mutex; the data under every coordinator lock is a queue/counter
/// ledger mutated in small all-or-nothing steps, so the poisoned value
/// is still consistent and observability (`poll`, `stats`, cancel,
/// `finish`) must keep working. Clears the poison flag so later plain
/// `lock()` callers (none remain in this module, but keep the invariant)
/// do not trip.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(guard) => guard,
        Err(poisoned) => {
            m.clear_poison();
            poisoned.into_inner()
        }
    }
}

// ---------------------------------------------------------------------------
// Request surface
// ---------------------------------------------------------------------------

/// Where a request's input tensor comes from.
#[derive(Clone, Debug)]
pub enum InputSource {
    /// Derive the input deterministically from a PRNG seed (the
    /// reproduction workloads and the differential test net).
    Seed(u64),
    /// A real input payload. Shared, never copied: submission, queueing
    /// and batch formation bump the `Arc`; the executor's instruction
    /// streams then alias the tensor's own `Arc`-backed buffer.
    Tensor(Arc<Tensor<i8>>),
}

impl InputSource {
    /// The seed, for seed-derived requests.
    pub fn seed(&self) -> Option<u64> {
        match self {
            Self::Seed(s) => Some(*s),
            Self::Tensor(_) => None,
        }
    }

    /// The concrete input tensor for a graph with `shape`.
    fn materialize(&self, shape: &[usize]) -> Tensor<i8> {
        match self {
            Self::Seed(s) => {
                let mut rng = Pcg32::new(*s);
                Tensor::<i8>::random(shape, &mut rng)
            }
            // `Tensor` clones are Arc bumps (copy-on-write buffers).
            Self::Tensor(t) => Tensor::clone(t),
        }
    }
}

/// Scheduling urgency. The derived order is urgency order:
/// `High < Normal < Low`, and the batch scheduler seeds batches with the
/// *minimum* — see the [module docs](self#batch-scheduling-priorities-and-fairness)
/// for the bounded-inversion guarantee protecting `Low`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive traffic: seeds batches ahead of other classes.
    High,
    /// The default class.
    #[default]
    Normal,
    /// Background/bulk traffic: yields within the inversion bound.
    Low,
}

impl Priority {
    /// Stable label for reports (`"high"`, `"normal"`, `"low"`).
    pub fn label(self) -> &'static str {
        match self {
            Self::High => "high",
            Self::Normal => "normal",
            Self::Low => "low",
        }
    }

    /// All classes, urgency order (for per-class report splits).
    pub const ALL: [Priority; 3] = [Priority::High, Priority::Normal, Priority::Low];
}

/// Service class of one request: scheduling priority plus an optional
/// deadline, measured from submission. A request whose deadline lapses
/// before batch formation is dropped and resolved as
/// [`Outcome::DeadlineExpired`] instead of executing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Class {
    /// Batch-scheduling urgency.
    pub priority: Priority,
    /// Time budget from submission to batch formation (`None` = no
    /// deadline). Enforced at batch formation only: once batched, a
    /// request always completes.
    pub deadline: Option<Duration>,
}

/// One inference request: an input source, a target graph (the batching
/// group) and a service [`Class`]. Compose with [`Request::seed`] /
/// [`Request::tensor`] and the [`RequestBuilder`] they return.
#[derive(Clone, Debug)]
pub struct Request {
    source: InputSource,
    graph: usize,
    class: Class,
}

impl Request {
    /// Builder for a seed-derived request (graph 0, [`Class::default`]).
    pub fn seed(seed: u64) -> RequestBuilder {
        RequestBuilder::new(InputSource::Seed(seed))
    }

    /// Builder for a real-payload request (graph 0, [`Class::default`]).
    /// The tensor is shared, not copied; its shape is validated against
    /// the target graph at submission.
    pub fn tensor(t: Arc<Tensor<i8>>) -> RequestBuilder {
        RequestBuilder::new(InputSource::Tensor(t))
    }

    /// Builder from an explicit [`InputSource`].
    pub fn builder(source: InputSource) -> RequestBuilder {
        RequestBuilder::new(source)
    }

    /// The request's input source.
    pub fn source(&self) -> &InputSource {
        &self.source
    }

    /// Index of the target graph (the batching group).
    pub fn graph(&self) -> usize {
        self.graph
    }

    /// The request's service class.
    pub fn class(&self) -> Class {
        self.class
    }
}

/// Composes a [`Request`]: input source first, then target graph,
/// priority and deadline. Anything accepting `impl Into<Request>`
/// (e.g. [`Server::submit`]) takes the builder directly.
#[derive(Clone, Debug)]
pub struct RequestBuilder {
    source: InputSource,
    graph: usize,
    class: Class,
}

impl RequestBuilder {
    /// Start from an input source (graph 0, [`Class::default`]).
    pub fn new(source: InputSource) -> Self {
        Self { source, graph: 0, class: Class::default() }
    }

    /// Target graph index (the batching group).
    pub fn graph(mut self, graph: usize) -> Self {
        self.graph = graph;
        self
    }

    /// Scheduling priority.
    pub fn priority(mut self, priority: Priority) -> Self {
        self.class.priority = priority;
        self
    }

    /// Deadline from submission to batch formation.
    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.class.deadline = Some(deadline);
        self
    }

    /// Replace the whole service class at once.
    pub fn class(mut self, class: Class) -> Self {
        self.class = class;
        self
    }

    /// Finish the request.
    pub fn build(self) -> Request {
        Request { source: self.source, graph: self.graph, class: self.class }
    }
}

impl From<RequestBuilder> for Request {
    fn from(b: RequestBuilder) -> Self {
        b.build()
    }
}

// ---------------------------------------------------------------------------
// Errors, outcomes, tickets
// ---------------------------------------------------------------------------

/// Why a submission was refused. Replaces the lossy `Option` return the
/// old `try_submit` had (which conflated "queue full" with "closed") and
/// the out-of-range panics `submit_to` used to throw.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is at capacity (`try_submit` only — `submit`
    /// blocks instead).
    QueueFull,
    /// The server has been closed; no further submissions are accepted.
    Closed,
    /// The request targeted a graph index the server does not host.
    UnknownGraph {
        /// The requested graph index.
        graph: usize,
        /// Graphs the server hosts (valid indices are `0..graphs`).
        graphs: usize,
    },
    /// A tensor payload's shape does not match the target graph's input.
    ShapeMismatch {
        /// The requested graph index.
        graph: usize,
        /// The payload's shape.
        got: Vec<usize>,
        /// The graph's expected input shape.
        want: Vec<usize>,
    },
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::QueueFull => write!(f, "request queue at capacity"),
            Self::Closed => write!(f, "server closed"),
            Self::UnknownGraph { graph, graphs } => {
                write!(f, "graph {graph} out of range (server hosts {graphs})")
            }
            Self::ShapeMismatch { graph, got, want } => {
                write!(f, "payload shape {got:?} does not match graph {graph} input {want:?}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Why a server could not be built ([`ServerBuilder::start`]) — or, for
/// [`ServeError::WorkerFailed`], why part of one degraded at runtime
/// (reported by [`Server::finish`] in [`ServeStats::worker_failures`]
/// instead of propagating the worker's panic into the caller).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The builder was started without any graph.
    NoGraphs,
    /// A configuration knob failed validation; the message names it.
    InvalidConfig(&'static str),
    /// The fault-injection spec (the `MM2IM_FAULT_SPEC` env var read at
    /// [`ServerBuilder::start`]) failed to parse; the message is the
    /// parser's. A *malformed* spec is a startup error — silently
    /// serving without the chaos the operator asked for would void the
    /// test run.
    InvalidFaultSpec(String),
    /// A worker thread died of a panic. Carries the captured panic
    /// message; requests stranded on the dead worker resolve as
    /// [`Outcome::Failed`] with [`FailReason::WorkerLost`].
    WorkerFailed {
        /// Index of the dead worker thread (spawn order).
        worker: usize,
        /// The panic payload, when it was a string (panics here always
        /// are: injected aborts and executor invariant violations both
        /// panic with formatted messages).
        message: String,
    },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::NoGraphs => write!(f, "server needs at least one graph"),
            Self::InvalidConfig(msg) => write!(f, "invalid server config: {msg}"),
            Self::InvalidFaultSpec(msg) => write!(f, "invalid fault spec: {msg}"),
            Self::WorkerFailed { worker, message } => {
                write!(f, "worker {worker} died: {message}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Why a request resolved as [`Outcome::Failed`]. Mirrors the
/// [`ExecError`] taxonomy plus the two worker-level causes; carried in
/// the outcome so clients can tell a flaky shard from a driver bug
/// without string matching.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailReason {
    /// Transient execution faults exhausted the retry budget.
    Transient,
    /// Corrupt-transfer detections exhausted the retry budget.
    CorruptTransfer,
    /// Malformed-stream (driver) errors exhausted the retry budget.
    Stream,
    /// Batch execution panicked (e.g. a dead shard's accelerator) until
    /// the retry budget ran out.
    ShardDead,
    /// The request was stranded — still queued or placed when its
    /// worker thread died and no surviving worker could take it before
    /// close.
    WorkerLost,
}

impl FailReason {
    /// Stable label for reports.
    pub fn label(self) -> &'static str {
        match self {
            Self::Transient => "transient",
            Self::CorruptTransfer => "corrupt_transfer",
            Self::Stream => "stream",
            Self::ShardDead => "shard_dead",
            Self::WorkerLost => "worker_lost",
        }
    }

    /// Classify a typed executor error.
    fn from_exec(e: &ExecError) -> Self {
        match e {
            ExecError::Transient(_) => Self::Transient,
            ExecError::CorruptTransfer(_) => Self::CorruptTransfer,
            ExecError::Stream(_) => Self::Stream,
        }
    }
}

/// How a submitted request resolved. Every ticket resolves to exactly
/// one outcome (the exactly-once guarantee the serving test net pins).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Executed; [`Response::output`] carries the tensor.
    Ok,
    /// Removed from the queue by [`Ticket::cancel`] before execution.
    Cancelled,
    /// Dropped at batch formation because its deadline lapsed.
    DeadlineExpired,
    /// Execution failed and the per-request retry budget is exhausted,
    /// or the request was stranded by a dead worker at close (see the
    /// [module docs](self#fault-model-and-supervision)); `output` is
    /// `None`.
    Failed(FailReason),
}

/// Handle to one submitted request, returned by [`Server::submit`] /
/// [`Server::try_submit`].
#[derive(Clone)]
pub struct Ticket {
    id: u64,
    shared: Arc<Shared>,
}

impl Ticket {
    /// The request's id (submission order); responses carry the same id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Cancel the request if it is still queued (not yet routed into a
    /// batch). Returns `true` when this call removed it — the request
    /// then resolves as [`Outcome::Cancelled`] through the normal
    /// `poll`/`finish` path. Returns `false` when the request already
    /// entered execution, completed, expired, or was cancelled before
    /// (cancellation is idempotent; so are `finish`/`drain` with respect
    /// to cancelled tickets — a cancelled request is resolved exactly
    /// once, at cancel time).
    pub fn cancel(&self) -> bool {
        // Poison-tolerant: cancellation keeps working after a worker
        // panic (the chaos suite cancels against wounded servers).
        let mut st = lock_recover(&self.shared.state);
        let Some(pos) = st.pending.iter().position(|q| q.id == self.id) else {
            return false;
        };
        let q = st.pending.remove(pos).expect("position in range");
        st.done.push(unserved_response(q, Outcome::Cancelled));
        drop(st);
        let t = &self.shared.telem;
        t.tree.txn(|| {
            t.cancelled.inc();
            t.in_flight.add(-1.0);
        });
        // The cancelled slot frees queue capacity.
        self.shared.space_cv.notify_all();
        true
    }
}

impl fmt::Debug for Ticket {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Ticket").field("id", &self.id).finish()
    }
}

/// Completed response with measured host wall-clock and modeled
/// PYNQ-Z1 latency for the shard's device configuration. Cancelled and
/// deadline-expired requests resolve with `output: None` and zero
/// execution time.
#[derive(Clone, Debug)]
pub struct Response {
    /// Submission-order id (matches the ticket's).
    pub id: u64,
    /// The request's input source (seed or shared tensor payload).
    pub source: InputSource,
    /// Graph (batching group) the request targeted.
    pub graph: usize,
    /// The request's service class.
    pub class: Class,
    /// How the request resolved.
    pub outcome: Outcome,
    /// Shard (simulated accelerator instance) that served the request;
    /// `None` unless [`Outcome::Ok`].
    pub shard: Option<usize>,
    /// Final int8 output tensor; `Some` iff [`Outcome::Ok`].
    pub output: Option<Tensor<i8>>,
    /// Seconds spent waiting in the bounded queue (until execution,
    /// cancellation, or expiry).
    pub queue_seconds: f64,
    /// Host wall-clock seconds of the numerics pass (amortized share of
    /// the batch the request rode in; 0 unless executed).
    pub wall_seconds: f64,
    /// Modeled end-to-end seconds on the PYNQ-Z1 testbed for the
    /// serving shard's config (amortized share of the batch; 0 unless
    /// executed).
    pub modeled_seconds: f64,
}

impl Response {
    /// Queue wait + execution: the latency a client observes.
    pub fn latency_seconds(&self) -> f64 {
        self.queue_seconds + self.wall_seconds
    }

    /// The request's seed, for seed-derived requests.
    pub fn seed(&self) -> Option<u64> {
        self.source.seed()
    }

    /// The output tensor of a served request. Panics unless the outcome
    /// is [`Outcome::Ok`] — check [`Response::outcome`] (or match on
    /// [`Response::output`]) when cancellations/deadlines are in play.
    pub fn output_tensor(&self) -> &Tensor<i8> {
        assert_eq!(self.outcome, Outcome::Ok, "request {} was not served", self.id);
        self.output.as_ref().expect("Ok outcome carries an output")
    }
}

/// Response for a request that never executed (cancelled, expired, or
/// failed out of its retry budget).
fn unserved_response(q: Queued, outcome: Outcome) -> Response {
    Response {
        id: q.id,
        source: q.source,
        graph: q.graph,
        class: q.class,
        outcome,
        shard: None,
        output: None,
        queue_seconds: q.enqueued.elapsed().as_secs_f64(),
        wall_seconds: 0.0,
        modeled_seconds: 0.0,
    }
}

// ---------------------------------------------------------------------------
// Server configuration and builder
// ---------------------------------------------------------------------------

/// How the batch scheduler decides which queued requests may share a
/// batch (and therefore a weight-reuse execution).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BatchGrouping {
    /// Group by the graph's [`GraphKey`] — the weight-independent digest
    /// of its compiled `PlanKey` chain, memoized at registration. Graphs
    /// with identical layer shapes/scales but different weights
    /// (chain-mates) batch together: one shared `Configure` + row
    /// schedule per tile, one `LoadWeights` per (tile, variant) via
    /// [`crate::model::executor::Executor::run_batch_multi`]. The
    /// default.
    #[default]
    PlanChain,
    /// Group by graph index only — requests batch solely with requests
    /// for the *same* registered graph (the pre-chain behavior, kept as
    /// the comparison baseline for the cross-graph differential tests).
    GraphIdentity,
}

/// Server topology and policy — the validated product of
/// [`Server::builder`]. Fields are private: [`ServerConfig::default`] is
/// the only non-builder constructor, so an invalid topology cannot be
/// struct-literal'd into existence.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Simulated accelerator instances (worker groups). >= 1. Ignored
    /// when `shard_accels` is non-empty (its length defines the fleet).
    shards: usize,
    /// Worker threads per shard. >= 1.
    workers_per_shard: usize,
    /// Bounded request-queue capacity; `submit` blocks and `try_submit`
    /// refuses once `queue_capacity` requests are waiting (un-routed
    /// *plus* routed-but-unserved, so placement cannot turn the bound
    /// into unbounded per-shard backlogs).
    queue_capacity: usize,
    /// Max same-group requests one worker batches per queue round-trip.
    max_batch: usize,
    /// How deep past the queue head the batch scheduler may scan — the
    /// bound on both out-of-order pulls and priority inversion (see the
    /// [module docs](self#batch-scheduling-priorities-and-fairness)).
    group_window: usize,
    /// Compiled plans the shared cache may hold.
    plan_cache_capacity: usize,
    /// CPU threads per worker for non-offloaded layers.
    cpu_threads: usize,
    /// Offload TCONV layers to the simulated accelerator.
    use_accelerator: bool,
    /// Device configuration used for modeled latency.
    run_config: RunConfig,
    /// Accelerator configuration shared by every shard of a homogeneous
    /// fleet (ignored when `shard_accels` is set).
    accel: AccelConfig,
    /// Heterogeneous fleet: one [`AccelConfig`] per shard. Empty (the
    /// default) means `shards` copies of `accel`.
    shard_accels: Vec<AccelConfig>,
    /// How batches are routed to shards.
    placement: PlacementPolicy,
    /// Which requests may share a batch (graph identity vs. chain-mates).
    batch_grouping: BatchGrouping,
    /// On-disk plan snapshot ([`crate::driver::persist`]): loaded (and
    /// validated) at startup, flushed on [`Server::finish`]/drain.
    /// `None` (the default) disables persistence entirely.
    plan_store: Option<std::path::PathBuf>,
    /// Retries a request may consume after execution failures before it
    /// resolves as [`Outcome::Failed`].
    retry_budget: u32,
    /// Consecutive batch failures before a shard is quarantined. >= 1.
    quarantine_after: u32,
    /// Where the fault-injection plan comes from.
    fault: FaultChoice,
}

/// How the server resolves its fault-injection plan at
/// [`ServerBuilder::start`].
#[derive(Clone, Debug, Default)]
enum FaultChoice {
    /// Read `MM2IM_FAULT_SPEC` from the environment (the default):
    /// unset or empty means no injection; a malformed value is
    /// [`ServeError::InvalidFaultSpec`].
    #[default]
    Env,
    /// Never inject, even when the env var is set — hermetic tests pin
    /// this so chaos CI matrices cannot perturb them.
    Disabled,
    /// Use this plan verbatim, ignoring the environment.
    Plan(FaultPlan),
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            shards: 1,
            workers_per_shard: 1,
            queue_capacity: 64,
            max_batch: 4,
            group_window: 64,
            plan_cache_capacity: 64,
            cpu_threads: 1,
            use_accelerator: true,
            run_config: RunConfig::AccPlusCpu { threads: 1 },
            accel: AccelConfig::default(),
            shard_accels: Vec::new(),
            placement: PlacementPolicy::default(),
            batch_grouping: BatchGrouping::default(),
            plan_store: None,
            retry_budget: 2,
            quarantine_after: 2,
            fault: FaultChoice::default(),
        }
    }
}

impl ServerConfig {
    /// Shards the fleet resolves to: `shard_accels.len()` when set,
    /// else the configured shard count.
    pub fn shard_count(&self) -> usize {
        if self.shard_accels.is_empty() {
            self.shards.max(1)
        } else {
            self.shard_accels.len()
        }
    }

    /// The fleet's per-shard configs: the heterogeneous fleet verbatim
    /// when set, else [`ServerConfig::shard_count`] copies of the shared
    /// config.
    pub fn shard_configs(&self) -> Vec<AccelConfig> {
        if self.shard_accels.is_empty() {
            vec![self.accel.clone(); self.shard_count()]
        } else {
            self.shard_accels.clone()
        }
    }

    /// Total worker threads the server spawns.
    pub fn workers(&self) -> usize {
        self.shard_count() * self.workers_per_shard.max(1)
    }

    /// Bounded request-queue capacity.
    pub fn queue_capacity(&self) -> usize {
        self.queue_capacity
    }

    /// Max same-group requests per batch.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// The scheduler's scan window (fairness/inversion bound).
    pub fn group_window(&self) -> usize {
        self.group_window
    }

    /// How the batch scheduler groups requests.
    pub fn batch_grouping(&self) -> BatchGrouping {
        self.batch_grouping
    }

    /// Retries a request may consume before [`Outcome::Failed`].
    pub fn retry_budget(&self) -> u32 {
        self.retry_budget
    }

    /// Consecutive batch failures before a shard is quarantined.
    pub fn quarantine_after(&self) -> u32 {
        self.quarantine_after
    }
}

/// Composes and validates a [`Server`]: graphs, shard fleet, queue and
/// scheduling knobs. Obtained from [`Server::builder`]; `start` spawns
/// the worker threads or returns a typed [`ServeError`]. The builder is
/// `Clone`, so one configuration can start several servers (the
/// differential test net compares topologies this way).
#[derive(Clone, Debug)]
pub struct ServerBuilder {
    graphs: Vec<Arc<Graph>>,
    cfg: ServerConfig,
}

impl ServerBuilder {
    /// Add one graph (requests target it by index, in insertion order).
    pub fn graph(mut self, g: Arc<Graph>) -> Self {
        self.graphs.push(g);
        self
    }

    /// Add several graphs at once.
    pub fn graphs(mut self, gs: impl IntoIterator<Item = Arc<Graph>>) -> Self {
        self.graphs.extend(gs);
        self
    }

    /// Homogeneous fleet size (ignored once [`ServerBuilder::shard_fleet`]
    /// is set).
    pub fn shards(mut self, n: usize) -> Self {
        self.cfg.shards = n;
        self
    }

    /// Worker threads per shard.
    pub fn workers_per_shard(mut self, n: usize) -> Self {
        self.cfg.workers_per_shard = n;
        self
    }

    /// Bounded request-queue capacity (backpressure threshold).
    pub fn queue_capacity(mut self, n: usize) -> Self {
        self.cfg.queue_capacity = n;
        self
    }

    /// Max same-group requests one worker batches per round-trip.
    pub fn max_batch(mut self, n: usize) -> Self {
        self.cfg.max_batch = n;
        self
    }

    /// Scheduler scan window — the fairness *and* priority-inversion
    /// bound (see the [module docs](self#batch-scheduling-priorities-and-fairness)).
    pub fn group_window(mut self, n: usize) -> Self {
        self.cfg.group_window = n;
        self
    }

    /// Compiled plans the shared cache may hold.
    pub fn plan_cache_capacity(mut self, n: usize) -> Self {
        self.cfg.plan_cache_capacity = n;
        self
    }

    /// CPU threads per worker for non-offloaded layers.
    pub fn cpu_threads(mut self, n: usize) -> Self {
        self.cfg.cpu_threads = n;
        self
    }

    /// Whether TCONV layers run on the simulated accelerator.
    pub fn use_accelerator(mut self, on: bool) -> Self {
        self.cfg.use_accelerator = on;
        self
    }

    /// Device configuration used for modeled latency.
    pub fn run_config(mut self, rc: RunConfig) -> Self {
        self.cfg.run_config = rc;
        self
    }

    /// Accelerator config shared by a homogeneous fleet.
    pub fn accel(mut self, cfg: AccelConfig) -> Self {
        self.cfg.accel = cfg;
        self
    }

    /// Heterogeneous fleet: one [`AccelConfig`] per shard (overrides
    /// [`ServerBuilder::shards`]).
    pub fn shard_fleet(mut self, fleet: Vec<AccelConfig>) -> Self {
        self.cfg.shard_accels = fleet;
        self
    }

    /// Batch-routing policy.
    pub fn placement(mut self, p: PlacementPolicy) -> Self {
        self.cfg.placement = p;
        self
    }

    /// Batch-grouping policy: [`BatchGrouping::PlanChain`] (the default)
    /// lets chain-mate graphs share batches;
    /// [`BatchGrouping::GraphIdentity`] restores graph-index grouping.
    pub fn batch_grouping(mut self, g: BatchGrouping) -> Self {
        self.cfg.batch_grouping = g;
        self
    }

    /// Persist compiled plans at `path` ([`crate::driver::persist`]
    /// snapshot format). At startup the server loads and validates the
    /// snapshot, preloading every entry whose config fingerprint matches
    /// the fleet ([`ServeStats::plans_preloaded`] reports how many) — a
    /// warm restart serves its first request with zero plan compiles. A
    /// missing, corrupt, version-skewed or foreign-fleet snapshot simply
    /// yields a cold start; it can never panic or serve a stale plan
    /// (stale weights change the `params_fp` live lookups key on, so a
    /// stale entry is unreachable by construction). On
    /// [`Server::finish`]/[`Server::drain`] the cache is flushed back
    /// atomically (temp file + rename).
    pub fn plan_store(mut self, path: impl Into<std::path::PathBuf>) -> Self {
        self.cfg.plan_store = Some(path.into());
        self
    }

    /// Install an explicit fault-injection plan (the chaos suite's
    /// entry point; production servers read `MM2IM_FAULT_SPEC` by
    /// default). See the
    /// [module docs](self#fault-model-and-supervision).
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.cfg.fault = FaultChoice::Plan(plan);
        self
    }

    /// Disable fault injection even when `MM2IM_FAULT_SPEC` is set, so
    /// a hermetic test stays correct under a chaos CI env matrix.
    pub fn no_fault_injection(mut self) -> Self {
        self.cfg.fault = FaultChoice::Disabled;
        self
    }

    /// Retries a request may consume after execution failures before it
    /// resolves as [`Outcome::Failed`] (default 2: one submission plus
    /// two retries).
    pub fn retry_budget(mut self, n: u32) -> Self {
        self.cfg.retry_budget = n;
        self
    }

    /// Consecutive batch failures before a shard is quarantined
    /// (default 2; must be >= 1 — a shard that fails every batch must
    /// eventually leave the placement pool).
    pub fn quarantine_after(mut self, n: u32) -> Self {
        self.cfg.quarantine_after = n;
        self
    }

    /// Validate the configuration and spawn the server's worker threads.
    pub fn start(self) -> Result<Server, ServeError> {
        if self.graphs.is_empty() {
            return Err(ServeError::NoGraphs);
        }
        let cfg = &self.cfg;
        if cfg.queue_capacity == 0 {
            return Err(ServeError::InvalidConfig("queue_capacity must be >= 1"));
        }
        if cfg.max_batch == 0 {
            return Err(ServeError::InvalidConfig("max_batch must be >= 1"));
        }
        if cfg.group_window == 0 {
            return Err(ServeError::InvalidConfig("group_window must be >= 1"));
        }
        if cfg.plan_cache_capacity == 0 {
            return Err(ServeError::InvalidConfig("plan_cache_capacity must be >= 1"));
        }
        if cfg.workers_per_shard == 0 {
            return Err(ServeError::InvalidConfig("workers_per_shard must be >= 1"));
        }
        if cfg.shards == 0 && cfg.shard_accels.is_empty() {
            return Err(ServeError::InvalidConfig("fleet needs >= 1 shard"));
        }
        if matches!(cfg.run_config, RunConfig::AccPlusCpu { .. }) && !cfg.use_accelerator {
            return Err(ServeError::InvalidConfig(
                "AccPlusCpu modeling requires the accelerator (no cycle reports otherwise)",
            ));
        }
        if cfg.quarantine_after == 0 {
            return Err(ServeError::InvalidConfig("quarantine_after must be >= 1"));
        }
        let fault = match &cfg.fault {
            FaultChoice::Disabled => None,
            FaultChoice::Plan(plan) => Some(plan.clone()),
            FaultChoice::Env => FaultPlan::from_env().map_err(ServeError::InvalidFaultSpec)?,
        };
        Ok(Server::spawn(self.graphs, self.cfg, fault))
    }
}

// ---------------------------------------------------------------------------
// Internal queue entry and shared state
// ---------------------------------------------------------------------------

/// One queued request: the client's [`Request`] plus the bookkeeping the
/// scheduler needs (id, enqueue time, pass-over ledger).
#[derive(Clone, Debug)]
struct Queued {
    id: u64,
    source: InputSource,
    graph: usize,
    class: Class,
    enqueued: Instant,
    /// Batches formed from the scan window that skipped this request —
    /// the bounded-inversion ledger (aging promotes at `group_window`).
    /// `u64`, not `u32`: `group_window` is a `usize`, and on 64-bit
    /// hosts a window above `u32::MAX` (e.g. the `usize::MAX` used by
    /// "unbounded" callers) would otherwise sit forever beyond a
    /// saturated 32-bit counter, silently voiding the inversion bound.
    passed_over: u64,
    /// Failed execution attempts so far; past
    /// [`ServerConfig::retry_budget`] the request resolves as
    /// [`Outcome::Failed`] instead of requeueing.
    attempts: u32,
    /// Reason of the most recent failed attempt (also the stranded-at-
    /// close verdict when a dead worker's shard never retried it).
    last_fail: Option<FailReason>,
}

/// Supervision state of one shard's accelerator, reported in
/// [`ServeStats::shard_health`]. Transitions are driven by consecutive
/// batch failures and recovery probes — see the
/// [module docs](self#fault-model-and-supervision).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardHealth {
    /// Serving normally.
    #[default]
    Healthy,
    /// At least one recent batch failed; still eligible for placement.
    Degraded,
    /// [`ServerConfig::quarantine_after`] consecutive failures:
    /// excluded from placement until a recovery probe succeeds.
    Quarantined,
}

impl ShardHealth {
    /// Stable label, as published at the `fleet/shard/<i>/health`
    /// telemetry text node.
    pub fn label(self) -> &'static str {
        match self {
            Self::Healthy => "healthy",
            Self::Degraded => "degraded",
            Self::Quarantined => "quarantined",
        }
    }

    /// Parse a [`ShardHealth::label`] back (`None` for unknown text).
    pub fn from_label(s: &str) -> Option<Self> {
        match s {
            "healthy" => Some(Self::Healthy),
            "degraded" => Some(Self::Degraded),
            "quarantined" => Some(Self::Quarantined),
            _ => None,
        }
    }
}

/// Per-shard health ledger: the public state plus the consecutive-
/// failure counter that drives it.
#[derive(Clone, Copy, Debug, Default)]
struct HealthSlot {
    state: ShardHealth,
    consecutive: u32,
}

struct State {
    /// Requests not yet grouped or routed (the bounded client queue).
    pending: VecDeque<Queued>,
    /// Batches already routed, per target shard, awaiting that shard's
    /// workers. Any idle worker may *place*; only the target executes.
    placed: Vec<VecDeque<Vec<Queued>>>,
    /// Requests sitting in `placed` queues (routed, not yet picked up
    /// for execution). Counted against `queue_capacity` so placement
    /// cannot launder the bounded queue into unbounded per-shard
    /// backlogs: `submit` blocks on `pending + staged`.
    staged: usize,
    done: Vec<Response>,
    closed: bool,
    /// While true, workers leave the queues untouched (maintenance /
    /// deterministic backpressure tests). Closing overrides pausing.
    paused: bool,
    /// Requests routed to each shard and not yet completed (the
    /// scorer's tie-breaker).
    backlog: Vec<u64>,
    /// Predicted resident filter-set signature per shard: what the
    /// shard's accelerator BRAM will hold once its placed batches
    /// execute. Exact for single-worker shards executing in placement
    /// order; a best-effort heuristic beyond that.
    resident: Vec<Option<WeightSetSig>>,
    /// Round-robin cursor for [`PlacementPolicy::RoundRobin`].
    rr_next: usize,
    /// Per-shard supervision ledger (see
    /// [module docs](self#fault-model-and-supervision)).
    health: Vec<HealthSlot>,
}

impl State {
    /// Drop every queued request whose deadline already lapsed,
    /// resolving each as [`Outcome::DeadlineExpired`]. Runs at batch
    /// formation, in `poll`, and at `finish`/`drain` close — the latter
    /// two so a lapsed request on an idle or paused server still
    /// resolves without further traffic. Returns how many were dropped
    /// so the caller can release queue capacity (and record the drops
    /// into the telemetry ledger — see [`record_expired`]).
    fn sweep_expired(&mut self) -> usize {
        let now = Instant::now();
        let mut dropped = 0;
        let mut i = 0;
        while i < self.pending.len() {
            let r = &self.pending[i];
            let expired = r.class.deadline.is_some_and(|d| now.duration_since(r.enqueued) >= d);
            if expired {
                let q = self.pending.remove(i).expect("index in range");
                self.done.push(unserved_response(q, Outcome::DeadlineExpired));
                dropped += 1;
            } else {
                i += 1;
            }
        }
        dropped
    }
}

/// Latency samples kept (as a telemetry ring at `fleet/latency_window`)
/// for percentile reporting; older samples rotate out so a long-lived
/// server's memory stays bounded.
const LATENCY_WINDOW: usize = 65_536;

/// Placement decisions kept in the `fleet/placements` telemetry ring
/// (projected into [`ServeStats::placements`]); older decisions rotate
/// out so a long-lived server's memory stays bounded.
const PLACEMENT_WINDOW: usize = 65_536;

/// Worker-failure records kept in the `faults/worker_failures` ring.
const WORKER_FAILURE_WINDOW: usize = 1024;

/// Index of a priority class in the per-class telemetry arrays
/// (urgency order, matching [`Priority::ALL`]).
fn class_slot(p: Priority) -> usize {
    match p {
        Priority::High => 0,
        Priority::Normal => 1,
        Priority::Low => 2,
    }
}

/// Pre-registered handles into one shard's `fleet/shard/<i>/` node.
struct ShardTelem {
    /// `requests` — requests served by this shard.
    requests: Counter,
    /// `busy_s` — wall seconds its workers spent executing batches.
    busy_s: Gauge,
    /// `exec_failures` — failed batch executions on this shard.
    exec_failures: Counter,
    /// `repacks_skipped` — packed-operand LRU hits on this shard's
    /// engine (im2col repacks elided across batch variants).
    repacks_skipped: Counter,
    /// `health` — current [`ShardHealth::label`].
    health: Text,
}

/// Pre-registered handles into the server's telemetry [`Tree`] — the
/// single source of truth for every serving counter. [`ServeStats`] is
/// a projection of a snapshot of this tree; nothing tallies outside it.
///
/// The exactly-once ledger fields (`submitted`, `served`, `cancelled`,
/// `deadline_expired`, `failed`, `in_flight`) move only inside
/// [`Tree::txn`] groups, so every snapshot — not just the final one —
/// satisfies `served + cancelled + deadline_expired + failed +
/// in_flight == submitted` (the always-on triage rule).
struct Telem {
    tree: Arc<Tree>,
    submitted: Counter,
    served: Counter,
    cancelled: Counter,
    deadline_expired: Counter,
    failed: Counter,
    /// Admitted but not yet resolved (gauge: moves both ways).
    in_flight: Gauge,
    /// `try_submit` rejections at capacity (feeds the queue-saturation
    /// triage rule).
    queue_full: Counter,
    batches: Counter,
    cross_graph_batches: Counter,
    cross_batch_resident_hits: Counter,
    weight_loads: Counter,
    weight_loads_skipped: Counter,
    weight_loads_equiv: Counter,
    repacks_skipped: Counter,
    wall_total_s: Gauge,
    modeled_total_s: Gauge,
    uptime_s: Gauge,
    exec_failures: Counter,
    retries: Counter,
    probes: Counter,
    probe_recoveries: Counter,
    shards_quarantined: Counter,
    quarantined_now: Gauge,
    latency: Histogram,
    latency_window: Ring,
    placements: Ring,
    worker_failures: Ring,
    class_submitted: [Counter; 3],
    class_served: [Counter; 3],
    shards: Vec<ShardTelem>,
}

impl Telem {
    /// Register the full node layout on a fresh tree. `fleet/shards` and
    /// `fleet/workers_per_shard` are recorded as gauges so projections
    /// (and the quarantined-majority triage rule) need no side channel.
    fn new(shards: usize, workers_per_shard: usize) -> Self {
        let tree = Arc::new(Tree::new());
        let fleet = tree.node("fleet");
        let class = |name: &str| {
            let node = tree.node("classes");
            let node = node.child(name);
            (node.counter("submitted"), node.counter("served"))
        };
        let (hi_sub, hi_served) = class("high");
        let (no_sub, no_served) = class("normal");
        let (lo_sub, lo_served) = class("low");
        fleet.gauge("shards").set(shards as f64);
        fleet.gauge("workers_per_shard").set(workers_per_shard as f64);
        let shard_nodes = (0..shards)
            .map(|i| {
                let node = fleet.child("shard");
                let node = node.child(&i.to_string());
                let t = ShardTelem {
                    requests: node.counter("requests"),
                    busy_s: node.gauge("busy_s"),
                    exec_failures: node.counter("exec_failures"),
                    repacks_skipped: node.counter("repacks_skipped"),
                    health: node.text("health"),
                };
                t.health.set(ShardHealth::Healthy.label());
                t
            })
            .collect();
        Self {
            submitted: fleet.counter("submitted"),
            served: fleet.counter("served"),
            cancelled: fleet.counter("cancelled"),
            deadline_expired: fleet.counter("deadline_expired"),
            failed: fleet.counter("failed"),
            in_flight: fleet.gauge("in_flight"),
            queue_full: fleet.counter("queue_full"),
            batches: fleet.counter("batches"),
            cross_graph_batches: fleet.counter("cross_graph_batches"),
            cross_batch_resident_hits: fleet.counter("cross_batch_resident_hits"),
            weight_loads: fleet.counter("weight_loads"),
            weight_loads_skipped: fleet.counter("weight_loads_skipped"),
            weight_loads_equiv: fleet.counter("weight_loads_equiv"),
            repacks_skipped: fleet.counter("repacks_skipped"),
            wall_total_s: fleet.gauge("wall_total_s"),
            modeled_total_s: fleet.gauge("modeled_total_s"),
            uptime_s: fleet.gauge("uptime_s"),
            exec_failures: fleet.counter("exec_failures"),
            retries: fleet.counter("retries"),
            probes: fleet.counter("probes"),
            probe_recoveries: fleet.counter("probe_recoveries"),
            shards_quarantined: fleet.counter("shards_quarantined"),
            quarantined_now: fleet.gauge("quarantined_now"),
            latency: fleet.histogram("latency", &telemetry::LATENCY_BUCKETS_S),
            latency_window: fleet.ring("latency_window", LATENCY_WINDOW),
            placements: fleet.ring("placements", PLACEMENT_WINDOW),
            worker_failures: tree.node("faults").ring("worker_failures", WORKER_FAILURE_WINDOW),
            class_submitted: [hi_sub, no_sub, lo_sub],
            class_served: [hi_served, no_served, lo_served],
            shards: shard_nodes,
            tree,
        }
    }
}

/// Record `n` deadline expiries as one ledger transaction (the caller
/// just swept them out of the queue).
fn record_expired(t: &Telem, n: u64) {
    if n == 0 {
        return;
    }
    t.tree.txn(|| {
        t.deadline_expired.add(n);
        t.in_flight.add(-(n as f64));
    });
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for work or close.
    work_cv: Condvar,
    /// Submitters wait here for queue space.
    space_cv: Condvar,
    /// The telemetry tree + pre-registered recording handles.
    telem: Telem,
}

// ---------------------------------------------------------------------------
// The server
// ---------------------------------------------------------------------------

/// Layer-batched, sharded inference server over one or more model
/// graphs, with priority/deadline-aware batch scheduling, cancellable
/// tickets, and modeled-latency placement across a possibly
/// heterogeneous shard fleet. Built with [`Server::builder`].
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    cache: Arc<PlanCache>,
    graphs: Vec<Arc<Graph>>,
    config: ServerConfig,
    shard_cfgs: Vec<AccelConfig>,
    submitted: u64,
    started: Instant,
    /// Plans preloaded from the `plan_store` snapshot at startup (0
    /// without a store or after a rejected/cold-start load).
    plans_preloaded: u64,
}

impl Server {
    /// Start composing a server: graphs, shard fleet, queue and
    /// scheduling knobs, then [`ServerBuilder::start`].
    pub fn builder() -> ServerBuilder {
        ServerBuilder { graphs: Vec::new(), cfg: ServerConfig::default() }
    }

    /// Spawn `config.workers()` threads over the shard fleet; each
    /// worker owns an executor whose delegate shares the server-wide plan
    /// cache *and its shard's persistent accelerator*, built from that
    /// shard's own [`AccelConfig`]. Only reachable through the builder,
    /// which has already validated `config` and resolved `fault`.
    fn spawn(graphs: Vec<Arc<Graph>>, mut config: ServerConfig, fault: Option<FaultPlan>) -> Self {
        let shard_cfgs = config.shard_configs();
        let shards = shard_cfgs.len();
        config.shards = shards;
        let workers_per_shard = config.workers_per_shard;
        let cache = PlanCache::shared(config.plan_cache_capacity);
        // Warm restart: load the plan snapshot before any worker spawns,
        // so the first batch already finds every plan resident. The
        // loader filters entries to this fleet's config fingerprints; a
        // missing or rejected snapshot (wrong magic/version, failed
        // checksum, truncation — any `PersistError`) is a clean cold
        // start, never a panic: a snapshot is a cache, and recompiling
        // is always correct.
        let plans_preloaded = match &config.plan_store {
            Some(path) => match persist::load(path) {
                Ok(snap) => {
                    let mut fps: Vec<u64> =
                        shard_cfgs.iter().map(AccelConfig::fingerprint).collect();
                    fps.sort_unstable();
                    fps.dedup();
                    snap.retain_configs(&fps).preload_into(&cache) as u64
                }
                Err(_) => 0,
            },
            None => 0,
        };
        // Score inputs for the placement table are memoized per (layer
        // geometry, config) — graphs sharing layer shapes across the
        // fleet pay the analytical walk once.
        let estimates = EstimateCache::new();
        let table = Arc::new(PlacementTable::build(&graphs, &shard_cfgs, &estimates));
        // Batch-group id per graph, memoized once at registration. Under
        // PlanChain two graphs share a group iff their GraphKeys (the
        // weight-independent digests of their compiled PlanKey chains)
        // are equal; graph-key equality is config-independent (the config
        // fingerprint folds identically into both digests), so one
        // reference config suffices even for a heterogeneous fleet.
        let group_of: Arc<Vec<usize>> = Arc::new(match config.batch_grouping {
            BatchGrouping::GraphIdentity => (0..graphs.len()).collect(),
            BatchGrouping::PlanChain => {
                let keys: Vec<GraphKey> =
                    graphs.iter().map(|g| g.graph_key(&shard_cfgs[0])).collect();
                keys.iter()
                    .map(|k| keys.iter().position(|k2| k2 == k).expect("key present"))
                    .collect()
            }
        });
        // The telemetry tree: registered up front so every path exists
        // from the first snapshot, then wired into the plan cache and
        // the fault injectors before any worker spawns.
        let telem = Telem::new(shards, workers_per_shard);
        cache.attach_telemetry(&telem.tree);
        telem.tree.counter("cache/preloaded").add(plans_preloaded);
        for (s, cfg_s) in shard_cfgs.iter().enumerate() {
            telem
                .tree
                .text(&format!("fleet/shard/{s}/config_fp"))
                .set(format!("{:#018x}", cfg_s.fingerprint()));
        }
        // One persistent accelerator per shard, built from the shard's
        // own config and shared by its workers.
        let shard_accels: Vec<_> = shard_cfgs.iter().map(Delegate::shared_accelerator).collect();
        // Arm the fault plan before any worker spawns: each shard's
        // accelerator gets its own deterministic injector stream (so
        // chaos outcomes depend on (seed, shard, stream ordinal), never
        // on thread interleaving). Injectors tally what they fire into
        // `faults/injected/<kind>`. Fresh mutexes cannot be poisoned.
        if let Some(plan) = &fault {
            for (s, accel) in shard_accels.iter().enumerate() {
                let mut injector = plan.injector_for_shard(s);
                injector.attach_telemetry(&telem.tree);
                accel.lock().expect("fresh accelerator mutex").set_fault_injector(injector);
            }
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                pending: VecDeque::new(),
                placed: vec![VecDeque::new(); shards],
                staged: 0,
                done: Vec::new(),
                closed: false,
                paused: false,
                backlog: vec![0; shards],
                resident: vec![None; shards],
                rr_next: 0,
                health: vec![HealthSlot::default(); shards],
            }),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            telem,
        });

        let mut handles = Vec::with_capacity(shards * workers_per_shard);
        for worker_idx in 0..shards * workers_per_shard {
            let shard = worker_idx % shards;
            let shard_cfg = shard_cfgs[shard].clone();
            let shared = shared.clone();
            let graphs = graphs.clone();
            let cache = cache.clone();
            let accel = shard_accels[shard].clone();
            let cfg = config.clone();
            let table = table.clone();
            let group_of = group_of.clone();
            // The injected worker-abort point, if this worker is the
            // plan's target (exercises the join-capture path in finish).
            let abort_at = fault.as_ref().and_then(|p| p.abort_for_worker(worker_idx));
            handles.push(std::thread::spawn(move || {
                let exec = Executor::with_shared_accelerator(
                    shard_cfg.clone(),
                    cfg.cpu_threads,
                    cfg.use_accelerator,
                    cache,
                    accel,
                );
                worker_loop(
                    &shared, &graphs, &exec, &cfg, shard, &shard_cfg, &table, &group_of,
                    worker_idx, abort_at,
                );
            }));
        }
        Self {
            shared,
            workers: handles,
            cache,
            graphs,
            config,
            shard_cfgs,
            submitted: 0,
            started: Instant::now(),
            plans_preloaded,
        }
    }

    /// Check a request against the hosted graphs before it enters the
    /// queue, so shape errors surface at the submission site.
    fn validate(&self, req: &Request) -> Result<(), SubmitError> {
        let Some(g) = self.graphs.get(req.graph) else {
            return Err(SubmitError::UnknownGraph { graph: req.graph, graphs: self.graphs.len() });
        };
        if let InputSource::Tensor(t) = &req.source {
            if t.shape() != &g.input_shape[..] {
                return Err(SubmitError::ShapeMismatch {
                    graph: req.graph,
                    got: t.shape().to_vec(),
                    want: g.input_shape.clone(),
                });
            }
        }
        Ok(())
    }

    /// Enqueue one request, blocking while the queue is at capacity
    /// (backpressure). Returns a [`Ticket`] whose id is the submission
    /// order.
    ///
    /// Caution: while the server is [`Server::pause`]d, nothing drains
    /// the queue, so a blocking submit past the queue capacity would
    /// wait until `resume` — which this same thread can then never call.
    /// Use [`Server::try_submit`] when submitting to a paused server.
    pub fn submit(&mut self, req: impl Into<Request>) -> Result<Ticket, SubmitError> {
        self.enqueue(req.into(), true)
    }

    /// Non-blocking submit: [`SubmitError::QueueFull`] when the bounded
    /// queue is at capacity (distinct from [`SubmitError::Closed`] — the
    /// old `Option` return conflated the two).
    pub fn try_submit(&mut self, req: impl Into<Request>) -> Result<Ticket, SubmitError> {
        self.enqueue(req.into(), false)
    }

    /// Shared enqueue tail of [`Server::submit`] / [`Server::try_submit`]:
    /// validate, then wait for queue space (`block`) or refuse
    /// (`QueueFull`), assign the id, and push. Ids are consumed only by
    /// admitted requests.
    fn enqueue(&mut self, req: Request, block: bool) -> Result<Ticket, SubmitError> {
        self.validate(&req)?;
        let shared = self.shared.clone();
        let mut st = lock_recover(&shared.state);
        if st.closed {
            return Err(SubmitError::Closed);
        }
        while st.pending.len() + st.staged >= self.config.queue_capacity {
            if !block {
                self.shared.telem.queue_full.inc();
                return Err(SubmitError::QueueFull);
            }
            st = shared.space_cv.wait(st).unwrap_or_else(PoisonError::into_inner);
            if st.closed {
                return Err(SubmitError::Closed);
            }
        }
        let id = self.next_id();
        let priority = req.class.priority;
        st.pending.push_back(Queued {
            id,
            source: req.source,
            graph: req.graph,
            class: req.class,
            enqueued: Instant::now(),
            passed_over: 0,
            attempts: 0,
            last_fail: None,
        });
        drop(st);
        let t = &self.shared.telem;
        t.tree.txn(|| {
            t.submitted.inc();
            t.in_flight.add(1.0);
        });
        t.class_submitted[class_slot(priority)].inc();
        self.shared.work_cv.notify_one();
        Ok(Ticket { id, shared: self.shared.clone() })
    }

    /// Blocking bulk submission; tickets come back in submission order.
    /// Stops at the first rejected request.
    pub fn submit_many<I>(&mut self, reqs: I) -> Result<Vec<Ticket>, SubmitError>
    where
        I: IntoIterator,
        I::Item: Into<Request>,
    {
        reqs.into_iter().map(|r| self.submit(r)).collect()
    }

    /// Collect responses completed so far (sorted by id) without closing
    /// the queue. Includes cancelled/expired resolutions.
    ///
    /// Polling also sweeps lapsed deadlines: on an otherwise idle (or
    /// paused) server no batch formation runs, so without this sweep a
    /// deadlined request would sit unresolved until the next submission
    /// woke a worker. `poll` is the client's observation point — by the
    /// time it returns, every request whose deadline has passed is
    /// resolved as [`Outcome::DeadlineExpired`].
    pub fn poll(&mut self) -> Vec<Response> {
        let mut st = lock_recover(&self.shared.state);
        let expired = st.sweep_expired();
        let mut out = std::mem::take(&mut st.done);
        drop(st);
        if expired > 0 {
            record_expired(&self.shared.telem, expired as u64);
            // Expired slots free queue capacity for blocked submitters.
            self.shared.space_cv.notify_all();
        }
        out.sort_by_key(|r| r.id);
        out
    }

    /// Stop workers from taking new work (in-flight batches complete).
    /// While paused, prefer [`Server::try_submit`] over the blocking
    /// [`Server::submit`] — see the caution there.
    pub fn pause(&mut self) {
        lock_recover(&self.shared.state).paused = true;
    }

    /// Resume a paused server.
    pub fn resume(&mut self) {
        lock_recover(&self.shared.state).paused = false;
        self.shared.work_cv.notify_all();
    }

    /// Requests waiting in the bounded client queue, before routing.
    /// Routed-but-unserved batches are not counted here (they left the
    /// queue at placement time) but still occupy queue capacity for
    /// backpressure purposes.
    pub fn queued(&self) -> usize {
        lock_recover(&self.shared.state).pending.len()
    }

    /// The server's live telemetry tree. Callers may hold the `Arc`
    /// past `finish`/`drain` (the tree outlives the server) — that is
    /// how `serve --stats-json` snapshots the final state — and may
    /// register their own nodes alongside the serving ones.
    pub fn telemetry(&self) -> Arc<Tree> {
        Arc::clone(&self.shared.telem.tree)
    }

    /// A consistent snapshot of the telemetry tree, taken mid-serve
    /// without pausing workers (seqlock read — see
    /// [`Tree::snapshot`]). The exactly-once ledger holds at every
    /// snapshot: `served + cancelled + deadline_expired + failed +
    /// in_flight == submitted`.
    pub fn inspect(&self) -> Snapshot {
        self.shared.telem.uptime_s.set(self.started.elapsed().as_secs_f64());
        self.shared.telem.tree.snapshot()
    }

    /// Close the queue, resolve everything still pending (executing,
    /// or expiring lapsed deadlines), and collect the remaining
    /// responses (sorted by id) — responses already taken by `poll`
    /// (including cancelled tickets, which resolved at cancel time) are
    /// not repeated.
    pub fn drain(self) -> Vec<Response> {
        self.finish().0
    }

    /// `drain` plus the server-lifetime statistics: plan-cache counters,
    /// weight-load amortization, placement decisions, per-shard
    /// utilization, latency percentiles, and the cancellation/deadline
    /// counters (see [`ServeStats`]). The stats are literally
    /// [`ServeStats::from_snapshot`] over the final telemetry snapshot —
    /// the tree is the single source of truth.
    pub fn finish(self) -> (Vec<Response>, ServeStats) {
        let Server {
            shared,
            workers,
            cache,
            graphs: _,
            config,
            shard_cfgs,
            submitted: _,
            started,
            plans_preloaded: _,
        } = self;
        {
            let mut st = lock_recover(&shared.state);
            st.closed = true;
            // Deterministic deadline enforcement at close: a lapsed
            // request on an idle/paused server expires here even if no
            // worker ever forms another batch.
            let expired = st.sweep_expired();
            record_expired(&shared.telem, expired as u64);
        }
        shared.work_cv.notify_all();
        // Join-capture: a dead worker (injected abort, or any real
        // panic that escaped supervision) must not take `finish` down
        // with it — completed responses still drain, and the panic
        // surfaces as a typed WorkerFailed in the stats.
        let mut worker_failures = Vec::new();
        for (worker, h) in workers.into_iter().enumerate() {
            if let Err(panic) = h.join() {
                let message = panic
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "worker panicked (non-string payload)".to_string());
                worker_failures.push(ServeError::WorkerFailed { worker, message });
            }
        }
        // Flush the drained cache to the plan store (atomic temp +
        // rename), so the next server over this fleet warm-restarts.
        // Best effort: a failed flush costs the next start a recompile,
        // never correctness — but say so on stderr.
        if let Some(path) = &config.plan_store {
            let mut fps: Vec<u64> = shard_cfgs.iter().map(AccelConfig::fingerprint).collect();
            fps.sort_unstable();
            fps.dedup();
            if let Err(e) = persist::save(path, &cache.export(), &fps) {
                eprintln!("warning: plan-store flush to {} failed: {e}", path.display());
            }
        }
        let mut done = {
            let mut st = lock_recover(&shared.state);
            // With every worker joined, anything still queued or placed
            // can only have been stranded by a dead thread (live workers
            // drain their own queues before exiting). Resolve each
            // stranded request exactly once so the ledger still
            // balances; a prior failed attempt keeps its reason, a
            // never-attempted request is WorkerLost.
            let mut stranded: Vec<Queued> = st.pending.drain(..).collect();
            for shard_queue in &mut st.placed {
                stranded.extend(std::mem::take(shard_queue).into_iter().flatten());
            }
            if !stranded.is_empty() {
                let n = stranded.len() as u64;
                for q in stranded {
                    let reason = q.last_fail.unwrap_or(FailReason::WorkerLost);
                    st.done.push(unserved_response(q, Outcome::Failed(reason)));
                }
                let t = &shared.telem;
                t.tree.txn(|| {
                    t.failed.add(n);
                    t.in_flight.add(-(n as f64));
                });
                st.staged = 0;
                st.backlog.iter_mut().for_each(|b| *b = 0);
            }
            if worker_failures.is_empty() {
                debug_assert!(st.backlog.iter().all(|&b| b == 0), "backlog must drain");
                debug_assert_eq!(st.staged, 0, "no batch may be left staged after join");
            }
            // Final health resync: the state machine is authoritative;
            // republish it so the snapshot's labels and quarantine gauge
            // can never drift from what supervision decided.
            let quarantined = st
                .health
                .iter()
                .zip(&shared.telem.shards)
                .map(|(h, sh)| {
                    sh.health.set(h.state.label());
                    u64::from(h.state == ShardHealth::Quarantined)
                })
                .sum::<u64>();
            shared.telem.quarantined_now.set(quarantined as f64);
            std::mem::take(&mut st.done)
        };
        done.sort_by_key(|r| r.id);

        // Worker panics become structured `faults/worker_failures` ring
        // entries; the projection rebuilds `ServeStats::worker_failures`
        // from exactly these.
        for failure in &worker_failures {
            if let ServeError::WorkerFailed { worker, message } = failure {
                let mut obj = BTreeMap::new();
                obj.insert("worker".to_string(), Value::Num(*worker as f64));
                obj.insert("message".to_string(), Value::Str(message.clone()));
                shared.telem.worker_failures.push(Value::Obj(obj));
            }
        }
        shared.telem.uptime_s.set(started.elapsed().as_secs_f64());
        let snap = shared.telem.tree.snapshot();
        let stats = ServeStats::from_snapshot(&snap)
            .expect("a snapshot of the server's own tree always projects");
        (done, stats)
    }

    fn next_id(&mut self) -> u64 {
        let id = self.submitted;
        self.submitted += 1;
        id
    }
}

// ---------------------------------------------------------------------------
// Batch formation and the worker loop
// ---------------------------------------------------------------------------

/// Form one batch from the queue. A *seed* request picks the group: the
/// most urgent priority among the first `window` entries, oldest first —
/// except that a request already passed over `window` times is promoted
/// above every class (the aging rule behind the bounded-inversion
/// guarantee; simultaneously promoted requests seed oldest-first, one
/// per formation, so promotion latency is bounded by the promoted
/// count — see the [module docs](self#batch-scheduling-priorities-and-fairness)).
/// Up to `max_batch` same-group requests among the scanned entries join
/// the seed, most urgent first (ties by queue position). Every scanned
/// entry left behind ages by one, so each batch formation either takes
/// a window entry or moves it one step toward promotion.
///
/// `group_of` maps a graph index to its batch-group id (identity under
/// [`BatchGrouping::GraphIdentity`]; the chain-representative index under
/// [`BatchGrouping::PlanChain`], so chain-mate graphs share a group).
fn take_group(
    pending: &mut VecDeque<Queued>,
    max_batch: usize,
    window: usize,
    group_of: &[usize],
) -> Vec<Queued> {
    let scan = pending.len().min(window);
    let seed_idx = (0..scan)
        .min_by_key(|&i| {
            let r = &pending[i];
            // `false < true`: promoted (aged) entries sort ahead of every
            // class, and drain oldest-first among themselves — their own
            // priority stops mattering once the inversion bound is hit.
            // Compared in u64 so an adversarially large window cannot
            // out-range the ledger (usize -> u64 is lossless on every
            // supported target).
            let fresh = r.passed_over < window as u64;
            let class = if fresh { r.class.priority } else { Priority::High };
            (fresh, class, i)
        })
        .expect("take_group on empty queue");
    let group = group_of[pending[seed_idx].graph];
    let seed_graph = pending[seed_idx].graph;
    // Fill the batch with the seed's group-mates, most urgent first.
    // Within a priority class, exact same-graph mates outrank chain-mates
    // of other graphs: when max_batch truncates a mixed window, keeping
    // same-variant requests together preserves their shared weight load
    // (a no-op under GraphIdentity, where every mate is the seed's graph).
    let mut mates: Vec<usize> =
        (0..scan).filter(|&i| i != seed_idx && group_of[pending[i].graph] == group).collect();
    mates.sort_by_key(|&i| (pending[i].class.priority, pending[i].graph != seed_graph, i));
    let chosen: Vec<usize> =
        std::iter::once(seed_idx).chain(mates).take(max_batch.max(1)).collect();
    // One pass over the queue: extract the chosen entries in batch order
    // (seed first, then urgency order), age the scanned leftovers.
    let mut slots: Vec<Option<Queued>> = (0..chosen.len()).map(|_| None).collect();
    let mut rest: VecDeque<Queued> = VecDeque::with_capacity(pending.len() - chosen.len());
    for (i, mut q) in pending.drain(..).enumerate() {
        if let Some(pos) = chosen.iter().position(|&c| c == i) {
            slots[pos] = Some(q);
        } else {
            if i < scan {
                q.passed_over = q.passed_over.saturating_add(1);
            }
            rest.push_back(q);
        }
    }
    *pending = rest;
    slots.into_iter().map(|s| s.expect("chosen index extracted")).collect()
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    shared: &Shared,
    graphs: &[Arc<Graph>],
    exec: &Executor,
    cfg: &ServerConfig,
    shard: usize,
    shard_cfg: &AccelConfig,
    table: &PlacementTable,
    group_of: &[usize],
    worker: usize,
    abort_at: Option<u64>,
) {
    let max_batch = cfg.max_batch.max(1);
    // CPU-only fleets never touch an accelerator: modeled accelerator
    // latencies and resident bonuses would be fiction, so fall back to
    // round-robin and leave the resident shadows untouched.
    let policy = if cfg.use_accelerator { cfg.placement } else { PlacementPolicy::RoundRobin };
    // Batches this worker has taken for execution — the injected-abort
    // ordinal counts these takes, not placements for other shards.
    let mut taken: u64 = 0;
    loop {
        let batch: Vec<Queued> = {
            let mut st = lock_recover(&shared.state);
            loop {
                // Recovery probe: a quarantined shard's worker checks
                // its accelerator before looking at the queues. The
                // probe runs unlocked (it touches the device mutex);
                // queue state is re-read afterwards, and the transition
                // back to Healthy is re-checked under the lock in case
                // a sibling worker probed concurrently.
                if st.health[shard].state == ShardHealth::Quarantined {
                    drop(st);
                    let recovered = exec.delegate.probe();
                    let t = &shared.telem;
                    t.probes.inc();
                    if recovered {
                        t.probe_recoveries.inc();
                    }
                    st = lock_recover(&shared.state);
                    if recovered && st.health[shard].state == ShardHealth::Quarantined {
                        st.health[shard] = HealthSlot::default();
                        t.quarantined_now.add(-1.0);
                        t.shards[shard].health.set(ShardHealth::Healthy.label());
                        shared.work_cv.notify_all();
                    }
                }
                let active = !st.paused || st.closed;
                if active {
                    // 0) Deadline enforcement point: lapsed requests are
                    // dropped (resolved as DeadlineExpired) before any
                    // batch forms, freeing their queue capacity.
                    let expired = st.sweep_expired();
                    if expired > 0 {
                        record_expired(&shared.telem, expired as u64);
                        shared.space_cv.notify_all();
                    }
                    // Injected worker abort: fires when this worker is
                    // about to take work, *outside* the supervised
                    // execution region — the thread itself dies (with
                    // the state lock poisoned, exercising recovery),
                    // and `finish` surfaces it as WorkerFailed. The
                    // queues are untouched: un-taken work is served by
                    // surviving workers or resolved at close.
                    if abort_at == Some(taken)
                        && (!st.placed[shard].is_empty() || !st.pending.is_empty())
                    {
                        panic!("injected fault: worker {worker} aborted at batch take {taken}");
                    }
                    // 1) Work already routed to this shard.
                    if let Some(batch) = st.placed[shard].pop_front() {
                        st.staged -= batch.len();
                        shared.space_cv.notify_all();
                        taken += 1;
                        break batch;
                    }
                    // 2) Route new work: form the priority-seeded batch
                    // and score it against every shard. Any worker
                    // places; only the target shard executes.
                    if !st.pending.is_empty() {
                        let batch =
                            take_group(&mut st.pending, max_batch, cfg.group_window, group_of);
                        shared.space_cv.notify_all();
                        let graph = batch[0].graph;
                        // A PlanChain batch may mix chain-mate graphs. All
                        // of them score identically (same layer geometry),
                        // so the seed's graph routes the batch — but the
                        // stream's *final* LoadWeights belongs to the last
                        // distinct variant in first-appearance order, so
                        // that graph's signature is what stays resident.
                        // (A heuristic: the delegate's residency-aware
                        // segment reorder can rotate an already-resident
                        // variant to the stream's front, shifting the true
                        // final load by one variant. The shadow only
                        // steers placement, never numerics.)
                        let resident_graph = {
                            let mut seen: Vec<usize> = Vec::new();
                            for r in &batch {
                                if !seen.contains(&r.graph) {
                                    seen.push(r.graph);
                                }
                            }
                            *seen.last().expect("non-empty batch")
                        };
                        let shards = st.placed.len();
                        // Quarantined shards take no placements. When
                        // the whole fleet is quarantined the mask is
                        // void and both policies fall back to all
                        // shards: requests then burn retry budget
                        // rather than deadlocking the queue.
                        let eligible: Vec<bool> = st
                            .health
                            .iter()
                            .map(|h| h.state != ShardHealth::Quarantined)
                            .collect();
                        let (target, scores_s, resident_hit_predicted) = match policy {
                            PlacementPolicy::Modeled { tolerance } => {
                                table.choose(graph, &st.resident, &st.backlog, tolerance, &eligible)
                            }
                            PlacementPolicy::RoundRobin => {
                                let mut t = st.rr_next % shards;
                                st.rr_next = st.rr_next.wrapping_add(1);
                                if eligible.iter().any(|&e| e) {
                                    // Advance past quarantined shards so
                                    // the rotation only visits healthy
                                    // ones (bounded: some shard is
                                    // eligible).
                                    while !eligible[t] {
                                        t = st.rr_next % shards;
                                        st.rr_next = st.rr_next.wrapping_add(1);
                                    }
                                }
                                let (scores, hits) = table.score_all(graph, &st.resident);
                                (t, scores, hits[t])
                            }
                        };
                        st.backlog[target] += batch.len() as u64;
                        // A graph with no TCONV layers never touches the
                        // accelerator: the shard's resident set survives
                        // it, so only overwrite the shadow with a real
                        // signature (and not at all on CPU-only fleets).
                        if cfg.use_accelerator {
                            if let Some(sig) = table.last_sig(resident_graph, target) {
                                st.resident[target] = Some(sig);
                            }
                        }
                        // Pushed while the state lock is held, so ring
                        // order is placement order.
                        shared.telem.placements.push(
                            PlacementDecision {
                                graph,
                                requests: batch.len(),
                                shard: target,
                                scores_s,
                                resident_hit_predicted,
                            }
                            .to_value(),
                        );
                        if target == shard {
                            taken += 1;
                            break batch;
                        }
                        st.staged += batch.len();
                        st.placed[target].push_back(batch);
                        shared.work_cv.notify_all();
                        continue;
                    }
                }
                if st.closed && st.pending.is_empty() && st.placed[shard].is_empty() {
                    return;
                }
                // A quarantined shard's worker re-probes on a timeout:
                // no queue event marks "the accelerator came back", so
                // an indefinite wait could park recovery forever.
                st = if st.health[shard].state == ShardHealth::Quarantined {
                    shared
                        .work_cv
                        .wait_timeout(st, Duration::from_millis(1))
                        .unwrap_or_else(PoisonError::into_inner)
                        .0
                } else {
                    shared.work_cv.wait(st).unwrap_or_else(PoisonError::into_inner)
                };
            }
        };

        let n = batch.len();
        // Distinct target graphs in first-appearance order. Length 1 for
        // every GraphIdentity batch; a PlanChain batch may mix chain-mate
        // graphs (equal GraphKeys — identical shapes, different weights).
        let mut distinct: Vec<usize> = Vec::new();
        for r in &batch {
            if !distinct.contains(&r.graph) {
                distinct.push(r.graph);
            }
        }
        let graph = &graphs[batch[0].graph];
        let t_batch = Instant::now();
        let queue_seconds: Vec<f64> =
            batch.iter().map(|r| r.enqueued.elapsed().as_secs_f64()).collect();
        // Chain-mates share an input shape (graph_key folds it), so the
        // seed graph's shape materializes every input.
        let inputs: Vec<Tensor<i8>> =
            batch.iter().map(|r| r.source.materialize(&graph.input_shape)).collect();

        // Layer-batched execution: every TCONV layer runs once for the
        // whole batch on the shard's persistent accelerator — one shared
        // Configure per tile, one LoadWeights per (tile, variant).
        //
        // Supervised: a typed ExecError *and* a panic (an injected
        // shard death, or any real accelerator invariant violation)
        // both leave the batch output-free — faults fire at stream
        // boundaries, before any instruction executes — so either way
        // the whole batch is safe to requeue. The closure only borrows;
        // `batch` stays owned here for the retry path.
        let t0 = Instant::now();
        let supervised = catch_unwind(AssertUnwindSafe(|| {
            if distinct.len() == 1 {
                exec.run_batch(graph, &inputs)
            } else {
                let variant_graphs: Vec<&Graph> = distinct.iter().map(|&g| &*graphs[g]).collect();
                let assignment: Vec<usize> = batch
                    .iter()
                    .map(|r| distinct.iter().position(|&g| g == r.graph).expect("distinct covers"))
                    .collect();
                exec.run_batch_multi(&variant_graphs, &assignment, &inputs)
            }
        }));
        let run = match supervised {
            Ok(Ok(run)) => run,
            Ok(Err(e)) => {
                supervise_failure(shared, cfg, shard, batch, FailReason::from_exec(&e));
                continue;
            }
            Err(_panic) => {
                supervise_failure(shared, cfg, shard, batch, FailReason::ShardDead);
                continue;
            }
        };
        let wall_batch = t0.elapsed().as_secs_f64();
        let modeled_batch = run.modeled(cfg.run_config, shard_cfg).total_s();
        let wl = run.weight_load_counters();
        let cross_batch_hit = run.first_layer_resident_hit();
        let repacks = run.repacks_skipped();
        // Amortized per-request shares.
        let wall_each = wall_batch / n as f64;
        let modeled_each = modeled_batch / n as f64;

        let mut responses = Vec::with_capacity(n);
        let mut latencies = Vec::with_capacity(n);
        let mut class_served = [0u64; 3];
        for ((req, output), queue_s) in batch.into_iter().zip(run.outputs).zip(&queue_seconds) {
            // A response is delivered only when its whole batch finishes:
            // client-observed latency counts the full batch wall time,
            // while `wall_seconds` carries the amortized per-request share.
            latencies.push(queue_s + wall_batch);
            class_served[class_slot(req.class.priority)] += 1;
            responses.push(Response {
                id: req.id,
                source: req.source,
                graph: req.graph,
                class: req.class,
                outcome: Outcome::Ok,
                shard: Some(shard),
                output: Some(output),
                queue_seconds: *queue_s,
                wall_seconds: wall_each,
                modeled_seconds: modeled_each,
            });
        }
        let busy_s = t_batch.elapsed().as_secs_f64();

        {
            let mut st = lock_recover(&shared.state);
            st.done.extend(responses);
            st.backlog[shard] -= n as u64;
            // A served batch proves the shard healthy: the consecutive-
            // failure ledger resets (Degraded -> Healthy; a Quarantined
            // shard only gets here after a probe already cleared it).
            st.health[shard] = HealthSlot::default();
        }
        let t = &shared.telem;
        // The ledger moves as one transaction; the remaining counters
        // are individually atomic throughput/amortization tallies.
        t.tree.txn(|| {
            t.served.add(n as u64);
            t.in_flight.add(-(n as f64));
        });
        for (slot, &count) in class_served.iter().enumerate() {
            if count > 0 {
                t.class_served[slot].add(count);
            }
        }
        for v in latencies {
            t.latency.record(v);
            t.latency_window.push(Value::Num(v));
        }
        t.wall_total_s.add(wall_batch);
        t.modeled_total_s.add(modeled_batch);
        t.batches.inc();
        t.weight_loads.add(wl.performed);
        t.weight_loads_skipped.add(wl.skipped);
        t.weight_loads_equiv.add(wl.equivalent);
        if repacks > 0 {
            t.repacks_skipped.add(repacks);
            t.shards[shard].repacks_skipped.add(repacks);
        }
        if distinct.len() > 1 {
            t.cross_graph_batches.inc();
        }
        if cross_batch_hit {
            t.cross_batch_resident_hits.inc();
        }
        t.shards[shard].busy_s.add(busy_s);
        t.shards[shard].requests.add(n as u64);
        t.shards[shard].health.set(ShardHealth::Healthy.label());
    }
}

/// Resolve one failed batch: bump attempt counters, requeue the
/// requests with budget left at the queue head (retrying can never
/// double-serve — the failed execution produced no output), resolve
/// exhausted ones as [`Outcome::Failed`], and advance the shard's
/// health machine.
fn supervise_failure(
    shared: &Shared,
    cfg: &ServerConfig,
    shard: usize,
    batch: Vec<Queued>,
    reason: FailReason,
) {
    let n = batch.len() as u64;
    let mut requeued = 0u64;
    let mut exhausted = 0u64;
    let quarantined_now;
    let health_label;
    {
        let mut st = lock_recover(&shared.state);
        st.backlog[shard] -= n;
        // Requeue at the queue head, preserving batch order (reverse
        // push_front), so retried requests keep their position. The
        // head insert may transiently push `pending` past
        // `queue_capacity`; these requests were already admitted once,
        // so the backpressure bound on *new* admissions is unaffected.
        for mut q in batch.into_iter().rev() {
            q.attempts += 1;
            q.last_fail = Some(reason);
            if q.attempts > cfg.retry_budget {
                exhausted += 1;
                st.done.push(unserved_response(q, Outcome::Failed(reason)));
            } else {
                st.pending.push_front(q);
                requeued += 1;
            }
        }
        let slot = &mut st.health[shard];
        slot.consecutive += 1;
        let quarantine = slot.consecutive >= cfg.quarantine_after.max(1);
        quarantined_now = quarantine && slot.state != ShardHealth::Quarantined;
        slot.state = if quarantine { ShardHealth::Quarantined } else { ShardHealth::Degraded };
        health_label = slot.state.label();
    }
    // Requeued work needs a worker (possibly on another shard);
    // resolved failures freed queue capacity.
    shared.work_cv.notify_all();
    shared.space_cv.notify_all();
    let t = &shared.telem;
    if exhausted > 0 {
        t.tree.txn(|| {
            t.failed.add(exhausted);
            t.in_flight.add(-(exhausted as f64));
        });
    }
    t.exec_failures.inc();
    t.shards[shard].exec_failures.inc();
    t.shards[shard].health.set(health_label);
    t.retries.add(requeued);
    if quarantined_now {
        t.shards_quarantined.inc();
        t.quarantined_now.add(1.0);
    }
}

// ---------------------------------------------------------------------------
// Stats
// ---------------------------------------------------------------------------

/// Serve-run summary. Latency percentiles cover queue wait + execution
/// of *served* requests (a 65 536-request recency window bounds memory);
/// `shard_utilization[i]` is shard i's busy time over the run, normalized
/// per worker slot (1.0 = that shard's workers never idled). Every
/// submitted request is accounted once:
/// `requests + cancelled + deadline_expired + requests_failed` covers
/// all resolved ids — the ledger the chaos suite pins under every fault
/// class.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Requests actually served (executed, [`Outcome::Ok`]). Widened
    /// from `usize` to `u64` so the ledger identity is closed over one
    /// integer type on every target.
    pub requests: u64,
    /// Requests submitted over the server's lifetime.
    pub submitted: u64,
    /// Requests resolved as [`Outcome::Cancelled`] via their tickets.
    pub cancelled: u64,
    /// Requests dropped at batch formation as [`Outcome::DeadlineExpired`].
    pub deadline_expired: u64,
    /// Requests resolved as [`Outcome::Failed`]: execution failures past
    /// the retry budget, plus requests stranded by dead workers at
    /// close. Additive field — all zeros without fault injection.
    pub requests_failed: u64,
    /// Batch executions that failed (typed [`ExecError`] or contained
    /// panic); each failed batch counts once however many requests it
    /// carried.
    pub exec_failures: u64,
    /// Requests requeued for retry after failed batches (one request
    /// retried twice counts twice).
    pub retries: u64,
    /// Recovery probes issued against quarantined shards.
    pub probes: u64,
    /// Recovery probes that succeeded (shard returned to service).
    pub probe_recoveries: u64,
    /// Healthy/Degraded -> Quarantined transitions over the lifetime.
    pub shards_quarantined: u64,
    /// Final supervision state per shard at close.
    pub shard_health: Vec<ShardHealth>,
    /// Worker threads that died of a panic, as
    /// [`ServeError::WorkerFailed`] (captured message included). Empty
    /// on a clean run; never causes `finish` itself to panic.
    pub worker_failures: Vec<ServeError>,
    /// Total host wall-clock seconds spent in numerics passes.
    pub wall_total_s: f64,
    /// Mean per-request host wall-clock seconds (amortized over batches).
    pub wall_mean_s: f64,
    /// Mean per-request modeled PYNQ-Z1 seconds (amortized over batches,
    /// on each serving shard's own config).
    pub modeled_mean_s: f64,
    /// Served requests per host wall-clock second.
    pub throughput_rps: f64,
    /// Median client-observed latency (queue wait + execution).
    pub p50_latency_s: f64,
    /// 95th-percentile client-observed latency.
    pub p95_latency_s: f64,
    /// Compiled-plan cache hits across all workers.
    pub cache_hits: u64,
    /// Compiled-plan cache misses (= compilations) across all workers.
    pub cache_misses: u64,
    /// Worker queue round-trips; `mean_batch_size` = requests / batches.
    pub batches: u64,
    /// Mean layer-batch width achieved by the group scheduler.
    pub mean_batch_size: f64,
    /// `LoadWeights` transfers actually performed across all layer
    /// executions (batched prologues + resident-skip elisions reduce
    /// this).
    pub weight_loads: u64,
    /// `LoadWeights` elided because the filter set was already resident
    /// in PM BRAM (within-batch and cross-batch skips).
    pub weight_loads_skipped: u64,
    /// `LoadWeights` transfers a per-request replay would have performed
    /// (requests x tiles per TCONV execution).
    pub weight_loads_equiv: u64,
    /// Batches that mixed requests for more than one chain-mate graph
    /// (only possible under [`BatchGrouping::PlanChain`]). Additive
    /// field — existing `ServeStats` consumers are unaffected.
    pub cross_graph_batches: u64,
    /// Batches whose first TCONV stream skipped its weight load because
    /// the previous batch on that shard left the same filter set
    /// resident — the cross-batch hits weight-aware placement creates.
    pub cross_batch_resident_hits: u64,
    /// Compiled plans preloaded from the [`ServerBuilder::plan_store`]
    /// snapshot at startup (0 without a store, or when the snapshot was
    /// rejected and the server cold-started). A warm restart shows
    /// `plans_preloaded == layer count` and `cache_misses == 0`.
    /// Additive field — existing `ServeStats` consumers are unaffected.
    pub plans_preloaded: u64,
    /// Per-shard busy fraction (1.0 = that shard's workers never idled).
    pub shard_utilization: Vec<f64>,
    /// Requests served per shard.
    pub shard_requests: Vec<u64>,
    /// [`AccelConfig::fingerprint`] of each shard's accelerator — equal
    /// entries mean a homogeneous fleet.
    pub shard_config_fps: Vec<u64>,
    /// Batch-routing decisions (scores are modeled seconds per shard
    /// with the resident bonus applied), in placement order while under
    /// the 65 536-decision recency window; older decisions rotate out so
    /// a long-lived server's memory stays bounded.
    pub placements: Vec<PlacementDecision>,
}

impl ServeStats {
    /// Project the legacy stats struct out of a telemetry [`Snapshot`].
    ///
    /// This is the *only* way a `ServeStats` is produced from a live
    /// server: [`Server::finish`] takes a final snapshot and projects
    /// it, so the tree is the single source of truth and this struct is
    /// a derived view. The projection also works on snapshots
    /// round-tripped through JSON (`serve --stats-json` →
    /// [`Snapshot::from_json`]), which is how `repro stats` rebuilds
    /// the summary offline. Errors name the first path that was missing
    /// or of the wrong kind — on a snapshot of a server's own tree that
    /// never happens.
    pub fn from_snapshot(snap: &Snapshot) -> Result<ServeStats, String> {
        let e = |err: telemetry::QueryError| err.to_string();
        let counter = |path: &str| snap.counter(path).map_err(e);
        let gauge = |path: &str| snap.gauge(path).map_err(e);

        let served = counter("fleet/served")?;
        let uptime_s = gauge("fleet/uptime_s")?;
        let wall_total_s = gauge("fleet/wall_total_s")?;
        let modeled_total_s = gauge("fleet/modeled_total_s")?;
        let batches = counter("fleet/batches")?;
        let denom = served.max(1) as f64;

        // Client-observed latency percentiles come from the bounded
        // recency ring, exactly as the legacy window kept them.
        let mut lat: Vec<f64> = snap
            .ring("fleet/latency_window")
            .map_err(e)?
            .iter()
            .filter_map(Value::as_f64)
            .collect();
        lat.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));

        // Per-shard subtrees: walk indices until the first missing
        // shard node (registration is dense, so this finds them all).
        let workers_per_shard = gauge("fleet/workers_per_shard")?.max(1.0);
        let mut shard_utilization = Vec::new();
        let mut shard_requests = Vec::new();
        let mut shard_config_fps = Vec::new();
        let mut shard_health = Vec::new();
        let mut i = 0usize;
        while snap.get(&format!("fleet/shard/{i}/requests")).is_ok() {
            shard_requests.push(counter(&format!("fleet/shard/{i}/requests"))?);
            let busy = gauge(&format!("fleet/shard/{i}/busy_s"))?;
            shard_utilization.push(busy / (uptime_s.max(1e-9) * workers_per_shard));
            let fp_hex = snap.text(&format!("fleet/shard/{i}/config_fp")).map_err(e)?;
            let fp = u64::from_str_radix(fp_hex.trim_start_matches("0x"), 16)
                .map_err(|err| format!("fleet/shard/{i}/config_fp: {err}"))?;
            shard_config_fps.push(fp);
            let label = snap.text(&format!("fleet/shard/{i}/health")).map_err(e)?;
            shard_health.push(
                ShardHealth::from_label(&label)
                    .ok_or_else(|| format!("fleet/shard/{i}/health: unknown label {label:?}"))?,
            );
            i += 1;
        }

        let worker_failures = snap
            .ring("faults/worker_failures")
            .map_err(e)?
            .iter()
            .map(|entry| {
                let worker = entry
                    .get("worker")
                    .and_then(Value::as_usize)
                    .ok_or("faults/worker_failures: entry missing numeric \"worker\"")?;
                let message = entry
                    .get("message")
                    .and_then(Value::as_str)
                    .ok_or("faults/worker_failures: entry missing string \"message\"")?;
                Ok(ServeError::WorkerFailed { worker, message: message.to_string() })
            })
            .collect::<Result<Vec<_>, String>>()?;

        let placements = snap
            .ring("fleet/placements")
            .map_err(e)?
            .iter()
            .map(PlacementDecision::from_value)
            .collect::<Result<Vec<_>, String>>()?;

        Ok(ServeStats {
            requests: served,
            submitted: counter("fleet/submitted")?,
            cancelled: counter("fleet/cancelled")?,
            deadline_expired: counter("fleet/deadline_expired")?,
            requests_failed: counter("fleet/failed")?,
            exec_failures: counter("fleet/exec_failures")?,
            retries: counter("fleet/retries")?,
            probes: counter("fleet/probes")?,
            probe_recoveries: counter("fleet/probe_recoveries")?,
            shards_quarantined: counter("fleet/shards_quarantined")?,
            shard_health,
            worker_failures,
            wall_total_s,
            wall_mean_s: wall_total_s / denom,
            modeled_mean_s: modeled_total_s / denom,
            throughput_rps: served as f64 / uptime_s.max(1e-9),
            p50_latency_s: percentile(&lat, 0.50),
            p95_latency_s: percentile(&lat, 0.95),
            cache_hits: counter("cache/hits")?,
            cache_misses: counter("cache/misses")?,
            batches,
            mean_batch_size: served as f64 / batches.max(1) as f64,
            weight_loads: counter("fleet/weight_loads")?,
            weight_loads_skipped: counter("fleet/weight_loads_skipped")?,
            weight_loads_equiv: counter("fleet/weight_loads_equiv")?,
            cross_graph_batches: counter("fleet/cross_graph_batches")?,
            cross_batch_resident_hits: counter("fleet/cross_batch_resident_hits")?,
            plans_preloaded: counter("cache/preloaded")?,
            shard_utilization,
            shard_requests,
            shard_config_fps,
            placements,
        })
    }

    /// Fraction of plan lookups served from cache (0 when none).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Fraction of per-request-equivalent weight loads that batching and
    /// resident-weight reuse eliminated (0 for per-request traffic, 1 -
    /// 1/N for full same-layer batches of width N, higher when
    /// cross-batch resident skips fire).
    pub fn weight_load_hit_rate(&self) -> f64 {
        if self.weight_loads_equiv == 0 {
            0.0
        } else {
            1.0 - self.weight_loads as f64 / self.weight_loads_equiv as f64
        }
    }
}

/// Nearest-rank percentile over an ascending-sorted sample (0 when
/// empty). Shared with `bench::harness::latency_by_class` so the
/// per-class split and [`ServeStats`] percentiles can never disagree on
/// the same data.
pub(crate) fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Summary over an explicit response set (e.g. one `poll` window).
/// Latency percentiles cover the served responses; cancelled/expired
/// resolutions are counted but contribute no latency samples. Cache,
/// shard, and placement fields are zero/empty here — those are
/// server-lifetime numbers reported by [`Server::finish`].
pub fn summarize(responses: &[Response], elapsed_s: f64) -> ServeStats {
    let served: Vec<&Response> = responses.iter().filter(|r| r.outcome == Outcome::Ok).collect();
    let n = served.len().max(1);
    let wall_total: f64 = served.iter().map(|r| r.wall_seconds).sum();
    let modeled: f64 = served.iter().map(|r| r.modeled_seconds).sum();
    let mut lat: Vec<f64> = served.iter().map(|r| r.latency_seconds()).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ServeStats {
        requests: served.len() as u64,
        submitted: responses.len() as u64,
        cancelled: responses.iter().filter(|r| r.outcome == Outcome::Cancelled).count() as u64,
        deadline_expired: responses
            .iter()
            .filter(|r| r.outcome == Outcome::DeadlineExpired)
            .count() as u64,
        requests_failed: responses
            .iter()
            .filter(|r| matches!(r.outcome, Outcome::Failed(_)))
            .count() as u64,
        exec_failures: 0,
        retries: 0,
        probes: 0,
        probe_recoveries: 0,
        shards_quarantined: 0,
        shard_health: Vec::new(),
        worker_failures: Vec::new(),
        wall_total_s: wall_total,
        wall_mean_s: wall_total / n as f64,
        modeled_mean_s: modeled / n as f64,
        throughput_rps: served.len() as f64 / elapsed_s.max(1e-9),
        p50_latency_s: percentile(&lat, 0.50),
        p95_latency_s: percentile(&lat, 0.95),
        cache_hits: 0,
        cache_misses: 0,
        batches: 0,
        mean_batch_size: 0.0,
        weight_loads: 0,
        weight_loads_skipped: 0,
        weight_loads_equiv: 0,
        cross_graph_batches: 0,
        cross_batch_resident_hits: 0,
        plans_preloaded: 0,
        shard_utilization: Vec::new(),
        shard_requests: Vec::new(),
        shard_config_fps: Vec::new(),
        placements: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::Delegate;
    use crate::model::graph::Layer;
    use crate::model::zoo;

    fn tiny_graph() -> Arc<Graph> {
        Arc::new(zoo::pix2pix(8, 2, 0))
    }

    fn tiny_builder(shards: usize, workers_per_shard: usize) -> ServerBuilder {
        Server::builder()
            .graph(tiny_graph())
            .shards(shards)
            .workers_per_shard(workers_per_shard)
            .queue_capacity(16)
            .max_batch(2)
    }

    fn queued(id: u64, graph: usize, priority: Priority) -> Queued {
        Queued {
            id,
            source: InputSource::Seed(id),
            graph,
            class: Class { priority, deadline: None },
            enqueued: Instant::now(),
            passed_over: 0,
            attempts: 0,
            last_fail: None,
        }
    }

    #[test]
    fn serves_all_requests_deterministically() {
        let mut server = tiny_builder(2, 1).start().unwrap();
        for seed in 0..6 {
            server.submit(Request::seed(seed)).unwrap();
        }
        let responses = server.drain();
        assert_eq!(responses.len(), 6);
        assert_eq!(responses.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4, 5]);
        assert!(responses.iter().all(|r| r.outcome == Outcome::Ok));

        // Same seeds on a different topology => identical outputs
        // (end-to-end determinism, independent of sharding).
        let mut server2 = tiny_builder(1, 1).start().unwrap();
        for seed in 0..6 {
            server2.submit(Request::seed(seed)).unwrap();
        }
        let responses2 = server2.drain();
        for (a, b) in responses.iter().zip(&responses2) {
            assert_eq!(a.output_tensor().data(), b.output_tensor().data());
        }
    }

    #[test]
    fn builder_validates_topology_and_modeling() {
        assert_eq!(Server::builder().start().err(), Some(ServeError::NoGraphs));
        let err = tiny_builder(1, 1).queue_capacity(0).start().err();
        assert_eq!(err, Some(ServeError::InvalidConfig("queue_capacity must be >= 1")));
        let err = tiny_builder(1, 1).max_batch(0).start().err();
        assert_eq!(err, Some(ServeError::InvalidConfig("max_batch must be >= 1")));
        let err = tiny_builder(0, 1).start().err();
        assert_eq!(err, Some(ServeError::InvalidConfig("fleet needs >= 1 shard")));
        // AccPlusCpu modeling without an accelerator used to panic in
        // start_multi; it is a typed error now.
        let err = tiny_builder(1, 1).use_accelerator(false).start().err();
        assert!(matches!(err, Some(ServeError::InvalidConfig(_))));
        // CPU-only serving with CPU modeling is valid.
        let mut server = tiny_builder(1, 1)
            .use_accelerator(false)
            .run_config(RunConfig::Cpu { threads: 1 })
            .start()
            .unwrap();
        server.submit(Request::seed(1)).unwrap();
        assert_eq!(server.drain().len(), 1);
    }

    #[test]
    fn submit_rejects_unknown_graph_and_shape_mismatch() {
        let mut server = tiny_builder(1, 1).start().unwrap();
        let err = server.submit(Request::seed(0).graph(3)).err();
        assert_eq!(err, Some(SubmitError::UnknownGraph { graph: 3, graphs: 1 }));
        let bad = Arc::new(Tensor::<i8>::zeros(&[2, 2, 2]));
        let err = server.submit(Request::tensor(bad)).err();
        assert!(matches!(err, Some(SubmitError::ShapeMismatch { graph: 0, .. })), "{err:?}");
        // Rejected submissions consume no ids.
        let t = server.submit(Request::seed(9)).unwrap();
        assert_eq!(t.id(), 0);
        server.drain();
    }

    #[test]
    fn tensor_payload_serves_byte_identical_to_executor() {
        let g = tiny_graph();
        let mut rng = Pcg32::new(77);
        let x = Arc::new(Tensor::<i8>::random(&g.input_shape, &mut rng));
        let mut server = tiny_builder(1, 1).start().unwrap();
        server.submit(Request::tensor(x.clone())).unwrap();
        let responses = server.drain();
        assert_eq!(responses.len(), 1);
        assert!(responses[0].seed().is_none(), "tensor payloads carry no seed");
        let reference = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
        let want = reference.run(&g, &x);
        assert_eq!(responses[0].output_tensor().data(), want.output.data());
    }

    #[test]
    fn stats_cover_latency_cache_weights_shards_and_placements() {
        let mut server = tiny_builder(2, 1).start().unwrap();
        for seed in 0..8 {
            server.submit(Request::seed(seed)).unwrap();
        }
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 8);
        assert_eq!(stats.requests, 8);
        assert_eq!(stats.submitted, 8);
        assert_eq!((stats.cancelled, stats.deadline_expired), (0, 0));
        assert!(stats.wall_mean_s > 0.0);
        assert!(stats.modeled_mean_s > 0.0);
        assert!(stats.throughput_rps > 0.0);
        assert!(stats.p50_latency_s > 0.0);
        assert!(stats.p95_latency_s >= stats.p50_latency_s);
        assert_eq!(stats.shard_utilization.len(), 2);
        assert_eq!(stats.shard_requests.iter().sum::<u64>(), 8);
        assert!(stats.batches >= 4, "8 requests at max_batch 2 need >= 4 batches");
        // A homogeneous default fleet: identical config fingerprints,
        // and one recorded decision per batch with one score per shard.
        assert_eq!(stats.shard_config_fps, vec![AccelConfig::default().fingerprint(); 2]);
        assert_eq!(stats.placements.len(), stats.batches as usize);
        assert_eq!(
            stats.placements.iter().map(|d| d.requests as u64).sum::<u64>(),
            8,
            "placements cover every request exactly once"
        );
        assert!(stats.placements.iter().all(|d| d.scores_s.len() == 2));
        // Plans are looked up once per (batch, layer); each layer
        // compiled once, everything else hit.
        assert!(stats.cache_hits > 0);
        assert!(stats.cache_misses > 0);
        assert!(stats.cache_hit_rate() > 0.0 && stats.cache_hit_rate() < 1.0);
        // Weight-load accounting is present and consistent.
        assert!(stats.weight_loads > 0);
        assert!(stats.weight_loads_equiv >= stats.weight_loads);
        let rate = stats.weight_load_hit_rate();
        assert!((0.0..1.0).contains(&rate), "hit rate {rate}");
    }

    /// The plan-cache acceptance criterion, batching-aware: N requests
    /// for the same graph compile each TCONV layer exactly once and look
    /// plans up once per (batch, layer); outputs are byte-identical to
    /// the uncached path. (The placement table compiles its signature
    /// plans *outside* the shared cache, so these counters stay exact.)
    #[test]
    fn plan_cache_compiles_each_layer_once_across_requests() {
        let g = tiny_graph();
        let tconv_layers =
            g.layers.iter().filter(|l| matches!(l, Layer::Tconv { .. })).count() as u64;
        assert!(tconv_layers >= 2, "graph should exercise several layers");

        // Single worker + pre-filled queue => deterministic batching:
        // 4 requests at max_batch 2 form exactly 2 batches.
        let mut server = tiny_builder(1, 1).start().unwrap();
        server.pause();
        let n_requests = 4u64;
        for seed in 0..n_requests {
            server.try_submit(Request::seed(seed)).unwrap();
        }
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(stats.batches, 2, "4 queued requests at max_batch 2");
        assert_eq!(stats.cache_misses, tconv_layers, "each layer compiled exactly once");
        assert_eq!(stats.cache_hits, (stats.batches - 1) * tconv_layers);
        // A full same-layer batch of 2 halves the weight loads.
        assert_eq!(stats.weight_loads_equiv, 2 * stats.weight_loads);
        assert!((stats.weight_load_hit_rate() - 0.5).abs() < 1e-12);

        // Byte-identical to the uncached executor on every request.
        let uncached = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
        for r in &responses {
            let input = r.source.materialize(&g.input_shape);
            let want = uncached.run(&g, &input);
            assert_eq!(r.output_tensor().data(), want.output.data(), "id {}", r.id);
        }
    }

    #[test]
    fn multi_graph_requests_group_by_graph_and_stay_correct() {
        // Two graphs with different weights. They are chain-mates (same
        // shapes), so pin GraphIdentity grouping — this test asserts the
        // baseline policy where batches never mix graphs.
        let g0 = Arc::new(zoo::pix2pix(8, 2, 0));
        let g1 = Arc::new(zoo::pix2pix(8, 2, 7));
        let mut server = Server::builder()
            .graphs([g0.clone(), g1.clone()])
            .shards(1)
            .queue_capacity(16)
            .max_batch(2)
            .batch_grouping(BatchGrouping::GraphIdentity)
            .start()
            .unwrap();
        server.pause();
        // Interleaved submission; the scheduler regroups by graph.
        for seed in 0..6u64 {
            server.try_submit(Request::seed(seed).graph((seed % 2) as usize)).unwrap();
        }
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 6);

        // Outputs byte-identical to per-request runs on the right graph.
        let reference = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
        for r in &responses {
            let g = if r.graph == 0 { &g0 } else { &g1 };
            let input = r.source.materialize(&g.input_shape);
            let want = reference.run(g, &input);
            let bytes = want.output.data();
            assert_eq!(r.output_tensor().data(), bytes, "id {} graph {}", r.id, r.graph);
        }
        // Batches never mix groups, so 3 same-graph requests at
        // max_batch 2 make 2 batches per graph.
        assert_eq!(stats.batches, 4);
    }

    /// The default PlanChain grouping batches chain-mates (equal
    /// GraphKeys — identical shapes, different weights) together: the
    /// same interleaved traffic that GraphIdentity serves as singletons
    /// (window 2 never holds two same-graph requests) coalesces into
    /// cross-graph batches, at byte-identical outputs.
    #[test]
    fn chain_mate_graphs_share_batches_under_plan_chain() {
        let g0 = Arc::new(zoo::pix2pix(8, 2, 0));
        let g1 = Arc::new(zoo::pix2pix(8, 2, 7));
        assert_eq!(
            g0.graph_key(&AccelConfig::default()),
            g1.graph_key(&AccelConfig::default()),
            "same-shape different-seed zoo models are chain-mates"
        );
        let build = || {
            Server::builder()
                .graphs([g0.clone(), g1.clone()])
                .shards(1)
                .queue_capacity(16)
                .max_batch(2)
                .group_window(2)
        };
        let traffic = |server: &mut Server| {
            server.pause();
            for seed in 0..6u64 {
                server.try_submit(Request::seed(seed).graph((seed % 2) as usize)).unwrap();
            }
            server.resume();
        };
        let mut chain = build().start().unwrap();
        traffic(&mut chain);
        let (responses, stats) = chain.finish();
        assert_eq!(responses.len(), 6);
        assert_eq!(stats.batches, 3, "interleave coalesces into pairs");
        assert_eq!(stats.cross_graph_batches, 3, "every pair mixes both graphs");

        // The baseline on identical traffic: window 2 never sees a
        // same-graph mate, so every batch is a singleton.
        let mut ident =
            build().batch_grouping(BatchGrouping::GraphIdentity).start().unwrap();
        traffic(&mut ident);
        let (ident_responses, ident_stats) = ident.finish();
        assert_eq!(ident_stats.batches, 6);
        assert_eq!(ident_stats.cross_graph_batches, 0);

        // Byte-identical outputs: per request against its own graph, and
        // across grouping policies.
        let reference = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
        for (r, ri) in responses.iter().zip(&ident_responses) {
            let g = if r.graph == 0 { &g0 } else { &g1 };
            let input = r.source.materialize(&g.input_shape);
            let want = reference.run(g, &input);
            assert_eq!(r.output_tensor().data(), want.output.data(), "id {}", r.id);
            assert_eq!(r.output_tensor().data(), ri.output_tensor().data(), "id {}", r.id);
        }
    }

    #[test]
    fn head_of_line_group_defines_each_batch_under_uniform_priority() {
        // Queue: [g1, g0, g0] with one worker, max_batch 2. The head (g1)
        // forms a singleton batch even though two g0 requests could fill
        // a batch — the uniform-priority starvation bound.
        let g0 = Arc::new(zoo::pix2pix(8, 2, 0));
        let g1 = Arc::new(zoo::pix2pix(8, 2, 7));
        let mut server = Server::builder()
            .graphs([g0, g1])
            .shards(1)
            .queue_capacity(16)
            .max_batch(2)
            .batch_grouping(BatchGrouping::GraphIdentity)
            .start()
            .unwrap();
        server.pause();
        server.try_submit(Request::seed(10).graph(1)).unwrap();
        server.try_submit(Request::seed(11).graph(0)).unwrap();
        server.try_submit(Request::seed(12).graph(0)).unwrap();
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 3);
        assert_eq!(stats.batches, 2, "one singleton (head group) + one pair");
        assert!((stats.mean_batch_size - 1.5).abs() < 1e-12);
    }

    #[test]
    fn group_window_bounds_out_of_order_pulls() {
        let mut pending: VecDeque<Queued> = VecDeque::new();
        // g0 at positions 0, 2, 4; g1 at 1, 3.
        for (i, g) in [0usize, 1, 0, 1, 0].iter().enumerate() {
            pending.push_back(queued(i as u64, *g, Priority::Normal));
        }
        // Window 3: scans positions 0..3 only — picks g0 ids 0 and 2, the
        // g0 at original position 4 stays put.
        let batch = take_group(&mut pending, 8, 3, &[0, 1]);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(pending.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3, 4]);
        // The passed-over g1 aged by one; the unscanned g0 did not.
        assert_eq!(pending[0].passed_over, 1);
        assert_eq!(pending[2].passed_over, 0);
        // Unbounded window takes the rest of the head group.
        let batch = take_group(&mut pending, 8, usize::MAX, &[0, 1]);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(pending.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4]);
        // max_batch caps the pull.
        let batch = take_group(&mut pending, 1, usize::MAX, &[0, 1]);
        assert_eq!(batch.len(), 1);
        assert!(pending.is_empty());
    }

    #[test]
    fn priority_seeds_the_batch_ahead_of_older_lower_classes() {
        let mut pending: VecDeque<Queued> = VecDeque::new();
        pending.push_back(queued(0, 0, Priority::Low));
        pending.push_back(queued(1, 1, Priority::High));
        pending.push_back(queued(2, 1, Priority::Normal));
        // The High request seeds even though the Low one is older; the
        // same-graph Normal request rides along.
        let batch = take_group(&mut pending, 4, 8, &[0, 1]);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(pending.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
        assert_eq!(pending[0].passed_over, 1, "the skipped Low request aged");
    }

    /// The bounded-inversion guarantee: under a constant stream of
    /// High-priority traffic for another graph, a Low-priority request is
    /// passed over at most `group_window` times before aging promotes it
    /// to batch seed.
    #[test]
    fn low_priority_request_is_passed_over_at_most_window_times() {
        let window = 4usize;
        let mut pending: VecDeque<Queued> = VecDeque::new();
        pending.push_back(queued(0, 0, Priority::Low));
        let mut next_id = 1u64;
        let mut formations = 0usize;
        loop {
            // Keep the window saturated with fresh High traffic for g1.
            while pending.len() < window + 2 {
                pending.push_back(queued(next_id, 1, Priority::High));
                next_id += 1;
            }
            let batch = take_group(&mut pending, 2, window, &[0, 1]);
            formations += 1;
            if batch.iter().any(|r| r.id == 0) {
                // The aged request must seed its batch (it is g0's only
                // request, so it forms a singleton batch).
                assert_eq!(batch[0].id, 0);
                break;
            }
            assert!(
                formations <= window + 1,
                "low-priority request passed over {formations} times (window {window})"
            );
        }
        assert_eq!(formations, window + 1, "promotion fires exactly at the bound");
    }

    /// Simultaneously promoted requests drain oldest-first, one per
    /// formation, regardless of their own classes — the `k` promoted
    /// entries term in the documented `group_window + k - 1` bound.
    #[test]
    fn promoted_requests_drain_oldest_first() {
        let window = 2usize;
        let mut pending: VecDeque<Queued> = VecDeque::new();
        // Two different-graph requests aged past the window; the younger
        // one has the nominally better class, but promotion outranks it.
        let mut a = queued(0, 0, Priority::Low);
        a.passed_over = window as u64;
        let mut b = queued(1, 1, Priority::High);
        b.passed_over = window as u64;
        pending.push_back(a);
        pending.push_back(b);
        pending.push_back(queued(2, 2, Priority::High));
        let batch = take_group(&mut pending, 4, window, &[0, 1, 2]);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
        let batch = take_group(&mut pending, 4, window, &[0, 1, 2]);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn cancel_resolves_queued_requests_exactly_once_and_is_idempotent() {
        let mut server = tiny_builder(1, 1).start().unwrap();
        server.pause();
        let keep = server.try_submit(Request::seed(0)).unwrap();
        let gone = server.try_submit(Request::seed(1)).unwrap();
        assert!(gone.cancel(), "queued request cancels");
        assert!(!gone.cancel(), "second cancel is a no-op");
        assert_eq!(server.queued(), 1, "cancellation freed the slot");
        server.resume();
        let (responses, stats) = server.finish();
        // finish() is idempotent w.r.t. the cancelled ticket: both ids
        // resolve exactly once.
        assert_eq!(responses.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(responses[0].outcome, Outcome::Ok);
        assert_eq!(responses[1].outcome, Outcome::Cancelled);
        assert!(responses[1].output.is_none());
        assert_eq!(responses[1].shard, None);
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.cancelled, 1);
        assert_eq!(stats.submitted, 2);
        assert!(!keep.cancel(), "already-served ticket cannot cancel");
    }

    #[test]
    fn expired_deadlines_drop_at_batch_formation_with_stats() {
        let mut server = tiny_builder(1, 1).start().unwrap();
        server.pause();
        server.try_submit(Request::seed(0)).unwrap();
        // An already-lapsed deadline: dropped before any batch forms.
        server.try_submit(Request::seed(1).deadline(Duration::ZERO)).unwrap();
        // A generous deadline: survives.
        server.try_submit(Request::seed(2).deadline(Duration::from_secs(3600))).unwrap();
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 3);
        assert_eq!(responses[0].outcome, Outcome::Ok);
        assert_eq!(responses[1].outcome, Outcome::DeadlineExpired);
        assert!(responses[1].output.is_none());
        assert_eq!(responses[2].outcome, Outcome::Ok);
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.deadline_expired, 1);
        assert_eq!(stats.cancelled, 0);
        assert_eq!(stats.submitted, 3);
    }

    /// The idle-queue deadline bug: deadlines used to be swept only at
    /// batch formation, so on a server with no further traffic (workers
    /// paused/idle) a deadlined request never resolved. `poll` now
    /// sweeps, so the expiry needs no new submission to surface — and
    /// the expired slot frees queue capacity immediately.
    #[test]
    fn idle_queue_deadline_expires_via_poll_without_traffic() {
        let mut server = tiny_builder(1, 1).queue_capacity(1).start().unwrap();
        // Paused workers never form a batch: whatever resolves the
        // deadline, it is not `take_group`.
        server.pause();
        server.try_submit(Request::seed(0).deadline(Duration::from_millis(5))).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        // Still unresolved and still occupying the (full) queue...
        assert_eq!(
            server.try_submit(Request::seed(1)).err(),
            Some(SubmitError::QueueFull),
            "lapsed request still holds its slot until a sweep runs"
        );
        // ...until poll sweeps it.
        let responses = server.poll();
        assert_eq!(responses.len(), 1);
        assert_eq!(responses[0].outcome, Outcome::DeadlineExpired);
        assert_eq!(server.queued(), 0, "expiry freed the queue slot");
        // Capacity is back without any worker having run.
        let t = server.try_submit(Request::seed(2)).unwrap();
        assert!(t.cancel());
        server.resume();
        let (rest, stats) = server.finish();
        assert!(rest.iter().all(|r| r.outcome == Outcome::Cancelled), "{rest:?}");
        assert_eq!(stats.deadline_expired, 1);
        assert_eq!(stats.requests, 0);
    }

    /// `finish` sweeps too: a deadlined request on a paused server
    /// resolves as expired at close even when `poll` never runs.
    #[test]
    fn idle_queue_deadline_expires_at_finish() {
        let mut server = tiny_builder(1, 1).start().unwrap();
        server.pause();
        server.try_submit(Request::seed(0).deadline(Duration::from_millis(5))).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 1);
        assert_eq!(responses[0].outcome, Outcome::DeadlineExpired);
        assert_eq!(stats.deadline_expired, 1);
    }

    /// The aging-counter truncation bug: `passed_over` was a `u32`, so
    /// under a `group_window` above `u32::MAX` (64-bit hosts; e.g. the
    /// `usize::MAX` "unbounded" window) a saturated counter stayed
    /// "fresh" forever and promotion silently never fired. The ledger is
    /// now `u64`: counts beyond the old saturation point keep rising,
    /// and promotion fires exactly at the bound even for windows a u32
    /// cannot represent.
    #[test]
    #[cfg(target_pointer_width = "64")]
    fn aging_ledger_survives_adversarial_windows() {
        let window = (u32::MAX as usize) + 1;
        let mut pending: VecDeque<Queued> = VecDeque::new();
        let mut a = queued(0, 0, Priority::Low);
        a.passed_over = u32::MAX as u64; // the old type's saturation point
        pending.push_back(a);
        pending.push_back(queued(1, 1, Priority::High));
        // Below the bound the Low request is still fresh: High seeds,
        // and the ledger keeps counting past u32::MAX instead of
        // sticking at the saturation point.
        let batch = take_group(&mut pending, 1, window, &[0, 1]);
        assert_eq!(batch[0].id, 1);
        assert_eq!(pending[0].passed_over, u32::MAX as u64 + 1, "no saturation plateau");
        // At the bound, promotion outranks a fresh High request — the
        // check a u32 ledger could never reach under this window.
        pending[0].passed_over = window as u64;
        pending.push_back(queued(2, 1, Priority::High));
        let batch = take_group(&mut pending, 1, window, &[0, 1]);
        assert_eq!(batch[0].id, 0, "promotion fires despite a beyond-u32 window");
        // usize::MAX windows (the "unbounded" idiom) are also safe.
        pending.push_back(queued(3, 0, Priority::Low));
        let batch = take_group(&mut pending, 1, usize::MAX, &[0, 1]);
        assert_eq!(batch[0].id, 2, "urgency order under an unbounded window");
    }

    #[test]
    fn poll_and_drain_return_each_response_exactly_once() {
        let mut server = tiny_builder(2, 2).start().unwrap();
        let tickets = server.submit_many((0..10u64).map(Request::seed)).unwrap();
        let ids: Vec<u64> = tickets.iter().map(Ticket::id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
        let mut seen = Vec::new();
        // Poll a few windows while work is in flight...
        for _ in 0..3 {
            seen.extend(server.poll().into_iter().map(|r| r.id));
            std::thread::yield_now();
        }
        // ...then close; drain returns only the remainder, sorted.
        let rest = server.drain();
        assert!(rest.windows(2).all(|w| w[0].id < w[1].id), "drain sorted by id");
        seen.extend(rest.iter().map(|r| r.id));
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn bounded_queue_refuses_when_paused_and_full() {
        let mut server = tiny_builder(1, 1).queue_capacity(3).start().unwrap();
        server.pause();
        for seed in 0..3 {
            server.try_submit(Request::seed(seed)).unwrap();
        }
        assert_eq!(server.queued(), 3);
        assert_eq!(
            server.try_submit(Request::seed(99)).err(),
            Some(SubmitError::QueueFull),
            "backpressure engaged"
        );
        server.resume();
        let responses = server.drain();
        assert_eq!(responses.len(), 3);
    }

    /// A heterogeneous fleet built from the builder's shard fleet serves
    /// correctly, reports per-shard fingerprints, and every modeled
    /// placement decision lands within the scorer's tolerance of the
    /// minimum.
    #[test]
    fn heterogeneous_fleet_serves_and_respects_tolerance() {
        let g = tiny_graph();
        let mut small = AccelConfig::default();
        small.x_pms = 4;
        small.uf = 32;
        let tolerance = 0.05;
        let mut server = Server::builder()
            .graph(g.clone())
            .workers_per_shard(1)
            .queue_capacity(16)
            .max_batch(2)
            .shard_fleet(vec![AccelConfig::default(), small.clone()])
            .placement(PlacementPolicy::Modeled { tolerance })
            .start()
            .unwrap();
        for seed in 0..6 {
            server.submit(Request::seed(seed)).unwrap();
        }
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 6);
        assert_eq!(
            stats.shard_config_fps,
            vec![AccelConfig::default().fingerprint(), small.fingerprint()]
        );
        assert_ne!(stats.shard_config_fps[0], stats.shard_config_fps[1]);
        // Every decision picked a shard within tolerance of the min.
        assert!(!stats.placements.is_empty());
        for d in &stats.placements {
            let min = d.scores_s.iter().copied().fold(f64::INFINITY, f64::min);
            assert!(
                d.scores_s[d.shard] <= min * (1.0 + tolerance) + 1e-12,
                "decision outside tolerance: {d:?}"
            );
        }
        // Outputs byte-identical to the default-config reference,
        // whichever shard config served them.
        let reference = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
        for r in &responses {
            let input = r.source.materialize(&g.input_shape);
            let want = reference.run(&g, &input);
            assert_eq!(r.output_tensor().data(), want.output.data(), "id {}", r.id);
        }
    }

    /// Round-robin routing alternates shards strictly — the route-blind
    /// baseline the benches compare the scorer against.
    #[test]
    fn round_robin_alternates_shards() {
        let mut server = Server::builder()
            .graph(tiny_graph())
            .shards(2)
            .workers_per_shard(1)
            .queue_capacity(16)
            .max_batch(1)
            .placement(PlacementPolicy::RoundRobin)
            .start()
            .unwrap();
        server.pause();
        for seed in 0..4 {
            server.try_submit(Request::seed(seed)).unwrap();
        }
        server.resume();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 4);
        let shards: Vec<usize> = stats.placements.iter().map(|d| d.shard).collect();
        assert_eq!(shards, vec![0, 1, 0, 1], "round-robin placement order");
    }

    /// Without fault injection, the supervision surface is inert: all
    /// fault counters zero, every shard Healthy, no worker failures —
    /// so the whole pre-existing suite is untouched by the layer. Pins
    /// `no_fault_injection`, which must hold even under a chaos CI
    /// matrix that exports MM2IM_FAULT_SPEC.
    #[test]
    fn fault_free_serving_reports_zero_fault_counters() {
        let mut server = tiny_builder(2, 1).no_fault_injection().start().unwrap();
        for seed in 0..4 {
            server.submit(Request::seed(seed)).unwrap();
        }
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 4);
        assert!(responses.iter().all(|r| r.outcome == Outcome::Ok));
        assert_eq!(stats.requests_failed, 0);
        assert_eq!(stats.exec_failures, 0);
        assert_eq!(stats.retries, 0);
        assert_eq!(stats.probes, 0);
        assert_eq!(stats.probe_recoveries, 0);
        assert_eq!(stats.shards_quarantined, 0);
        assert_eq!(stats.shard_health, vec![ShardHealth::Healthy; 2]);
        assert!(stats.worker_failures.is_empty());
        // The ledger balances with the new term at zero.
        assert_eq!(
            stats.requests + stats.cancelled + stats.deadline_expired + stats.requests_failed,
            stats.submitted
        );
    }

    #[test]
    fn builder_validates_fault_knobs() {
        let err = tiny_builder(1, 1).quarantine_after(0).start().err();
        assert_eq!(err, Some(ServeError::InvalidConfig("quarantine_after must be >= 1")));
        // An explicit plan bypasses the env read entirely.
        let plan = FaultPlan::new(crate::accel::FaultSpec::new(7));
        let mut server = tiny_builder(1, 1).fault_plan(plan).start().unwrap();
        server.submit(Request::seed(0)).unwrap();
        let (responses, stats) = server.finish();
        assert_eq!(responses.len(), 1);
        assert_eq!(stats.exec_failures, 0, "seed-only plan arms no fault class");
    }
}
