//! Modeled-latency, weight-aware placement for heterogeneous fleets.
//!
//! The paper sweeps MM2IM across 261 TCONV configurations precisely
//! because no single `(X, UF)` instantiation wins everywhere (§V-B);
//! GANAX makes the same argument for heterogeneous execution resources
//! inside one generative model. This module is the serving-layer
//! consequence: when shards run *different* [`AccelConfig`]s, the
//! scheduler must decide per batch which backend serves it. The scorer
//! combines two signals:
//!
//! * **Modeled latency** — for each shard config, the sum of
//!   [`crate::perf_model`] estimates over the group's TCONV layers
//!   (memoized in an [`EstimateCache`]; weights never change the cycle
//!   estimate, so one walk per `(layer geometry, config)` pair serves
//!   the whole process).
//! * **Resident-weight bonus** — a shard whose accelerator still holds
//!   the group's *first* filter set in PM BRAM (tracked as a
//!   [`WeightSetSig`] shadow) will elide that stream's opening
//!   `LoadWeights`, so its score is reduced by the modeled transfer time
//!   of that filter set. This is what makes the PR-2 resident-skip fire
//!   *across* consecutive batches instead of only within one.
//!
//! Among all shards whose score lands within `tolerance` of the minimum,
//! the one with the smallest backlog wins (ties break to the lowest
//! shard index), so a homogeneous fleet degrades gracefully to
//! load-balancing rather than piling onto shard 0.
//!
//! Placement is deliberately class-blind: batches arrive here already
//! formed by the priority/deadline-aware scheduler (see the
//! [coordinator docs](super#batch-scheduling-priorities-and-fairness) —
//! lapsed deadlines never reach placement, and a batch's priority mix
//! influenced only its formation order). Scores depend on the batch's
//! *graph*, never its service classes, so routing stays byte-identical
//! across priority mixes.
//!
//! Everything here is precomputed at server start from graph metadata —
//! the dispatch path only compares a handful of floats per decision and
//! never touches an accelerator lock.

use crate::accel::axi::transfer_cycles;
use crate::accel::{AccelConfig, WeightSetSig};
use crate::driver::instructions::compile_layer;
use crate::driver::CompiledPlan;
use crate::model::executor::post_act_scale;
use crate::model::graph::{Graph, Layer};
use crate::perf_model::EstimateCache;
use crate::tensor::quant::PerChannel;
use crate::tensor::QuantParams;
use crate::util::json::Value;
use std::collections::BTreeMap;
use std::sync::Arc;

/// How the coordinator assigns request groups to shards.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PlacementPolicy {
    /// Score every shard by modeled latency minus the resident-weight
    /// bonus; among shards within `tolerance` (a relative fraction) of
    /// the minimum, the smallest backlog wins.
    Modeled {
        /// Relative latency slack: a shard qualifies when its score is
        /// `<= min_score * (1 + tolerance)`.
        tolerance: f64,
    },
    /// Route-blind round-robin — the baseline the benches compare the
    /// scorer against.
    RoundRobin,
}

impl Default for PlacementPolicy {
    fn default() -> Self {
        Self::Modeled { tolerance: 0.05 }
    }
}

/// One batch-to-shard routing decision, recorded for observability and
/// the differential test net.
#[derive(Clone, Debug)]
pub struct PlacementDecision {
    /// Graph (request group) the batch belonged to.
    pub graph: usize,
    /// Requests in the batch.
    pub requests: usize,
    /// Shard the batch was routed to.
    pub shard: usize,
    /// Per-shard scores at decision time (modeled seconds, resident
    /// bonus already applied).
    pub scores_s: Vec<f64>,
    /// Whether the chosen shard's predicted resident filter set matched
    /// the group's first layer (the cross-batch weight-skip steer).
    pub resident_hit_predicted: bool,
}

impl PlacementDecision {
    /// Encode the decision as the JSON object pushed into the
    /// `fleet/placements` telemetry ring.
    pub fn to_value(&self) -> Value {
        let mut obj = BTreeMap::new();
        obj.insert("graph".to_string(), Value::Num(self.graph as f64));
        obj.insert("requests".to_string(), Value::Num(self.requests as f64));
        obj.insert("shard".to_string(), Value::Num(self.shard as f64));
        obj.insert(
            "scores_s".to_string(),
            Value::Arr(self.scores_s.iter().map(|&s| Value::Num(s)).collect()),
        );
        obj.insert("resident_hit_predicted".to_string(), Value::Bool(self.resident_hit_predicted));
        Value::Obj(obj)
    }

    /// Decode a ring entry written by [`Self::to_value`] (how
    /// [`super::ServeStats::from_snapshot`] rebuilds the decision log).
    pub fn from_value(v: &Value) -> Result<Self, String> {
        let field = |name: &str| {
            v.get(name).ok_or_else(|| format!("placement entry missing {name:?}"))
        };
        let index = |name: &str| {
            field(name)?
                .as_usize()
                .ok_or_else(|| format!("placement entry {name:?} must be a non-negative integer"))
        };
        let scores_s = field("scores_s")?
            .as_arr()
            .ok_or("placement entry \"scores_s\" must be an array")?
            .iter()
            .map(|s| s.as_f64().ok_or("placement entry \"scores_s\" must hold numbers"))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Self {
            graph: index("graph")?,
            requests: index("requests")?,
            shard: index("shard")?,
            scores_s,
            resident_hit_predicted: field("resident_hit_predicted")?
                .as_bool()
                .ok_or("placement entry \"resident_hit_predicted\" must be a bool")?,
        })
    }
}

/// Precomputed routing metadata for one `(graph, shard config)` pair.
#[derive(Clone, Debug)]
struct GraphOnConfig {
    /// Σ modeled end-to-end seconds over the graph's TCONV layers.
    score_s: f64,
    /// Signature of the first weight load a request stream issues.
    first_sig: Option<WeightSetSig>,
    /// Signature left resident after the stream completes.
    last_sig: Option<WeightSetSig>,
    /// Modeled seconds saved when the first load is elided.
    resident_bonus_s: f64,
}

/// The placement scorer's precomputed table: for every graph and every
/// shard, the modeled TCONV latency on that shard's config plus the
/// weight signatures needed to predict cross-batch resident skips.
#[derive(Debug)]
pub struct PlacementTable {
    /// `per_graph[graph][shard]`.
    per_graph: Vec<Vec<GraphOnConfig>>,
}

/// TCONV layers of `g` with the activation scale entering each of them
/// (replicates the executor's scale chain without running numerics).
fn tconv_entry_scales(g: &Graph) -> Vec<(usize, f32)> {
    let mut scale = g.input_scale;
    let mut out = Vec::new();
    for (i, layer) in g.layers.iter().enumerate() {
        match layer {
            Layer::Dense { out_scale, act, .. } | Layer::Conv { out_scale, act, .. } => {
                scale = post_act_scale(*act, *out_scale);
            }
            Layer::Tconv { out_scale, act, .. } => {
                out.push((i, scale));
                scale = post_act_scale(*act, *out_scale);
            }
            _ => {}
        }
    }
    out
}

/// Compile the TCONV layer at `g.layers[idx]` for `cfg` with exactly the
/// requant parameters the executor will use at serve time, so the plan's
/// weight signatures byte-match the payloads the accelerator sees.
fn compile_graph_tconv(g: &Graph, idx: usize, entry_scale: f32, cfg: &AccelConfig) -> CompiledPlan {
    let Layer::Tconv { p, w, bias, w_scale, out_scale, .. } = &g.layers[idx] else {
        unreachable!("tconv_entry_scales only yields TCONV indices");
    };
    let out_q = QuantParams { scale: *out_scale, zero_point: 0 };
    let requant = PerChannel::new(entry_scale, &vec![*w_scale; p.oc], out_q);
    compile_layer(p, w, bias, Some(&requant), cfg, crate::accel::OutMode::Int8)
}

impl GraphOnConfig {
    fn build(g: &Graph, cfg: &AccelConfig, estimates: &EstimateCache) -> Self {
        let tconvs = tconv_entry_scales(g);
        let mut score_s = 0.0;
        for &(i, _) in &tconvs {
            if let Layer::Tconv { p, .. } = &g.layers[i] {
                score_s += estimates.modeled_seconds(p, cfg);
            }
        }
        let (first_sig, last_sig, resident_bonus_s) = match (tconvs.first(), tconvs.last()) {
            (Some(&(fi, f_scale)), Some(&(li, l_scale))) => {
                let first_plan = compile_graph_tconv(g, fi, f_scale, cfg);
                // The bonus is the modeled transfer the resident skip
                // elides: tile 0's filter payload bytes at this config's
                // AXI cost and clock (never overlapped with compute).
                let bytes: u64 = first_plan.tiles[0].weights.transfer_bytes();
                let bonus = cfg.seconds(transfer_cycles(bytes, cfg));
                let first_sig = first_plan.first_weight_sig();
                let last_sig = if li == fi {
                    first_plan.last_weight_sig()
                } else {
                    compile_graph_tconv(g, li, l_scale, cfg).last_weight_sig()
                };
                (Some(first_sig), Some(last_sig), bonus)
            }
            _ => (None, None, 0.0),
        };
        Self { score_s, first_sig, last_sig, resident_bonus_s }
    }
}

impl PlacementTable {
    /// Precompute the table for `graphs` over `shard_cfgs`. Identical
    /// configs (by fingerprint) share their per-graph work, so a
    /// homogeneous fleet pays for one config regardless of shard count.
    /// Compilation here bypasses the serving plan cache on purpose: the
    /// table only needs weight signatures, and warming the cache would
    /// distort its hit/miss accounting.
    pub fn build(
        graphs: &[Arc<Graph>],
        shard_cfgs: &[AccelConfig],
        estimates: &EstimateCache,
    ) -> Self {
        let mut distinct: Vec<(u64, usize)> = Vec::new();
        let mut computed: Vec<Vec<GraphOnConfig>> = Vec::new();
        let mut shard_slot = Vec::with_capacity(shard_cfgs.len());
        for cfg in shard_cfgs {
            let fp = cfg.fingerprint();
            let slot = match distinct.iter().find(|(f, _)| *f == fp) {
                Some(&(_, s)) => s,
                None => {
                    let s = computed.len();
                    computed.push(
                        graphs.iter().map(|g| GraphOnConfig::build(g, cfg, estimates)).collect(),
                    );
                    distinct.push((fp, s));
                    s
                }
            };
            shard_slot.push(slot);
        }
        let per_graph = (0..graphs.len())
            .map(|g| shard_slot.iter().map(|&s| computed[s][g].clone()).collect())
            .collect();
        Self { per_graph }
    }

    /// Shards the table was built for.
    pub fn shards(&self) -> usize {
        self.per_graph.first().map_or(0, Vec::len)
    }

    /// Per-shard scores for `graph` given each shard's predicted
    /// resident signature, plus which shards got the resident bonus.
    pub fn score_all(
        &self,
        graph: usize,
        resident: &[Option<WeightSetSig>],
    ) -> (Vec<f64>, Vec<bool>) {
        let row = &self.per_graph[graph];
        let mut scores = Vec::with_capacity(row.len());
        let mut hits = Vec::with_capacity(row.len());
        for (s, info) in row.iter().enumerate() {
            let hit = matches!(
                (info.first_sig, resident[s]),
                (Some(a), Some(b)) if a == b
            );
            scores.push(if hit { info.score_s - info.resident_bonus_s } else { info.score_s });
            hits.push(hit);
        }
        (scores, hits)
    }

    /// The scorer: returns `(shard, per-shard scores, resident hit)`.
    /// The chosen shard's score is always within `tolerance`
    /// (relative) of the minimum *eligible* score; among qualifying
    /// shards the smallest `backlog` wins, ties breaking to the lowest
    /// index.
    ///
    /// `eligible` masks shards the supervisor has quarantined (see the
    /// [coordinator docs](super#fault-model-and-supervision)). When no
    /// shard is eligible the mask is ignored — placing somewhere and
    /// letting the retry/probe machinery sort it out beats deadlocking
    /// the queue.
    pub fn choose(
        &self,
        graph: usize,
        resident: &[Option<WeightSetSig>],
        backlog: &[u64],
        tolerance: f64,
        eligible: &[bool],
    ) -> (usize, Vec<f64>, bool) {
        let (scores, hits) = self.score_all(graph, resident);
        let any_eligible = eligible.iter().any(|&e| e);
        let usable = |s: usize| !any_eligible || eligible[s];
        let min = scores
            .iter()
            .enumerate()
            .filter(|&(s, _)| usable(s))
            .map(|(_, &sc)| sc)
            .fold(f64::INFINITY, f64::min);
        let cutoff = min * (1.0 + tolerance.max(0.0)) + f64::EPSILON;
        let mut best: Option<usize> = None;
        for (s, &score) in scores.iter().enumerate() {
            if usable(s) && score <= cutoff {
                best = match best {
                    Some(b) if backlog[s] >= backlog[b] => Some(b),
                    _ => Some(s),
                };
            }
        }
        let shard = best.expect("scorer needs at least one shard");
        (shard, scores, hits[shard])
    }

    /// Signature left resident on `shard`'s accelerator after it serves
    /// a `graph` batch (the shadow the coordinator tracks per shard).
    pub fn last_sig(&self, graph: usize, shard: usize) -> Option<WeightSetSig> {
        self.per_graph[graph][shard].last_sig
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::tconv::problem::TconvProblem;

    /// Single-TCONV graph whose one layer is single-tile on X=8.
    fn single_layer_graph(seed: u64) -> Arc<Graph> {
        Arc::new(zoo::single_tconv("single", TconvProblem::new(5, 5, 16, 3, 8, 2), seed))
    }

    #[test]
    fn homogeneous_fleet_ties_break_by_backlog_then_index() {
        let g = single_layer_graph(1);
        let cfgs = vec![AccelConfig::default(), AccelConfig::default()];
        let table = PlacementTable::build(&[g], &cfgs, &EstimateCache::new());
        assert_eq!(table.shards(), 2);
        let none = [None, None];
        let (shard, scores, hit) = table.choose(0, &none, &[0, 0], 0.05, &[true, true]);
        assert_eq!(shard, 0, "equal scores, equal backlog: lowest index");
        assert!((scores[0] - scores[1]).abs() < 1e-18, "identical configs tie");
        assert!(!hit);
        let (shard, _, _) = table.choose(0, &none, &[4, 1], 0.05, &[true, true]);
        assert_eq!(shard, 1, "backlog breaks the tie");
    }

    #[test]
    fn resident_bonus_steers_to_the_warm_shard_and_predicts_hits() {
        let g = single_layer_graph(2);
        let cfgs = vec![AccelConfig::default(), AccelConfig::default()];
        let table = PlacementTable::build(&[g.clone()], &cfgs, &EstimateCache::new());
        // Single-tile single-layer graph: what stays resident after a
        // batch is exactly what the next batch loads first.
        let warm = table.last_sig(0, 1);
        assert!(warm.is_some());
        let resident = [None, warm];
        let (scores, hits) = table.score_all(0, &resident);
        assert!(scores[1] < scores[0], "bonus lowers the warm shard's score");
        assert_eq!(hits, vec![false, true]);
        // Even with a slight backlog, the warm shard wins once the cold
        // shard falls outside tolerance.
        let (shard, _, hit) = table.choose(0, &resident, &[0, 1], 0.0, &[true, true]);
        assert_eq!(shard, 1);
        assert!(hit);
    }

    #[test]
    fn quarantined_shards_are_skipped_unless_none_remain() {
        let g = single_layer_graph(4);
        let mut small = AccelConfig::default();
        small.x_pms = 4;
        small.uf = 8;
        // Shard 1 (default config) is strictly faster than shard 0.
        let cfgs = vec![small, AccelConfig::default()];
        let table = PlacementTable::build(&[g], &cfgs, &EstimateCache::new());
        let none = [None, None];
        let (fast, _, _) = table.choose(0, &none, &[0, 0], 0.0, &[true, true]);
        assert_eq!(fast, 1, "default config wins on modeled latency");
        // Quarantine the fast shard: the slow one must take the batch
        // even at zero tolerance.
        let (shard, _, _) = table.choose(0, &none, &[0, 0], 0.0, &[true, false]);
        assert_eq!(shard, 0, "quarantined shard excluded from placement");
        // All shards quarantined: the mask is ignored for liveness.
        let (shard, _, _) = table.choose(0, &none, &[0, 0], 0.0, &[false, false]);
        assert_eq!(shard, 1, "empty mask falls back to the full fleet");
    }

    #[test]
    fn heterogeneous_scores_differ_and_tolerance_gates_eligibility() {
        let g = single_layer_graph(3);
        let mut small = AccelConfig::default();
        small.x_pms = 4;
        small.uf = 8;
        let cfgs = vec![AccelConfig::default(), small];
        let table = PlacementTable::build(&[g], &cfgs, &EstimateCache::new());
        let none = [None, None];
        let (scores, _) = table.score_all(0, &none);
        assert!(
            (scores[0] - scores[1]).abs() > 1e-12,
            "different configs must score differently: {scores:?}"
        );
        // With zero tolerance only the strict minimum qualifies, no
        // matter how lopsided the backlog is.
        let min_shard = if scores[0] < scores[1] { 0 } else { 1 };
        let (shard, _, _) = table.choose(0, &none, &[u64::MAX, u64::MAX], 0.0, &[true, true]);
        assert_eq!(shard, min_shard);
    }

    #[test]
    fn graphs_without_tconv_layers_score_zero_everywhere() {
        let g = Arc::new(Graph {
            name: "dense_only".into(),
            input_shape: vec![4],
            input_scale: 0.05,
            layers: vec![],
        });
        let table = PlacementTable::build(&[g], &[AccelConfig::default()], &EstimateCache::new());
        let (scores, hits) = table.score_all(0, &[None]);
        assert_eq!(scores, vec![0.0]);
        assert_eq!(hits, vec![false]);
        assert_eq!(table.last_sig(0, 0), None);
        let (shard, _, _) = table.choose(0, &[None], &[0], 0.05, &[true]);
        assert_eq!(shard, 0);
    }
}
