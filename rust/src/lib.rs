//! # MM2IM — Accelerating Transposed Convolutions on (simulated) FPGA edge devices
//!
//! Reproduction of Haris & Cano, *"Accelerating Transposed Convolutions on
//! FPGA-based Edge Devices"* (CS.AR 2025), as a three-layer Rust + JAX +
//! Pallas system (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the paper's contribution: the MM2IM accelerator
//!   (cycle-level simulator of the full microarchitecture in [`accel`]),
//!   the host driver + TFLite-style delegate ([`driver`]), the dual-thread
//!   CPU baseline ([`cpu`]), the analytical performance model
//!   ([`perf_model`]), a mini int8 inference runtime + model zoo
//!   ([`model`]), the 261-problem benchmark harness ([`bench`]), and the
//!   serving subsystem ([`coordinator`]).
//! * **L2/L1 (python, build-time only)** — JAX graphs + the Pallas MM2IM
//!   kernel, AOT-lowered to HLO text artifacts which [`runtime`] loads and
//!   executes through PJRT for golden-numerics cross-validation (stubbed
//!   in images without the `xla` crate — see [`runtime::pjrt`]).
//!
//! Python never runs on the request path; after `make artifacts` the rust
//! binary is self-contained.
//!
//! # Serving architecture (coordinator + plan cache)
//!
//! The paper's accelerator amortizes mapping work in hardware — maps are
//! generated once per row and broadcast to all PMs (§IV-E). The serving
//! stack applies the same amortization one level up, in three pieces:
//!
//! * **Compile/execute split** ([`driver::instructions::compile_layer`] /
//!   [`driver::plan::CompiledPlan`]): everything Algorithm 1 derives that
//!   is input-independent — output-channel tiling, packed filter/requant
//!   payloads, the `i_end_row` row-streaming schedule — is compiled once
//!   per layer; a request only splices its input rows in
//!   ([`driver::plan::CompiledPlan::instantiate`]).
//! * **Keyed plan cache** ([`driver::plan::PlanCache`]): bounded and
//!   LRU-evicting, shared across all workers of a server. Keys are
//!   (`TconvProblem`, `OutMode`, [`accel::AccelConfig::fingerprint`],
//!   parameter fingerprint) — the parameter fingerprint keeps two
//!   same-geometry layers with different weights apart. Compilation runs
//!   under the cache lock, so every key compiles exactly once per
//!   process; hit/miss counters surface in
//!   [`coordinator::ServeStats`].
//! * **Sharded, batched server** ([`coordinator::Server`]): N shards of
//!   workers (one simulated accelerator instance each) pull batches from
//!   one bounded queue. Submission is async with backpressure
//!   ([`coordinator::Server::submit`] blocks when full,
//!   [`coordinator::Server::try_submit`] refuses,
//!   [`coordinator::Server::poll`] collects without closing), and
//!   [`coordinator::Server::finish`] reports p50/p95 latency, cache hit
//!   rate and per-shard utilization.

pub mod accel;
pub mod bench;
pub mod coordinator;
pub mod cpu;
pub mod driver;
pub mod model;
pub mod perf_model;
pub mod runtime;
pub mod tconv;
pub mod tensor;
pub mod util;

pub use tconv::problem::TconvProblem;
