//! # MM2IM — Accelerating Transposed Convolutions on (simulated) FPGA edge devices
//!
//! Reproduction of Haris & Cano, *"Accelerating Transposed Convolutions on
//! FPGA-based Edge Devices"* (CS.AR 2025), as a three-layer Rust + JAX +
//! Pallas system (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the paper's contribution: the MM2IM accelerator
//!   (cycle-level simulator of the full microarchitecture in [`accel`]),
//!   the host driver + TFLite-style delegate ([`driver`]), the dual-thread
//!   CPU baseline ([`cpu`]), the analytical performance model
//!   ([`perf_model`]), a mini int8 inference runtime + model zoo
//!   ([`model`]), the 261-problem benchmark harness ([`bench`]), and an
//!   inference service ([`coordinator`]).
//! * **L2/L1 (python, build-time only)** — JAX graphs + the Pallas MM2IM
//!   kernel, AOT-lowered to HLO text artifacts which [`runtime`] loads and
//!   executes through PJRT for golden-numerics cross-validation.
//!
//! Python never runs on the request path; after `make artifacts` the rust
//! binary is self-contained.

pub mod accel;
pub mod bench;
pub mod coordinator;
pub mod cpu;
pub mod driver;
pub mod model;
pub mod perf_model;
pub mod runtime;
pub mod tconv;
pub mod tensor;
pub mod util;

pub use tconv::problem::TconvProblem;
