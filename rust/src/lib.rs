//! # MM2IM — Accelerating Transposed Convolutions on (simulated) FPGA edge devices
//!
//! Reproduction of Haris & Cano, *"Accelerating Transposed Convolutions on
//! FPGA-based Edge Devices"* (CS.AR 2025), as a three-layer Rust + JAX +
//! Pallas system (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the paper's contribution: the MM2IM accelerator
//!   (cycle-level simulator of the full microarchitecture in [`accel`]),
//!   the host driver + TFLite-style delegate ([`driver`]), the dual-thread
//!   CPU baseline ([`cpu`]), the analytical performance model
//!   ([`perf_model`]), a mini int8 inference runtime + model zoo
//!   ([`model`]), the 261-problem benchmark harness ([`bench`]), and the
//!   serving subsystem ([`coordinator`]).
//! * **L2/L1 (python, build-time only)** — JAX graphs + the Pallas MM2IM
//!   kernel, AOT-lowered to HLO text artifacts which [`runtime`] loads and
//!   executes through PJRT for golden-numerics cross-validation (stubbed
//!   in images without the `xla` crate — see [`runtime::pjrt`]).
//!
//! Python never runs on the request path; after `make artifacts` the rust
//! binary is self-contained.
//!
//! # Serving architecture
//!
//! The request path — typed request (seed or `Arc`-shared tensor
//! payload, priority class, optional deadline) → ticket → batch
//! scheduler → placement scorer → shard → plan cache → compiled plan →
//! persistent simulator — is documented end to end in
//! `docs/architecture.md`. The short version: requests are composed
//! with [`coordinator::Request`]/[`coordinator::RequestBuilder`] and
//! submitted to a [`coordinator::Server`] built via
//! [`coordinator::Server::builder`]; every submission returns a
//! cancellable [`coordinator::Ticket`] and resolves to exactly one
//! [`coordinator::Outcome`]. Layer programs compile once per process
//! per backend config
//! ([`driver::plan::PlanCache`]), same-graph requests are batched by
//! layer so one `Configure`/`LoadWeights` prologue per tile serves the
//! whole batch ([`driver::plan::CompiledPlan::instantiate_batch`]), and
//! every shard owns a persistent [`accel::Accelerator`] — built from
//! that shard's own [`accel::AccelConfig`], so the fleet can be
//! heterogeneous — whose weight BRAM survives across batches (redundant
//! loads are elided and counted). Batches are routed to shards by
//! modeled latency with a resident-weight bonus
//! ([`coordinator::placement`]), steering consecutive same-layer
//! batches onto the shard that already holds their filters. The
//! [`coordinator`] module documents the scheduler's fairness bound;
//! [`coordinator::ServeStats`] exposes the resulting plan-cache and
//! weight-load hit rates, cross-batch resident hits, and the placement
//! decision log. Compiled plans outlive the process: a server built
//! with a plan store ([`driver::persist`]) flushes its cache to a
//! versioned, checksummed, fingerprint-validated snapshot on finish and
//! preloads it on the next start, so a restarted shard serves its first
//! request with zero plan compiles.
//!
//! The stack is supervised: seeded, deterministic fault injection
//! ([`accel::fault`] — transient faults, corrupt-transfer detection,
//! latency stalls, shard death, worker aborts, via the
//! `MM2IM_FAULT_SPEC` env var or
//! [`coordinator::ServerBuilder::fault_plan`]) drives a retry +
//! quarantine layer in the coordinator: failed batches are requeued to
//! healthy shards under a bounded retry budget, repeatedly failing
//! shards are excluded from placement until a recovery probe succeeds,
//! worker panics surface as [`coordinator::ServeError::WorkerFailed`]
//! instead of propagating, and every request still resolves exactly
//! once (`served + cancelled + deadline_expired + failed ==
//! submitted`), with survivors byte-identical to a fault-free run.
//!
//! Everything the stack counts flows into one hierarchical
//! [`telemetry`] tree (mist-os Inspect-style: per-shard / per-class /
//! per-plan nodes of atomic counters, gauges, latency histograms, and
//! ring-buffer logs), snapshot-consistent mid-serve via
//! [`coordinator::Server::inspect`] and serialized stably as JSON
//! (`serve --stats-json`, `repro stats`). The legacy
//! [`coordinator::ServeStats`] struct is now a pure projection of a
//! final snapshot ([`coordinator::ServeStats::from_snapshot`]), and
//! declarative [`telemetry::triage`] rules — the exactly-once ledger
//! above chief among them — turn any snapshot into a health verdict.
#![warn(missing_docs)]

pub mod accel;
pub mod bench;
pub mod coordinator;
pub mod cpu;
pub mod driver;
pub mod model;
pub mod perf_model;
pub mod runtime;
pub mod tconv;
pub mod telemetry;
pub mod tensor;
pub mod util;

pub use tconv::problem::{MapperKind, TconvProblem};
