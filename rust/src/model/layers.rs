//! CPU kernels for the non-TCONV layers (im2col + GEMM convolution,
//! dense, int8 activations). These are the layers the paper leaves on the
//! board's CPU during end-to-end GAN runs (§V-E).

use crate::cpu::gemm;
use crate::model::graph::{Act, ConvProblem};
use crate::tensor::quant::QuantizedMultiplier;
use crate::tensor::Tensor;

/// Standard SAME convolution, int8 -> int32 accumulators (+bias), via
/// im2col + blocked GEMM.
pub fn conv2d_i32(
    p: &ConvProblem,
    x: &Tensor<i8>,
    w: &Tensor<i8>,
    bias: &[i32],
    threads: usize,
) -> Tensor<i32> {
    assert_eq!(x.shape(), &[p.ih, p.iw, p.ic]);
    assert_eq!(w.shape(), &[p.oc, p.ks, p.ks, p.ic]);
    assert_eq!(bias.len(), p.oc);
    let (oh, ow) = (p.oh(), p.ow());
    let patch = p.ks * p.ks * p.ic;
    let pad = p.pad_top() as i64;

    // im2col: [oh*ow, ks*ks*ic]
    let mut cols = vec![0i8; oh * ow * patch];
    for out_y in 0..oh {
        for out_x in 0..ow {
            let dst0 = (out_y * ow + out_x) * patch;
            for kh in 0..p.ks {
                let iy = out_y as i64 * p.stride as i64 + kh as i64 - pad;
                if iy < 0 || iy >= p.ih as i64 {
                    continue; // zero padding
                }
                for kw in 0..p.ks {
                    let ix = out_x as i64 * p.stride as i64 + kw as i64 - pad;
                    if ix < 0 || ix >= p.iw as i64 {
                        continue;
                    }
                    let src = (iy as usize * p.iw + ix as usize) * p.ic;
                    let dst = dst0 + (kh * p.ks + kw) * p.ic;
                    cols[dst..dst + p.ic].copy_from_slice(&x.data()[src..src + p.ic]);
                }
            }
        }
    }

    // weight matrix [patch, oc]
    let mut wm = vec![0i8; patch * p.oc];
    for oc in 0..p.oc {
        for kh in 0..p.ks {
            for kw in 0..p.ks {
                for c in 0..p.ic {
                    wm[((kh * p.ks + kw) * p.ic + c) * p.oc + oc] = w.at4(oc, kh, kw, c);
                }
            }
        }
    }

    let mut out = vec![0i32; oh * ow * p.oc];
    gemm::gemm_i8_i32(oh * ow, p.oc, patch, &cols, &wm, &mut out, threads);
    for px in 0..oh * ow {
        for oc in 0..p.oc {
            out[px * p.oc + oc] += bias[oc];
        }
    }
    Tensor::from_vec(&[oh, ow, p.oc], out)
}

/// Dense: x [in_dim] * w [out_dim, in_dim] + bias -> int32 [out_dim].
pub fn dense_i32(x: &[i8], w: &Tensor<i8>, bias: &[i32], threads: usize) -> Vec<i32> {
    let out_dim = w.shape()[0];
    let in_dim = w.shape()[1];
    assert_eq!(x.len(), in_dim);
    assert_eq!(bias.len(), out_dim);
    // GEMM with M = out_dim rows of W against the x column.
    let mut out = vec![0i32; out_dim];
    gemm::gemm_i8_i32(out_dim, 1, in_dim, w.data(), x, &mut out, threads);
    for (o, b) in out.iter_mut().zip(bias) {
        *o += b;
    }
    out
}

/// Requantize int32 accumulators to int8 and apply the fused activation.
///
/// `mult` converts accumulator scale (in_scale*w_scale) to `out_scale`.
/// For `Act::Tanh` the caller must pass `out_scale = 1/127` semantics:
/// tanh is evaluated in real space on the *accumulator* value.
pub fn requant_activate(
    acc: &[i32],
    mult: QuantizedMultiplier,
    act: Act,
    acc_scale: f32,
) -> Vec<i8> {
    match act {
        Act::Tanh => acc
            .iter()
            .map(|&a| {
                let real = a as f32 * acc_scale;
                (real.tanh() * 127.0).round().clamp(-127.0, 127.0) as i8
            })
            .collect(),
        _ => acc
            .iter()
            .map(|&a| {
                let q = mult.apply(a).clamp(-128, 127) as i8;
                match act {
                    Act::None => q,
                    Act::Relu => q.max(0),
                    Act::Leaky(alpha) => {
                        if q >= 0 {
                            q
                        } else {
                            // int8 leaky: round(alpha * q), same scale
                            (alpha * q as f32).round().clamp(-128.0, 127.0) as i8
                        }
                    }
                    Act::Tanh => unreachable!(),
                }
            })
            .collect(),
    }
}

/// Apply an int8 activation in-place on an already-quantized tensor
/// (used after the accelerator's PPU, which performs requant only).
pub fn activate_i8(q: &[i8], act: Act, scale: f32) -> Vec<i8> {
    match act {
        Act::None => q.to_vec(),
        Act::Relu => q.iter().map(|&v| v.max(0)).collect(),
        Act::Leaky(alpha) => q
            .iter()
            .map(|&v| {
                if v >= 0 {
                    v
                } else {
                    (alpha * v as f32).round().clamp(-128.0, 127.0) as i8
                }
            })
            .collect(),
        Act::Tanh => q
            .iter()
            .map(|&v| {
                let real = v as f32 * scale;
                (real.tanh() * 127.0).round().clamp(-127.0, 127.0) as i8
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Direct-loop conv oracle.
    fn conv_naive(p: &ConvProblem, x: &Tensor<i8>, w: &Tensor<i8>, bias: &[i32]) -> Vec<i32> {
        let (oh, ow) = (p.oh(), p.ow());
        let pad = p.pad_top() as i64;
        let mut out = vec![0i32; oh * ow * p.oc];
        for oy in 0..oh {
            for ox in 0..ow {
                for oc in 0..p.oc {
                    let mut acc = bias[oc];
                    for kh in 0..p.ks {
                        for kw in 0..p.ks {
                            let iy = oy as i64 * p.stride as i64 + kh as i64 - pad;
                            let ix = ox as i64 * p.stride as i64 + kw as i64 - pad;
                            if iy < 0 || ix < 0 || iy >= p.ih as i64 || ix >= p.iw as i64 {
                                continue;
                            }
                            for c in 0..p.ic {
                                acc += x.at3(iy as usize, ix as usize, c) as i32
                                    * w.at4(oc, kh, kw, c) as i32;
                            }
                        }
                    }
                    out[(oy * ow + ox) * p.oc + oc] = acc;
                }
            }
        }
        out
    }

    #[test]
    fn conv_matches_naive() {
        for (ih, ic, ks, oc, s) in [(8, 3, 4, 6, 2), (7, 5, 3, 4, 1), (6, 2, 4, 3, 2), (5, 4, 1, 2, 1)] {
            let p = ConvProblem { ih, iw: ih, ic, ks, oc, stride: s };
            let mut rng = Pcg32::new(7);
            let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
            let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
            let bias: Vec<i32> = (0..p.oc).map(|i| i as i32 * 7 - 3).collect();
            let want = conv_naive(&p, &x, &w, &bias);
            for threads in [1, 2] {
                let got = conv2d_i32(&p, &x, &w, &bias, threads);
                assert_eq!(got.data(), &want[..], "ks={ks} s={s} threads={threads}");
            }
        }
    }

    #[test]
    fn dense_matches_naive() {
        let mut rng = Pcg32::new(8);
        let w = Tensor::<i8>::random(&[5, 7], &mut rng);
        let x: Vec<i8> = (0..7).map(|_| rng.i8()).collect();
        let bias = vec![100i32; 5];
        let got = dense_i32(&x, &w, &bias, 1);
        for o in 0..5 {
            let want: i32 =
                100 + (0..7).map(|i| w.data()[o * 7 + i] as i32 * x[i] as i32).sum::<i32>();
            assert_eq!(got[o], want);
        }
    }

    #[test]
    fn activations() {
        let mult = QuantizedMultiplier::from_real(0.5);
        assert_eq!(requant_activate(&[100, -100], mult, Act::None, 1.0), vec![50, -50]);
        assert_eq!(requant_activate(&[100, -100], mult, Act::Relu, 1.0), vec![50, 0]);
        assert_eq!(requant_activate(&[100, -100], mult, Act::Leaky(0.2), 1.0), vec![50, -10]);
        // tanh of large accumulator saturates to ±127
        let t = requant_activate(&[10_000, -10_000], mult, Act::Tanh, 0.01);
        assert_eq!(t, vec![127, -127]);
    }

    #[test]
    fn activate_i8_matches_requant_path_for_identity_mult() {
        let mult = QuantizedMultiplier::from_real(0.999_999_999);
        let accs: Vec<i32> = (-128..=127).collect();
        let via_requant = requant_activate(&accs, mult, Act::Leaky(0.3), 1.0);
        let qs: Vec<i8> = accs.iter().map(|&a| a as i8).collect();
        let via_i8 = activate_i8(&qs, Act::Leaky(0.3), 1.0);
        assert_eq!(via_requant, via_i8);
    }
}
