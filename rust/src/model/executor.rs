//! Graph executor: real int8 numerics once, modeled PYNQ-Z1 timing for
//! any device configuration afterwards (Table IV's four rows come from a
//! single numerics pass).

use crate::accel::config::AccelConfig;
use crate::accel::cycles::CycleReport;
use crate::cpu::cost_model;
use crate::driver::instructions::DRIVER_FIXED_OVERHEAD_S;
use crate::driver::Delegate;
use crate::model::graph::{Act, Graph, Layer};
use crate::model::layers;
use crate::tconv::problem::TconvProblem;
use crate::tensor::quant::{PerChannel, QuantParams, QuantizedMultiplier};
use crate::tensor::Tensor;

/// Per-layer workload record (device-independent).
#[derive(Clone, Debug)]
pub enum Work {
    Tconv { p: TconvProblem, report: Option<CycleReport> },
    Conv { macs: u64, outputs: u64 },
    Dense { macs: u64, outputs: u64 },
    Elementwise { elems: u64 },
}

#[derive(Clone, Debug)]
pub struct LayerRecord {
    pub name: String,
    pub work: Work,
}

/// Table IV configurations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunConfig {
    Cpu { threads: usize },
    AccPlusCpu { threads: usize },
}

#[derive(Clone, Copy, Debug, Default)]
pub struct TimeBreakdown {
    /// Seconds in TCONV layers (the paper's "TCONV (ms)" column).
    pub tconv_s: f64,
    /// Seconds in all other layers ("Overall" minus TCONV).
    pub other_s: f64,
    /// Energy for the full run ("Energy (J/pic)").
    pub energy_j: f64,
}

impl TimeBreakdown {
    pub fn total_s(&self) -> f64 {
        self.tconv_s + self.other_s
    }
}

pub struct Executor {
    pub delegate: Delegate,
}

/// Output of one numerics pass.
#[derive(Debug)]
pub struct ModelRun {
    pub output: Tensor<i8>,
    /// Scale of the output tensor (tanh heads force 1/127).
    pub output_scale: f32,
    pub records: Vec<LayerRecord>,
}

impl Executor {
    pub fn new(delegate: Delegate) -> Self {
        Self { delegate }
    }

    /// Executor whose delegate resolves TCONV layer programs through a
    /// compiled-plan cache shared across workers (the serving path: the
    /// coordinator builds one cache per server and hands every worker a
    /// clone of the `Arc`).
    pub fn with_shared_cache(
        cfg: AccelConfig,
        cpu_threads: usize,
        use_accelerator: bool,
        cache: std::sync::Arc<crate::driver::PlanCache>,
    ) -> Self {
        Self { delegate: Delegate::with_cache(cfg, cpu_threads, use_accelerator, cache) }
    }

    /// Run the graph on an int8 input. Numerics are identical regardless
    /// of `delegate.use_accelerator` (verified in tests / §V-E).
    pub fn run(&self, g: &Graph, input: &Tensor<i8>) -> ModelRun {
        assert_eq!(input.shape(), &g.input_shape[..], "{} input shape", g.name);
        let threads = self.delegate.cpu_threads;
        let mut cur = input.clone();
        let mut scale = g.input_scale;
        let mut skips: Vec<Option<(Tensor<i8>, f32)>> = vec![None; 16];
        let mut records = Vec::with_capacity(g.layers.len());

        for layer in &g.layers {
            match layer {
                Layer::Dense { name, w, bias, w_scale, out_scale, act } => {
                    let acc = layers::dense_i32(cur.data(), w, bias, threads);
                    let acc_scale = scale * w_scale;
                    let mult = QuantizedMultiplier::from_real(acc_scale as f64 / *out_scale as f64);
                    let q = layers::requant_activate(&acc, mult, *act, acc_scale);
                    let out_dim = w.shape()[0];
                    records.push(LayerRecord {
                        name: name.clone(),
                        work: Work::Dense {
                            macs: (w.shape()[0] * w.shape()[1]) as u64,
                            outputs: out_dim as u64,
                        },
                    });
                    cur = Tensor::from_vec(&[out_dim], q);
                    scale = post_act_scale(*act, *out_scale);
                }
                Layer::Conv { name, p, w, bias, w_scale, out_scale, act } => {
                    let acc = layers::conv2d_i32(p, &cur, w, bias, threads);
                    let acc_scale = scale * w_scale;
                    let mult = QuantizedMultiplier::from_real(acc_scale as f64 / *out_scale as f64);
                    let q = layers::requant_activate(acc.data(), mult, *act, acc_scale);
                    records.push(LayerRecord {
                        name: name.clone(),
                        work: Work::Conv { macs: p.macs(), outputs: p.outputs() },
                    });
                    cur = Tensor::from_vec(&[p.oh(), p.ow(), p.oc], q);
                    scale = post_act_scale(*act, *out_scale);
                }
                Layer::Tconv { name, p, w, bias, w_scale, out_scale, act } => {
                    let out_q = QuantParams { scale: *out_scale, zero_point: 0 };
                    let requant = PerChannel::new(scale, &vec![*w_scale; p.oc], out_q);
                    let (q, exec) = self.delegate.run_tconv_quant(p, &cur, w, bias, 0, &requant);
                    let activated = layers::activate_i8(q.data(), *act, *out_scale);
                    records.push(LayerRecord {
                        name: name.clone(),
                        work: Work::Tconv { p: *p, report: exec.report },
                    });
                    cur = Tensor::from_vec(&[p.oh(), p.ow(), p.oc], activated);
                    scale = post_act_scale(*act, *out_scale);
                }
                Layer::Reshape { name: _, shape } => {
                    cur = cur.reshape(shape);
                }
                Layer::SaveSkip { slot } => {
                    skips[*slot] = Some((cur.clone(), scale));
                }
                Layer::ConcatSkip { slot } => {
                    let (saved, s_scale) = skips[*slot].clone().expect("skip slot empty");
                    assert!(
                        (s_scale - scale).abs() < 1e-9,
                        "concat scale mismatch: {s_scale} vs {scale}"
                    );
                    cur = concat_channels(&cur, &saved);
                    records.push(LayerRecord {
                        name: format!("concat_{slot}"),
                        work: Work::Elementwise { elems: cur.numel() as u64 },
                    });
                }
            }
        }

        ModelRun { output: cur, output_scale: scale, records }
    }
}

fn post_act_scale(act: Act, out_scale: f32) -> f32 {
    match act {
        Act::Tanh => 1.0 / 127.0,
        _ => out_scale,
    }
}

fn concat_channels(a: &Tensor<i8>, b: &Tensor<i8>) -> Tensor<i8> {
    assert_eq!(a.shape().len(), 3);
    assert_eq!(a.shape()[..2], b.shape()[..2], "spatial dims must match");
    let (h, w) = (a.shape()[0], a.shape()[1]);
    let (ca, cb) = (a.shape()[2], b.shape()[2]);
    let mut out = Tensor::<i8>::zeros(&[h, w, ca + cb]);
    for px in 0..h * w {
        out.data_mut()[px * (ca + cb)..px * (ca + cb) + ca]
            .copy_from_slice(&a.data()[px * ca..(px + 1) * ca]);
        out.data_mut()[px * (ca + cb) + ca..(px + 1) * (ca + cb)]
            .copy_from_slice(&b.data()[px * cb..(px + 1) * cb]);
    }
    out
}

impl ModelRun {
    /// Model the run's latency/energy on a Table IV configuration.
    pub fn modeled(&self, config: RunConfig, acc_cfg: &AccelConfig) -> TimeBreakdown {
        let mut tb = TimeBreakdown::default();
        let threads = match config {
            RunConfig::Cpu { threads } | RunConfig::AccPlusCpu { threads } => threads,
        };
        for rec in &self.records {
            match &rec.work {
                Work::Tconv { p, report } => match config {
                    RunConfig::AccPlusCpu { .. } => {
                        let report = report
                            .as_ref()
                            .expect("accelerated run required for AccPlusCpu modeling");
                        let t = report.seconds(acc_cfg) + DRIVER_FIXED_OVERHEAD_S;
                        tb.tconv_s += t;
                        tb.energy_j += crate::accel::energy::accel_energy_j(report, acc_cfg);
                    }
                    RunConfig::Cpu { threads } => {
                        let t = cost_model::tconv_seconds(p, threads);
                        tb.tconv_s += t;
                        tb.energy_j += crate::accel::energy::cpu_energy_j(t, threads);
                    }
                },
                Work::Conv { macs, outputs } | Work::Dense { macs, outputs } => {
                    let t = cost_model::conv_seconds(*macs, *outputs, threads);
                    tb.other_s += t;
                    tb.energy_j += crate::accel::energy::cpu_energy_j(t, threads);
                }
                Work::Elementwise { elems } => {
                    let t = cost_model::elementwise_seconds(*elems, threads);
                    tb.other_s += t;
                    tb.energy_j += crate::accel::energy::cpu_energy_j(t, threads);
                }
            }
        }
        tb
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::util::rng::Pcg32;

    fn run_both(g: &Graph, seed: u64) -> (ModelRun, ModelRun) {
        let mut rng = Pcg32::new(seed);
        let input = Tensor::<i8>::random(&g.input_shape, &mut rng);
        let acc = Executor::new(Delegate::new(AccelConfig::default(), 2, true));
        let cpu = Executor::new(Delegate::new(AccelConfig::default(), 2, false));
        (acc.run(g, &input), cpu.run(g, &input))
    }

    #[test]
    fn dcgan_acc_and_cpu_bit_exact() {
        let g = zoo::dcgan_tf(0);
        let (a, c) = run_both(&g, 42);
        assert_eq!(a.output.data(), c.output.data());
        assert_eq!(a.output.shape(), &[28, 28, 1]);
        assert_eq!(a.output_scale, 1.0 / 127.0);
    }

    #[test]
    fn small_pix2pix_acc_and_cpu_bit_exact() {
        let g = zoo::pix2pix(32, 8, 0);
        let (a, c) = run_both(&g, 43);
        assert_eq!(a.output.data(), c.output.data());
        assert_eq!(a.output.shape(), &[32, 32, 3]);
    }

    #[test]
    fn table4_modeling_accelerator_wins_tconv_time() {
        let g = zoo::dcgan_tf(0);
        let (a, _) = run_both(&g, 44);
        let cfg = AccelConfig::default();
        let cpu1 = a.modeled(RunConfig::Cpu { threads: 1 }, &cfg);
        let cpu2 = a.modeled(RunConfig::Cpu { threads: 2 }, &cfg);
        let acc1 = a.modeled(RunConfig::AccPlusCpu { threads: 1 }, &cfg);
        assert!(acc1.tconv_s < cpu1.tconv_s, "acc {} cpu {}", acc1.tconv_s, cpu1.tconv_s);
        assert!(cpu2.tconv_s < cpu1.tconv_s);
        assert!(acc1.total_s() < cpu1.total_s());
        assert!(acc1.energy_j < cpu1.energy_j);
    }

    #[test]
    fn records_cover_all_compute_layers() {
        let g = zoo::dcgan_tf(0);
        let (a, _) = run_both(&g, 45);
        let tconvs = a
            .records
            .iter()
            .filter(|r| matches!(r.work, Work::Tconv { .. }))
            .count();
        assert_eq!(tconvs, 3);
        assert!(a.records.iter().any(|r| matches!(r.work, Work::Dense { .. })));
    }
}
