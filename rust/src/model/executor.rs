//! Graph executor: real int8 numerics once, modeled PYNQ-Z1 timing for
//! any device configuration afterwards (Table IV's four rows come from a
//! single numerics pass).

use crate::accel::config::AccelConfig;
use crate::accel::cycles::CycleReport;
use crate::accel::ExecError;
use crate::cpu::cost_model;
use crate::driver::instructions::DRIVER_FIXED_OVERHEAD_S;
use crate::driver::Delegate;
use crate::model::graph::{Act, Graph, Layer};
use crate::model::layers;
use crate::tconv::problem::TconvProblem;
use crate::tensor::quant::{PerChannel, QuantParams, QuantizedMultiplier};
use crate::tensor::Tensor;

/// Per-layer workload record (device-independent).
#[derive(Clone, Debug)]
pub enum Work {
    /// One TCONV layer executed for one request.
    Tconv {
        /// Layer geometry.
        p: TconvProblem,
        /// Accelerator cycle report (`None` on the CPU path).
        report: Option<CycleReport>,
    },
    /// One TCONV layer executed for a whole same-layer batch (one weight
    /// prologue per tile, one driver dispatch, one shared timeline).
    TconvBatch {
        /// Layer geometry.
        p: TconvProblem,
        /// Requests served by this single execution.
        requests: usize,
        /// Distinct weight variants in the batch: 1 for a same-graph
        /// batch ([`Executor::run_batch`]), the number of chain-mate
        /// graphs for a cross-graph batch ([`Executor::run_batch_multi`]
        /// — each (tile, variant) pair issues one `LoadWeights`).
        variants: usize,
        /// Whole-batch accelerator cycle report.
        report: Option<CycleReport>,
    },
    /// A standard convolution (CPU path).
    Conv {
        /// MACs performed.
        macs: u64,
        /// Output elements produced.
        outputs: u64,
    },
    /// A dense layer (CPU path).
    Dense {
        /// MACs performed.
        macs: u64,
        /// Output elements produced.
        outputs: u64,
    },
    /// Elementwise work (concat, activation-only passes).
    Elementwise {
        /// Elements touched.
        elems: u64,
    },
}

/// One executed layer: its graph name plus the work it performed.
#[derive(Clone, Debug)]
pub struct LayerRecord {
    /// Layer name from the graph.
    pub name: String,
    /// What ran and what it cost.
    pub work: Work,
}

/// Table IV configurations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunConfig {
    /// CPU-only baseline.
    Cpu {
        /// CPU threads.
        threads: usize,
    },
    /// TCONVs on the accelerator, everything else on the CPU.
    AccPlusCpu {
        /// CPU threads for non-offloaded layers.
        threads: usize,
    },
}

/// Modeled latency/energy split of one run (the paper's Table IV view).
#[derive(Clone, Copy, Debug, Default)]
pub struct TimeBreakdown {
    /// Seconds in TCONV layers (the paper's "TCONV (ms)" column).
    pub tconv_s: f64,
    /// Seconds in all other layers ("Overall" minus TCONV).
    pub other_s: f64,
    /// Energy for the full run ("Energy (J/pic)").
    pub energy_j: f64,
}

impl TimeBreakdown {
    /// End-to-end modeled seconds (TCONV + everything else).
    pub fn total_s(&self) -> f64 {
        self.tconv_s + self.other_s
    }
}

/// Runs a [`Graph`] through the delegate, layer by layer.
pub struct Executor {
    /// The TFLite-style delegate doing per-layer device routing.
    pub delegate: Delegate,
}

/// Output of one numerics pass.
#[derive(Debug)]
pub struct ModelRun {
    /// Final int8 activation tensor.
    pub output: Tensor<i8>,
    /// Scale of the output tensor (tanh heads force 1/127).
    pub output_scale: f32,
    /// Per-layer workload records, in execution order.
    pub records: Vec<LayerRecord>,
}

/// Output of one *batched* numerics pass ([`Executor::run_batch`]): per
/// request outputs, batch-level workload records.
#[derive(Debug)]
pub struct BatchRun {
    /// Final int8 tensors, index = request position in the input slice.
    pub outputs: Vec<Tensor<i8>>,
    /// Scale of the output tensors (identical across the batch).
    pub output_scale: f32,
    /// Workload records. TCONV layers appear once per *batch*
    /// ([`Work::TconvBatch`]); CPU layers appear once per request, so
    /// [`BatchRun::modeled`] sums to the whole batch's cost.
    pub records: Vec<LayerRecord>,
    /// Requests in the batch.
    pub requests: usize,
}

/// Weight-load accounting over one batch's records (see
/// [`BatchRun::weight_load_counters`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WeightLoadCounters {
    /// `LoadWeights` that actually moved filter payloads.
    pub performed: u64,
    /// `LoadWeights` elided because the filter set was already resident
    /// in PM BRAM (within-process *and* cross-batch skips).
    pub skipped: u64,
    /// Loads a per-request replay would have performed (requests x tiles
    /// per TCONV execution).
    pub equivalent: u64,
}

impl BatchRun {
    /// Model the whole batch's latency/energy on a Table IV
    /// configuration; divide by [`BatchRun::requests`] for the amortized
    /// per-request cost.
    pub fn modeled(&self, config: RunConfig, acc_cfg: &AccelConfig) -> TimeBreakdown {
        modeled_from_records(&self.records, config, acc_cfg)
    }

    /// Weight-load accounting over the batch. `performed` counts
    /// `LoadWeights` that actually moved filter payloads, `skipped` the
    /// resident-set elisions, and `equivalent` what a per-request replay
    /// would have issued (requests x tiles per TCONV layer).
    /// `1 - performed / equivalent` is the serving layer's weight-load
    /// hit rate.
    pub fn weight_load_counters(&self) -> WeightLoadCounters {
        let mut c = WeightLoadCounters::default();
        for rec in &self.records {
            match &rec.work {
                Work::Tconv { report: Some(r), .. } => {
                    c.performed += r.weight_loads;
                    c.skipped += r.weight_loads_skipped;
                    c.equivalent += r.weight_loads + r.weight_loads_skipped;
                }
                Work::TconvBatch { requests, variants, report: Some(r), .. } => {
                    c.performed += r.weight_loads;
                    c.skipped += r.weight_loads_skipped;
                    // The stream issued one LoadWeights per (tile,
                    // variant); a per-request replay (each request
                    // against its own variant) issues one per (tile,
                    // request).
                    let tiles = (r.weight_loads + r.weight_loads_skipped) / *variants as u64;
                    c.equivalent += *requests as u64 * tiles;
                }
                _ => {}
            }
        }
        c
    }

    /// Host-side packed-operand repacks the engine's LRU elided across
    /// the batch's TCONV executions
    /// ([`CycleReport::repacks_skipped`](crate::accel::CycleReport) —
    /// zero modeled cycles, pure host throughput), summed the same way
    /// as [`BatchRun::weight_load_counters`].
    pub fn repacks_skipped(&self) -> u64 {
        self.records
            .iter()
            .map(|rec| match &rec.work {
                Work::Tconv { report: Some(r), .. }
                | Work::TconvBatch { report: Some(r), .. } => r.repacks_skipped,
                _ => 0,
            })
            .sum()
    }

    /// True when the batch's *first* TCONV execution skipped a weight
    /// load — i.e. the shard's accelerator still held this graph's first
    /// filter set from a previous batch (the cross-batch resident hit the
    /// placement scorer steers toward).
    pub fn first_layer_resident_hit(&self) -> bool {
        self.records
            .iter()
            .find_map(|rec| match &rec.work {
                Work::Tconv { report: Some(r), .. }
                | Work::TconvBatch { report: Some(r), .. } => {
                    Some(r.weight_loads_skipped > 0)
                }
                _ => None,
            })
            .unwrap_or(false)
    }
}

impl Executor {
    /// Executor over an existing delegate.
    pub fn new(delegate: Delegate) -> Self {
        Self { delegate }
    }

    /// Executor whose delegate resolves TCONV layer programs through a
    /// compiled-plan cache shared across workers, but owns a *private*
    /// persistent accelerator. The coordinator's serving path uses
    /// [`Executor::with_shared_accelerator`] instead so workers of one
    /// shard also share the accelerator's weight-residency state.
    pub fn with_shared_cache(
        cfg: AccelConfig,
        cpu_threads: usize,
        use_accelerator: bool,
        cache: std::sync::Arc<crate::driver::PlanCache>,
    ) -> Self {
        Self { delegate: Delegate::with_cache(cfg, cpu_threads, use_accelerator, cache) }
    }

    /// Executor sharing both the plan cache and a persistent accelerator
    /// (one per coordinator shard), so weight/BRAM state survives across
    /// the requests the shard serves.
    pub fn with_shared_accelerator(
        cfg: AccelConfig,
        cpu_threads: usize,
        use_accelerator: bool,
        cache: std::sync::Arc<crate::driver::PlanCache>,
        accel: std::sync::Arc<std::sync::Mutex<crate::accel::Accelerator>>,
    ) -> Self {
        Self {
            delegate: Delegate::with_shared_accelerator(
                cfg,
                cpu_threads,
                use_accelerator,
                cache,
                accel,
            ),
        }
    }

    /// Run the graph on an int8 input. Numerics are identical regardless
    /// of `delegate.use_accelerator` (verified in tests / §V-E).
    ///
    /// Panics on accelerator execution errors: the single-request path
    /// is the differential-testing and benchmarking workhorse, never the
    /// serving path, so no fault injector is ever installed on its
    /// delegate and an [`ExecError`] here is a driver bug. The serving
    /// path uses the fallible [`Executor::run_batch`] /
    /// [`Executor::run_batch_multi`] instead.
    pub fn run(&self, g: &Graph, input: &Tensor<i8>) -> ModelRun {
        assert_eq!(input.shape(), &g.input_shape[..], "{} input shape", g.name);
        let threads = self.delegate.cpu_threads;
        let mut cur = input.clone();
        let mut scale = g.input_scale;
        let mut skips: Vec<Option<(Tensor<i8>, f32)>> = vec![None; 16];
        let mut records = Vec::with_capacity(g.layers.len());

        for layer in &g.layers {
            match layer {
                Layer::Dense { name, w, bias, w_scale, out_scale, act } => {
                    let acc = layers::dense_i32(cur.data(), w, bias, threads);
                    let acc_scale = scale * w_scale;
                    let mult = QuantizedMultiplier::from_real(acc_scale as f64 / *out_scale as f64);
                    let q = layers::requant_activate(&acc, mult, *act, acc_scale);
                    let out_dim = w.shape()[0];
                    records.push(LayerRecord {
                        name: name.clone(),
                        work: Work::Dense {
                            macs: (w.shape()[0] * w.shape()[1]) as u64,
                            outputs: out_dim as u64,
                        },
                    });
                    cur = Tensor::from_vec(&[out_dim], q);
                    scale = post_act_scale(*act, *out_scale);
                }
                Layer::Conv { name, p, w, bias, w_scale, out_scale, act } => {
                    let acc = layers::conv2d_i32(p, &cur, w, bias, threads);
                    let acc_scale = scale * w_scale;
                    let mult = QuantizedMultiplier::from_real(acc_scale as f64 / *out_scale as f64);
                    let q = layers::requant_activate(acc.data(), mult, *act, acc_scale);
                    records.push(LayerRecord {
                        name: name.clone(),
                        work: Work::Conv { macs: p.macs(), outputs: p.outputs() },
                    });
                    cur = Tensor::from_vec(&[p.oh(), p.ow(), p.oc], q);
                    scale = post_act_scale(*act, *out_scale);
                }
                Layer::Tconv { name, p, w, bias, w_scale, out_scale, act } => {
                    let out_q = QuantParams { scale: *out_scale, zero_point: 0 };
                    let requant = PerChannel::new(scale, &vec![*w_scale; p.oc], out_q);
                    let (q, exec) = self
                        .delegate
                        .run_tconv_quant(p, &cur, w, bias, 0, &requant)
                        .unwrap_or_else(|e| panic!("{}: layer {name}: {e}", g.name));
                    let activated = layers::activate_i8(q.data(), *act, *out_scale);
                    records.push(LayerRecord {
                        name: name.clone(),
                        work: Work::Tconv { p: *p, report: exec.report },
                    });
                    cur = Tensor::from_vec(&[p.oh(), p.ow(), p.oc], activated);
                    scale = post_act_scale(*act, *out_scale);
                }
                Layer::Reshape { name: _, shape } => {
                    cur = cur.reshape(shape);
                }
                Layer::SaveSkip { slot } => {
                    skips[*slot] = Some((cur.clone(), scale));
                }
                Layer::ConcatSkip { slot } => {
                    let (saved, s_scale) = skips[*slot].clone().expect("skip slot empty");
                    assert!(
                        (s_scale - scale).abs() < 1e-9,
                        "concat scale mismatch: {s_scale} vs {scale}"
                    );
                    cur = concat_channels(&cur, &saved);
                    records.push(LayerRecord {
                        name: format!("concat_{slot}"),
                        work: Work::Elementwise { elems: cur.numel() as u64 },
                    });
                }
            }
        }

        ModelRun { output: cur, output_scale: scale, records }
    }

    /// Run the graph for a whole batch of inputs with *layer batching*:
    /// the graph is walked once, and each TCONV layer executes all
    /// requests in one batched stream (one weight prologue per tile — see
    /// [`Delegate::run_tconv_quant_batch`]). Non-TCONV layers run per
    /// request. Outputs are byte-identical to [`Executor::run`] on each
    /// input individually, in any submission order.
    ///
    /// `Err` surfaces accelerator execution failures (in practice only
    /// under fault injection — see [`crate::accel::fault`]). On `Err`,
    /// no request in the batch has produced an output: the first TCONV
    /// layer to fail aborts the whole walk, which is what lets the
    /// coordinator retry the entire batch without double-serving.
    pub fn run_batch(&self, g: &Graph, inputs: &[Tensor<i8>]) -> Result<BatchRun, ExecError> {
        assert!(!inputs.is_empty(), "empty batch");
        for input in inputs {
            assert_eq!(input.shape(), &g.input_shape[..], "{} input shape", g.name);
        }
        let n = inputs.len();
        let threads = self.delegate.cpu_threads;
        let mut curs: Vec<Tensor<i8>> = inputs.to_vec();
        // Scales evolve identically across the batch (same graph).
        let mut scale = g.input_scale;
        let mut skips: Vec<Vec<Option<(Tensor<i8>, f32)>>> = vec![vec![None; 16]; n];
        let mut records = Vec::with_capacity(g.layers.len() * n);

        for layer in &g.layers {
            match layer {
                Layer::Dense { name, w, bias, w_scale, out_scale, act } => {
                    let acc_scale = scale * w_scale;
                    let mult = QuantizedMultiplier::from_real(acc_scale as f64 / *out_scale as f64);
                    let out_dim = w.shape()[0];
                    for cur in curs.iter_mut() {
                        let acc = layers::dense_i32(cur.data(), w, bias, threads);
                        let q = layers::requant_activate(&acc, mult, *act, acc_scale);
                        records.push(LayerRecord {
                            name: name.clone(),
                            work: Work::Dense {
                                macs: (w.shape()[0] * w.shape()[1]) as u64,
                                outputs: out_dim as u64,
                            },
                        });
                        *cur = Tensor::from_vec(&[out_dim], q);
                    }
                    scale = post_act_scale(*act, *out_scale);
                }
                Layer::Conv { name, p, w, bias, w_scale, out_scale, act } => {
                    let acc_scale = scale * w_scale;
                    let mult = QuantizedMultiplier::from_real(acc_scale as f64 / *out_scale as f64);
                    for cur in curs.iter_mut() {
                        let acc = layers::conv2d_i32(p, cur, w, bias, threads);
                        let q = layers::requant_activate(acc.data(), mult, *act, acc_scale);
                        records.push(LayerRecord {
                            name: name.clone(),
                            work: Work::Conv { macs: p.macs(), outputs: p.outputs() },
                        });
                        *cur = Tensor::from_vec(&[p.oh(), p.ow(), p.oc], q);
                    }
                    scale = post_act_scale(*act, *out_scale);
                }
                Layer::Tconv { name, p, w, bias, w_scale, out_scale, act } => {
                    let out_q = QuantParams { scale: *out_scale, zero_point: 0 };
                    let requant = PerChannel::new(scale, &vec![*w_scale; p.oc], out_q);
                    if self.delegate.use_accelerator {
                        let xs: Vec<&Tensor<i8>> = curs.iter().collect();
                        let (qs, exec) =
                            self.delegate.run_tconv_quant_batch(p, &xs, w, bias, &requant)?;
                        records.push(LayerRecord {
                            name: name.clone(),
                            work: Work::TconvBatch {
                                p: *p,
                                requests: n,
                                variants: 1,
                                report: exec.report,
                            },
                        });
                        curs = qs
                            .into_iter()
                            .map(|q| {
                                let activated = layers::activate_i8(q.data(), *act, *out_scale);
                                Tensor::from_vec(&[p.oh(), p.ow(), p.oc], activated)
                            })
                            .collect();
                    } else {
                        for cur in curs.iter_mut() {
                            let (q, exec) =
                                self.delegate.run_tconv_quant(p, cur, w, bias, 0, &requant)?;
                            let activated = layers::activate_i8(q.data(), *act, *out_scale);
                            records.push(LayerRecord {
                                name: name.clone(),
                                work: Work::Tconv { p: *p, report: exec.report },
                            });
                            *cur = Tensor::from_vec(&[p.oh(), p.ow(), p.oc], activated);
                        }
                    }
                    scale = post_act_scale(*act, *out_scale);
                }
                Layer::Reshape { name: _, shape } => {
                    for cur in curs.iter_mut() {
                        // `reshape` consumes; swap the tensor out first.
                        let owned = std::mem::replace(cur, Tensor::zeros(&[0]));
                        *cur = owned.reshape(shape);
                    }
                }
                Layer::SaveSkip { slot } => {
                    for (k, cur) in curs.iter().enumerate() {
                        skips[k][*slot] = Some((cur.clone(), scale));
                    }
                }
                Layer::ConcatSkip { slot } => {
                    for (k, cur) in curs.iter_mut().enumerate() {
                        let (saved, s_scale) = skips[k][*slot].clone().expect("skip slot empty");
                        assert!(
                            (s_scale - scale).abs() < 1e-9,
                            "concat scale mismatch: {s_scale} vs {scale}"
                        );
                        let merged = concat_channels(cur, &saved);
                        *cur = merged;
                        records.push(LayerRecord {
                            name: format!("concat_{slot}"),
                            work: Work::Elementwise { elems: cur.numel() as u64 },
                        });
                    }
                }
            }
        }

        Ok(BatchRun { outputs: curs, output_scale: scale, records, requests: n })
    }

    /// Run a **cross-graph** batch: requests spread over several
    /// chain-mate graphs (equal
    /// [`Graph::graph_key`](crate::model::graph::Graph::graph_key)s —
    /// identical structure, shapes, scales, and activations; weights and
    /// biases free to differ). `assignment[k]` names the graph in
    /// `graphs` serving request `k`.
    ///
    /// The graph structure is walked once. Each TCONV layer executes the
    /// *whole mixed batch* in one stream via
    /// [`Delegate::run_tconv_quant_batch_multi`]: every tile's
    /// `Configure` is shared across all requests and one `LoadWeights`
    /// is paid per (tile, variant) — strictly fewer than the
    /// per-(tile, request) loads of splitting the batch by graph
    /// identity whenever any graph contributes more than one request.
    /// Non-TCONV layers run per request against the request's own
    /// graph's parameters. Outputs are byte-identical to
    /// [`Executor::run`] on each request's own graph, in any submission
    /// order. Degenerates to [`Executor::run_batch`] when `graphs` has
    /// one entry.
    ///
    /// `Err` has the same contract as [`Executor::run_batch`]: the
    /// failing TCONV layer aborts the whole walk before any request
    /// produced an output, so the batch is retryable as a unit.
    pub fn run_batch_multi(
        &self,
        graphs: &[&Graph],
        assignment: &[usize],
        inputs: &[Tensor<i8>],
    ) -> Result<BatchRun, ExecError> {
        assert!(!inputs.is_empty(), "empty batch");
        assert_eq!(assignment.len(), inputs.len(), "one graph assignment per input");
        assert!(!graphs.is_empty(), "no graphs");
        let lead = graphs[0];
        let lead_key = lead.graph_key(&self.delegate.cfg);
        for g in &graphs[1..] {
            assert_eq!(
                g.graph_key(&self.delegate.cfg),
                lead_key,
                "cross-graph batch requires chain-mates: {} vs {}",
                lead.name,
                g.name
            );
        }
        for (k, input) in inputs.iter().enumerate() {
            let g = graphs[assignment[k]];
            assert_eq!(input.shape(), &g.input_shape[..], "{} input shape", g.name);
        }
        let n = inputs.len();
        let threads = self.delegate.cpu_threads;
        let mut curs: Vec<Tensor<i8>> = inputs.to_vec();
        // Chain-mates evolve scales identically (scales are chain
        // identity), so one scale walk covers the whole mixed batch.
        let mut scale = lead.input_scale;
        let mut skips: Vec<Vec<Option<(Tensor<i8>, f32)>>> = vec![vec![None; 16]; n];
        let mut records = Vec::with_capacity(lead.layers.len() * n);

        for (j, layer) in lead.layers.iter().enumerate() {
            match layer {
                Layer::Dense { name, out_scale, act, .. } => {
                    for (k, cur) in curs.iter_mut().enumerate() {
                        let (w, bias, w_scale) = match &graphs[assignment[k]].layers[j] {
                            Layer::Dense { w, bias, w_scale, .. } => (w, bias, *w_scale),
                            other => panic!("chain-mate layer {} diverged", other.name()),
                        };
                        let acc = layers::dense_i32(cur.data(), w, bias, threads);
                        let acc_scale = scale * w_scale;
                        let mult =
                            QuantizedMultiplier::from_real(acc_scale as f64 / *out_scale as f64);
                        let q = layers::requant_activate(&acc, mult, *act, acc_scale);
                        let out_dim = w.shape()[0];
                        records.push(LayerRecord {
                            name: name.clone(),
                            work: Work::Dense {
                                macs: (w.shape()[0] * w.shape()[1]) as u64,
                                outputs: out_dim as u64,
                            },
                        });
                        *cur = Tensor::from_vec(&[out_dim], q);
                    }
                    scale = post_act_scale(*act, *out_scale);
                }
                Layer::Conv { name, p, out_scale, act, .. } => {
                    for (k, cur) in curs.iter_mut().enumerate() {
                        let (w, bias, w_scale) = match &graphs[assignment[k]].layers[j] {
                            Layer::Conv { w, bias, w_scale, .. } => (w, bias, *w_scale),
                            other => panic!("chain-mate layer {} diverged", other.name()),
                        };
                        let acc = layers::conv2d_i32(p, cur, w, bias, threads);
                        let acc_scale = scale * w_scale;
                        let mult =
                            QuantizedMultiplier::from_real(acc_scale as f64 / *out_scale as f64);
                        let q = layers::requant_activate(acc.data(), mult, *act, acc_scale);
                        records.push(LayerRecord {
                            name: name.clone(),
                            work: Work::Conv { macs: p.macs(), outputs: p.outputs() },
                        });
                        *cur = Tensor::from_vec(&[p.oh(), p.ow(), p.oc], q);
                    }
                    scale = post_act_scale(*act, *out_scale);
                }
                Layer::Tconv { name, p, out_scale, act, .. } => {
                    let out_q = QuantParams { scale: *out_scale, zero_point: 0 };
                    // One weight variant per chain-mate graph.
                    let parts: Vec<(&Tensor<i8>, &[i32], f32)> = graphs
                        .iter()
                        .map(|g| match &g.layers[j] {
                            Layer::Tconv { w, bias, w_scale, .. } => {
                                (w, bias.as_slice(), *w_scale)
                            }
                            other => panic!("chain-mate layer {} diverged", other.name()),
                        })
                        .collect();
                    if self.delegate.use_accelerator {
                        let requants: Vec<PerChannel> = parts
                            .iter()
                            .map(|&(_, _, ws)| PerChannel::new(scale, &vec![ws; p.oc], out_q))
                            .collect();
                        let variants: Vec<crate::driver::TconvVariant<'_>> = parts
                            .iter()
                            .zip(&requants)
                            .map(|(&(w, bias, _), rq)| crate::driver::TconvVariant {
                                w,
                                bias,
                                requant: rq,
                            })
                            .collect();
                        let reqs: Vec<(usize, &Tensor<i8>)> =
                            assignment.iter().zip(curs.iter()).map(|(&v, x)| (v, x)).collect();
                        let (qs, exec) =
                            self.delegate.run_tconv_quant_batch_multi(p, &variants, &reqs)?;
                        records.push(LayerRecord {
                            name: name.clone(),
                            work: Work::TconvBatch {
                                p: *p,
                                requests: n,
                                variants: graphs.len(),
                                report: exec.report,
                            },
                        });
                        curs = qs
                            .into_iter()
                            .map(|q| {
                                let activated = layers::activate_i8(q.data(), *act, *out_scale);
                                Tensor::from_vec(&[p.oh(), p.ow(), p.oc], activated)
                            })
                            .collect();
                    } else {
                        for (k, cur) in curs.iter_mut().enumerate() {
                            let (w, bias, ws) = parts[assignment[k]];
                            let requant = PerChannel::new(scale, &vec![ws; p.oc], out_q);
                            let (q, exec) =
                                self.delegate.run_tconv_quant(p, cur, w, bias, 0, &requant)?;
                            let activated = layers::activate_i8(q.data(), *act, *out_scale);
                            records.push(LayerRecord {
                                name: name.clone(),
                                work: Work::Tconv { p: *p, report: exec.report },
                            });
                            *cur = Tensor::from_vec(&[p.oh(), p.ow(), p.oc], activated);
                        }
                    }
                    scale = post_act_scale(*act, *out_scale);
                }
                Layer::Reshape { name: _, shape } => {
                    for cur in curs.iter_mut() {
                        let owned = std::mem::replace(cur, Tensor::zeros(&[0]));
                        *cur = owned.reshape(shape);
                    }
                }
                Layer::SaveSkip { slot } => {
                    for (k, cur) in curs.iter().enumerate() {
                        skips[k][*slot] = Some((cur.clone(), scale));
                    }
                }
                Layer::ConcatSkip { slot } => {
                    for (k, cur) in curs.iter_mut().enumerate() {
                        let (saved, s_scale) = skips[k][*slot].clone().expect("skip slot empty");
                        assert!(
                            (s_scale - scale).abs() < 1e-9,
                            "concat scale mismatch: {s_scale} vs {scale}"
                        );
                        let merged = concat_channels(cur, &saved);
                        *cur = merged;
                        records.push(LayerRecord {
                            name: format!("concat_{slot}"),
                            work: Work::Elementwise { elems: cur.numel() as u64 },
                        });
                    }
                }
            }
        }

        Ok(BatchRun { outputs: curs, output_scale: scale, records, requests: n })
    }
}

/// Activation-output scale rule shared by the executor and the placement
/// table's scale walk (tanh forces the full [-1, 1] range).
pub(crate) fn post_act_scale(act: Act, out_scale: f32) -> f32 {
    match act {
        Act::Tanh => 1.0 / 127.0,
        _ => out_scale,
    }
}

fn concat_channels(a: &Tensor<i8>, b: &Tensor<i8>) -> Tensor<i8> {
    assert_eq!(a.shape().len(), 3);
    assert_eq!(a.shape()[..2], b.shape()[..2], "spatial dims must match");
    let (h, w) = (a.shape()[0], a.shape()[1]);
    let (ca, cb) = (a.shape()[2], b.shape()[2]);
    let mut out = Tensor::<i8>::zeros(&[h, w, ca + cb]);
    for px in 0..h * w {
        out.data_mut()[px * (ca + cb)..px * (ca + cb) + ca]
            .copy_from_slice(&a.data()[px * ca..(px + 1) * ca]);
        out.data_mut()[px * (ca + cb) + ca..(px + 1) * (ca + cb)]
            .copy_from_slice(&b.data()[px * cb..(px + 1) * cb]);
    }
    out
}

impl ModelRun {
    /// Model the run's latency/energy on a Table IV configuration.
    pub fn modeled(&self, config: RunConfig, acc_cfg: &AccelConfig) -> TimeBreakdown {
        modeled_from_records(&self.records, config, acc_cfg)
    }
}

/// Shared latency/energy modeling over workload records (single-request
/// [`ModelRun`] and batched [`BatchRun`] use the same arithmetic; batch
/// records simply cover several requests at once).
fn modeled_from_records(
    records: &[LayerRecord],
    config: RunConfig,
    acc_cfg: &AccelConfig,
) -> TimeBreakdown {
    let mut tb = TimeBreakdown::default();
    let threads = match config {
        RunConfig::Cpu { threads } | RunConfig::AccPlusCpu { threads } => threads,
    };
    let accel_tconv = |tb: &mut TimeBreakdown, report: &Option<CycleReport>| {
        let report = report
            .as_ref()
            .expect("accelerated run required for AccPlusCpu modeling");
        tb.tconv_s += report.seconds(acc_cfg) + DRIVER_FIXED_OVERHEAD_S;
        tb.energy_j += crate::accel::energy::accel_energy_j(report, acc_cfg);
    };
    for rec in records {
        match &rec.work {
            Work::Tconv { p, report } => match config {
                RunConfig::AccPlusCpu { .. } => accel_tconv(&mut tb, report),
                RunConfig::Cpu { threads } => {
                    let t = cost_model::tconv_seconds(p, threads);
                    tb.tconv_s += t;
                    tb.energy_j += crate::accel::energy::cpu_energy_j(t, threads);
                }
            },
            Work::TconvBatch { p, requests, report, .. } => match config {
                // One batched stream, one driver dispatch: the report
                // already covers all requests.
                RunConfig::AccPlusCpu { .. } => accel_tconv(&mut tb, report),
                // A CPU would run the layer once per request.
                RunConfig::Cpu { threads } => {
                    let t = cost_model::tconv_seconds(p, threads) * *requests as f64;
                    tb.tconv_s += t;
                    tb.energy_j += crate::accel::energy::cpu_energy_j(t, threads);
                }
            },
            Work::Conv { macs, outputs } | Work::Dense { macs, outputs } => {
                let t = cost_model::conv_seconds(*macs, *outputs, threads);
                tb.other_s += t;
                tb.energy_j += crate::accel::energy::cpu_energy_j(t, threads);
            }
            Work::Elementwise { elems } => {
                let t = cost_model::elementwise_seconds(*elems, threads);
                tb.other_s += t;
                tb.energy_j += crate::accel::energy::cpu_energy_j(t, threads);
            }
        }
    }
    tb
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::util::rng::Pcg32;

    fn run_both(g: &Graph, seed: u64) -> (ModelRun, ModelRun) {
        let mut rng = Pcg32::new(seed);
        let input = Tensor::<i8>::random(&g.input_shape, &mut rng);
        let acc = Executor::new(Delegate::new(AccelConfig::default(), 2, true));
        let cpu = Executor::new(Delegate::new(AccelConfig::default(), 2, false));
        (acc.run(g, &input), cpu.run(g, &input))
    }

    #[test]
    fn dcgan_acc_and_cpu_bit_exact() {
        let g = zoo::dcgan_tf(0);
        let (a, c) = run_both(&g, 42);
        assert_eq!(a.output.data(), c.output.data());
        assert_eq!(a.output.shape(), &[28, 28, 1]);
        assert_eq!(a.output_scale, 1.0 / 127.0);
    }

    #[test]
    fn small_pix2pix_acc_and_cpu_bit_exact() {
        let g = zoo::pix2pix(32, 8, 0);
        let (a, c) = run_both(&g, 43);
        assert_eq!(a.output.data(), c.output.data());
        assert_eq!(a.output.shape(), &[32, 32, 3]);
    }

    #[test]
    fn table4_modeling_accelerator_wins_tconv_time() {
        let g = zoo::dcgan_tf(0);
        let (a, _) = run_both(&g, 44);
        let cfg = AccelConfig::default();
        let cpu1 = a.modeled(RunConfig::Cpu { threads: 1 }, &cfg);
        let cpu2 = a.modeled(RunConfig::Cpu { threads: 2 }, &cfg);
        let acc1 = a.modeled(RunConfig::AccPlusCpu { threads: 1 }, &cfg);
        assert!(acc1.tconv_s < cpu1.tconv_s, "acc {} cpu {}", acc1.tconv_s, cpu1.tconv_s);
        assert!(cpu2.tconv_s < cpu1.tconv_s);
        assert!(acc1.total_s() < cpu1.total_s());
        assert!(acc1.energy_j < cpu1.energy_j);
    }

    #[test]
    fn batched_graph_run_matches_per_request() {
        let g = zoo::pix2pix(16, 4, 0);
        let exec = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
        let mut rng = Pcg32::new(46);
        let inputs: Vec<Tensor<i8>> = (0..3)
            .map(|_| Tensor::<i8>::random(&g.input_shape, &mut rng))
            .collect();
        let batch = exec.run_batch(&g, &inputs).unwrap();
        assert_eq!(batch.requests, 3);
        for (k, input) in inputs.iter().enumerate() {
            let single = exec.run(&g, input);
            assert_eq!(batch.outputs[k].data(), single.output.data(), "request {k}");
            assert_eq!(batch.output_scale, single.output_scale);
        }
        // Weight accounting: every TCONV executed once for 3 requests.
        let counters = batch.weight_load_counters();
        assert!(counters.performed > 0);
        assert_eq!(counters.equivalent, 3 * counters.performed, "batch of 3 amortizes 3x");
        // Batched modeling beats per-request modeling (fewer weight
        // loads + one driver dispatch per layer instead of three).
        let cfg = AccelConfig::default();
        let batched_s = batch.modeled(RunConfig::AccPlusCpu { threads: 1 }, &cfg).total_s();
        let per_request_s: f64 = inputs
            .iter()
            .map(|x| {
                exec.run(&g, x).modeled(RunConfig::AccPlusCpu { threads: 1 }, &cfg).total_s()
            })
            .sum();
        assert!(batched_s < per_request_s, "{batched_s} vs {per_request_s}");
    }

    /// Cross-graph batching: two same-architecture pix2pix variants with
    /// different weights execute as one mixed batch, byte-identical to
    /// per-request runs, paying one weight load per (tile, variant).
    #[test]
    fn cross_graph_batch_matches_per_request_and_amortizes() {
        let ga = zoo::pix2pix(16, 4, 0);
        let gb = zoo::pix2pix(16, 4, 7);
        let cfg = AccelConfig::default();
        assert_eq!(ga.graph_key(&cfg), gb.graph_key(&cfg), "zoo variants are chain-mates");
        let exec = Executor::new(Delegate::new(cfg.clone(), 1, true));
        let mut rng = Pcg32::new(47);
        let inputs: Vec<Tensor<i8>> = (0..4)
            .map(|_| Tensor::<i8>::random(&ga.input_shape, &mut rng))
            .collect();
        let graphs = [&ga, &gb];
        let assignment = [0usize, 1, 0, 1]; // interleaved variants
        let batch = exec.run_batch_multi(&graphs, &assignment, &inputs).unwrap();
        assert_eq!(batch.requests, 4);
        for (k, input) in inputs.iter().enumerate() {
            let single = exec.run(graphs[assignment[k]], input);
            assert_eq!(batch.outputs[k].data(), single.output.data(), "request {k}");
            assert_eq!(batch.output_scale, single.output_scale);
        }
        // Per TCONV layer the stream paid (tiles x 2 variants) loads
        // where a per-request replay pays (tiles x 4 requests).
        let c = batch.weight_load_counters();
        assert!(c.performed > 0);
        assert_eq!(c.equivalent, 2 * (c.performed + c.skipped), "4 requests over 2 variants");
        // And the modeled batch beats four per-request dispatches.
        let batched_s = batch.modeled(RunConfig::AccPlusCpu { threads: 1 }, &cfg).total_s();
        let per_request_s: f64 = inputs
            .iter()
            .zip(assignment)
            .map(|(x, v)| {
                exec.run(graphs[v], x).modeled(RunConfig::AccPlusCpu { threads: 1 }, &cfg).total_s()
            })
            .sum();
        assert!(batched_s < per_request_s, "{batched_s} vs {per_request_s}");
    }

    #[test]
    #[should_panic(expected = "chain-mates")]
    fn cross_graph_batch_rejects_non_chain_mates() {
        let ga = zoo::pix2pix(16, 4, 0);
        let gb = zoo::pix2pix(32, 4, 0); // different geometry
        let exec = Executor::new(Delegate::new(AccelConfig::default(), 1, true));
        let input = Tensor::<i8>::zeros(&ga.input_shape);
        let _ = exec.run_batch_multi(&[&ga, &gb], &[0], &[input]);
    }

    #[test]
    fn records_cover_all_compute_layers() {
        let g = zoo::dcgan_tf(0);
        let (a, _) = run_both(&g, 45);
        let tconvs = a
            .records
            .iter()
            .filter(|r| matches!(r.work, Work::Tconv { .. }))
            .count();
        assert_eq!(tconvs, 3);
        assert!(a.records.iter().any(|r| matches!(r.work, Work::Dense { .. })));
    }
}
