//! f32 reference of the L2 JAX DCGAN generator (`python/compile/model.py`)
//! — used to cross-validate the PJRT-executed HLO artifact against native
//! rust numerics with identical parameter values (the artifact takes
//! parameters as arguments, so no RNG coupling with python is needed).

use crate::tconv::problem::TconvProblem;
use crate::tconv::reference;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// DCGAN latent vector length.
pub const LATENT: usize = 100;
/// Spatial size of the dense seed feature map (7x7).
pub const SEED_HW: usize = 7;
/// Channels of the dense seed feature map.
pub const SEED_C: usize = 256;

/// (oc, ks, stride, activation) — mirrors model.py DCGAN_SPECS.
pub const SPECS: [(usize, usize, usize, DcganAct); 3] = [
    (128, 5, 1, DcganAct::Leaky),
    (64, 5, 2, DcganAct::Leaky),
    (1, 5, 2, DcganAct::Tanh),
];

/// Activation selector of one DCGAN TCONV stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DcganAct {
    /// LeakyReLU(0.3).
    Leaky,
    /// Tanh output head.
    Tanh,
}

fn leaky(x: f32) -> f32 {
    if x >= 0.0 {
        x
    } else {
        0.3 * x
    }
}

/// Parameter shapes in artifact argument order (after z):
/// dense_w [100, 12544], dense_b [12544], then per tconv layer:
/// w [oc, ks, ks, ic], b [oc], and for leaky layers scale [oc], shift [oc].
pub fn param_shapes() -> Vec<Vec<usize>> {
    let mut shapes = vec![vec![LATENT, SEED_HW * SEED_HW * SEED_C], vec![SEED_HW * SEED_HW * SEED_C]];
    let mut ic = SEED_C;
    for (oc, ks, _s, act) in SPECS {
        shapes.push(vec![oc, ks, ks, ic]);
        shapes.push(vec![oc]);
        if act == DcganAct::Leaky {
            shapes.push(vec![oc]);
            shapes.push(vec![oc]);
        }
        ic = oc;
    }
    shapes
}

/// Deterministic random parameter set (for PJRT cross-checks).
pub fn random_params(rng: &mut Pcg32, scale: f32) -> Vec<Tensor<f32>> {
    param_shapes()
        .iter()
        .map(|s| Tensor::random_normal(s, scale, rng))
        .collect()
}

/// Forward pass: z [100] + params -> image [28, 28, 1] in [-1, 1].
pub fn dcgan_forward(z: &[f32], params: &[Tensor<f32>]) -> Tensor<f32> {
    assert_eq!(z.len(), LATENT);
    let shapes = param_shapes();
    assert_eq!(params.len(), shapes.len(), "param count");
    for (p, s) in params.iter().zip(&shapes) {
        assert_eq!(p.shape(), &s[..], "param shape");
    }

    let mut it = params.iter();
    let dense_w = it.next().unwrap(); // [100, 12544]
    let dense_b = it.next().unwrap();
    let d = SEED_HW * SEED_HW * SEED_C;
    let mut h = vec![0f32; d];
    for j in 0..d {
        let mut acc = dense_b.data()[j];
        for i in 0..LATENT {
            acc += z[i] * dense_w.data()[i * d + j];
        }
        h[j] = leaky(acc);
    }
    let mut cur = Tensor::from_vec(&[SEED_HW, SEED_HW, SEED_C], h);

    let mut hw = SEED_HW;
    let mut ic = SEED_C;
    for (oc, ks, s, act) in SPECS {
        let w = it.next().unwrap();
        let b = it.next().unwrap();
        let p = TconvProblem::new(hw, hw, ic, ks, oc, s);
        let mut out = reference::direct_f32(&p, &cur, w, Some(b.data()));
        match act {
            DcganAct::Leaky => {
                let scale = it.next().unwrap();
                let shift = it.next().unwrap();
                for px in 0..p.oh() * p.ow() {
                    for c in 0..oc {
                        let v = out.data()[px * oc + c] * scale.data()[c] + shift.data()[c];
                        out.data_mut()[px * oc + c] = leaky(v);
                    }
                }
            }
            DcganAct::Tanh => {
                for v in out.data_mut() {
                    *v = v.tanh();
                }
            }
        }
        cur = out;
        hw *= s;
        ic = oc;
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_shape_and_range() {
        let mut rng = Pcg32::new(3);
        let params = random_params(&mut rng, 0.02);
        let z: Vec<f32> = (0..LATENT).map(|_| rng.normal()).collect();
        let img = dcgan_forward(&z, &params);
        assert_eq!(img.shape(), &[28, 28, 1]);
        assert!(img.data().iter().all(|v| (-1.0..=1.0).contains(v) && v.is_finite()));
    }

    #[test]
    fn param_shapes_match_manifest_expectation() {
        let shapes = param_shapes();
        assert_eq!(shapes.len(), 12); // dense(2) + 3 layers * (4, 4, 2)
        assert_eq!(shapes[0], vec![100, 12544]);
        assert_eq!(shapes[2], vec![128, 5, 5, 256]);
        assert_eq!(shapes[6], vec![64, 5, 5, 128]);
        assert_eq!(shapes[10], vec![1, 5, 5, 64]);
        assert_eq!(shapes[11], vec![1]);
    }

    #[test]
    fn deterministic_forward() {
        let mut rng = Pcg32::new(5);
        let params = random_params(&mut rng, 0.02);
        let z = vec![0.1f32; LATENT];
        let a = dcgan_forward(&z, &params);
        let b = dcgan_forward(&z, &params);
        assert_eq!(a.data(), b.data());
    }
}
