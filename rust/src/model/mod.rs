//! Mini int8 inference runtime (the TFLite stand-in) + model zoo.
//!
//! A [`graph::Graph`] is a linear chain of quantized layers with explicit
//! skip-connection save/concat ops (enough for GAN generators and U-Nets).
//! The [`executor`] runs real int8 numerics — TCONV layers through the
//! [`crate::driver::Delegate`] (accelerator simulator or CPU baseline),
//! everything else on CPU kernels — and records a per-layer trace from
//! which Table IV's four configurations (CPU 1T/2T, ACC+CPU 1T/2T) are
//! modeled without re-running numerics.

pub mod executor;
pub mod float_ref;
pub mod graph;
pub mod layers;
pub mod zoo;

pub use executor::{BatchRun, Executor, ModelRun, RunConfig, TimeBreakdown};
pub use graph::{Act, Graph, Layer};
