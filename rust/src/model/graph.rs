//! Quantized layer-graph IR.
//!
//! Quantization convention (documented in DESIGN.md §1): all activation
//! tensors are int8 *symmetric* (zero_point = 0) with a per-tensor scale;
//! weights are int8 symmetric per-tensor. This keeps the accelerator's
//! zero-point fast path exact while exercising the full fixed-point
//! requant pipeline.

use crate::tconv::problem::TconvProblem;
use crate::tensor::Tensor;

/// Activation fused after a compute layer (int8-to-int8, same scale).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Act {
    /// Identity.
    None,
    /// max(0, x).
    Relu,
    /// LeakyReLU with the given negative slope (0.3 = TF default, 0.2 =
    /// pix2pix encoder).
    Leaky(f32),
    /// Tanh: output scale becomes 1/127 (full [-1, 1] range).
    Tanh,
}

/// Geometry of a standard (stride-s, SAME) convolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvProblem {
    /// Input height.
    pub ih: usize,
    /// Input width.
    pub iw: usize,
    /// Input channels.
    pub ic: usize,
    /// Square kernel size.
    pub ks: usize,
    /// Output channels.
    pub oc: usize,
    /// Downsampling stride.
    pub stride: usize,
}

impl ConvProblem {
    /// Output height under SAME padding.
    pub fn oh(&self) -> usize {
        self.ih.div_ceil(self.stride)
    }

    /// Output width under SAME padding.
    pub fn ow(&self) -> usize {
        self.iw.div_ceil(self.stride)
    }

    /// Rows of zero padding above the input.
    pub fn pad_top(&self) -> usize {
        // TF SAME for ih % s == 0: total = max(ks - s, 0).
        self.ks.saturating_sub(self.stride) / 2
    }

    /// MACs of the convolution.
    pub fn macs(&self) -> u64 {
        (self.oh() * self.ow() * self.oc * self.ks * self.ks * self.ic) as u64
    }

    /// Output elements produced.
    pub fn outputs(&self) -> u64 {
        (self.oh() * self.ow() * self.oc) as u64
    }
}

/// One graph node. Compute layers carry their weights and scales.
#[derive(Clone, Debug)]
pub enum Layer {
    /// Fully connected: in [in_dim] -> out [out_dim].
    Dense {
        /// Layer name.
        name: String,
        /// Weights, [out_dim, in_dim].
        w: Tensor<i8>,
        /// Per-output-unit bias.
        bias: Vec<i32>,
        /// Weight quantization scale.
        w_scale: f32,
        /// Output quantization scale.
        out_scale: f32,
        /// Fused activation.
        act: Act,
    },
    /// Standard convolution (NHWC, OHWI weights, SAME).
    Conv {
        /// Layer name.
        name: String,
        /// Geometry.
        p: ConvProblem,
        /// Weights, [oc, ks, ks, ic].
        w: Tensor<i8>,
        /// Per-channel bias.
        bias: Vec<i32>,
        /// Weight quantization scale.
        w_scale: f32,
        /// Output quantization scale.
        out_scale: f32,
        /// Fused activation.
        act: Act,
    },
    /// Transposed convolution — the delegate offload target.
    Tconv {
        /// Layer name.
        name: String,
        /// Geometry.
        p: TconvProblem,
        /// Weights, [oc, ks, ks, ic].
        w: Tensor<i8>,
        /// Per-channel bias.
        bias: Vec<i32>,
        /// Weight quantization scale.
        w_scale: f32,
        /// Output quantization scale.
        out_scale: f32,
        /// Fused activation.
        act: Act,
    },
    /// Reshape the current tensor (metadata only).
    Reshape {
        /// Layer name.
        name: String,
        /// Target shape.
        shape: Vec<usize>,
    },
    /// Save the current tensor (+scale) into skip slot `slot`.
    SaveSkip {
        /// Skip-slot index.
        slot: usize,
    },
    /// Concatenate skip slot `slot` onto the channel axis. Scales must
    /// match (the zoo constructs graphs that guarantee it).
    ConcatSkip {
        /// Skip-slot index.
        slot: usize,
    },
}

impl Layer {
    /// The layer's display name.
    pub fn name(&self) -> &str {
        match self {
            Layer::Dense { name, .. } | Layer::Conv { name, .. } | Layer::Tconv { name, .. } => name,
            Layer::Reshape { name, .. } => name,
            Layer::SaveSkip { .. } => "save_skip",
            Layer::ConcatSkip { .. } => "concat_skip",
        }
    }
}

/// A model: input geometry + scale, then the layer chain.
#[derive(Clone, Debug)]
pub struct Graph {
    /// Model name (zoo identity).
    pub name: String,
    /// Shape of the input tensor.
    pub input_shape: Vec<usize>,
    /// Quantization scale of the input tensor.
    pub input_scale: f32,
    /// The layer chain, in execution order.
    pub layers: Vec<Layer>,
}

impl Graph {
    /// Output scale after the last compute layer.
    pub fn output_scale(&self) -> f32 {
        let mut scale = self.input_scale;
        for l in &self.layers {
            match l {
                Layer::Dense { out_scale, .. }
                | Layer::Conv { out_scale, .. }
                | Layer::Tconv { out_scale, .. } => scale = *out_scale,
                _ => {}
            }
        }
        scale
    }

    /// Total TCONV OPs (2*MACs) — the delegate-eligible work.
    pub fn tconv_ops(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| match l {
                Layer::Tconv { p, .. } => p.ops(),
                _ => 0,
            })
            .sum()
    }

    /// The graph's TCONV problems, in execution order.
    pub fn tconv_layers(&self) -> Vec<&TconvProblem> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                Layer::Tconv { p, .. } => Some(p),
                _ => None,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_same_geometry() {
        let c = ConvProblem { ih: 256, iw: 256, ic: 3, ks: 4, oc: 64, stride: 2 };
        assert_eq!((c.oh(), c.ow()), (128, 128));
        assert_eq!(c.pad_top(), 1);
        assert_eq!(c.macs(), 128 * 128 * 64 * 16 * 3);
        let c1 = ConvProblem { ih: 8, iw: 8, ic: 4, ks: 3, oc: 8, stride: 1 };
        assert_eq!((c1.oh(), c1.ow()), (8, 8));
        assert_eq!(c1.pad_top(), 1);
    }

    #[test]
    fn graph_metadata() {
        let g = Graph {
            name: "t".into(),
            input_shape: vec![4, 4, 2],
            input_scale: 0.05,
            layers: vec![Layer::Tconv {
                name: "up".into(),
                p: TconvProblem::new(4, 4, 2, 3, 2, 2),
                w: Tensor::zeros(&[2, 3, 3, 2]),
                bias: vec![0, 0],
                w_scale: 0.02,
                out_scale: 0.07,
                act: Act::None,
            }],
        };
        assert_eq!(g.output_scale(), 0.07);
        assert_eq!(g.tconv_ops(), TconvProblem::new(4, 4, 2, 3, 2, 2).ops());
        assert_eq!(g.tconv_layers().len(), 1);
    }
}
