//! Quantized layer-graph IR.
//!
//! Quantization convention (documented in DESIGN.md §1): all activation
//! tensors are int8 *symmetric* (zero_point = 0) with a per-tensor scale;
//! weights are int8 symmetric per-tensor. This keeps the accelerator's
//! zero-point fast path exact while exercising the full fixed-point
//! requant pipeline.

use crate::tconv::problem::TconvProblem;
use crate::tensor::Tensor;

/// Activation fused after a compute layer (int8-to-int8, same scale).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Act {
    /// Identity.
    None,
    /// max(0, x).
    Relu,
    /// LeakyReLU with the given negative slope (0.3 = TF default, 0.2 =
    /// pix2pix encoder).
    Leaky(f32),
    /// Tanh: output scale becomes 1/127 (full [-1, 1] range).
    Tanh,
}

/// Geometry of a standard (stride-s, SAME) convolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvProblem {
    /// Input height.
    pub ih: usize,
    /// Input width.
    pub iw: usize,
    /// Input channels.
    pub ic: usize,
    /// Square kernel size.
    pub ks: usize,
    /// Output channels.
    pub oc: usize,
    /// Downsampling stride.
    pub stride: usize,
}

impl ConvProblem {
    /// Output height under SAME padding.
    pub fn oh(&self) -> usize {
        self.ih.div_ceil(self.stride)
    }

    /// Output width under SAME padding.
    pub fn ow(&self) -> usize {
        self.iw.div_ceil(self.stride)
    }

    /// Rows of zero padding above the input.
    pub fn pad_top(&self) -> usize {
        // TF SAME for ih % s == 0: total = max(ks - s, 0).
        self.ks.saturating_sub(self.stride) / 2
    }

    /// MACs of the convolution.
    pub fn macs(&self) -> u64 {
        (self.oh() * self.ow() * self.oc * self.ks * self.ks * self.ic) as u64
    }

    /// Output elements produced.
    pub fn outputs(&self) -> u64 {
        (self.oh() * self.ow() * self.oc) as u64
    }
}

/// One graph node. Compute layers carry their weights and scales.
#[derive(Clone, Debug)]
pub enum Layer {
    /// Fully connected: in [in_dim] -> out [out_dim].
    Dense {
        /// Layer name.
        name: String,
        /// Weights, [out_dim, in_dim].
        w: Tensor<i8>,
        /// Per-output-unit bias.
        bias: Vec<i32>,
        /// Weight quantization scale.
        w_scale: f32,
        /// Output quantization scale.
        out_scale: f32,
        /// Fused activation.
        act: Act,
    },
    /// Standard convolution (NHWC, OHWI weights, SAME).
    Conv {
        /// Layer name.
        name: String,
        /// Geometry.
        p: ConvProblem,
        /// Weights, [oc, ks, ks, ic].
        w: Tensor<i8>,
        /// Per-channel bias.
        bias: Vec<i32>,
        /// Weight quantization scale.
        w_scale: f32,
        /// Output quantization scale.
        out_scale: f32,
        /// Fused activation.
        act: Act,
    },
    /// Transposed convolution — the delegate offload target.
    Tconv {
        /// Layer name.
        name: String,
        /// Geometry.
        p: TconvProblem,
        /// Weights, [oc, ks, ks, ic].
        w: Tensor<i8>,
        /// Per-channel bias.
        bias: Vec<i32>,
        /// Weight quantization scale.
        w_scale: f32,
        /// Output quantization scale.
        out_scale: f32,
        /// Fused activation.
        act: Act,
    },
    /// Reshape the current tensor (metadata only).
    Reshape {
        /// Layer name.
        name: String,
        /// Target shape.
        shape: Vec<usize>,
    },
    /// Save the current tensor (+scale) into skip slot `slot`.
    SaveSkip {
        /// Skip-slot index.
        slot: usize,
    },
    /// Concatenate skip slot `slot` onto the channel axis. Scales must
    /// match (the zoo constructs graphs that guarantee it).
    ConcatSkip {
        /// Skip-slot index.
        slot: usize,
    },
}

impl Layer {
    /// The layer's display name.
    pub fn name(&self) -> &str {
        match self {
            Layer::Dense { name, .. } | Layer::Conv { name, .. } | Layer::Tconv { name, .. } => name,
            Layer::Reshape { name, .. } => name,
            Layer::SaveSkip { .. } => "save_skip",
            Layer::ConcatSkip { .. } => "concat_skip",
        }
    }
}

/// A model: input geometry + scale, then the layer chain.
#[derive(Clone, Debug)]
pub struct Graph {
    /// Model name (zoo identity).
    pub name: String,
    /// Shape of the input tensor.
    pub input_shape: Vec<usize>,
    /// Quantization scale of the input tensor.
    pub input_scale: f32,
    /// The layer chain, in execution order.
    pub layers: Vec<Layer>,
}

impl Graph {
    /// Output scale after the last compute layer.
    pub fn output_scale(&self) -> f32 {
        let mut scale = self.input_scale;
        for l in &self.layers {
            match l {
                Layer::Dense { out_scale, .. }
                | Layer::Conv { out_scale, .. }
                | Layer::Tconv { out_scale, .. } => scale = *out_scale,
                _ => {}
            }
        }
        scale
    }

    /// Total TCONV OPs (2*MACs) — the delegate-eligible work.
    pub fn tconv_ops(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| match l {
                Layer::Tconv { p, .. } => p.ops(),
                _ => 0,
            })
            .sum()
    }

    /// The graph's TCONV problems, in execution order.
    pub fn tconv_layers(&self) -> Vec<&TconvProblem> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                Layer::Tconv { p, .. } => Some(p),
                _ => None,
            })
            .collect()
    }

    /// Weight-independent chain identity of this graph when served on an
    /// accelerator configured as `cfg`
    /// ([`GraphKey`](crate::driver::plan::GraphKey)).
    ///
    /// Digests the full structural skeleton — input geometry and scale,
    /// every layer's kind, shapes, quantization scales, and activation,
    /// and for each TCONV layer the weight-independent projection of its
    /// compiled [`PlanKey`](crate::driver::plan::PlanKey) (geometry
    /// including the mapper kind, `Int8` output mode, config
    /// fingerprint) — while excluding parameter *values* (weights,
    /// bias). Two graphs with equal keys execute the same instruction
    /// schedule per layer and evolve activation scales identically, so
    /// their requests can share one cross-graph batch: same `Configure`
    /// per tile, per-variant `LoadWeights`
    /// ([`CompiledPlan::instantiate_batch_multi`](crate::driver::plan::CompiledPlan::instantiate_batch_multi)).
    ///
    /// The serving layer memoizes this at graph registration
    /// (`Server::builder`) — the digest costs one pass over the layer
    /// list plus, for each TCONV layer, the memoized weight fingerprint
    /// its first `PlanKey` would pay anyway.
    pub fn graph_key(&self, cfg: &crate::accel::AccelConfig) -> crate::driver::plan::GraphKey {
        use crate::accel::isa::OutMode;
        use crate::driver::plan::{GraphKey, PlanKey};
        let fold_act = |b: &mut crate::driver::plan::GraphKeyBuilder, act: &Act| {
            match act {
                Act::None => b.word(0),
                Act::Relu => b.word(1),
                Act::Leaky(s) => b.word(2).word(s.to_bits() as u64),
                Act::Tanh => b.word(3),
            };
        };
        let mut b = GraphKey::builder();
        for d in &self.input_shape {
            b.word(*d as u64);
        }
        b.word(self.input_scale.to_bits() as u64);
        for layer in &self.layers {
            match layer {
                Layer::Dense { w, w_scale, out_scale, act, .. } => {
                    b.word(1);
                    for d in w.shape() {
                        b.word(*d as u64);
                    }
                    b.word(w_scale.to_bits() as u64).word(out_scale.to_bits() as u64);
                    fold_act(&mut b, act);
                }
                Layer::Conv { p, w_scale, out_scale, act, .. } => {
                    b.word(2);
                    for d in [p.ih, p.iw, p.ic, p.ks, p.oc, p.stride] {
                        b.word(d as u64);
                    }
                    b.word(w_scale.to_bits() as u64).word(out_scale.to_bits() as u64);
                    fold_act(&mut b, act);
                }
                Layer::Tconv { p, w, bias, w_scale, out_scale, act, .. } => {
                    b.word(3);
                    // The chain link proper: this layer's PlanKey minus
                    // its parameter fingerprints. Serving always requants
                    // on-accelerator, hence Int8.
                    b.chain_link(&PlanKey::new(p, OutMode::Int8, cfg, w, bias, None));
                    b.word(w_scale.to_bits() as u64).word(out_scale.to_bits() as u64);
                    fold_act(&mut b, act);
                }
                Layer::Reshape { shape, .. } => {
                    b.word(4);
                    for d in shape {
                        b.word(*d as u64);
                    }
                }
                Layer::SaveSkip { slot } => {
                    b.word(5).word(*slot as u64);
                }
                Layer::ConcatSkip { slot } => {
                    b.word(6).word(*slot as u64);
                }
            }
        }
        b.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph(seed: u64, out_scale: f32) -> Graph {
        use crate::util::rng::Pcg32;
        let p = TconvProblem::new(4, 4, 2, 3, 2, 2);
        let mut rng = Pcg32::new(seed);
        Graph {
            name: format!("t{seed}"),
            input_shape: vec![4, 4, 2],
            input_scale: 0.05,
            layers: vec![Layer::Tconv {
                name: "up".into(),
                p,
                w: Tensor::<i8>::random(&[2, 3, 3, 2], &mut rng),
                bias: vec![seed as i32, -(seed as i32)],
                w_scale: 0.02,
                out_scale,
                act: Act::None,
            }],
        }
    }

    /// Chain identity: blind to weight/bias values, sensitive to
    /// structure, scales, mapper kind, and target config.
    #[test]
    fn graph_key_weight_blind_structure_aware() {
        let cfg = crate::accel::AccelConfig::default();
        let a = tiny_graph(1, 0.07);
        let b = tiny_graph(2, 0.07); // different weights + bias, same shapes
        assert_eq!(a.graph_key(&cfg), b.graph_key(&cfg), "chain-mates");

        let c = tiny_graph(1, 0.09); // different out_scale
        assert_ne!(a.graph_key(&cfg), c.graph_key(&cfg));

        let mut d = tiny_graph(1, 0.07);
        if let Layer::Tconv { p, .. } = &mut d.layers[0] {
            *p = p.with_mapper(crate::tconv::problem::MapperKind::Segregated);
        }
        assert_ne!(a.graph_key(&cfg), d.graph_key(&cfg), "mapper kind splits chains");

        let mut cfg2 = crate::accel::AccelConfig::default();
        cfg2.x_pms = 4;
        assert_ne!(a.graph_key(&cfg), a.graph_key(&cfg2), "config splits chains");

        let mut e = tiny_graph(1, 0.07);
        e.layers.push(Layer::Reshape { name: "r".into(), shape: vec![8, 8, 2] });
        assert_ne!(a.graph_key(&cfg), e.graph_key(&cfg), "extra layer splits chains");
    }

    #[test]
    fn conv_same_geometry() {
        let c = ConvProblem { ih: 256, iw: 256, ic: 3, ks: 4, oc: 64, stride: 2 };
        assert_eq!((c.oh(), c.ow()), (128, 128));
        assert_eq!(c.pad_top(), 1);
        assert_eq!(c.macs(), 128 * 128 * 64 * 16 * 3);
        let c1 = ConvProblem { ih: 8, iw: 8, ic: 4, ks: 3, oc: 8, stride: 1 };
        assert_eq!((c1.oh(), c1.ow()), (8, 8));
        assert_eq!(c1.pad_top(), 1);
    }

    #[test]
    fn graph_metadata() {
        let g = Graph {
            name: "t".into(),
            input_shape: vec![4, 4, 2],
            input_scale: 0.05,
            layers: vec![Layer::Tconv {
                name: "up".into(),
                p: TconvProblem::new(4, 4, 2, 3, 2, 2),
                w: Tensor::zeros(&[2, 3, 3, 2]),
                bias: vec![0, 0],
                w_scale: 0.02,
                out_scale: 0.07,
                act: Act::None,
            }],
        };
        assert_eq!(g.output_scale(), 0.07);
        assert_eq!(g.tconv_ops(), TconvProblem::new(4, 4, 2, 3, 2, 2).ops());
        assert_eq!(g.tconv_layers().len(), 1);
    }
}
