//! Model zoo: the generative models of the paper's evaluation with the
//! exact layer shapes (weights are seeded-synthetic — DESIGN.md §8).
//!
//! * [`dcgan_tf`] — the TF-tutorial DCGAN generator of Table IV.
//! * [`pix2pix`] — the pix2pix U-Net generator (size-parameterized; 256
//!   reproduces Table IV, smaller sizes keep tests fast).
//! * [`fsrcnn`] — FSRCNN super-resolution tail (conv stack + TCONV head).
//! * [`fsrcnn_seg`] — same net compiled for the kernel-segregated mapper.
//! * [`table2_layers`] — the nine single TCONV layers of Table II.
//! * [`sweep261`] — lives in `bench::workloads` (261 synthetic problems).

use crate::model::graph::{Act, ConvProblem, Graph, Layer};
use crate::tconv::problem::{MapperKind, TconvProblem};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// Shared synthetic activation scale: requant multipliers land ≈0.02 —
/// inside TFLite's expected (0, 1) band.
pub const ACT_SCALE: f32 = 0.05;
/// Shared synthetic weight scale (see [`ACT_SCALE`]).
pub const W_SCALE: f32 = 0.02;

fn rand_w(rng: &mut Pcg32, shape: &[usize]) -> Tensor<i8> {
    Tensor::<i8>::random(shape, rng)
}

fn small_bias(rng: &mut Pcg32, n: usize) -> Vec<i32> {
    (0..n).map(|_| (rng.below(2001) as i32) - 1000).collect()
}

/// TF-tutorial DCGAN generator (Table IV footnote 2):
/// z[100] -> Dense 7*7*256 -> tconv(128,5,1) -> tconv(64,5,2) ->
/// tconv(1,5,2) tanh -> [28,28,1].
pub fn dcgan_tf(seed: u64) -> Graph {
    let mut rng = Pcg32::with_stream(seed, 0xdc6a);
    let mut layers = vec![
        Layer::Dense {
            name: "dense".into(),
            w: rand_w(&mut rng, &[7 * 7 * 256, 100]),
            bias: small_bias(&mut rng, 7 * 7 * 256),
            w_scale: W_SCALE,
            out_scale: ACT_SCALE,
            act: Act::Leaky(0.3),
        },
        Layer::Reshape { name: "reshape".into(), shape: vec![7, 7, 256] },
    ];
    let specs = [(128usize, 5usize, 1usize, Act::Leaky(0.3)), (64, 5, 2, Act::Leaky(0.3)), (1, 5, 2, Act::Tanh)];
    let mut hw = 7;
    let mut ic = 256;
    for (i, (oc, ks, s, act)) in specs.into_iter().enumerate() {
        let p = TconvProblem::new(hw, hw, ic, ks, oc, s);
        layers.push(Layer::Tconv {
            name: format!("tconv_{i}"),
            p,
            w: rand_w(&mut rng, &[oc, ks, ks, ic]),
            bias: small_bias(&mut rng, oc),
            w_scale: W_SCALE,
            out_scale: ACT_SCALE,
            act,
        });
        hw *= s;
        ic = oc;
    }
    Graph {
        name: "dcgan_tf".into(),
        input_shape: vec![100],
        input_scale: ACT_SCALE,
        layers,
    }
}

/// pix2pix U-Net generator (Isola et al.), parameterized:
/// `size` = input resolution (256 for Table IV), `width` = first-layer
/// filters (64 for the paper). Depth scales with log2(size) down to 1x1.
/// Encoder: C(width)..C(width*8) 4x4 s2 LeakyReLU(0.2); decoder mirrors
/// with TCONV 4x4 s2 + skip concats; tanh head to 3 channels.
pub fn pix2pix(size: usize, width: usize, seed: u64) -> Graph {
    assert!(size.is_power_of_two() && size >= 8, "size must be a power of two >= 8");
    let mut rng = Pcg32::with_stream(seed, 0x9126);
    let depth = (size as f64).log2() as usize - 1; // stop at 2x2
    let mut layers = Vec::new();

    // ---- encoder -----------------------------------------------------------
    let mut hw = size;
    let mut ic = 3usize;
    let mut enc_channels = Vec::new();
    for d in 0..depth {
        let oc = width * (1 << d.min(3)); // cap at width*8
        let p = ConvProblem { ih: hw, iw: hw, ic, ks: 4, oc, stride: 2 };
        layers.push(Layer::Conv {
            name: format!("enc_{d}"),
            p,
            w: rand_w(&mut rng, &[oc, 4, 4, ic]),
            bias: small_bias(&mut rng, oc),
            w_scale: W_SCALE,
            out_scale: ACT_SCALE,
            act: Act::Leaky(0.2),
        });
        hw /= 2;
        ic = oc;
        enc_channels.push(oc);
        if d + 1 < depth {
            layers.push(Layer::SaveSkip { slot: d });
        }
    }

    // ---- decoder (TCONV ups with skip concats) -----------------------------
    for d in (0..depth - 1).rev() {
        let oc = enc_channels[d];
        let p = TconvProblem::new(hw, hw, ic, 4, oc, 2);
        layers.push(Layer::Tconv {
            name: format!("dec_{d}"),
            p,
            w: rand_w(&mut rng, &[oc, 4, 4, ic]),
            bias: small_bias(&mut rng, oc),
            w_scale: W_SCALE,
            out_scale: ACT_SCALE,
            act: Act::Relu,
        });
        hw *= 2;
        layers.push(Layer::ConcatSkip { slot: d });
        ic = oc * 2; // concat doubles channels
    }

    // ---- head: upscale to full res, 3 channels, tanh ----------------------
    let p = TconvProblem::new(hw, hw, ic, 4, 3, 2);
    layers.push(Layer::Tconv {
        name: "head".into(),
        p,
        w: rand_w(&mut rng, &[3, 4, 4, ic]),
        bias: small_bias(&mut rng, 3),
        w_scale: W_SCALE,
        out_scale: ACT_SCALE,
        act: Act::Tanh,
    });

    Graph {
        name: format!("pix2pix_{size}"),
        input_shape: vec![size, size, 3],
        input_scale: ACT_SCALE,
        layers,
    }
}

/// FSRCNN-style super-resolution net: feature conv, mapping convs, and
/// the TCONV(9, s2) head of Table II.
pub fn fsrcnn(size: usize, seed: u64) -> Graph {
    let mut rng = Pcg32::with_stream(seed, 0xf5cc);
    let mut layers = Vec::new();
    let d = 32usize;
    // feature extraction 5x5
    layers.push(Layer::Conv {
        name: "feat".into(),
        p: ConvProblem { ih: size, iw: size, ic: 1, ks: 5, oc: d, stride: 1 },
        w: rand_w(&mut rng, &[d, 5, 5, 1]),
        bias: small_bias(&mut rng, d),
        w_scale: W_SCALE,
        out_scale: ACT_SCALE,
        act: Act::Relu,
    });
    // two 3x3 mapping layers
    for i in 0..2 {
        layers.push(Layer::Conv {
            name: format!("map_{i}"),
            p: ConvProblem { ih: size, iw: size, ic: d, ks: 3, oc: d, stride: 1 },
            w: rand_w(&mut rng, &[d, 3, 3, d]),
            bias: small_bias(&mut rng, d),
            w_scale: W_SCALE,
            out_scale: ACT_SCALE,
            act: Act::Relu,
        });
    }
    // TCONV upscaling head (Table II FSRCNN row: ks 9, ih 32, ic 32, oc 2)
    layers.push(Layer::Tconv {
        name: "up".into(),
        p: TconvProblem::new(size, size, d, 9, 2, 2),
        w: rand_w(&mut rng, &[2, 9, 9, d]),
        bias: small_bias(&mut rng, 2),
        w_scale: W_SCALE,
        out_scale: ACT_SCALE,
        act: Act::None,
    });
    Graph {
        name: "fsrcnn".into(),
        input_shape: vec![size, size, 1],
        input_scale: ACT_SCALE,
        layers,
    }
}

/// [`fsrcnn`] with every TCONV layer rebuilt for the kernel-segregated
/// mapper ([`MapperKind::Segregated`]): byte-identical weights and
/// geometry (the seeded RNG stream is shared with the overlapped
/// build), but a different [`crate::driver::PlanKey`], so the two
/// variants compile to distinct plans. The differential net pairs this
/// model with the overlapped build to prove both mapper walks agree
/// bit-for-bit end-to-end.
pub fn fsrcnn_seg(size: usize, seed: u64) -> Graph {
    let mut g = fsrcnn(size, seed);
    g.name = "fsrcnn_seg".into();
    for layer in &mut g.layers {
        if let Layer::Tconv { p, .. } = layer {
            *p = p.with_mapper(MapperKind::Segregated);
        }
    }
    g
}

/// Johnson-style style-transfer network tail (the paper's
/// StyleTransfer_1/2 layers): a conv encoder, two TCONV(3, s2) upsamples
/// and a 9x9 conv head. `size` = input resolution of the *first* TCONV
/// (64 reproduces StyleTransfer_1's geometry scaled by `width`).
pub fn style_transfer(size: usize, width: usize, seed: u64) -> Graph {
    let mut rng = Pcg32::with_stream(seed, 0x57e1);
    let mut layers = Vec::new();
    // encoder conv (stand-in for the residual trunk)
    layers.push(Layer::Conv {
        name: "trunk".into(),
        p: ConvProblem { ih: size, iw: size, ic: width * 2, ks: 3, oc: width * 2, stride: 1 },
        w: rand_w(&mut rng, &[width * 2, 3, 3, width * 2]),
        bias: small_bias(&mut rng, width * 2),
        w_scale: W_SCALE,
        out_scale: ACT_SCALE,
        act: Act::Relu,
    });
    // two TCONV upsamples (StyleTransfer_1/_2 shapes when width=64)
    let mut hw = size;
    let mut ic = width * 2;
    for (i, oc) in [width, width / 2].into_iter().enumerate() {
        layers.push(Layer::Tconv {
            name: format!("up_{i}"),
            p: TconvProblem::new(hw, hw, ic, 3, oc, 2),
            w: rand_w(&mut rng, &[oc, 3, 3, ic]),
            bias: small_bias(&mut rng, oc),
            w_scale: W_SCALE,
            out_scale: ACT_SCALE,
            act: Act::Relu,
        });
        hw *= 2;
        ic = oc;
    }
    // 9x9 conv head to RGB, tanh
    layers.push(Layer::Conv {
        name: "head".into(),
        p: ConvProblem { ih: hw, iw: hw, ic, ks: 9, oc: 3, stride: 1 },
        w: rand_w(&mut rng, &[3, 9, 9, ic]),
        bias: small_bias(&mut rng, 3),
        w_scale: W_SCALE,
        out_scale: ACT_SCALE,
        act: Act::Tanh,
    });
    Graph {
        name: "style_transfer".into(),
        input_shape: vec![size, size, width * 2],
        input_scale: ACT_SCALE,
        layers,
    }
}

/// Single-TCONV graph for one problem (seeded-synthetic weights and
/// bias, identity activation): the minimal serving workload. Used by
/// the placement test net and the heterogeneous-fleet bench scenarios —
/// one builder so per-layer scales and weight seeding cannot drift
/// between them.
pub fn single_tconv(name: &str, p: TconvProblem, seed: u64) -> Graph {
    let mut rng = Pcg32::with_stream(seed, 0x51c1);
    Graph {
        name: name.into(),
        input_shape: vec![p.ih, p.iw, p.ic],
        input_scale: ACT_SCALE,
        layers: vec![Layer::Tconv {
            name: "up".into(),
            p,
            w: rand_w(&mut rng, &[p.oc, p.ks, p.ks, p.ic]),
            bias: small_bias(&mut rng, p.oc),
            w_scale: W_SCALE,
            out_scale: ACT_SCALE,
            act: Act::None,
        }],
    }
}

/// A Table II row: name, problem, paper's measured numbers for
/// side-by-side reporting (latency ms, CPU ms, GOPs, GOPs/W).
#[derive(Clone, Copy, Debug)]
pub struct Table2Row {
    /// Layer label as printed in Table II.
    pub name: &'static str,
    /// The TCONV geometry.
    pub problem: TconvProblem,
    /// Paper's measured accelerator latency, ms.
    pub paper_acc_ms: f64,
    /// Paper's measured dual-thread CPU latency, ms.
    pub paper_cpu_ms: f64,
    /// Paper's reported speedup.
    pub paper_speedup: f64,
    /// Paper's reported accelerator GOPs.
    pub paper_gops: f64,
    /// Paper's reported energy efficiency, GOPs/W.
    pub paper_gops_w: f64,
}

/// The nine generative-model TCONV layers of Table II.
pub fn table2_layers() -> Vec<Table2Row> {
    let r = |name, p, a, c, s, g, gw| Table2Row {
        name,
        problem: p,
        paper_acc_ms: a,
        paper_cpu_ms: c,
        paper_speedup: s,
        paper_gops: g,
        paper_gops_w: gw,
    };
    vec![
        r("DCGAN_1", TconvProblem::square(4, 1024, 5, 512, 2), 46.26, 166.56, 3.60, 9.07, 15.64),
        r("DCGAN_2", TconvProblem::square(8, 512, 5, 256, 2), 33.97, 141.05, 4.15, 12.35, 15.03),
        r("DCGAN_3", TconvProblem::square(16, 256, 5, 128, 2), 35.86, 149.70, 4.17, 11.70, 14.92),
        r("DCGAN_4", TconvProblem::square(32, 128, 5, 3, 2), 4.67, 10.71, 2.29, 4.21, 0.87),
        r("FCN", TconvProblem::square(1, 21, 4, 21, 4), 0.22, 0.22, 1.00, 0.06, 0.01),
        r("StyleTransfer_1", TconvProblem::square(64, 128, 3, 64, 2), 164.62, 304.48, 1.85, 3.67, 23.22),
        r("StyleTransfer_2", TconvProblem::square(128, 64, 3, 32, 2), 282.83, 460.23, 1.63, 2.14, 23.65),
        r("StyleTransfer_3", TconvProblem::square(256, 32, 9, 3, 2), 264.27, 1045.36, 3.96, 3.86, 40.49),
        r("FSRCNN", TconvProblem::square(32, 32, 9, 2, 2), 5.21, 12.47, 2.39, 2.04, 0.51),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dcgan_shapes_follow_tf_tutorial() {
        let g = dcgan_tf(0);
        let probs = g.tconv_layers();
        assert_eq!(probs.len(), 3);
        assert_eq!(*probs[0], TconvProblem::new(7, 7, 256, 5, 128, 1));
        assert_eq!(*probs[1], TconvProblem::new(7, 7, 128, 5, 64, 2));
        assert_eq!(*probs[2], TconvProblem::new(14, 14, 64, 5, 1, 2));
    }

    #[test]
    fn pix2pix_256_has_paper_structure() {
        let g = pix2pix(256, 64, 0);
        // depth = 7 (256 -> 2), so 7 encoder convs, 6 skip tconvs + head.
        let convs = g.layers.iter().filter(|l| matches!(l, Layer::Conv { .. })).count();
        let tconvs = g.tconv_layers().len();
        assert_eq!(convs, 7);
        assert_eq!(tconvs, 7);
        // encoder channel ladder caps at 512
        let last_enc = g.layers.iter().filter_map(|l| match l {
            Layer::Conv { p, .. } => Some(p.oc),
            _ => None,
        }).max().unwrap();
        assert_eq!(last_enc, 512);
    }

    #[test]
    fn pix2pix_small_is_consistent() {
        let g = pix2pix(32, 8, 0);
        assert_eq!(g.input_shape, vec![32, 32, 3]);
        // all tconv inputs' spatial dims double to reach 32 at the head
        let head = g.tconv_layers().last().cloned().unwrap();
        assert_eq!(head.oh(), 32);
        assert_eq!(head.oc, 3);
    }

    #[test]
    fn table2_ops_match_paper_column() {
        // Paper lists OPs per layer; spot-check the three magnitudes.
        let rows = table2_layers();
        let ops = |n: &str| rows.iter().find(|r| r.name == n).unwrap().problem.ops() as f64;
        assert!((ops("DCGAN_1") / 1e6 - 420.0).abs() < 15.0);
        assert!((ops("StyleTransfer_3") / 1e6 - 1020.0).abs() < 40.0);
        assert!((ops("FSRCNN") / 1e6 - 11.0).abs() < 3.0);
        assert!((ops("FCN") / 1e3 - 14.0).abs() < 2.0);
    }

    #[test]
    fn style_transfer_matches_table2_shapes_when_full_width() {
        let g = style_transfer(64, 64, 0);
        let probs = g.tconv_layers();
        // StyleTransfer_1: tconv(64,64,128,3,64,2); _2: tconv(128,128,64,3,32,2)
        assert_eq!(*probs[0], TconvProblem::new(64, 64, 128, 3, 64, 2));
        assert_eq!(*probs[1], TconvProblem::new(128, 128, 64, 3, 32, 2));
        let small = style_transfer(8, 4, 0);
        assert_eq!(small.input_shape, vec![8, 8, 8]);
    }

    #[test]
    fn fsrcnn_seg_shares_weights_and_differs_only_in_mapper() {
        let a = fsrcnn(16, 3);
        let b = fsrcnn_seg(16, 3);
        assert_eq!(a.layers.len(), b.layers.len());
        let mut tconvs = 0;
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            if let (
                Layer::Tconv { p: pa, w: wa, bias: ba, .. },
                Layer::Tconv { p: pb, w: wb, bias: bb, .. },
            ) = (la, lb)
            {
                tconvs += 1;
                assert_eq!(wa.data(), wb.data(), "weights must be identical");
                assert_eq!(ba, bb, "bias must be identical");
                assert_eq!(pa.mapper, MapperKind::Overlapped);
                assert_eq!(pb.mapper, MapperKind::Segregated);
                assert_eq!(*pa, pb.with_mapper(MapperKind::Overlapped));
            }
        }
        assert!(tconvs >= 1, "fsrcnn must contain a TCONV head");
    }

    #[test]
    fn seeded_models_are_deterministic() {
        let a = dcgan_tf(7);
        let b = dcgan_tf(7);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            if let (Layer::Tconv { w: wa, .. }, Layer::Tconv { w: wb, .. }) = (la, lb) {
                assert_eq!(wa.data(), wb.data());
            }
        }
    }
}
