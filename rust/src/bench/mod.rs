//! Benchmark harness: workload generators + the experiment runners that
//! regenerate every table and figure of the paper (`rust/benches/*`).

pub mod harness;
pub mod workloads;

pub use harness::{
    compile_amortization, latency_by_class, run_problem, AmortizationResult, ClassLatency,
    ProblemResult,
};
pub use workloads::{sweep261, SweepEntry};
