//! Experiment runner shared by all paper-table benches: run one TCONV
//! problem through the simulated accelerator and the modeled CPU
//! baseline, collect every metric the paper reports.

use crate::accel::isa::OutMode;
use crate::accel::{Accelerator, AccelConfig, CycleReport};
use crate::coordinator::{Outcome, Priority, Response};
use crate::cpu::cost_model;
use crate::driver::instructions::{build_layer_stream, compile_layer, DRIVER_FIXED_OVERHEAD_S};
use crate::driver::{CacheStats, PlanCache, PlanKey};
use crate::tconv::metrics::DropStats;
use crate::tconv::problem::TconvProblem;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;
use std::time::Instant;

/// Everything the paper reports about one TCONV problem.
#[derive(Clone, Debug)]
pub struct ProblemResult {
    /// The problem that ran.
    pub problem: TconvProblem,
    /// §III-A drop/storage statistics.
    pub drop: DropStats,
    /// Modeled accelerator seconds (incl. host driver overhead).
    pub acc_seconds: f64,
    /// Modeled single-thread CPU seconds.
    pub cpu1_seconds: f64,
    /// Modeled dual-thread CPU seconds.
    pub cpu2_seconds: f64,
    /// Achieved GOPs (algorithm ops over modeled time).
    pub gops: f64,
    /// Energy efficiency, GOPs per watt.
    pub gops_per_watt: f64,
    /// MAC-array utilization.
    pub utilization: f64,
    /// The full cycle report.
    pub report: CycleReport,
}

impl ProblemResult {
    /// Fig. 6's y-axis: speedup vs the dual-thread CPU baseline.
    pub fn speedup_2t(&self) -> f64 {
        self.cpu2_seconds / self.acc_seconds
    }

    /// Table II's speedup column (vs single-thread CPU).
    pub fn speedup_1t(&self) -> f64 {
        self.cpu1_seconds / self.acc_seconds
    }
}

/// Run one problem (numerics + cycle model) with seeded data.
pub fn run_problem(p: &TconvProblem, cfg: &AccelConfig, seed: u64) -> ProblemResult {
    let mut rng = Pcg32::new(seed);
    let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
    let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
    let bias = vec![0i32; p.oc];
    let stream = build_layer_stream(p, &x, &w, &bias, None, cfg, OutMode::Raw32);
    let result = Accelerator::new(cfg.clone())
        .execute(&stream)
        .unwrap_or_else(|e| panic!("{p}: {e}"));
    let report = result.report;
    let acc_seconds = report.seconds(cfg) + DRIVER_FIXED_OVERHEAD_S;
    ProblemResult {
        problem: *p,
        drop: DropStats::compute(p),
        acc_seconds,
        cpu1_seconds: cost_model::tconv_seconds(p, 1),
        cpu2_seconds: cost_model::tconv_seconds(p, 2),
        gops: report.achieved_gops(p.macs(), cfg),
        gops_per_watt: crate::accel::energy::gops_per_watt(&report, p.macs(), cfg),
        utilization: report.utilization(cfg),
        report,
    }
}

/// Analytical-only variant (no numerics): the perf-model estimate, for
/// benches that sweep many configs cheaply.
pub fn estimate_problem(p: &TconvProblem, cfg: &AccelConfig) -> f64 {
    crate::perf_model::estimate_seconds(p, cfg)
}

/// Compile-amortization measurement for the serving path
/// (`benches/serving_scale.rs`): produce the instruction stream for
/// `requests` different inputs of one problem both ways — compiling the
/// layer program from scratch every time vs instantiating one cached
/// [`crate::driver::CompiledPlan`] — and verify the executed outputs stay
/// byte-identical.
#[derive(Clone, Debug)]
pub struct AmortizationResult {
    /// The problem that ran.
    pub problem: TconvProblem,
    /// Distinct inputs streamed.
    pub requests: usize,
    /// Total seconds producing streams by compiling per request.
    pub fresh_stream_s: f64,
    /// Total seconds producing streams from the cached plan (the single
    /// cold-miss compile included).
    pub cached_stream_s: f64,
    /// Cache counters after the cached pass.
    pub cache: CacheStats,
    /// Accelerator outputs of both stream variants matched on every
    /// request.
    pub outputs_identical: bool,
}

impl AmortizationResult {
    /// How much per-request stream-production work the cache removed.
    pub fn stream_speedup(&self) -> f64 {
        self.fresh_stream_s / self.cached_stream_s.max(1e-12)
    }
}

/// Measure stream-production cost with and without the plan cache; see
/// [`AmortizationResult`].
pub fn compile_amortization(
    p: &TconvProblem,
    cfg: &AccelConfig,
    requests: usize,
    seed: u64,
) -> AmortizationResult {
    assert!(requests >= 2, "amortization needs at least two requests");
    let mut rng = Pcg32::new(seed);
    let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
    let bias = vec![0i32; p.oc];
    let cache = PlanCache::new(2);
    let key = PlanKey::new(p, OutMode::Raw32, cfg, &w, &bias, None);

    let mut fresh_s = 0.0;
    let mut cached_s = 0.0;
    let mut identical = true;
    for _ in 0..requests {
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);

        let t0 = Instant::now();
        let fresh_stream = build_layer_stream(p, &x, &w, &bias, None, cfg, OutMode::Raw32);
        fresh_s += t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let plan = cache
            .get_or_compile(key, || compile_layer(p, &w, &bias, None, cfg, OutMode::Raw32));
        let cached_stream = plan.instantiate(&x);
        cached_s += t1.elapsed().as_secs_f64();

        let a = Accelerator::new(cfg.clone()).execute(&fresh_stream).expect("fresh");
        let b = Accelerator::new(cfg.clone()).execute(&cached_stream).expect("cached");
        identical &= a.raw.data() == b.raw.data() && a.quant.data() == b.quant.data();
    }
    AmortizationResult {
        problem: *p,
        requests,
        fresh_stream_s: fresh_s,
        cached_stream_s: cached_s,
        cache: cache.stats(),
        outputs_identical: identical,
    }
}

/// Client-observed latency of one priority class over a served response
/// set (the SLO view the request API exists for).
#[derive(Clone, Copy, Debug)]
pub struct ClassLatency {
    /// The class.
    pub priority: Priority,
    /// Served ([`Outcome::Ok`]) requests of this class.
    pub requests: usize,
    /// Median latency (queue wait + execution), seconds.
    pub p50_s: f64,
    /// 95th-percentile latency, seconds.
    pub p95_s: f64,
}

/// Split client-observed latency percentiles by [`Priority`] class over
/// one response set. Only served requests contribute samples; classes
/// with no served requests are omitted. Used by `benches/serving_scale`
/// and `repro serve` to report SLO-class traffic.
pub fn latency_by_class(responses: &[Response]) -> Vec<ClassLatency> {
    Priority::ALL
        .into_iter()
        .filter_map(|priority| {
            let mut lat: Vec<f64> = responses
                .iter()
                .filter(|r| r.outcome == Outcome::Ok && r.class.priority == priority)
                .map(Response::latency_seconds)
                .collect();
            if lat.is_empty() {
                return None;
            }
            lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
            Some(ClassLatency {
                priority,
                requests: lat.len(),
                p50_s: crate::coordinator::percentile(&lat, 0.50),
                p95_s: crate::coordinator::percentile(&lat, 0.95),
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Class, InputSource};

    fn resp(id: u64, priority: Priority, outcome: Outcome, queue_s: f64) -> Response {
        Response {
            id,
            source: InputSource::Seed(id),
            graph: 0,
            class: Class { priority, deadline: None },
            outcome,
            shard: if outcome == Outcome::Ok { Some(0) } else { None },
            output: if outcome == Outcome::Ok {
                Some(crate::tensor::Tensor::<i8>::zeros(&[1]))
            } else {
                None
            },
            queue_seconds: queue_s,
            wall_seconds: 0.0,
            modeled_seconds: 0.0,
        }
    }

    #[test]
    fn latency_split_groups_served_requests_by_class() {
        let responses = vec![
            resp(0, Priority::High, Outcome::Ok, 1.0),
            resp(1, Priority::High, Outcome::Ok, 3.0),
            resp(2, Priority::Low, Outcome::Ok, 10.0),
            resp(3, Priority::Low, Outcome::Cancelled, 99.0), // no sample
        ];
        let split = latency_by_class(&responses);
        assert_eq!(split.len(), 2, "Normal had no traffic, so it is omitted");
        assert_eq!(split[0].priority, Priority::High);
        assert_eq!(split[0].requests, 2);
        assert!((split[0].p95_s - 3.0).abs() < 1e-12);
        assert_eq!(split[1].priority, Priority::Low);
        assert_eq!(split[1].requests, 1, "cancelled requests contribute no latency");
        assert!((split[1].p50_s - 10.0).abs() < 1e-12);
    }

    #[test]
    fn result_fields_consistent() {
        let p = TconvProblem::square(7, 64, 5, 16, 2);
        let r = run_problem(&p, &AccelConfig::default(), 1);
        assert!(r.acc_seconds > 0.0);
        assert!(r.cpu2_seconds < r.cpu1_seconds);
        assert!(r.speedup_1t() > r.speedup_2t());
        assert!(r.gops > 0.0 && r.utilization > 0.0 && r.utilization < 1.0);
        assert!((r.drop.d_r - DropStats::compute(&p).d_r).abs() < 1e-12);
    }

    #[test]
    fn amortization_compiles_once_and_stays_bit_exact() {
        let p = TconvProblem::square(7, 32, 3, 16, 2);
        let r = compile_amortization(&p, &AccelConfig::default(), 4, 3);
        assert!(r.outputs_identical, "cached plan changed numerics");
        assert_eq!(r.cache.misses, 1, "layer must compile exactly once");
        assert_eq!(r.cache.hits, 3);
        assert!(r.fresh_stream_s > 0.0 && r.cached_stream_s > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let p = TconvProblem::square(7, 32, 3, 16, 1);
        let a = run_problem(&p, &AccelConfig::default(), 9);
        let b = run_problem(&p, &AccelConfig::default(), 9);
        assert_eq!(a.report.total_cycles, b.report.total_cycles);
        assert_eq!(a.acc_seconds, b.acc_seconds);
    }
}
