//! The 261 TCONV problem configurations of §V-B.
//!
//! The paper's stated grid — O_c ∈ {16,32,64}, Ks ∈ {3,5,7},
//! I_h ∈ {7,9,11}, I_c ∈ {32,64,128,256}, S ∈ {1,2} — yields 216
//! permutations; the remaining 45 are the TFLite-benchmark-suite variants
//! we add (documented in DESIGN.md §8): a small-feature-map set (I_h = 5),
//! a wide-output set (O_c = 128), and three model-derived shapes.

use crate::accel::AccelConfig;
use crate::tconv::problem::{MapperKind, TconvProblem};
use crate::util::rng::Pcg32;

/// One sweep problem plus its figure grouping.
#[derive(Clone, Copy, Debug)]
pub struct SweepEntry {
    /// The TCONV geometry.
    pub problem: TconvProblem,
    /// Grouping key used by Figs. 6/7 ("similar problems are grouped").
    pub group: &'static str,
}

/// All 261 problems, grid-major ordering.
pub fn sweep261() -> Vec<SweepEntry> {
    let mut out = Vec::with_capacity(261);
    // ---- the paper's stated 216-permutation grid ---------------------------
    for &oc in &[16usize, 32, 64] {
        for &ks in &[3usize, 5, 7] {
            for &ih in &[7usize, 9, 11] {
                for &ic in &[32usize, 64, 128, 256] {
                    for &s in &[1usize, 2] {
                        out.push(SweepEntry {
                            problem: TconvProblem::square(ih, ic, ks, oc, s),
                            group: "grid216",
                        });
                    }
                }
            }
        }
    }
    // ---- +24: small feature maps (I_h = 5, O_c = 16) -----------------------
    for &ks in &[3usize, 5, 7] {
        for &ic in &[32usize, 64, 128, 256] {
            for &s in &[1usize, 2] {
                out.push(SweepEntry {
                    problem: TconvProblem::square(5, ic, ks, 16, s),
                    group: "ih5",
                });
            }
        }
    }
    // ---- +18: wide output channels (O_c = 128, I_c = 64) -------------------
    for &ks in &[3usize, 5, 7] {
        for &ih in &[7usize, 9, 11] {
            for &s in &[1usize, 2] {
                out.push(SweepEntry {
                    problem: TconvProblem::square(ih, 64, ks, 128, s),
                    group: "oc128",
                });
            }
        }
    }
    // ---- +3: model-derived shapes ------------------------------------------
    out.push(SweepEntry { problem: TconvProblem::square(1, 21, 4, 21, 4), group: "model" }); // FCN
    out.push(SweepEntry { problem: TconvProblem::square(32, 32, 9, 2, 2), group: "model" }); // FSRCNN
    out.push(SweepEntry { problem: TconvProblem::square(32, 128, 5, 3, 2), group: "model" }); // DCGAN_4
    out
}

/// Kernel-segregated twins of the sweep: every `step`-th problem of
/// [`sweep261`] rebuilt with [`MapperKind::Segregated`] (group
/// `"segregated"`). Kept separate from [`sweep261`] so the paper's
/// 261-problem census stays pinned; the differential nets walk this
/// slice to prove the segregated mapper agrees with the overlapped walk
/// across every grid axis.
pub fn sweep_segregated(step: usize) -> Vec<SweepEntry> {
    assert!(step > 0, "step must be positive");
    sweep261()
        .into_iter()
        .step_by(step)
        .map(|e| SweepEntry {
            problem: e.problem.with_mapper(MapperKind::Segregated),
            group: "segregated",
        })
        .collect()
}

/// Fig. 6/7 grouping: problems sharing (Oc, Ks, Ih) form one x-axis
/// bucket; the figure shows per-bucket values across (Ic, S).
pub fn group_label(p: &TconvProblem) -> String {
    format!("oc{}_k{}_ih{}", p.oc, p.ks, p.ih)
}

/// The canonical two-backend heterogeneous fleet of the serving benches
/// and tests: the paper instantiation (X=8, UF=16) next to a
/// narrow-array, deep-unroll variant (X=4, UF=32). One definition so
/// the bench, the placement test net, and the docs cannot drift.
pub fn hetero_fleet() -> Vec<AccelConfig> {
    let narrow = AccelConfig { x_pms: 4, uf: 32, ..AccelConfig::default() };
    vec![AccelConfig::default(), narrow]
}

/// Deterministic mixed-model serving traffic for the scaling benches:
/// `requests` submissions as `(graph index, seed)` pairs, graph drawn
/// uniformly from `0..graphs` so batches of different models interleave
/// the way mixed production traffic would.
pub fn mixed_traffic(graphs: usize, requests: usize, seed: u64) -> Vec<(usize, u64)> {
    assert!(graphs > 0);
    let mut rng = Pcg32::with_stream(seed, 0x7a4f);
    (0..requests as u64).map(|i| (rng.below(graphs as u32) as usize, i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn exactly_261_unique_problems() {
        let all = sweep261();
        assert_eq!(all.len(), 261);
        let unique: HashSet<_> = all.iter().map(|e| e.problem).collect();
        assert_eq!(unique.len(), 261, "no duplicate configurations");
    }

    #[test]
    fn segregated_twins_mirror_the_sweep_geometry() {
        let twins = sweep_segregated(8);
        assert_eq!(twins.len(), sweep261().len().div_ceil(8));
        let base: Vec<_> = sweep261().into_iter().step_by(8).collect();
        for (t, b) in twins.iter().zip(&base) {
            assert_eq!(t.group, "segregated");
            assert_eq!(t.problem.mapper, MapperKind::Segregated);
            assert_eq!(t.problem.with_mapper(MapperKind::Overlapped), b.problem);
        }
        // Twins never collide with the pinned 261 (mapper is part of
        // problem identity).
        let all: HashSet<_> = sweep261().iter().map(|e| e.problem).collect();
        assert!(twins.iter().all(|t| !all.contains(&t.problem)));
    }

    #[test]
    fn grid_subset_is_216() {
        let n = sweep261().iter().filter(|e| e.group == "grid216").count();
        assert_eq!(n, 216);
    }

    #[test]
    fn parameter_ranges_match_paper() {
        for e in sweep261().iter().filter(|e| e.group == "grid216") {
            let p = e.problem;
            assert!([16, 32, 64].contains(&p.oc));
            assert!([3, 5, 7].contains(&p.ks));
            assert!([7, 9, 11].contains(&p.ih));
            assert!([32, 64, 128, 256].contains(&p.ic));
            assert!([1, 2].contains(&p.stride));
        }
    }

    #[test]
    fn group_labels_bucket_by_oc_ks_ih() {
        let all = sweep261();
        let labels: HashSet<_> = all
            .iter()
            .filter(|e| e.group == "grid216")
            .map(|e| group_label(&e.problem))
            .collect();
        assert_eq!(labels.len(), 27); // 3 oc * 3 ks * 3 ih
    }
}
