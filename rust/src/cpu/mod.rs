//! The CPU baseline: TFLite-style IOM TCONV (blocked int8 GEMM + col2im)
//! with 1/2-thread execution, plus the calibrated ARM Cortex-A9 cost model
//! used for paper-comparable latency numbers.
//!
//! Two time scales coexist deliberately (DESIGN.md §1):
//! * `baseline::*` computes real numerics (bit-exact against
//!   `tconv::reference`) and real wall-clock on *this* host — used for
//!   correctness and the §Perf pass;
//! * `cost_model::*` converts the same workload into modeled PYNQ-Z1
//!   Cortex-A9 seconds — used wherever the paper compares against its CPU.

pub mod baseline;
pub mod cost_model;
pub mod gemm;
pub mod threadpool;
