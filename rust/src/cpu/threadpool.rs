//! Minimal scoped work-sharing helper (rayon stand-in for this offline
//! image): split an index range across T OS threads.

/// Run `f(t, lo, hi)` on `threads` scoped threads covering `[0, n)` in
/// contiguous chunks. `f` gets the thread index and its half-open range.
pub fn parallel_ranges(n: usize, threads: usize, f: impl Fn(usize, usize, usize) + Sync) {
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            scope.spawn(move || f(t, lo, hi));
        }
    });
}

/// Map `[0, n)` in parallel into a Vec, chunk-contiguous.
pub fn parallel_map<T: Send + Clone + Default>(
    n: usize,
    threads: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let mut out = vec![T::default(); n];
    let threads = threads.max(1).min(n.max(1));
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest: &mut [T] = &mut out;
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let (this, next) = rest.split_at_mut(hi - lo);
            rest = next;
            let f = &f;
            scope.spawn(move || {
                for (i, slot) in this.iter_mut().enumerate() {
                    *slot = f(lo + i);
                }
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_full_range_once() {
        let hits = AtomicUsize::new(0);
        parallel_ranges(1000, 4, |_, lo, hi| {
            hits.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn single_thread_fallback() {
        let hits = AtomicUsize::new(0);
        parallel_ranges(10, 1, |t, lo, hi| {
            assert_eq!((t, lo, hi), (0, 0, 10));
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn map_matches_serial() {
        let got = parallel_map(97, 3, |i| i * i);
        let want: Vec<usize> = (0..97).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn empty_range_is_fine() {
        parallel_ranges(0, 4, |_, _, _| panic!("must not be called"));
        assert!(parallel_map(0, 4, |i| i).is_empty());
    }
}
