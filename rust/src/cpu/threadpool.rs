//! Minimal work-sharing helpers (rayon stand-in for this offline image):
//! one-shot scoped range splitting ([`parallel_ranges`], [`parallel_map`])
//! and a persistent [`ThreadPool`] for hot loops where per-call thread
//! spawning would dominate the work (the fused engine's per-pass fan-out
//! — a pass is tens of microseconds, an OS thread spawn about as much).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Run `f(t, lo, hi)` on `threads` scoped threads covering `[0, n)` in
/// contiguous chunks. `f` gets the thread index and its half-open range.
pub fn parallel_ranges(n: usize, threads: usize, f: impl Fn(usize, usize, usize) + Sync) {
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            scope.spawn(move || f(t, lo, hi));
        }
    });
}

/// Map `[0, n)` in parallel into a Vec, chunk-contiguous.
pub fn parallel_map<T: Send + Clone + Default>(
    n: usize,
    threads: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let mut out = vec![T::default(); n];
    let threads = threads.max(1).min(n.max(1));
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest: &mut [T] = &mut out;
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let (this, next) = rest.split_at_mut(hi - lo);
            rest = next;
            let f = &f;
            scope.spawn(move || {
                for (i, slot) in this.iter_mut().enumerate() {
                    *slot = f(lo + i);
                }
            });
        }
    });
    out
}

/// The borrowed-job trait object a [`ThreadPool::run`] call shares with
/// its workers. The `'static` is a lie told under supervision: `run`
/// erases the caller's lifetime but does not return until every chunk
/// has finished executing, so the borrow strictly outlives all uses.
type Task = dyn Fn(usize) + Sync;

#[derive(Default)]
struct PoolState {
    /// Current job, present from `run`'s submission until its last
    /// chunk completes (the completion signal `run` waits on).
    job: Option<&'static Task>,
    /// Chunks in the current job.
    n_chunks: usize,
    /// Next unclaimed chunk index (workers and the caller both pull).
    next: usize,
    /// Chunks that finished executing.
    done: usize,
    /// First panic payload out of any chunk, re-thrown by `run`.
    panic: Option<Box<dyn std::any::Any + Send>>,
    /// Tells workers to exit (set once, by `Drop`).
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work: Condvar,
    /// The submitting caller parks here until `done == n_chunks`.
    idle: Condvar,
}

/// A persistent pool of parked worker threads executing borrowed
/// chunk-indexed jobs ([`ThreadPool::run`]). Unlike [`parallel_ranges`]
/// — which spawns fresh OS threads per call — submission costs one
/// mutex/condvar round-trip, so it is usable inside per-pass hot loops.
/// Chunks are pulled dynamically, but correctness never depends on the
/// chunk-to-worker assignment: callers hand each chunk disjoint output
/// state, so results are deterministic regardless of scheduling.
///
/// The *submitting thread participates*: a pool built with `workers`
/// OS threads executes a job on up to `workers + 1` cores.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
    /// Jobs submitted over the pool's lifetime (telemetry for tests).
    jobs: AtomicUsize,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool")
            .field("workers", &self.handles.len())
            .field("jobs", &self.jobs.load(Ordering::Relaxed))
            .finish()
    }
}

impl ThreadPool {
    /// Spawn `workers` parked OS threads (0 is valid: every job then
    /// runs entirely on the submitting thread).
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState::default()),
            work: Condvar::new(),
            idle: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        Self { shared, handles, jobs: AtomicUsize::new(0) }
    }

    /// OS worker threads owned by the pool (the submitting caller adds
    /// one more execution lane on top).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Jobs executed so far (telemetry; used by tests to pin reuse).
    pub fn jobs_run(&self) -> usize {
        self.jobs.load(Ordering::Relaxed)
    }

    /// Execute `f(0), f(1), ..., f(chunks - 1)` across the workers and
    /// the calling thread; blocks until every chunk has finished. `f`
    /// is shared by reference — chunks must write only disjoint state.
    /// If any chunk panics the panic is re-thrown here (after all other
    /// chunks completed), leaving the pool reusable.
    pub fn run(&self, chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        if chunks == 0 {
            return;
        }
        self.jobs.fetch_add(1, Ordering::Relaxed);
        // Safety: see `Task` — the erased borrow outlives all uses
        // because this function only returns after `done == n_chunks`.
        let job: &'static Task = unsafe { std::mem::transmute::<&Task, &'static Task>(f) };
        {
            let mut st = self.shared.state.lock().unwrap();
            assert!(st.job.is_none(), "ThreadPool::run is not reentrant");
            st.job = Some(job);
            st.n_chunks = chunks;
            st.next = 0;
            st.done = 0;
        }
        self.shared.work.notify_all();
        // The caller pulls chunks too, then waits out stragglers.
        loop {
            let idx = {
                let mut st = self.shared.state.lock().unwrap();
                if st.next < st.n_chunks {
                    st.next += 1;
                    Some(st.next - 1)
                } else {
                    None
                }
            };
            let Some(idx) = idx else { break };
            run_chunk(&self.shared, job, idx);
        }
        let mut st = self.shared.state.lock().unwrap();
        while st.job.is_some() {
            st = self.shared.idle.wait(st).unwrap();
        }
        if let Some(payload) = st.panic.take() {
            drop(st);
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Execute one chunk, then publish completion. Panics are captured so
/// the job's completion accounting (and `run`'s borrowed closure) stay
/// sound even when a chunk dies mid-job.
fn run_chunk(shared: &PoolShared, job: &'static Task, idx: usize) {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(idx)));
    let mut st = shared.state.lock().unwrap();
    if let Err(payload) = result {
        st.panic.get_or_insert(payload);
    }
    st.done += 1;
    if st.done == st.n_chunks {
        st.job = None;
        shared.idle.notify_all();
    }
}

fn worker_loop(shared: &PoolShared) {
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        match st.job {
            Some(job) if st.next < st.n_chunks => {
                st.next += 1;
                let idx = st.next - 1;
                drop(st);
                run_chunk(shared, job, idx);
                st = shared.state.lock().unwrap();
            }
            _ => st = shared.work.wait(st).unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_full_range_once() {
        let hits = AtomicUsize::new(0);
        parallel_ranges(1000, 4, |_, lo, hi| {
            hits.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn single_thread_fallback() {
        let hits = AtomicUsize::new(0);
        parallel_ranges(10, 1, |t, lo, hi| {
            assert_eq!((t, lo, hi), (0, 0, 10));
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn map_matches_serial() {
        let got = parallel_map(97, 3, |i| i * i);
        let want: Vec<usize> = (0..97).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn empty_range_is_fine() {
        parallel_ranges(0, 4, |_, _, _| panic!("must not be called"));
        assert!(parallel_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn pool_executes_every_chunk_exactly_once() {
        let pool = ThreadPool::new(3);
        for chunks in [1usize, 2, 3, 4, 17, 100] {
            let hits: Vec<AtomicUsize> = (0..chunks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(chunks, &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "chunk {i} of {chunks}");
            }
        }
        assert_eq!(pool.jobs_run(), 6);
        assert_eq!(pool.workers(), 3);
    }

    #[test]
    fn pool_with_zero_workers_runs_on_caller() {
        let pool = ThreadPool::new(0);
        let me = std::thread::current().id();
        let sum = AtomicUsize::new(0);
        pool.run(8, &|i| {
            assert_eq!(std::thread::current().id(), me);
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 28);
    }

    #[test]
    fn pool_writes_borrowed_disjoint_state() {
        // The exact usage pattern the engine relies on: chunks mutate
        // disjoint slices of caller-owned (stack-borrowed) memory.
        let pool = ThreadPool::new(2);
        let mut out = vec![0usize; 64];
        let slots: Vec<std::sync::Mutex<&mut [usize]>> =
            out.chunks_mut(16).map(std::sync::Mutex::new).collect();
        pool.run(slots.len(), &|ci| {
            for (i, v) in slots[ci].lock().unwrap().iter_mut().enumerate() {
                *v = ci * 16 + i;
            }
        });
        drop(slots); // release the chunk borrows before reading `out`
        let want: Vec<usize> = (0..64).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn pool_is_reusable_and_zero_chunks_is_a_noop() {
        let pool = ThreadPool::new(1);
        pool.run(0, &|_| panic!("must not be called"));
        assert_eq!(pool.jobs_run(), 0);
        for round in 1..20usize {
            let total = AtomicUsize::new(0);
            pool.run(round, &|_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(total.load(Ordering::SeqCst), round);
        }
    }

    #[test]
    fn pool_propagates_chunk_panics_and_survives() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|i| {
                if i == 2 {
                    panic!("chunk 2 exploded");
                }
            });
        }));
        let msg = *caught.unwrap_err().downcast::<&str>().unwrap();
        assert!(msg.contains("exploded"), "{msg}");
        // Pool must remain usable after a panicked job.
        let ok = AtomicUsize::new(0);
        pool.run(3, &|_| {
            ok.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 3);
    }
}
