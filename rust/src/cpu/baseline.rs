//! The CPU IOM baseline: TCONV = blocked GEMM + col2im, exactly what the
//! paper's dual-thread ARM-Neon TFLite baseline does algorithmically.
//!
//! Bit-exact int8 path (int32 accumulate, TFLite fixed-point requantize)
//! plus an f32 path for PJRT cross-validation. `threads = 2` is the
//! paper's "CPU 2T" configuration.

use crate::cpu::gemm;
use crate::tconv::maps::OutputMap;
use crate::tconv::problem::TconvProblem;
use crate::tensor::quant::PerChannel;
use crate::tensor::Tensor;

/// Pack OHWI weights [Oc,Ks,Ks,Ic] into the Eq.-2 W_T matrix [K, N] with
/// N ordered (kh, kw, oc) — matches `ref.py::weight_matrix`.
pub fn pack_weight_matrix_i8(p: &TconvProblem, w: &Tensor<i8>) -> Vec<i8> {
    let (k, n) = (p.k(), p.n());
    let mut wm = vec![0i8; k * n];
    for kh in 0..p.ks {
        for kw in 0..p.ks {
            for oc in 0..p.oc {
                let col = (kh * p.ks + kw) * p.oc + oc;
                for c in 0..k {
                    wm[c * n + col] = w.at4(oc, kh, kw, c);
                }
            }
        }
    }
    wm
}

/// f32 twin of [`pack_weight_matrix_i8`] (PJRT cross-validation path).
pub fn pack_weight_matrix_f32(p: &TconvProblem, w: &Tensor<f32>) -> Vec<f32> {
    let (k, n) = (p.k(), p.n());
    let mut wm = vec![0f32; k * n];
    for kh in 0..p.ks {
        for kw in 0..p.ks {
            for oc in 0..p.oc {
                let col = (kh * p.ks + kw) * p.oc + oc;
                for c in 0..k {
                    wm[c * n + col] = w.at4(oc, kh, kw, c);
                }
            }
        }
    }
    wm
}

/// int8 IOM TCONV returning raw int32 accumulators (+bias).
pub fn tconv_i32(
    p: &TconvProblem,
    x: &Tensor<i8>,
    w: &Tensor<i8>,
    bias: Option<&[i32]>,
    threads: usize,
) -> Tensor<i32> {
    let wm = pack_weight_matrix_i8(p, w);
    tconv_i32_prepacked(p, x, &wm, bias, threads)
}

/// Same, with a caller-prepacked weight matrix (the model executor packs
/// once per layer, as TFLite does at Prepare() time).
pub fn tconv_i32_prepacked(
    p: &TconvProblem,
    x: &Tensor<i8>,
    wm: &[i8],
    bias: Option<&[i32]>,
    threads: usize,
) -> Tensor<i32> {
    let (m, n) = (p.m(), p.n());
    assert_eq!(x.shape(), &[p.ih, p.iw, p.ic]);
    assert_eq!(wm.len(), p.k() * n);

    // MatMul: partials[M, N].
    let mut partials = vec![0i32; m * n];
    gemm::gemm_i8_i32(m, n, p.k(), x.data(), wm, &mut partials, threads);

    // col2im: accumulate survivors into the output; threads split M rows
    // with per-thread output replicas merged at the end (the overlapping-
    // sum problem makes in-place parallel accumulation racy).
    let map = OutputMap::build(p);
    let out_len = p.output_elems();
    let mut out = Tensor::<i32>::zeros(&[p.oh(), p.ow(), p.oc]);
    if threads <= 1 {
        col2im_rows(p, &map, &partials, 0, m, out.data_mut());
    } else {
        let t = threads.min(m.max(1));
        let mut replicas: Vec<Vec<i32>> = (0..t).map(|_| vec![0i32; out_len]).collect();
        let chunk = m.div_ceil(t);
        std::thread::scope(|scope| {
            for (ti, replica) in replicas.iter_mut().enumerate() {
                let lo = ti * chunk;
                let hi = ((ti + 1) * chunk).min(m);
                if lo >= hi {
                    break;
                }
                let (map, partials) = (&map, &partials);
                scope.spawn(move || col2im_rows(p, map, partials, lo, hi, replica));
            }
        });
        let od = out.data_mut();
        for replica in &replicas {
            for (o, r) in od.iter_mut().zip(replica) {
                *o += r;
            }
        }
    }

    if let Some(b) = bias {
        assert_eq!(b.len(), p.oc);
        let od = out.data_mut();
        for px in 0..p.oh() * p.ow() {
            for oc in 0..p.oc {
                od[px * p.oc + oc] += b[oc];
            }
        }
    }
    out
}

fn col2im_rows(
    p: &TconvProblem,
    map: &OutputMap,
    partials: &[i32],
    row_lo: usize,
    row_hi: usize,
    out: &mut [i32],
) {
    let n = p.n();
    let oc = p.oc;
    for row in row_lo..row_hi {
        let prow = &partials[row * n..(row + 1) * n];
        for e in map.row(row) {
            let src = e.col as usize * oc;
            let dst = e.out as usize * oc;
            for c in 0..oc {
                out[dst + c] += prow[src + c];
            }
        }
    }
}

/// Full quantized layer: int8 in -> int8 out via per-channel requantize.
/// `zp_in` is subtracted on the fly by folding it into the bias
/// (sum-of-weights trick, like TFLite).
pub fn tconv_quantized(
    p: &TconvProblem,
    x: &Tensor<i8>,
    w: &Tensor<i8>,
    bias: &[i32],
    zp_in: i32,
    requant: &PerChannel,
    threads: usize,
) -> Tensor<i8> {
    // Fold input zero-point: acc = sum((x - zp) * w) = sum(x*w) - zp*sum(w)
    // per (output pixel, oc): zp correction depends on which taps survive
    // for that output, so compute correction per output pixel from the map.
    let raw = tconv_i32(p, x, w, Some(bias), threads);
    let mut corr = vec![0i32; p.output_elems()];
    if zp_in != 0 {
        // weight tap sums per (oc, kh, kw)
        let mut tap_sums = vec![0i32; p.oc * p.ks * p.ks];
        for oc in 0..p.oc {
            for kh in 0..p.ks {
                for kw in 0..p.ks {
                    let mut s = 0i32;
                    for c in 0..p.ic {
                        s += w.at4(oc, kh, kw, c) as i32;
                    }
                    tap_sums[(oc * p.ks + kh) * p.ks + kw] = s;
                }
            }
        }
        let map = OutputMap::build(p);
        for row in 0..p.m() {
            for e in map.row(row) {
                let kh = e.col as usize / p.ks;
                let kw = e.col as usize % p.ks;
                for oc in 0..p.oc {
                    corr[e.out as usize * p.oc + oc] +=
                        zp_in * tap_sums[(oc * p.ks + kh) * p.ks + kw];
                }
            }
        }
    }
    let mut out = Tensor::<i8>::zeros(&[p.oh(), p.ow(), p.oc]);
    let od = out.data_mut();
    let rd = raw.data();
    // Requant is cheap; do it serially (measured negligible vs GEMM).
    for px in 0..p.oh() * p.ow() {
        for oc in 0..p.oc {
            let acc = rd[px * p.oc + oc] - corr[px * p.oc + oc];
            od[px * p.oc + oc] = requant.requantize(acc, oc);
        }
    }
    out
}

/// f32 IOM TCONV (for PJRT artifact cross-validation).
pub fn tconv_f32(
    p: &TconvProblem,
    x: &Tensor<f32>,
    w: &Tensor<f32>,
    bias: Option<&[f32]>,
    threads: usize,
) -> Tensor<f32> {
    let wm = pack_weight_matrix_f32(p, w);
    let (m, n) = (p.m(), p.n());
    let mut partials = vec![0f32; m * n];
    gemm::gemm_f32(m, n, p.k(), x.data(), &wm, &mut partials, threads);
    let map = OutputMap::build(p);
    let mut out = Tensor::<f32>::zeros(&[p.oh(), p.ow(), p.oc]);
    let od = out.data_mut();
    for row in 0..m {
        let prow = &partials[row * n..(row + 1) * n];
        for e in map.row(row) {
            let src = e.col as usize * p.oc;
            let dst = e.out as usize * p.oc;
            for c in 0..p.oc {
                od[dst + c] += prow[src + c];
            }
        }
    }
    if let Some(b) = bias {
        for px in 0..p.oh() * p.ow() {
            for oc in 0..p.oc {
                od[px * p.oc + oc] += b[oc];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tconv::reference;
    use crate::tensor::quant::{PerChannel, QuantParams};
    use crate::util::rng::Pcg32;

    fn problems() -> Vec<TconvProblem> {
        vec![
            TconvProblem::new(2, 2, 2, 3, 2, 1),
            TconvProblem::new(7, 7, 32, 5, 16, 2),
            TconvProblem::new(5, 3, 8, 3, 4, 2),
            TconvProblem::new(4, 4, 4, 2, 4, 2),
            TconvProblem::new(3, 3, 4, 2, 4, 3),
            TconvProblem::new(1, 1, 21, 4, 21, 4),
        ]
    }

    #[test]
    fn i32_matches_direct_reference_all_threads() {
        for p in problems() {
            let mut rng = Pcg32::new(17);
            let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
            let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
            let bias: Vec<i32> = (0..p.oc).map(|i| (i as i32 - 3) * 11).collect();
            let want = reference::direct_i32(&p, &x, &w, Some(&bias));
            for threads in [1, 2, 4] {
                let got = tconv_i32(&p, &x, &w, Some(&bias), threads);
                assert_eq!(got.data(), want.data(), "{p} threads={threads}");
            }
        }
    }

    #[test]
    fn f32_matches_direct_reference() {
        for p in problems() {
            let mut rng = Pcg32::new(23);
            let x = Tensor::random_normal(&[p.ih, p.iw, p.ic], 1.0, &mut rng);
            let w = Tensor::random_normal(&[p.oc, p.ks, p.ks, p.ic], 1.0, &mut rng);
            let b: Vec<f32> = (0..p.oc).map(|_| rng.normal()).collect();
            let want = reference::direct_f32(&p, &x, &w, Some(&b));
            for threads in [1, 2] {
                let got = tconv_f32(&p, &x, &w, Some(&b), threads);
                assert!(got.max_abs_diff(&want) < 1e-3, "{p} threads={threads}");
            }
        }
    }

    #[test]
    fn quantized_layer_tracks_float_within_tolerance() {
        let p = TconvProblem::new(5, 5, 16, 5, 8, 2);
        let mut rng = Pcg32::new(31);
        let xf = Tensor::random_normal(&[p.ih, p.iw, p.ic], 0.5, &mut rng);
        let wf = Tensor::random_normal(&[p.oc, p.ks, p.ks, p.ic], 0.05, &mut rng);

        let in_q = QuantParams::from_range(-2.0, 2.0);
        let w_q = QuantParams::symmetric(0.2);
        let x: Tensor<i8> = Tensor::from_vec(
            &[p.ih, p.iw, p.ic],
            in_q.quantize_slice(xf.data()),
        );
        let w: Tensor<i8> = Tensor::from_vec(
            &[p.oc, p.ks, p.ks, p.ic],
            w_q.quantize_slice(wf.data()),
        );
        // float output range drives output quant
        let want_f = reference::direct_f32(
            &p,
            &Tensor::from_vec(&[p.ih, p.iw, p.ic], in_q.dequantize_slice(x.data())),
            &Tensor::from_vec(&[p.oc, p.ks, p.ks, p.ic], w_q.dequantize_slice(w.data())),
            None,
        );
        let lo = want_f.data().iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = want_f.data().iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let out_q = QuantParams::from_range(lo, hi);
        let requant = PerChannel::new(in_q.scale, &vec![w_q.scale; p.oc], out_q);
        let bias = vec![0i32; p.oc];

        let got = tconv_quantized(&p, &x, &w, &bias, in_q.zero_point, &requant, 2);
        for (g, wf) in got.data().iter().zip(want_f.data()) {
            let gf = out_q.dequantize(*g);
            assert!(
                (gf - wf).abs() <= 3.0 * out_q.scale + 1e-4,
                "got {gf} want {wf} (scale {})",
                out_q.scale
            );
        }
    }

    #[test]
    fn quantized_zero_point_fold_exact() {
        // With zp_in != 0 the folded correction must equal literally
        // subtracting zp from x before the int32 reference.
        let p = TconvProblem::new(4, 4, 8, 3, 4, 2);
        let mut rng = Pcg32::new(41);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let zp_in = 7i32;
        // reference: x - zp as i32 tconv
        let xs: Vec<i32> = x.data().iter().map(|&v| v as i32 - zp_in).collect();
        let mut want = Tensor::<i32>::zeros(&[p.oh(), p.ow(), p.oc]);
        {
            let wd = want.data_mut();
            let map = OutputMap::build(&p);
            for row in 0..p.m() {
                for e in map.row(row) {
                    let kh = e.col as usize / p.ks;
                    let kw = e.col as usize % p.ks;
                    for oc in 0..p.oc {
                        let mut acc = 0i32;
                        for c in 0..p.ic {
                            acc += xs[row * p.ic + c] * w.at4(oc, kh, kw, c) as i32;
                        }
                        wd[e.out as usize * p.oc + oc] += acc;
                    }
                }
            }
        }
        let out_q = QuantParams { scale: 0.25, zero_point: 0 };
        let requant = PerChannel::new(1.0, &vec![1.0; p.oc], out_q);
        let got = tconv_quantized(&p, &x, &w, &vec![0; p.oc], zp_in, &requant, 1);
        // compare via the same requant of the reference accumulators
        for (i, &acc) in want.data().iter().enumerate() {
            let oc = i % p.oc;
            assert_eq!(got.data()[i], requant.requantize(acc, oc), "i={i}");
        }
    }
}
