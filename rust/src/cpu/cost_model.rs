//! Calibrated ARM Cortex-A9 (PYNQ-Z1) cost model for the CPU baseline.
//!
//! The paper's speedups compare the accelerator against TFLite's
//! NEON-optimized int8 TCONV on the board's dual-core 650 MHz Cortex-A9.
//! We don't have that board, so CPU latencies are *modeled*:
//!
//! ```text
//! t = partials * (K / MACS_PER_CYCLE + COL2IM_OVERHEAD) / freq / eff(T)
//! ```
//!
//! where `partials = M*N` (the CPU IOM baseline computes and stores every
//! partial — it cannot skip cropped outputs) and the two constants were
//! fitted against the paper's own Table II CPU column (single-thread):
//!
//! | layer    | paper CPU ms | model ms |
//! |----------|--------------|----------|
//! | DCGAN_1  | 166.56       | ~163     |
//! | DCGAN_4  | 10.71        | ~11.1    |
//! | StyleT_2 | 460.23       | ~460     |
//! | StyleT_3 | 1045.36      | ~1170    |
//! | FSRCNN   | 12.47        | ~12.2    |
//!
//! Fit: MACS_PER_CYCLE = 2.07 (TFLite NEON int8 efficiency on A9),
//! COL2IM_OVERHEAD = 32.4 cycles/partial (store + later accumulate +
//! requant + loop overhead). MAPE over all 9 Table II layers ≈ 12%.

use crate::tconv::problem::TconvProblem;

/// 650 MHz Cortex-A9 (PYNQ-Z1 PS clock).
pub const A9_FREQ_HZ: f64 = 650.0e6;
/// Effective NEON int8 MACs per cycle per core (fitted; ideal is 8).
pub const MACS_PER_CYCLE: f64 = 2.07;
/// Per-partial col2im/bookkeeping cycles (fitted).
pub const COL2IM_OVERHEAD_CYCLES: f64 = 32.4;
/// Dual-thread scaling (Table IV shows 1.6–1.8x; memory-bound col2im
/// limits it below 2).
pub const TWO_THREAD_SPEEDUP: f64 = 1.75;
/// Fixed per-layer TFLite invoke overhead (op dispatch, tensor prep).
/// Anchor: the FCN layer in Table II (14K OPs) measures 0.22 ms on both
/// CPU and accelerator — almost pure overhead on either side.
pub const CPU_LAYER_OVERHEAD_S: f64 = 200e-6;

/// Modeled seconds for the CPU IOM TCONV baseline with `threads` (1 or 2).
pub fn tconv_seconds(p: &TconvProblem, threads: usize) -> f64 {
    let partials = p.p_outs() as f64;
    let cycles = partials * (p.k() as f64 / MACS_PER_CYCLE + COL2IM_OVERHEAD_CYCLES);
    let t1 = cycles / A9_FREQ_HZ;
    CPU_LAYER_OVERHEAD_S
        + match threads {
            0 | 1 => t1,
            2 => t1 / TWO_THREAD_SPEEDUP,
            t => t1 / (TWO_THREAD_SPEEDUP * (t as f64 / 2.0).sqrt()), // not used by the paper
        }
}

/// Modeled seconds for a standard convolution layer on the A9 (used for
/// the non-TCONV layers of the end-to-end GAN runs, Table IV).
/// Same NEON GEMM core; im2col instead of col2im on the input side.
pub fn conv_seconds(macs: u64, outputs: u64, threads: usize) -> f64 {
    let cycles = macs as f64 / MACS_PER_CYCLE + outputs as f64 * 12.0;
    let t1 = cycles / A9_FREQ_HZ;
    match threads {
        0 | 1 => t1,
        2 => t1 / TWO_THREAD_SPEEDUP,
        t => t1 / (TWO_THREAD_SPEEDUP * (t as f64 / 2.0).sqrt()),
    }
}

/// Modeled seconds for cheap elementwise layers (activations, quantize).
pub fn elementwise_seconds(elems: u64, threads: usize) -> f64 {
    let cycles = elems as f64 * 4.0;
    let t1 = cycles / A9_FREQ_HZ;
    if threads >= 2 {
        t1 / 1.6
    } else {
        t1
    }
}

/// Active power draw of the A9 complex (W). Used by the energy model.
/// PYNQ-Z1 PS measurements: ~1.25 W one core busy, ~1.9 W both.
pub fn cpu_power_w(threads: usize) -> f64 {
    match threads {
        0 | 1 => 1.25,
        _ => 1.90,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table II CPU column (single-thread), within fit tolerance.
    #[test]
    fn table2_cpu_latencies_within_fit_band() {
        let cases = [
            (TconvProblem::square(4, 1024, 5, 512, 2), 166.56),
            (TconvProblem::square(8, 512, 5, 256, 2), 141.05),
            (TconvProblem::square(16, 256, 5, 128, 2), 149.70),
            (TconvProblem::square(32, 128, 5, 3, 2), 10.71),
            (TconvProblem::square(64, 128, 3, 64, 2), 304.48),
            (TconvProblem::square(128, 64, 3, 32, 2), 460.23),
            (TconvProblem::square(256, 32, 9, 3, 2), 1045.36),
            (TconvProblem::square(32, 32, 9, 2, 2), 12.47),
        ];
        let mut errs = Vec::new();
        for (p, paper_ms) in cases {
            let model_ms = tconv_seconds(&p, 1) * 1e3;
            let err = (model_ms - paper_ms).abs() / paper_ms;
            errs.push(err);
            assert!(err < 0.45, "{p}: model {model_ms:.1}ms vs paper {paper_ms}ms");
        }
        let mape = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mape < 0.20, "MAPE {mape}");
    }

    #[test]
    fn two_threads_faster_but_sublinear() {
        let p = TconvProblem::square(16, 256, 5, 128, 2);
        let t1 = tconv_seconds(&p, 1);
        let t2 = tconv_seconds(&p, 2);
        assert!(t2 < t1);
        assert!(t1 / t2 > 1.5 && t1 / t2 < 2.0);
    }

    #[test]
    fn monotone_in_problem_size() {
        let small = tconv_seconds(&TconvProblem::square(7, 32, 3, 16, 1), 1);
        let large = tconv_seconds(&TconvProblem::square(11, 256, 7, 64, 1), 1);
        assert!(large > small * 10.0);
    }

    #[test]
    fn power_sane() {
        assert!(cpu_power_w(1) < cpu_power_w(2));
        assert!(cpu_power_w(2) < 3.0);
    }
}
