//! Blocked multi-threaded GEMM kernels (int8 -> int32 and f32).
//!
//! This is the MatMul half of the CPU IOM baseline (Eq. 2) — the stand-in
//! for TFLite's NEON-optimized quantized kernels. The layout is classic
//! L1-blocked row-major GEMM with a K-unrolled inner loop; threads split M.
//! Hot path of the §Perf pass (see `rust/benches/hotpath_micro.rs`).

/// C[M,N] (i32) = A[M,K] (i8) * B[K,N] (i8), C preinitialized by caller.
/// `threads` splits rows of A; 0 or 1 means single-threaded.
pub fn gemm_i8_i32(m: usize, n: usize, k: usize, a: &[i8], b: &[i8], c: &mut [i32], threads: usize) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    let threads = threads.max(1).min(m.max(1));
    if threads <= 1 {
        gemm_i8_rows(n, k, a, b, c);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut c_rest = c;
        let mut a_rest = a;
        for _ in 0..threads {
            let take = rows_per.min(a_rest.len() / k);
            if take == 0 {
                break;
            }
            let (a_chunk, a_next) = a_rest.split_at(take * k);
            let (c_chunk, c_next) = c_rest.split_at_mut(take * n);
            a_rest = a_next;
            c_rest = c_next;
            scope.spawn(move || gemm_i8_rows(n, k, a_chunk, b, c_chunk));
        }
    });
}

/// Single-threaded core: rows of A against all of B.
///
/// i-k-j loop order: for each (row, kk) the B row is streamed
/// contiguously and the C row stays hot — the inner loop is a
/// scalar-times-vector saxpy over i8 that LLVM auto-vectorizes (widening
/// i8 -> i32 multiplies). Measured ~6x over the previous column-strided
/// dot-product formulation on this host (EXPERIMENTS.md §Perf).
fn gemm_i8_rows(n: usize, k: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
    let m = a.len() / k;
    for row in 0..m {
        let arow = &a[row * k..(row + 1) * k];
        let crow = &mut c[row * n..(row + 1) * n];
        let mut kk = 0;
        // Unroll K by 4: four B rows per pass amortizes the C-row traffic.
        while kk + 4 <= k {
            let av0 = arow[kk] as i32;
            let av1 = arow[kk + 1] as i32;
            let av2 = arow[kk + 2] as i32;
            let av3 = arow[kk + 3] as i32;
            let b0 = &b[kk * n..(kk + 1) * n];
            let b1 = &b[(kk + 1) * n..(kk + 2) * n];
            let b2 = &b[(kk + 2) * n..(kk + 3) * n];
            let b3 = &b[(kk + 3) * n..(kk + 4) * n];
            for i in 0..n {
                crow[i] += av0 * b0[i] as i32
                    + av1 * b1[i] as i32
                    + av2 * b2[i] as i32
                    + av3 * b3[i] as i32;
            }
            kk += 4;
        }
        while kk < k {
            let av = arow[kk] as i32;
            if av != 0 {
                let brow = &b[kk * n..(kk + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv as i32;
                }
            }
            kk += 1;
        }
    }
}

/// C[M,N] += A[M,K] · B[N,K]ᵀ — both operands K-contiguous ("NT"
/// layout), single-threaded, C preinitialized by the caller.
///
/// The fused accelerator engine's microkernel (`accel::engine`): A is a
/// contiguous run of input pixels `[taps, Ic]`, B a packed block of
/// per-PM filter columns `[X, Ic]`, C the `[tap, pm]` partial-product
/// block the col2IM scatter consumes. 2x2 register blocking: four dot
/// products share every A/B element load, halving memory traffic
/// against the per-tap scalar dots it replaces, and the four
/// independent accumulator chains give the auto-vectorizer parallel
/// widening i8 -> i32 reductions to work with.
pub fn gemm_i8_i32_nt(m: usize, n: usize, k: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), n * k, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    let mut i = 0;
    while i + 2 <= m {
        let a0 = &a[i * k..(i + 1) * k];
        let a1 = &a[(i + 1) * k..(i + 2) * k];
        let mut j = 0;
        while j + 2 <= n {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let (mut s00, mut s01, mut s10, mut s11) = (0i32, 0i32, 0i32, 0i32);
            for l in 0..k {
                let (x0, x1) = (a0[l] as i32, a1[l] as i32);
                let (w0, w1) = (b0[l] as i32, b1[l] as i32);
                s00 += x0 * w0;
                s01 += x0 * w1;
                s10 += x1 * w0;
                s11 += x1 * w1;
            }
            c[i * n + j] += s00;
            c[i * n + j + 1] += s01;
            c[(i + 1) * n + j] += s10;
            c[(i + 1) * n + j + 1] += s11;
            j += 2;
        }
        if j < n {
            let bj = &b[j * k..(j + 1) * k];
            let (mut s0, mut s1) = (0i32, 0i32);
            for l in 0..k {
                let w = bj[l] as i32;
                s0 += a0[l] as i32 * w;
                s1 += a1[l] as i32 * w;
            }
            c[i * n + j] += s0;
            c[(i + 1) * n + j] += s1;
        }
        i += 2;
    }
    if i < m {
        let a0 = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let bj = &b[j * k..(j + 1) * k];
            let s: i32 = a0.iter().zip(bj).map(|(&x, &w)| x as i32 * w as i32).sum();
            c[i * n + j] += s;
        }
    }
}

/// C[M,N] = A[M,K] * B[K,N], f32, threads split M.
pub fn gemm_f32(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32], threads: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let threads = threads.max(1).min(m.max(1));
    if threads <= 1 {
        gemm_f32_rows(n, k, a, b, c);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut c_rest = c;
        let mut a_rest = a;
        for _ in 0..threads {
            let take = rows_per.min(a_rest.len() / k);
            if take == 0 {
                break;
            }
            let (a_chunk, a_next) = a_rest.split_at(take * k);
            let (c_chunk, c_next) = c_rest.split_at_mut(take * n);
            a_rest = a_next;
            c_rest = c_next;
            scope.spawn(move || gemm_f32_rows(n, k, a_chunk, b, c_chunk));
        }
    });
}

fn gemm_f32_rows(n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    let m = a.len() / k;
    // i-k-j loop order: stream B rows, accumulate into the C row — auto-
    // vectorizes on the j loop.
    for row in 0..m {
        let arow = &a[row * k..(row + 1) * k];
        let crow = &mut c[row * n..(row + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn naive_i32(m: usize, n: usize, k: usize, a: &[i8], b: &[i8]) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                for l in 0..k {
                    c[i * n + j] += a[i * k + l] as i32 * b[l * n + j] as i32;
                }
            }
        }
        c
    }

    #[test]
    fn i8_matches_naive_odd_shapes() {
        let mut rng = Pcg32::new(1);
        for (m, n, k) in [(1, 1, 1), (3, 5, 7), (17, 9, 13), (4, 64, 3), (8, 130, 33)] {
            let mut a = vec![0i8; m * k];
            let mut b = vec![0i8; k * n];
            rng.fill_i8(&mut a);
            rng.fill_i8(&mut b);
            let want = naive_i32(m, n, k, &a, &b);
            for threads in [1, 2, 4] {
                let mut c = vec![0i32; m * n];
                gemm_i8_i32(m, n, k, &a, &b, &mut c, threads);
                assert_eq!(c, want, "m={m} n={n} k={k} threads={threads}");
            }
        }
    }

    /// The NT microkernel must agree with the naive kernel under a
    /// transposed-B view, across odd shapes that hit every blocking
    /// tail (m odd, n odd, both, k not a multiple of the unroll).
    #[test]
    fn nt_matches_naive_transposed_all_tails() {
        let mut rng = Pcg32::new(7);
        for (m, n, k) in [
            (1, 1, 1),
            (1, 8, 17),
            (2, 2, 4),
            (3, 5, 7),
            (5, 8, 33),
            (7, 3, 256),
            (9, 8, 512),
            (4, 7, 128),
        ] {
            let mut a = vec![0i8; m * k];
            let mut bt = vec![0i8; n * k]; // B[N,K] row-major == Bᵀ
            rng.fill_i8(&mut a);
            rng.fill_i8(&mut bt);
            // Naive expects B[K,N]: transpose the NT operand.
            let mut b = vec![0i8; k * n];
            for j in 0..n {
                for l in 0..k {
                    b[l * n + j] = bt[j * k + l];
                }
            }
            let want = naive_i32(m, n, k, &a, &b);
            let mut c = vec![3i32; m * n]; // accumulates into existing C
            gemm_i8_i32_nt(m, n, k, &a, &bt, &mut c);
            let got: Vec<i32> = c.iter().map(|v| v - 3).collect();
            assert_eq!(got, want, "m={m} n={n} k={k}");
        }
    }

    #[test]
    fn i8_accumulates_into_existing_c() {
        let a = vec![1i8; 4];
        let b = vec![1i8; 4];
        let mut c = vec![100i32; 4];
        gemm_i8_i32(2, 2, 2, &a, &b, &mut c, 1);
        assert_eq!(c, vec![102; 4]);
    }

    #[test]
    fn f32_matches_naive() {
        let mut rng = Pcg32::new(2);
        for (m, n, k) in [(3, 4, 5), (16, 16, 16), (7, 33, 12)] {
            let mut a = vec![0f32; m * k];
            let mut b = vec![0f32; k * n];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            let mut want = vec![0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    for l in 0..k {
                        want[i * n + j] += a[i * k + l] * b[l * n + j];
                    }
                }
            }
            for threads in [1, 2] {
                let mut c = vec![0f32; m * n];
                gemm_f32(m, n, k, &a, &b, &mut c, threads);
                for (g, w) in c.iter().zip(&want) {
                    assert!((g - w).abs() < 1e-3, "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn extreme_values_do_not_overflow_i32() {
        // K up to 4096 at |a*b| <= 128*128 stays well inside i32.
        let k = 4096;
        let a = vec![-128i8; k];
        let b = vec![-128i8; k];
        let mut c = vec![0i32; 1];
        gemm_i8_i32(1, 1, k, &a, &b, &mut c, 1);
        assert_eq!(c[0], 128 * 128 * k as i32);
    }

    #[test]
    fn more_threads_than_rows() {
        let a = vec![1i8; 2 * 3];
        let b = vec![2i8; 3 * 2];
        let mut c = vec![0i32; 4];
        gemm_i8_i32(2, 2, 3, &a, &b, &mut c, 16);
        assert_eq!(c, vec![6; 4]);
    }
}
