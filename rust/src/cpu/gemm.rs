//! Blocked multi-threaded GEMM kernels (int8 -> int32 and f32), with
//! explicit SIMD paths for the NT microkernel.
//!
//! This is the MatMul half of the CPU IOM baseline (Eq. 2) — the stand-in
//! for TFLite's NEON-optimized quantized kernels — and, through
//! [`gemm_i8_i32_nt`], the serving hot loop of the fused accelerator
//! engine (`accel::engine`). The NN kernels are classic L1-blocked
//! row-major GEMM with a K-unrolled inner loop; threads split M.
//!
//! # NT kernel dispatch
//!
//! [`gemm_i8_i32_nt`] dispatches to one of several [`GemmKernel`]s:
//!
//! * [`GemmKernel::Scalar`] — the register-blocked scalar microkernel,
//!   retained verbatim as the **differential oracle** every SIMD path is
//!   fuzzed against (`rust/tests/gemm_kernels.rs`).
//! * [`GemmKernel::Avx2`] (x86_64) — 16-lane widening MAC:
//!   `i8 -> i16` sign extension + `_mm256_madd_epi16` pair-dot into i32
//!   accumulators. (The `_mm256_maddubs_epi16` u8×i8 trick saves the
//!   extension step but saturates at i16; the sign-extended form is
//!   exact by construction, which is what the oracle contract demands.)
//! * [`GemmKernel::Neon`] / [`GemmKernel::NeonDot`] (aarch64) —
//!   `vmull_s8` widening multiplies folded with `vpadalq_s16`, or the
//!   `vdotq_s32` four-way dot product where the `dotprod` extension is
//!   detected.
//!
//! The CPU is probed once ([`detect_kernel`]); the choice can be forced
//! via the [`GEMM_KERNEL_ENV`] environment variable (read once, at first
//! dispatch) or programmatically with [`force_nt_kernel`] — both exist
//! so CI can drive the scalar oracle and the SIMD paths independently.
//!
//! **Exactness**: every path computes the same i32 sums, merely
//! reassociated. i32 addition is associative/commutative and each
//! product is bounded by 2^14, so results are bit-identical for any
//! k <= 2^17 — far above the deepest layer in the zoo (Ic = 1024) and
//! asserted against the oracle across saturation extremes in the fuzz
//! net. Intermediate i16 products are exact too: |a*b| <= 16384 fits
//! i16, and `madd`'s pair sums are formed in i32.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Environment variable forcing the NT-kernel choice (`scalar`, `avx2`,
/// `neon`, `neondot`, or `auto`). Read once at first dispatch; a kernel
/// the running CPU cannot execute falls back to [`GemmKernel::Scalar`].
pub const GEMM_KERNEL_ENV: &str = "MM2IM_GEMM_KERNEL";

/// One NT-microkernel implementation. All variants exist on every
/// target so tests and tooling can name them; [`GemmKernel::compiled`]
/// and [`GemmKernel::supported`] report what this binary / this CPU can
/// actually run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmKernel {
    /// Register-blocked scalar loop — the differential oracle, always
    /// available.
    Scalar,
    /// x86_64 AVX2: sign-extend to i16, `madd_epi16` pair-dots into
    /// eight i32 accumulator lanes.
    Avx2,
    /// aarch64 NEON: `vmull_s8` widening multiply + `vpadalq_s16`
    /// pairwise accumulate.
    Neon,
    /// aarch64 NEON with the `dotprod` extension: `vdotq_s32` four-way
    /// dot product per lane.
    NeonDot,
}

impl GemmKernel {
    /// Canonical lowercase name (the [`GEMM_KERNEL_ENV`] vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            GemmKernel::Scalar => "scalar",
            GemmKernel::Avx2 => "avx2",
            GemmKernel::Neon => "neon",
            GemmKernel::NeonDot => "neondot",
        }
    }

    /// Parse a [`GemmKernel::name`]; `None` for anything unknown.
    pub fn from_name(name: &str) -> Option<GemmKernel> {
        match name {
            "scalar" => Some(GemmKernel::Scalar),
            "avx2" => Some(GemmKernel::Avx2),
            "neon" => Some(GemmKernel::Neon),
            "neondot" => Some(GemmKernel::NeonDot),
            _ => None,
        }
    }

    /// Whether this kernel's code exists in the compiled binary (a
    /// target-architecture fact, independent of the running CPU).
    pub fn compiled(self) -> bool {
        match self {
            GemmKernel::Scalar => true,
            GemmKernel::Avx2 => cfg!(target_arch = "x86_64"),
            GemmKernel::Neon | GemmKernel::NeonDot => cfg!(target_arch = "aarch64"),
        }
    }

    /// Whether the running CPU can execute this kernel (compiled-in and
    /// the required feature is detected at runtime).
    pub fn supported(self) -> bool {
        match self {
            GemmKernel::Scalar => true,
            GemmKernel::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            GemmKernel::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    false
                }
            }
            GemmKernel::NeonDot => {
                #[cfg(target_arch = "aarch64")]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                        && std::arch::is_aarch64_feature_detected!("dotprod")
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    false
                }
            }
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            GemmKernel::Scalar => 1,
            GemmKernel::Avx2 => 2,
            GemmKernel::Neon => 3,
            GemmKernel::NeonDot => 4,
        }
    }

    fn from_u8(v: u8) -> Option<GemmKernel> {
        match v {
            1 => Some(GemmKernel::Scalar),
            2 => Some(GemmKernel::Avx2),
            3 => Some(GemmKernel::Neon),
            4 => Some(GemmKernel::NeonDot),
            _ => None,
        }
    }
}

impl std::fmt::Display for GemmKernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The kernels compiled into this binary, scalar oracle first — what
/// the differential fuzz net iterates over.
pub fn compiled_kernels() -> &'static [GemmKernel] {
    #[cfg(target_arch = "x86_64")]
    const LIST: &[GemmKernel] = &[GemmKernel::Scalar, GemmKernel::Avx2];
    #[cfg(target_arch = "aarch64")]
    const LIST: &[GemmKernel] = &[GemmKernel::Scalar, GemmKernel::Neon, GemmKernel::NeonDot];
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    const LIST: &[GemmKernel] = &[GemmKernel::Scalar];
    LIST
}

/// Probe the CPU for the best supported NT kernel (no caching, no
/// override — [`nt_kernel`] is the cached dispatch entry).
pub fn detect_kernel() -> GemmKernel {
    for k in [GemmKernel::NeonDot, GemmKernel::Neon, GemmKernel::Avx2] {
        if k.supported() {
            return k;
        }
    }
    GemmKernel::Scalar
}

/// Cached env/detect choice; 0 in `FORCED` means "no runtime override".
static SELECTED: OnceLock<GemmKernel> = OnceLock::new();
static FORCED: AtomicU8 = AtomicU8::new(0);

/// Resolve a raw [`GEMM_KERNEL_ENV`] value to the kernel dispatch will
/// use: unset/empty/`auto` means hardware detection, a known name picks
/// that kernel (clamped to the scalar oracle when the CPU cannot run
/// it), and anything else **panics** with the accepted vocabulary — a
/// typo in CI must abort loudly, not silently fall back to a path that
/// wasn't the one under test. Public so tests can pin the panic
/// contract without racing the process-wide dispatch cache.
pub fn resolve_env_choice(value: Option<&str>) -> GemmKernel {
    match value {
        Some(v) if !v.is_empty() && v != "auto" => {
            let k = GemmKernel::from_name(v).unwrap_or_else(|| {
                panic!("{GEMM_KERNEL_ENV}={v}: unknown kernel (scalar|avx2|neon|neondot|auto)")
            });
            if k.supported() {
                k
            } else {
                GemmKernel::Scalar
            }
        }
        _ => detect_kernel(),
    }
}

fn selected_from_env() -> GemmKernel {
    resolve_env_choice(std::env::var(GEMM_KERNEL_ENV).ok().as_deref())
}

/// The kernel [`gemm_i8_i32_nt`] dispatches to right now: the
/// [`force_nt_kernel`] override if set, else the cached
/// [`GEMM_KERNEL_ENV`]/[`detect_kernel`] choice.
pub fn nt_kernel() -> GemmKernel {
    if let Some(k) = GemmKernel::from_u8(FORCED.load(Ordering::Relaxed)) {
        return k;
    }
    *SELECTED.get_or_init(selected_from_env)
}

/// Process-wide runtime override of the NT-kernel choice (`None`
/// restores env/detected dispatch). Unsupported kernels clamp to the
/// scalar oracle, so forcing is always safe. Intended for tests and
/// benches that drive both sides of the kernel matrix in one process.
pub fn force_nt_kernel(kernel: Option<GemmKernel>) {
    let k = kernel.map(|k| if k.supported() { k } else { GemmKernel::Scalar });
    FORCED.store(k.map_or(0, GemmKernel::to_u8), Ordering::Relaxed);
}

/// C[M,N] (i32) = A[M,K] (i8) * B[K,N] (i8), C preinitialized by caller.
/// `threads` splits rows of A; 0 or 1 means single-threaded.
pub fn gemm_i8_i32(m: usize, n: usize, k: usize, a: &[i8], b: &[i8], c: &mut [i32], threads: usize) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    let threads = threads.max(1).min(m.max(1));
    if threads <= 1 {
        gemm_i8_rows(n, k, a, b, c);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut c_rest = c;
        let mut a_rest = a;
        for _ in 0..threads {
            let take = rows_per.min(a_rest.len() / k);
            if take == 0 {
                break;
            }
            let (a_chunk, a_next) = a_rest.split_at(take * k);
            let (c_chunk, c_next) = c_rest.split_at_mut(take * n);
            a_rest = a_next;
            c_rest = c_next;
            scope.spawn(move || gemm_i8_rows(n, k, a_chunk, b, c_chunk));
        }
    });
}

/// Single-threaded core: rows of A against all of B.
///
/// i-k-j loop order: for each (row, kk) the B row is streamed
/// contiguously and the C row stays hot — the inner loop is a
/// scalar-times-vector saxpy over i8 that LLVM auto-vectorizes (widening
/// i8 -> i32 multiplies). Measured ~6x over the previous column-strided
/// dot-product formulation on this host (EXPERIMENTS.md §Perf).
fn gemm_i8_rows(n: usize, k: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
    let m = a.len() / k;
    for row in 0..m {
        let arow = &a[row * k..(row + 1) * k];
        let crow = &mut c[row * n..(row + 1) * n];
        let mut kk = 0;
        // Unroll K by 4: four B rows per pass amortizes the C-row traffic.
        while kk + 4 <= k {
            let av0 = arow[kk] as i32;
            let av1 = arow[kk + 1] as i32;
            let av2 = arow[kk + 2] as i32;
            let av3 = arow[kk + 3] as i32;
            let b0 = &b[kk * n..(kk + 1) * n];
            let b1 = &b[(kk + 1) * n..(kk + 2) * n];
            let b2 = &b[(kk + 2) * n..(kk + 3) * n];
            let b3 = &b[(kk + 3) * n..(kk + 4) * n];
            for i in 0..n {
                crow[i] += av0 * b0[i] as i32
                    + av1 * b1[i] as i32
                    + av2 * b2[i] as i32
                    + av3 * b3[i] as i32;
            }
            kk += 4;
        }
        while kk < k {
            let av = arow[kk] as i32;
            if av != 0 {
                let brow = &b[kk * n..(kk + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv as i32;
                }
            }
            kk += 1;
        }
    }
}

/// C[M,N] += A[M,K] · B[N,K]ᵀ — both operands K-contiguous ("NT"
/// layout), single-threaded, C preinitialized by the caller.
///
/// The fused accelerator engine's microkernel (`accel::engine`): A is a
/// contiguous run of input pixels `[taps, Ic]`, B a packed block of
/// per-PM filter columns `[X, Ic]`, C the `[tap, pm]` partial-product
/// block the col2IM scatter consumes. Dispatches to the best
/// [`GemmKernel`] for this CPU (see [`nt_kernel`]); every path is
/// bit-identical to the scalar oracle.
pub fn gemm_i8_i32_nt(m: usize, n: usize, k: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
    gemm_i8_i32_nt_with(nt_kernel(), m, n, k, a, b, c)
}

/// [`gemm_i8_i32_nt`] through an explicitly chosen kernel — the
/// differential-test entry point. A kernel the running CPU cannot
/// execute falls back to the scalar oracle (identical results), so
/// callers may iterate [`compiled_kernels`] blindly.
pub fn gemm_i8_i32_nt_with(
    kernel: GemmKernel,
    m: usize,
    n: usize,
    k: usize,
    a: &[i8],
    b: &[i8],
    c: &mut [i32],
) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), n * k, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    match kernel {
        #[cfg(target_arch = "x86_64")]
        GemmKernel::Avx2 if kernel.supported() => {
            // Safety: AVX2 presence just checked; operand shapes
            // asserted above.
            unsafe { x86::gemm_nt_avx2(m, n, k, a, b, c) }
        }
        #[cfg(target_arch = "aarch64")]
        GemmKernel::Neon if kernel.supported() => {
            // Safety: NEON presence just checked; shapes asserted above.
            unsafe { arm::gemm_nt_neon(m, n, k, a, b, c) }
        }
        #[cfg(target_arch = "aarch64")]
        GemmKernel::NeonDot if kernel.supported() => {
            // Safety: NEON + dotprod presence just checked.
            unsafe { arm::gemm_nt_neondot(m, n, k, a, b, c) }
        }
        _ => gemm_i8_i32_nt_scalar_unchecked(m, n, k, a, b, c),
    }
}

/// The scalar NT oracle, callable directly (benches, differential
/// tests). 2x2 register blocking: four dot products share every A/B
/// element load, halving memory traffic against the per-tap scalar dots
/// it replaced, and the four independent accumulator chains give the
/// auto-vectorizer parallel widening i8 -> i32 reductions to work with.
pub fn gemm_i8_i32_nt_scalar(m: usize, n: usize, k: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), n * k, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    gemm_i8_i32_nt_scalar_unchecked(m, n, k, a, b, c)
}

fn gemm_i8_i32_nt_scalar_unchecked(
    m: usize,
    n: usize,
    k: usize,
    a: &[i8],
    b: &[i8],
    c: &mut [i32],
) {
    let mut i = 0;
    while i + 2 <= m {
        let a0 = &a[i * k..(i + 1) * k];
        let a1 = &a[(i + 1) * k..(i + 2) * k];
        let mut j = 0;
        while j + 2 <= n {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let (mut s00, mut s01, mut s10, mut s11) = (0i32, 0i32, 0i32, 0i32);
            for l in 0..k {
                let (x0, x1) = (a0[l] as i32, a1[l] as i32);
                let (w0, w1) = (b0[l] as i32, b1[l] as i32);
                s00 += x0 * w0;
                s01 += x0 * w1;
                s10 += x1 * w0;
                s11 += x1 * w1;
            }
            c[i * n + j] += s00;
            c[i * n + j + 1] += s01;
            c[(i + 1) * n + j] += s10;
            c[(i + 1) * n + j + 1] += s11;
            j += 2;
        }
        if j < n {
            let bj = &b[j * k..(j + 1) * k];
            let (mut s0, mut s1) = (0i32, 0i32);
            for l in 0..k {
                let w = bj[l] as i32;
                s0 += a0[l] as i32 * w;
                s1 += a1[l] as i32 * w;
            }
            c[i * n + j] += s0;
            c[(i + 1) * n + j] += s1;
        }
        i += 2;
    }
    if i < m {
        let a0 = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let bj = &b[j * k..(j + 1) * k];
            let s: i32 = a0.iter().zip(bj).map(|(&x, &w)| x as i32 * w as i32).sum();
            c[i * n + j] += s;
        }
    }
}

/// AVX2 NT microkernel. 16 k-elements per step: both operands
/// sign-extend i8 -> i16 (`cvtepi8_epi16`), `madd_epi16` forms exact
/// pair-dots in i32, accumulated across the k loop in eight i32 lanes
/// and horizontally summed once per dot product. Two B rows share every
/// A vector load (the same 2-wide blocking as the scalar oracle), and
/// the sub-16 k tail finishes scalar — bit-identical reassociation
/// either way.
#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// Safety: requires AVX2; `a`, `b`, `c` must be exactly `m*k`,
    /// `n*k`, `m*n` long (asserted by the dispatching caller).
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_nt_avx2(m: usize, n: usize, k: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            let mut j = 0;
            while j + 2 <= n {
                let (s0, s1) =
                    dot2(arow, &b[j * k..(j + 1) * k], &b[(j + 1) * k..(j + 2) * k], k);
                crow[j] += s0;
                crow[j + 1] += s1;
                j += 2;
            }
            if j < n {
                crow[j] += dot1(arow, &b[j * k..(j + 1) * k], k);
            }
        }
    }

    /// One A row against two B rows, sharing the A loads.
    #[target_feature(enable = "avx2")]
    unsafe fn dot2(a: &[i8], b0: &[i8], b1: &[i8], k: usize) -> (i32, i32) {
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let mut l = 0;
        while l + 16 <= k {
            let av = _mm256_cvtepi8_epi16(_mm_loadu_si128(a.as_ptr().add(l).cast()));
            let b0v = _mm256_cvtepi8_epi16(_mm_loadu_si128(b0.as_ptr().add(l).cast()));
            let b1v = _mm256_cvtepi8_epi16(_mm_loadu_si128(b1.as_ptr().add(l).cast()));
            acc0 = _mm256_add_epi32(acc0, _mm256_madd_epi16(av, b0v));
            acc1 = _mm256_add_epi32(acc1, _mm256_madd_epi16(av, b1v));
            l += 16;
        }
        let mut s0 = hsum(acc0);
        let mut s1 = hsum(acc1);
        while l < k {
            s0 += a[l] as i32 * b0[l] as i32;
            s1 += a[l] as i32 * b1[l] as i32;
            l += 1;
        }
        (s0, s1)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dot1(a: &[i8], b: &[i8], k: usize) -> i32 {
        let mut acc = _mm256_setzero_si256();
        let mut l = 0;
        while l + 16 <= k {
            let av = _mm256_cvtepi8_epi16(_mm_loadu_si128(a.as_ptr().add(l).cast()));
            let bv = _mm256_cvtepi8_epi16(_mm_loadu_si128(b.as_ptr().add(l).cast()));
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(av, bv));
            l += 16;
        }
        let mut s = hsum(acc);
        while l < k {
            s += a[l] as i32 * b[l] as i32;
            l += 1;
        }
        s
    }

    /// Sum the eight i32 lanes.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256i) -> i32 {
        let quad = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256::<1>(v));
        let pair = _mm_add_epi32(quad, _mm_shuffle_epi32::<0b0100_1110>(quad));
        let one = _mm_add_epi32(pair, _mm_shuffle_epi32::<0b1011_0001>(pair));
        _mm_cvtsi128_si32(one)
    }
}

/// NEON NT microkernels. The plain-NEON path widens with `vmull_s8`
/// (i8 x i8 -> i16, exact: |product| <= 16384) and folds pairs into
/// four i32 accumulator lanes with `vpadalq_s16`; the `dotprod` path
/// replaces that with a single `vdotq_s32` per 16 k-elements. Both
/// share the A vector load across two B rows and finish sub-16 k tails
/// scalar, like the AVX2 kernel.
#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::*;

    /// Safety: requires NEON; operand shapes asserted by the caller.
    #[target_feature(enable = "neon")]
    pub unsafe fn gemm_nt_neon(m: usize, n: usize, k: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            let mut j = 0;
            while j + 2 <= n {
                let b0 = &b[j * k..(j + 1) * k];
                let b1 = &b[(j + 1) * k..(j + 2) * k];
                let mut acc0 = vdupq_n_s32(0);
                let mut acc1 = vdupq_n_s32(0);
                let mut l = 0;
                while l + 16 <= k {
                    let av = vld1q_s8(arow.as_ptr().add(l));
                    let b0v = vld1q_s8(b0.as_ptr().add(l));
                    let b1v = vld1q_s8(b1.as_ptr().add(l));
                    acc0 = vpadalq_s16(acc0, vmull_s8(vget_low_s8(av), vget_low_s8(b0v)));
                    acc0 = vpadalq_s16(acc0, vmull_high_s8(av, b0v));
                    acc1 = vpadalq_s16(acc1, vmull_s8(vget_low_s8(av), vget_low_s8(b1v)));
                    acc1 = vpadalq_s16(acc1, vmull_high_s8(av, b1v));
                    l += 16;
                }
                let mut s0 = vaddvq_s32(acc0);
                let mut s1 = vaddvq_s32(acc1);
                while l < k {
                    s0 += arow[l] as i32 * b0[l] as i32;
                    s1 += arow[l] as i32 * b1[l] as i32;
                    l += 1;
                }
                crow[j] += s0;
                crow[j + 1] += s1;
                j += 2;
            }
            if j < n {
                let bj = &b[j * k..(j + 1) * k];
                let mut acc = vdupq_n_s32(0);
                let mut l = 0;
                while l + 16 <= k {
                    let av = vld1q_s8(arow.as_ptr().add(l));
                    let bv = vld1q_s8(bj.as_ptr().add(l));
                    acc = vpadalq_s16(acc, vmull_s8(vget_low_s8(av), vget_low_s8(bv)));
                    acc = vpadalq_s16(acc, vmull_high_s8(av, bv));
                    l += 16;
                }
                let mut s = vaddvq_s32(acc);
                while l < k {
                    s += arow[l] as i32 * bj[l] as i32;
                    l += 1;
                }
                crow[j] += s;
            }
        }
    }

    /// Safety: requires NEON + dotprod; shapes asserted by the caller.
    #[target_feature(enable = "neon,dotprod")]
    pub unsafe fn gemm_nt_neondot(m: usize, n: usize, k: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            let mut j = 0;
            while j + 2 <= n {
                let b0 = &b[j * k..(j + 1) * k];
                let b1 = &b[(j + 1) * k..(j + 2) * k];
                let mut acc0 = vdupq_n_s32(0);
                let mut acc1 = vdupq_n_s32(0);
                let mut l = 0;
                while l + 16 <= k {
                    let av = vld1q_s8(arow.as_ptr().add(l));
                    acc0 = vdotq_s32(acc0, av, vld1q_s8(b0.as_ptr().add(l)));
                    acc1 = vdotq_s32(acc1, av, vld1q_s8(b1.as_ptr().add(l)));
                    l += 16;
                }
                let mut s0 = vaddvq_s32(acc0);
                let mut s1 = vaddvq_s32(acc1);
                while l < k {
                    s0 += arow[l] as i32 * b0[l] as i32;
                    s1 += arow[l] as i32 * b1[l] as i32;
                    l += 1;
                }
                crow[j] += s0;
                crow[j + 1] += s1;
                j += 2;
            }
            if j < n {
                let bj = &b[j * k..(j + 1) * k];
                let mut acc = vdupq_n_s32(0);
                let mut l = 0;
                while l + 16 <= k {
                    let av = vld1q_s8(arow.as_ptr().add(l));
                    acc = vdotq_s32(acc, av, vld1q_s8(bj.as_ptr().add(l)));
                    l += 16;
                }
                let mut s = vaddvq_s32(acc);
                while l < k {
                    s += arow[l] as i32 * bj[l] as i32;
                    l += 1;
                }
                crow[j] += s;
            }
        }
    }
}

/// C[M,N] = A[M,K] * B[K,N], f32, threads split M.
pub fn gemm_f32(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32], threads: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let threads = threads.max(1).min(m.max(1));
    if threads <= 1 {
        gemm_f32_rows(n, k, a, b, c);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut c_rest = c;
        let mut a_rest = a;
        for _ in 0..threads {
            let take = rows_per.min(a_rest.len() / k);
            if take == 0 {
                break;
            }
            let (a_chunk, a_next) = a_rest.split_at(take * k);
            let (c_chunk, c_next) = c_rest.split_at_mut(take * n);
            a_rest = a_next;
            c_rest = c_next;
            scope.spawn(move || gemm_f32_rows(n, k, a_chunk, b, c_chunk));
        }
    });
}

fn gemm_f32_rows(n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    let m = a.len() / k;
    // i-k-j loop order: stream B rows, accumulate into the C row — auto-
    // vectorizes on the j loop.
    for row in 0..m {
        let arow = &a[row * k..(row + 1) * k];
        let crow = &mut c[row * n..(row + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn naive_i32(m: usize, n: usize, k: usize, a: &[i8], b: &[i8]) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                for l in 0..k {
                    c[i * n + j] += a[i * k + l] as i32 * b[l * n + j] as i32;
                }
            }
        }
        c
    }

    #[test]
    fn i8_matches_naive_odd_shapes() {
        let mut rng = Pcg32::new(1);
        for (m, n, k) in [(1, 1, 1), (3, 5, 7), (17, 9, 13), (4, 64, 3), (8, 130, 33)] {
            let mut a = vec![0i8; m * k];
            let mut b = vec![0i8; k * n];
            rng.fill_i8(&mut a);
            rng.fill_i8(&mut b);
            let want = naive_i32(m, n, k, &a, &b);
            for threads in [1, 2, 4] {
                let mut c = vec![0i32; m * n];
                gemm_i8_i32(m, n, k, &a, &b, &mut c, threads);
                assert_eq!(c, want, "m={m} n={n} k={k} threads={threads}");
            }
        }
    }

    /// The NT microkernel must agree with the naive kernel under a
    /// transposed-B view, across odd shapes that hit every blocking
    /// tail (m odd, n odd, both, k not a multiple of the unroll) — for
    /// every compiled kernel, not just whatever dispatch picks.
    #[test]
    fn nt_matches_naive_transposed_all_tails() {
        let mut rng = Pcg32::new(7);
        for (m, n, k) in [
            (1, 1, 1),
            (1, 8, 17),
            (2, 2, 4),
            (3, 5, 7),
            (5, 8, 33),
            (7, 3, 256),
            (9, 8, 512),
            (4, 7, 128),
        ] {
            let mut a = vec![0i8; m * k];
            let mut bt = vec![0i8; n * k]; // B[N,K] row-major == Bᵀ
            rng.fill_i8(&mut a);
            rng.fill_i8(&mut bt);
            // Naive expects B[K,N]: transpose the NT operand.
            let mut b = vec![0i8; k * n];
            for j in 0..n {
                for l in 0..k {
                    b[l * n + j] = bt[j * k + l];
                }
            }
            let want = naive_i32(m, n, k, &a, &b);
            {
                let mut c = vec![3i32; m * n]; // accumulates into existing C
                gemm_i8_i32_nt(m, n, k, &a, &bt, &mut c);
                let got: Vec<i32> = c.iter().map(|v| v - 3).collect();
                assert_eq!(got, want, "dispatch m={m} n={n} k={k}");
            }
            for &kernel in compiled_kernels() {
                let mut c = vec![3i32; m * n];
                gemm_i8_i32_nt_with(kernel, m, n, k, &a, &bt, &mut c);
                let got: Vec<i32> = c.iter().map(|v| v - 3).collect();
                assert_eq!(got, want, "{kernel} m={m} n={n} k={k}");
            }
        }
    }

    #[test]
    fn i8_accumulates_into_existing_c() {
        let a = vec![1i8; 4];
        let b = vec![1i8; 4];
        let mut c = vec![100i32; 4];
        gemm_i8_i32(2, 2, 2, &a, &b, &mut c, 1);
        assert_eq!(c, vec![102; 4]);
    }

    #[test]
    fn f32_matches_naive() {
        let mut rng = Pcg32::new(2);
        for (m, n, k) in [(3, 4, 5), (16, 16, 16), (7, 33, 12)] {
            let mut a = vec![0f32; m * k];
            let mut b = vec![0f32; k * n];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            let mut want = vec![0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    for l in 0..k {
                        want[i * n + j] += a[i * k + l] * b[l * n + j];
                    }
                }
            }
            for threads in [1, 2] {
                let mut c = vec![0f32; m * n];
                gemm_f32(m, n, k, &a, &b, &mut c, threads);
                for (g, w) in c.iter().zip(&want) {
                    assert!((g - w).abs() < 1e-3, "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn extreme_values_do_not_overflow_i32() {
        // K up to 4096 at |a*b| <= 128*128 stays well inside i32 — on
        // every kernel (the SIMD paths' i16 intermediates hold 16384
        // exactly and their pair sums are formed in i32).
        let k = 4096;
        let a = vec![-128i8; k];
        let b = vec![-128i8; k];
        let mut c = vec![0i32; 1];
        gemm_i8_i32(1, 1, k, &a, &b, &mut c, 1);
        assert_eq!(c[0], 128 * 128 * k as i32);
        for &kernel in compiled_kernels() {
            let mut c = vec![0i32; 1];
            gemm_i8_i32_nt_with(kernel, 1, 1, k, &a, &b, &mut c);
            assert_eq!(c[0], 128 * 128 * k as i32, "{kernel}");
        }
    }

    #[test]
    fn more_threads_than_rows() {
        let a = vec![1i8; 2 * 3];
        let b = vec![2i8; 3 * 2];
        let mut c = vec![0i32; 4];
        gemm_i8_i32(2, 2, 3, &a, &b, &mut c, 16);
        assert_eq!(c, vec![6; 4]);
    }

    #[test]
    fn kernel_names_roundtrip() {
        for &k in &[GemmKernel::Scalar, GemmKernel::Avx2, GemmKernel::Neon, GemmKernel::NeonDot] {
            assert_eq!(GemmKernel::from_name(k.name()), Some(k));
        }
        assert_eq!(GemmKernel::from_name("sse9"), None);
        assert_eq!(GemmKernel::from_u8(GemmKernel::NeonDot.to_u8()), Some(GemmKernel::NeonDot));
    }

    #[test]
    fn compiled_kernel_list_is_honest() {
        let list = compiled_kernels();
        assert_eq!(list[0], GemmKernel::Scalar, "oracle leads the list");
        for &k in list {
            assert!(k.compiled(), "{k} listed but not compiled");
        }
        // Detection only ever returns something the CPU supports.
        assert!(detect_kernel().supported());
    }
}
