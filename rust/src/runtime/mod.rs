//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the CPU PJRT client.
//!
//! This is the bridge between L2/L1 (JAX + Pallas, build-time python) and
//! L3 (this crate): `make artifacts` lowers the kernels once; this module
//! compiles and runs them natively — python is never on the request path.
//! HLO **text** is the interchange format (jax >= 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1's proto path rejects; the
//! text parser reassigns ids).

pub mod manifest;
pub mod pjrt;

pub use manifest::{ArtifactKind, ArtifactMeta, Manifest};
pub use pjrt::{Executable, PjrtRuntime};
