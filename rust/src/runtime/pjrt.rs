//! PJRT execution stub.
//!
//! The original wiring went through the `xla` crate's PJRT CPU client
//! (xla_extension 0.5.1) to execute the HLO-text artifacts that
//! `python/compile/aot.py` lowers from the JAX/Pallas layer. That crate —
//! and its native `libxla_extension` — are not available in this build
//! image (no crates.io access), so this module keeps the *API contract*
//! of the runtime while returning a descriptive error from the
//! constructor. `repro validate` and `rust/tests/pjrt_numerics.rs` treat
//! the error / missing artifacts as a skip, so the rest of the crate is
//! unaffected.
//!
//! Notes preserved for when the backend is re-enabled:
//! * **Main-thread pinning:** with xla_extension 0.5.1's CPU client,
//!   executing HLO modules containing `while` loops (as the Pallas
//!   interpret-mode lowering does) from a *spawned* thread returns
//!   all-NaN buffers; the identical call on the process main thread is
//!   correct. The types are `!Send` anyway (`Rc` internals), so PJRT is
//!   only ever driven from the main thread via the `repro validate`
//!   subcommand, which `rust/tests/pjrt_numerics.rs` shells out to
//!   through `CARGO_BIN_EXE_repro`.
//! * **HLO text is the interchange format:** jax >= 0.5 emits 64-bit
//!   instruction ids that xla_extension 0.5.1's proto path rejects; the
//!   text parser reassigns ids.

use crate::tensor::Tensor;
use std::path::Path;

/// String-typed runtime errors (no external error crates in this image).
pub type Result<T> = std::result::Result<T, String>;

const UNAVAILABLE: &str = "PJRT backend unavailable: the `xla` crate is not vendored in this build \
     image; rust-native numerics (accel::sim vs tconv::reference) remain fully verified";

/// Handle to the (unavailable) PJRT CPU client.
pub struct PjrtRuntime {
    _private: (),
}

/// A compiled HLO computation (API contract only in this build).
pub struct Executable {
    /// Number of tuple elements the computation returns (aot.py lowers
    /// with return_tuple=True).
    pub outputs: usize,
}

impl PjrtRuntime {
    /// CPU PJRT client. Always errors in this build — see module docs.
    pub fn cpu() -> Result<Self> {
        Err(UNAVAILABLE.to_string())
    }

    /// Backend platform name.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, _path: &Path, _outputs: usize) -> Result<Executable> {
        Err(UNAVAILABLE.to_string())
    }
}

impl Executable {
    /// Execute with f32 tensor arguments; returns the tuple elements as
    /// (shape, data) tensors.
    pub fn run_f32(&self, _args: &[Tensor<f32>]) -> Result<Vec<Tensor<f32>>> {
        Err(UNAVAILABLE.to_string())
    }
}

// Tests live in rust/tests/pjrt_numerics.rs (they need `make artifacts`).
