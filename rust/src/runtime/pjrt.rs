//! Thin wrapper over the `xla` crate's PJRT client (see
//! /opt/xla-example/load_hlo for the reference wiring).
//!
//! **Main-thread pinning (empirical gotcha):** with xla_extension 0.5.1's
//! CPU client, executing HLO modules that contain `while` loops (as the
//! Pallas interpret-mode lowering does) from a *spawned* thread returns
//! all-NaN buffers; the identical call on the process main thread is
//! correct (simple builder computations work on any thread). The types
//! are `!Send` anyway (`Rc` internals), so this module is used from the
//! main thread only: the `repro validate` subcommand does the numerics
//! cross-checks, and `rust/tests/pjrt_numerics.rs` shells out to it via
//! `CARGO_BIN_EXE_repro`.

use crate::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Number of tuple elements the computation returns (aot.py lowers
    /// with return_tuple=True).
    pub outputs: usize,
}

impl PjrtRuntime {
    /// CPU PJRT client (the only backend in this image).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: &Path, outputs: usize) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        Ok(Executable { exe, outputs })
    }
}

impl Executable {
    /// Execute with f32 tensor arguments; returns the tuple elements as
    /// (shape, data) tensors.
    pub fn run_f32(&self, args: &[Tensor<f32>]) -> Result<Vec<Tensor<f32>>> {
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.data())
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape arg: {e:?}"))
            })
            .collect::<Result<_>>()?;

        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;

        let elems = out.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        if elems.len() != self.outputs {
            return Err(anyhow!("expected {} outputs, got {}", self.outputs, elems.len()));
        }
        elems
            .into_iter()
            .map(|lit| {
                let shape = lit
                    .array_shape()
                    .map_err(|e| anyhow!("shape: {e:?}"))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
                Ok(Tensor::from_vec(&dims, data))
            })
            .collect()
    }
}

// Tests live in rust/tests/pjrt_numerics.rs (they need `make artifacts`).
