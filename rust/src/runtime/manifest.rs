//! `artifacts/manifest.json` reader — the contract between `aot.py` and
//! the rust runtime (argument order, shapes, problem geometry).

use crate::tconv::problem::TconvProblem;
use crate::util::json::{self, Value};
use std::path::{Path, PathBuf};

/// Manifest errors are plain strings (no external error crates in this
/// image); they surface through the `repro validate` CLI.
pub type Result<T> = std::result::Result<T, String>;

/// What an HLO artifact computes.
#[derive(Clone, Debug, PartialEq)]
pub enum ArtifactKind {
    /// A single TCONV layer.
    Tconv {
        /// Layer name from the compile spec.
        name: String,
        /// The TCONV geometry.
        problem: TconvProblem,
    },
    /// The full DCGAN generator.
    DcganGenerator {
        /// Seed the python side derived the parameters from.
        param_seed: u64,
        /// Latent vector length.
        latent: usize,
    },
}

/// One artifact's metadata from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// File name relative to the artifact directory.
    pub file: String,
    /// What the artifact computes.
    pub kind: ArtifactKind,
    /// Argument shapes in call order.
    pub arg_shapes: Vec<Vec<usize>>,
    /// Whether the computation returns a tuple.
    pub returns_tuple: bool,
}

/// Parsed `manifest.json`: the artifact directory plus its entries.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// All artifacts listed, in manifest order.
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("reading {dir:?}/manifest.json — run `make artifacts`: {e}"))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text against its directory (separated from
    /// [`Manifest::load`] for in-memory tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        let arts = v
            .get("artifacts")
            .and_then(Value::as_obj)
            .ok_or_else(|| "missing 'artifacts'".to_string())?;
        let mut artifacts = Vec::new();
        for (file, meta) in arts {
            let kind_str = meta
                .get("kind")
                .and_then(Value::as_str)
                .ok_or_else(|| "kind".to_string())?;
            let kind = match kind_str {
                "tconv" => {
                    let p = meta.get("problem").ok_or_else(|| "problem".to_string())?;
                    let f = |k: &str| -> Result<usize> {
                        p.get(k).and_then(Value::as_usize).ok_or_else(|| format!("problem.{k}"))
                    };
                    ArtifactKind::Tconv {
                        name: meta
                            .get("name")
                            .and_then(Value::as_str)
                            .unwrap_or_default()
                            .to_string(),
                        problem: TconvProblem::new(
                            f("ih")?,
                            f("iw")?,
                            f("ic")?,
                            f("ks")?,
                            f("oc")?,
                            f("stride")?,
                        ),
                    }
                }
                "dcgan_generator" => ArtifactKind::DcganGenerator {
                    param_seed: meta
                        .get("param_seed")
                        .and_then(Value::as_usize)
                        .unwrap_or(0) as u64,
                    latent: meta
                        .get("latent")
                        .and_then(Value::as_usize)
                        .ok_or_else(|| "latent".to_string())?,
                },
                other => return Err(format!("unknown artifact kind '{other}'")),
            };
            let arg_shapes = meta
                .get("args")
                .and_then(Value::as_arr)
                .ok_or_else(|| "args".to_string())?
                .iter()
                .map(|a| {
                    a.get("shape")
                        .and_then(Value::as_arr)
                        .ok_or_else(|| "shape".to_string())
                        .map(|dims| dims.iter().filter_map(Value::as_usize).collect())
                })
                .collect::<Result<Vec<Vec<usize>>>>()?;
            artifacts.push(ArtifactMeta {
                file: file.clone(),
                kind,
                arg_shapes,
                returns_tuple: meta
                    .get("returns_tuple")
                    .and_then(Value::as_bool)
                    .unwrap_or(false),
            });
        }
        Ok(Self { dir: dir.to_path_buf(), artifacts })
    }

    /// All TCONV-layer artifacts.
    pub fn tconv_artifacts(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| matches!(a.kind, ArtifactKind::Tconv { .. }))
    }

    /// The DCGAN generator artifact, if present.
    pub fn dcgan(&self) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| matches!(a.kind, ArtifactKind::DcganGenerator { .. }))
    }

    /// Absolute path of one artifact.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

/// Default artifact directory: `$REPO/artifacts` (override with
/// `MM2IM_ARTIFACTS`).
pub fn default_dir() -> PathBuf {
    std::env::var_os("MM2IM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "model.hlo.txt": {
          "kind": "tconv", "name": "k5s2",
          "problem": {"ih": 7, "iw": 7, "ic": 32, "ks": 5, "oc": 16, "stride": 2},
          "args": [
            {"shape": [7, 7, 32], "dtype": "float32"},
            {"shape": [16, 5, 5, 32], "dtype": "float32"},
            {"shape": [16], "dtype": "float32"}
          ],
          "returns_tuple": true
        },
        "dcgan_gen.hlo.txt": {
          "kind": "dcgan_generator", "param_seed": 0, "latent": 100,
          "args": [{"shape": [100], "dtype": "float32"}],
          "returns_tuple": true
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let t = m.tconv_artifacts().next().unwrap();
        match &t.kind {
            ArtifactKind::Tconv { name, problem } => {
                assert_eq!(name, "k5s2");
                assert_eq!(*problem, TconvProblem::new(7, 7, 32, 5, 16, 2));
            }
            _ => panic!(),
        }
        assert_eq!(t.arg_shapes[1], vec![16, 5, 5, 32]);
        assert!(t.returns_tuple);
        assert!(m.dcgan().is_some());
    }

    #[test]
    fn rejects_unknown_kind() {
        let bad = r#"{"artifacts": {"x": {"kind": "wat", "args": []}}}"#;
        assert!(Manifest::parse(Path::new("/"), bad).is_err());
    }

    #[test]
    fn real_manifest_parses_if_built() {
        let dir = default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.tconv_artifacts().count() >= 3);
            assert!(m.dcgan().is_some());
            // dcgan arg shapes must match the rust float_ref contract
            let d = m.dcgan().unwrap();
            let want = crate::model::float_ref::param_shapes();
            assert_eq!(d.arg_shapes.len(), 1 + want.len());
            for (got, want) in d.arg_shapes[1..].iter().zip(&want) {
                assert_eq!(got, want);
            }
        }
    }
}
