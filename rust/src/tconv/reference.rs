//! Reference TCONV implementations — correctness anchors for the CPU
//! baseline, the accelerator simulator, and the PJRT artifacts.
//!
//! Two independent formulations are provided on purpose:
//! * `direct_*`: the scatter-style definition (loop over input pixels and
//!   filter taps, accumulate in the output window);
//! * `iom_*`: the paper's Eq. 2 (MatMul into partials, then col2im via the
//!   output map).
//! They must agree exactly (int32) / to rounding (f32); everything else in
//! the repo is validated against them.

use super::maps::{for_each_entry, OutputMap};
use super::problem::TconvProblem;
use crate::tensor::Tensor;

/// Direct f32 TCONV. x: [Ih,Iw,Ic], w: [Oc,Ks,Ks,Ic], b: Option<[Oc]>.
pub fn direct_f32(
    p: &TconvProblem,
    x: &Tensor<f32>,
    w: &Tensor<f32>,
    b: Option<&[f32]>,
) -> Tensor<f32> {
    check_shapes(p, x.shape(), w.shape());
    let mut out = Tensor::<f32>::zeros(&[p.oh(), p.ow(), p.oc]);
    scatter(p, |ih, iw, oh, ow, _kh_kw| {
        for oc in 0..p.oc {
            let mut acc = 0.0f32;
            let (kh, kw) = _kh_kw;
            for c in 0..p.ic {
                acc += x.at3(ih, iw, c) * w.at4(oc, kh, kw, c);
            }
            let i = out.idx3(oh, ow, oc);
            out.data_mut()[i] += acc;
        }
    });
    if let Some(bias) = b {
        assert_eq!(bias.len(), p.oc);
        for px in 0..p.oh() * p.ow() {
            for oc in 0..p.oc {
                out.data_mut()[px * p.oc + oc] += bias[oc];
            }
        }
    }
    out
}

/// Direct int8 x int8 -> int32 TCONV (exact accumulator contract).
pub fn direct_i32(
    p: &TconvProblem,
    x: &Tensor<i8>,
    w: &Tensor<i8>,
    bias: Option<&[i32]>,
) -> Tensor<i32> {
    check_shapes(p, x.shape(), w.shape());
    let mut out = Tensor::<i32>::zeros(&[p.oh(), p.ow(), p.oc]);
    scatter(p, |ih, iw, oh, ow, (kh, kw)| {
        for oc in 0..p.oc {
            let mut acc = 0i32;
            for c in 0..p.ic {
                acc += x.at3(ih, iw, c) as i32 * w.at4(oc, kh, kw, c) as i32;
            }
            let i = out.idx3(oh, ow, oc);
            out.data_mut()[i] += acc;
        }
    });
    if let Some(b) = bias {
        assert_eq!(b.len(), p.oc);
        for px in 0..p.oh() * p.ow() {
            for oc in 0..p.oc {
                out.data_mut()[px * p.oc + oc] += b[oc];
            }
        }
    }
    out
}

/// Shared scatter loop: visits every *surviving* (pixel, tap) pair with
/// its output coordinates.
fn scatter(p: &TconvProblem, mut visit: impl FnMut(usize, usize, usize, usize, (usize, usize))) {
    for ih in 0..p.ih {
        for iw in 0..p.iw {
            let row_id = ih * p.iw + iw;
            for_each_entry(p, row_id, |col, out| {
                let kh = col as usize / p.ks;
                let kw = col as usize % p.ks;
                let oh = out as usize / p.ow();
                let ow = out as usize % p.ow();
                visit(ih, iw, oh, ow, (kh, kw));
            });
        }
    }
}

/// Eq. 2 MatMul: partials[M, N] with N ordered (kh, kw, oc) — f32.
pub fn iom_matmul_f32(p: &TconvProblem, x: &Tensor<f32>, w: &Tensor<f32>) -> Vec<f32> {
    check_shapes(p, x.shape(), w.shape());
    let (m, n, k) = (p.m(), p.n(), p.k());
    let mut partials = vec![0f32; m * n];
    for row in 0..m {
        let xrow = &x.data()[row * k..(row + 1) * k];
        for kh in 0..p.ks {
            for kw in 0..p.ks {
                for oc in 0..p.oc {
                    let col = (kh * p.ks + kw) * p.oc + oc;
                    let mut acc = 0f32;
                    for c in 0..k {
                        acc += xrow[c] * w.at4(oc, kh, kw, c);
                    }
                    partials[row * n + col] = acc;
                }
            }
        }
    }
    partials
}

/// col2im over the output map — f32.
pub fn col2im_f32(p: &TconvProblem, partials: &[f32], b: Option<&[f32]>) -> Tensor<f32> {
    let map = OutputMap::build(p);
    let mut out = Tensor::<f32>::zeros(&[p.oh(), p.ow(), p.oc]);
    let n = p.n();
    for row in 0..p.m() {
        for e in map.row(row) {
            for oc in 0..p.oc {
                let col = e.col as usize * p.oc + oc;
                let i = e.out as usize * p.oc + oc;
                out.data_mut()[i] += partials[row * n + col];
            }
        }
    }
    if let Some(bias) = b {
        for px in 0..p.oh() * p.ow() {
            for oc in 0..p.oc {
                out.data_mut()[px * p.oc + oc] += bias[oc];
            }
        }
    }
    out
}

/// Full IOM pipeline (Eq. 2): col2im(mm(I, W_T)) — f32.
pub fn iom_f32(p: &TconvProblem, x: &Tensor<f32>, w: &Tensor<f32>, b: Option<&[f32]>) -> Tensor<f32> {
    col2im_f32(p, &iom_matmul_f32(p, x, w), b)
}

fn check_shapes(p: &TconvProblem, x: &[usize], w: &[usize]) {
    assert_eq!(x, &[p.ih, p.iw, p.ic], "input shape mismatch for {p}");
    assert_eq!(w, &[p.oc, p.ks, p.ks, p.ic], "weight shape mismatch for {p}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_case(p: &TconvProblem, seed: u64) -> (Tensor<f32>, Tensor<f32>, Vec<f32>) {
        let mut rng = Pcg32::new(seed);
        let x = Tensor::random_normal(&[p.ih, p.iw, p.ic], 1.0, &mut rng);
        let w = Tensor::random_normal(&[p.oc, p.ks, p.ks, p.ic], 1.0, &mut rng);
        let b: Vec<f32> = (0..p.oc).map(|_| rng.normal()).collect();
        (x, w, b)
    }

    #[test]
    fn direct_equals_iom_f32() {
        for (ih, iw, ic, ks, oc, s) in [
            (2, 2, 2, 3, 2, 1),
            (4, 4, 8, 5, 4, 2),
            (3, 5, 3, 3, 6, 2),
            (5, 5, 7, 7, 3, 1),
            (4, 4, 4, 2, 4, 2),
            (3, 3, 4, 2, 4, 3), // Ks < S
            (1, 1, 21, 4, 21, 4), // FCN shape
        ] {
            let p = TconvProblem::new(ih, iw, ic, ks, oc, s);
            let (x, w, b) = rand_case(&p, 7);
            let d = direct_f32(&p, &x, &w, Some(&b));
            let i = iom_f32(&p, &x, &w, Some(&b));
            assert!(d.max_abs_diff(&i) < 1e-4, "{p}: {}", d.max_abs_diff(&i));
        }
    }

    #[test]
    fn direct_i32_bit_exact_vs_f32_on_small_ints() {
        let p = TconvProblem::new(3, 4, 5, 3, 2, 2);
        let mut rng = Pcg32::new(3);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let xf = Tensor::from_vec(
            &[p.ih, p.iw, p.ic],
            x.data().iter().map(|&v| v as f32).collect(),
        );
        let wf = Tensor::from_vec(
            &[p.oc, p.ks, p.ks, p.ic],
            w.data().iter().map(|&v| v as f32).collect(),
        );
        let gi = direct_i32(&p, &x, &w, None);
        let gf = direct_f32(&p, &xf, &wf, None);
        for (a, b) in gi.data().iter().zip(gf.data()) {
            assert_eq!(*a as f32, *b);
        }
    }

    #[test]
    fn bias_broadcasts_per_channel() {
        let p = TconvProblem::new(2, 2, 3, 3, 2, 1);
        let (x, w, _) = rand_case(&p, 11);
        let b = vec![10.0, -20.0];
        let without = direct_f32(&p, &x, &w, None);
        let with = direct_f32(&p, &x, &w, Some(&b));
        for px in 0..p.oh() * p.ow() {
            for oc in 0..p.oc {
                let d = with.data()[px * p.oc + oc] - without.data()[px * p.oc + oc];
                assert!((d - b[oc]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn zero_input_zero_output() {
        let p = TconvProblem::new(3, 3, 4, 5, 2, 2);
        let x = Tensor::<f32>::zeros(&[3, 3, 4]);
        let mut rng = Pcg32::new(1);
        let w = Tensor::random_normal(&[2, 5, 5, 4], 1.0, &mut rng);
        let out = direct_f32(&p, &x, &w, None);
        assert!(out.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic(expected = "input shape mismatch")]
    fn shape_checked() {
        let p = TconvProblem::new(3, 3, 4, 5, 2, 2);
        let x = Tensor::<f32>::zeros(&[3, 3, 5]);
        let w = Tensor::<f32>::zeros(&[2, 5, 5, 4]);
        direct_f32(&p, &x, &w, None);
    }
}
