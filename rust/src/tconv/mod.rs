//! TCONV problem definitions, compute/output maps, reference
//! implementations, and the paper's §III-A efficiency metrics.

pub mod maps;
pub mod metrics;
pub mod problem;
pub mod reference;

pub use maps::{MapEntry, OutputMap, RowSchedule};
pub use metrics::DropStats;
pub use problem::{MapperKind, TconvProblem};
