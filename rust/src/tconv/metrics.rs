//! §III-A efficiency metrics: ineffectual computation (drop rate) and
//! partial-output storage waste — the quantities behind Figs. 1 and 7 and
//! the 2.25x / 9x worked example.

use super::maps::OutputMap;
use super::problem::TconvProblem;

/// The §III-A ineffectual-computation and storage-waste quantities.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DropStats {
    /// Dropped MatMul outputs D_o (taps * Oc).
    pub d_o: u64,
    /// Drop rate D_r = D_o / (M*N).
    pub d_r: f64,
    /// Ineffectual MACs skipped by MM2IM: D_o * K.
    pub skipped_macs: u64,
    /// Storage-efficiency gain from skipping dropped partials:
    /// P_outs / (P_outs - D_o)  (the paper's 2.25x for Fig. 2).
    pub storage_gain_skip: f64,
    /// Storage-efficiency gain from accumulating straight into final
    /// outputs: P_outs / F_outs' where F_outs' = Oc*Oh*Ow (9x for Fig. 2).
    pub storage_gain_accumulate: f64,
}

impl DropStats {
    /// Build the output map for `p` and derive its drop statistics.
    pub fn compute(p: &TconvProblem) -> Self {
        Self::from_map(&OutputMap::build(p))
    }

    /// Derive drop statistics from an already-built output map.
    pub fn from_map(map: &OutputMap) -> Self {
        let p = &map.problem;
        let d_o = map.dropped_taps() as u64 * p.oc as u64;
        let p_outs = p.p_outs() as u64;
        let d_r = d_o as f64 / p_outs as f64;
        DropStats {
            d_o,
            d_r,
            skipped_macs: d_o * p.k() as u64,
            storage_gain_skip: p_outs as f64 / (p_outs - d_o).max(1) as f64,
            storage_gain_accumulate: p_outs as f64 / p.f_outs() as f64,
        }
    }

    /// Effectual MACs actually executed by MM2IM (survivors only).
    pub fn effectual_macs(&self, p: &TconvProblem) -> u64 {
        p.macs() - self.skipped_macs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_worked_example() {
        // Paper §III-A: D_o = 40, M*N = 72, D_r = 0.55; gains 2.25x and 9x.
        let p = TconvProblem::new(2, 2, 2, 3, 2, 1);
        let s = DropStats::compute(&p);
        assert_eq!(s.d_o, 40);
        assert!((s.d_r - 40.0 / 72.0).abs() < 1e-12);
        assert!((s.storage_gain_skip - 2.25).abs() < 1e-12);
        assert!((s.storage_gain_accumulate - 9.0).abs() < 1e-12);
        assert_eq!(s.skipped_macs, 80);
        assert_eq!(s.effectual_macs(&p), 144 - 80);
    }

    #[test]
    fn drop_rate_dcgan_order_of_magnitude() {
        // §II-A: "up to 28% for DCGAN" ineffectual computations. DCGAN_2/3
        // (Ks=5, S=2, small feature maps) should be in the 10-30% band.
        let p = TconvProblem::square(8, 512, 5, 256, 2);
        let s = DropStats::compute(&p);
        assert!(s.d_r > 0.08 && s.d_r < 0.35, "d_r = {}", s.d_r);
    }

    #[test]
    fn stride_lowers_drop_rate_ks_raises_it() {
        let base = DropStats::compute(&TconvProblem::square(9, 32, 5, 16, 1)).d_r;
        let s2 = DropStats::compute(&TconvProblem::square(9, 32, 5, 16, 2)).d_r;
        assert!(s2 < base);
        let k3 = DropStats::compute(&TconvProblem::square(9, 32, 3, 16, 1)).d_r;
        let k7 = DropStats::compute(&TconvProblem::square(9, 32, 7, 16, 1)).d_r;
        assert!(k3 < base && base < k7);
    }

    #[test]
    fn larger_input_lowers_drop_rate() {
        // Perimeter/area argument: drops live on the border.
        let small = DropStats::compute(&TconvProblem::square(7, 32, 5, 16, 2)).d_r;
        let large = DropStats::compute(&TconvProblem::square(11, 32, 5, 16, 2)).d_r;
        assert!(large < small);
    }

    #[test]
    fn no_drops_when_ks_equals_stride() {
        let p = TconvProblem::new(4, 4, 8, 2, 4, 2);
        let s = DropStats::compute(&p);
        assert_eq!(s.d_o, 0);
        assert_eq!(s.d_r, 0.0);
    }
}
