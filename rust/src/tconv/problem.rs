//! The TCONV problem (Eq. 1 of the paper) and its derived geometry.
//!
//! Normative semantics (shared bit-for-bit with `python/compile/kernels/ref.py`,
//! see DESIGN.md §4): NHWC input `[Ih, Iw, Ic]`, OHWI weights
//! `[Oc, Ks, Ks, Ic]`, output `[Oh=S*Ih, Ow=S*Iw, Oc]`,
//! `pad_top = pad_left = max(Ks - S, 0) / 2`.

/// How the accelerator's Mapper walks a layer's TCONV-to-MatMul mapping.
/// A *per-layer* knob (the EcoFlow observation: the best dataflow depends
/// on the layer, not the device): it changes cycle accounting and the
/// instruction encoding but never the tap set or the numerics, so both
/// kinds are bit-identical to the CPU reference.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum MapperKind {
    /// The paper's Algorithm-2 walk: every (iw, kw) candidate is visited
    /// and the cmap decides survival, so each pass walks `Iw * Ks`
    /// candidate taps regardless of how many survive cropping.
    #[default]
    Overlapped,
    /// Kernel-segregated walk (arXiv 2502.20493): the filter is split
    /// into `stride x stride` non-overlapping sub-kernels whose taps are
    /// effectual by construction, so the walk enumerates only surviving
    /// taps (plus a per-pass sub-kernel setup of `stride^2` slots) and
    /// ineffectual MACs never exist as candidates at rest.
    Segregated,
}

impl MapperKind {
    /// Candidate taps the mapper presents per (output row, input row)
    /// pass: `Overlapped` walks the full `Iw * Ks` cross product and
    /// crops via the cmap; `Segregated` presents only the `surviving`
    /// taps (its sub-kernels contain no croppable positions), so the
    /// cmap-skip ablation has zero wasted work to restore.
    pub fn candidate_taps(&self, iw: usize, ks: usize, surviving: usize) -> u64 {
        match self {
            MapperKind::Overlapped => (iw * ks) as u64,
            MapperKind::Segregated => surviving as u64,
        }
    }

    /// Walk slots the mapper spends generating one pass's cmap/omap
    /// (multiply by `AccelConfig::mapper_cycles_per_tap` for cycles):
    /// `Overlapped` visits all `Iw * Ks` candidates; `Segregated` visits
    /// the surviving taps plus `stride^2` sub-kernel boundary slots.
    pub fn mapper_walk_slots(&self, iw: usize, ks: usize, stride: usize, surviving: usize) -> u64 {
        match self {
            MapperKind::Overlapped => (iw * ks) as u64,
            MapperKind::Segregated => (surviving + stride * stride) as u64,
        }
    }
}

/// `out(Oh, Ow, Oc) = tconv(Ih, Iw, Ic, Ks, Oc, S)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TconvProblem {
    /// Input height.
    pub ih: usize,
    /// Input width.
    pub iw: usize,
    /// Input channels.
    pub ic: usize,
    /// Square kernel size.
    pub ks: usize,
    /// Output channels.
    pub oc: usize,
    /// Upsampling stride S.
    pub stride: usize,
    /// Mapper walk for this layer (per-layer knob; part of the problem's
    /// identity, so it folds into `PlanKey` and the instruction stream).
    pub mapper: MapperKind,
}

impl TconvProblem {
    /// Construct a problem; every dimension must be positive. Uses the
    /// paper's [`MapperKind::Overlapped`] walk; see
    /// [`TconvProblem::with_mapper`].
    pub fn new(ih: usize, iw: usize, ic: usize, ks: usize, oc: usize, stride: usize) -> Self {
        assert!(ih > 0 && iw > 0 && ic > 0 && ks > 0 && oc > 0 && stride > 0);
        Self { ih, iw, ic, ks, oc, stride, mapper: MapperKind::Overlapped }
    }

    /// The same geometry under a different mapper walk.
    pub fn with_mapper(mut self, mapper: MapperKind) -> Self {
        self.mapper = mapper;
        self
    }

    /// Square-input shorthand used by the benchmark sweep.
    pub fn square(ih: usize, ic: usize, ks: usize, oc: usize, stride: usize) -> Self {
        Self::new(ih, ih, ic, ks, oc, stride)
    }

    /// Output height: S * Ih.
    pub fn oh(&self) -> usize {
        self.stride * self.ih
    }

    /// Output width: S * Iw.
    pub fn ow(&self) -> usize {
        self.stride * self.iw
    }

    /// Total crop padding: max(Ks - S, 0).
    pub fn pad_total(&self) -> usize {
        self.ks.saturating_sub(self.stride)
    }

    /// Rows cropped off the top of the padded output.
    pub fn pad_top(&self) -> usize {
        self.pad_total() / 2
    }

    /// Columns cropped off the left of the padded output.
    pub fn pad_left(&self) -> usize {
        self.pad_total() / 2
    }

    // ---- MatMul view of the IOM method (Eq. 2) -----------------------------

    /// MatMul rows: M = Ih * Iw.
    pub fn m(&self) -> usize {
        self.ih * self.iw
    }

    /// MatMul depth: K = Ic.
    pub fn k(&self) -> usize {
        self.ic
    }

    /// MatMul cols: N = Ks^2 * Oc.
    pub fn n(&self) -> usize {
        self.ks * self.ks * self.oc
    }

    /// MACs of the unskipped IOM MatMul: M*N*K.
    pub fn macs(&self) -> u64 {
        self.m() as u64 * self.n() as u64 * self.k() as u64
    }

    /// OPs as the paper counts them (1 MAC = 2 ops).
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Uncropped (padded) IOM output height: (Ih-1)*S + Ks.
    pub fn full_h(&self) -> usize {
        (self.ih - 1) * self.stride + self.ks
    }

    /// Uncropped (padded) IOM output width: (Iw-1)*S + Ks.
    pub fn full_w(&self) -> usize {
        (self.iw - 1) * self.stride + self.ks
    }

    /// Input tensor element count.
    pub fn input_elems(&self) -> usize {
        self.ih * self.iw * self.ic
    }

    /// Weight tensor element count.
    pub fn weight_elems(&self) -> usize {
        self.oc * self.ks * self.ks * self.ic
    }

    /// Output tensor element count.
    pub fn output_elems(&self) -> usize {
        self.oh() * self.ow() * self.oc
    }

    /// Final outputs F_outs = Oc * Oh * Ow (§III-A.2).
    pub fn f_outs(&self) -> usize {
        self.output_elems()
    }

    /// Partial outputs P_outs = M * N (§III-A.2).
    pub fn p_outs(&self) -> usize {
        self.m() * self.n()
    }
}

impl std::fmt::Display for TconvProblem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "tconv({},{},{},{},{},{}{})",
            self.ih,
            self.iw,
            self.ic,
            self.ks,
            self.oc,
            self.stride,
            match self.mapper {
                MapperKind::Overlapped => "",
                MapperKind::Segregated => ",seg",
            }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The Fig. 2 worked example: tconv(2,2,2,3,2,1).
    #[test]
    fn fig2_example_geometry() {
        let p = TconvProblem::new(2, 2, 2, 3, 2, 1);
        assert_eq!((p.oh(), p.ow()), (2, 2));
        assert_eq!((p.m(), p.n(), p.k()), (4, 18, 2));
        assert_eq!(p.p_outs(), 72);
        assert_eq!(p.macs(), 144);
        assert_eq!(p.pad_top(), 1);
        assert_eq!((p.full_h(), p.full_w()), (4, 4));
    }

    #[test]
    fn dcgan1_op_count_matches_table2() {
        // Table II: DCGAN_1 = OC 512, KS 5, IH/IW 4, IC 1024 -> 420M OPs.
        let p = TconvProblem::square(4, 1024, 5, 512, 2);
        let gops = p.ops() as f64 / 1e9;
        assert!((gops - 0.42).abs() < 0.03, "gops = {gops}");
    }

    #[test]
    fn stride_scales_output() {
        let p = TconvProblem::square(7, 32, 5, 16, 2);
        assert_eq!((p.oh(), p.ow()), (14, 14));
        assert_eq!(p.pad_total(), 3);
        assert_eq!(p.pad_top(), 1);
    }

    #[test]
    fn ks_equals_stride_no_padding() {
        let p = TconvProblem::new(1, 1, 21, 4, 21, 4);
        assert_eq!(p.pad_total(), 0);
        assert_eq!((p.oh(), p.ow()), (4, 4));
    }

    #[test]
    fn display_roundtrip() {
        let p = TconvProblem::new(7, 9, 32, 5, 16, 2);
        assert_eq!(p.to_string(), "tconv(7,9,32,5,16,2)");
        assert_eq!(
            p.with_mapper(MapperKind::Segregated).to_string(),
            "tconv(7,9,32,5,16,2,seg)"
        );
    }

    #[test]
    fn mapper_kind_is_part_of_identity_but_not_geometry() {
        let a = TconvProblem::new(4, 4, 8, 3, 4, 2);
        let b = a.with_mapper(MapperKind::Segregated);
        assert_ne!(a, b, "mapper kind is identity");
        assert_eq!((a.oh(), a.ow(), a.macs()), (b.oh(), b.ow(), b.macs()), "geometry unchanged");
        assert_eq!(a.mapper, MapperKind::default());
    }

    #[test]
    fn segregated_census_has_no_croppable_candidates() {
        // iw=6, ks=3, stride=2, 14 survivors (say): Overlapped walks 18
        // candidates, Segregated exactly the survivors.
        assert_eq!(MapperKind::Overlapped.candidate_taps(6, 3, 14), 18);
        assert_eq!(MapperKind::Segregated.candidate_taps(6, 3, 14), 14);
        assert_eq!(MapperKind::Overlapped.mapper_walk_slots(6, 3, 2, 14), 18);
        assert_eq!(MapperKind::Segregated.mapper_walk_slots(6, 3, 2, 14), 14 + 4);
    }
}
