//! The TCONV problem (Eq. 1 of the paper) and its derived geometry.
//!
//! Normative semantics (shared bit-for-bit with `python/compile/kernels/ref.py`,
//! see DESIGN.md §4): NHWC input `[Ih, Iw, Ic]`, OHWI weights
//! `[Oc, Ks, Ks, Ic]`, output `[Oh=S*Ih, Ow=S*Iw, Oc]`,
//! `pad_top = pad_left = max(Ks - S, 0) / 2`.

/// `out(Oh, Ow, Oc) = tconv(Ih, Iw, Ic, Ks, Oc, S)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TconvProblem {
    /// Input height.
    pub ih: usize,
    /// Input width.
    pub iw: usize,
    /// Input channels.
    pub ic: usize,
    /// Square kernel size.
    pub ks: usize,
    /// Output channels.
    pub oc: usize,
    /// Upsampling stride S.
    pub stride: usize,
}

impl TconvProblem {
    /// Construct a problem; every dimension must be positive.
    pub fn new(ih: usize, iw: usize, ic: usize, ks: usize, oc: usize, stride: usize) -> Self {
        assert!(ih > 0 && iw > 0 && ic > 0 && ks > 0 && oc > 0 && stride > 0);
        Self { ih, iw, ic, ks, oc, stride }
    }

    /// Square-input shorthand used by the benchmark sweep.
    pub fn square(ih: usize, ic: usize, ks: usize, oc: usize, stride: usize) -> Self {
        Self::new(ih, ih, ic, ks, oc, stride)
    }

    /// Output height: S * Ih.
    pub fn oh(&self) -> usize {
        self.stride * self.ih
    }

    /// Output width: S * Iw.
    pub fn ow(&self) -> usize {
        self.stride * self.iw
    }

    /// Total crop padding: max(Ks - S, 0).
    pub fn pad_total(&self) -> usize {
        self.ks.saturating_sub(self.stride)
    }

    /// Rows cropped off the top of the padded output.
    pub fn pad_top(&self) -> usize {
        self.pad_total() / 2
    }

    /// Columns cropped off the left of the padded output.
    pub fn pad_left(&self) -> usize {
        self.pad_total() / 2
    }

    // ---- MatMul view of the IOM method (Eq. 2) -----------------------------

    /// MatMul rows: M = Ih * Iw.
    pub fn m(&self) -> usize {
        self.ih * self.iw
    }

    /// MatMul depth: K = Ic.
    pub fn k(&self) -> usize {
        self.ic
    }

    /// MatMul cols: N = Ks^2 * Oc.
    pub fn n(&self) -> usize {
        self.ks * self.ks * self.oc
    }

    /// MACs of the unskipped IOM MatMul: M*N*K.
    pub fn macs(&self) -> u64 {
        self.m() as u64 * self.n() as u64 * self.k() as u64
    }

    /// OPs as the paper counts them (1 MAC = 2 ops).
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Uncropped (padded) IOM output height: (Ih-1)*S + Ks.
    pub fn full_h(&self) -> usize {
        (self.ih - 1) * self.stride + self.ks
    }

    /// Uncropped (padded) IOM output width: (Iw-1)*S + Ks.
    pub fn full_w(&self) -> usize {
        (self.iw - 1) * self.stride + self.ks
    }

    /// Input tensor element count.
    pub fn input_elems(&self) -> usize {
        self.ih * self.iw * self.ic
    }

    /// Weight tensor element count.
    pub fn weight_elems(&self) -> usize {
        self.oc * self.ks * self.ks * self.ic
    }

    /// Output tensor element count.
    pub fn output_elems(&self) -> usize {
        self.oh() * self.ow() * self.oc
    }

    /// Final outputs F_outs = Oc * Oh * Ow (§III-A.2).
    pub fn f_outs(&self) -> usize {
        self.output_elems()
    }

    /// Partial outputs P_outs = M * N (§III-A.2).
    pub fn p_outs(&self) -> usize {
        self.m() * self.n()
    }
}

impl std::fmt::Display for TconvProblem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "tconv({},{},{},{},{},{})",
            self.ih, self.iw, self.ic, self.ks, self.oc, self.stride
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The Fig. 2 worked example: tconv(2,2,2,3,2,1).
    #[test]
    fn fig2_example_geometry() {
        let p = TconvProblem::new(2, 2, 2, 3, 2, 1);
        assert_eq!((p.oh(), p.ow()), (2, 2));
        assert_eq!((p.m(), p.n(), p.k()), (4, 18, 2));
        assert_eq!(p.p_outs(), 72);
        assert_eq!(p.macs(), 144);
        assert_eq!(p.pad_top(), 1);
        assert_eq!((p.full_h(), p.full_w()), (4, 4));
    }

    #[test]
    fn dcgan1_op_count_matches_table2() {
        // Table II: DCGAN_1 = OC 512, KS 5, IH/IW 4, IC 1024 -> 420M OPs.
        let p = TconvProblem::square(4, 1024, 5, 512, 2);
        let gops = p.ops() as f64 / 1e9;
        assert!((gops - 0.42).abs() < 0.03, "gops = {gops}");
    }

    #[test]
    fn stride_scales_output() {
        let p = TconvProblem::square(7, 32, 5, 16, 2);
        assert_eq!((p.oh(), p.ow()), (14, 14));
        assert_eq!(p.pad_total(), 3);
        assert_eq!(p.pad_top(), 1);
    }

    #[test]
    fn ks_equals_stride_no_padding() {
        let p = TconvProblem::new(1, 1, 21, 4, 21, 4);
        assert_eq!(p.pad_total(), 0);
        assert_eq!((p.oh(), p.ow()), (4, 4));
    }

    #[test]
    fn display_roundtrip() {
        let p = TconvProblem::new(7, 9, 32, 5, 16, 2);
        assert_eq!(p.to_string(), "tconv(7,9,32,5,16,2)");
    }
}
