//! Compute map (cmap) + output map (omap) — the paper's first key insight
//! (§III-A.3), and the tiling schedule of Algorithm 1.
//!
//! A MatMul row `row_id` (one input pixel) crossed with filter tap
//! `col = kh*Ks + kw` produces a partial output that either lands at flat
//! output index `oh*Ow + ow` or is **cropped** (ineffectual). The cmap is
//! the set of surviving taps per row; the omap is their target indices.
//! This module is the single software source of truth: the hardware
//! MM2IM Mapper (`accel::mapper`) must generate identical streams
//! (property-tested in `rust/tests/prop_invariants.rs`), and it mirrors
//! `python/compile/kernels/ref.py::output_map` bit-for-bit.

use super::problem::TconvProblem;

/// One surviving (non-cropped) partial: filter tap `col` of the row's
/// dot-product block accumulates into flat output pixel `out`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MapEntry {
    /// Tap index within the row: kh * Ks + kw (the cmap value).
    pub col: u32,
    /// Flat output pixel index: oh * Ow + ow (the omap value).
    pub out: u32,
}

/// Enumerate Algorithm 2 for one MatMul row: calls `emit(col, out)` for
/// every surviving tap, in col order. This exact loop nest is what the
/// hardware mapper implements.
#[inline]
pub fn for_each_entry(p: &TconvProblem, row_id: usize, mut emit: impl FnMut(u32, u32)) {
    debug_assert!(row_id < p.m());
    // Row-major row_id = ih*Iw + iw (paper listing swaps div/mod; DESIGN.md §4).
    let h_pad = (p.stride * (row_id / p.iw)) as i64 - p.pad_top() as i64;
    let w_pad = (p.stride * (row_id % p.iw)) as i64 - p.pad_left() as i64;
    let (oh_max, ow_max) = (p.oh() as i64, p.ow() as i64);
    let mut col = 0u32;
    for kh in 0..p.ks as i64 {
        for kw in 0..p.ks as i64 {
            let oh = kh + h_pad;
            let ow = kw + w_pad;
            if oh >= 0 && oh < oh_max && ow >= 0 && ow < ow_max {
                emit(col, (oh * ow_max + ow) as u32);
            }
            col += 1;
        }
    }
}

/// CSR-packed cmap+omap for a whole problem.
#[derive(Clone, Debug)]
pub struct OutputMap {
    /// entries[offsets[m]..offsets[m+1]] are row m's surviving taps.
    pub offsets: Vec<usize>,
    /// All surviving taps, rows concatenated.
    pub entries: Vec<MapEntry>,
    /// Problem the map was built for.
    pub problem: TconvProblem,
}

impl OutputMap {
    /// Enumerate the full cmap/omap for `p` (CSR layout).
    pub fn build(p: &TconvProblem) -> Self {
        let mut offsets = Vec::with_capacity(p.m() + 1);
        let mut entries = Vec::with_capacity(p.m() * p.ks * p.ks);
        offsets.push(0);
        for row in 0..p.m() {
            for_each_entry(p, row, |col, out| entries.push(MapEntry { col, out }));
            offsets.push(entries.len());
        }
        Self { offsets, entries, problem: *p }
    }

    /// Row `m`'s surviving taps.
    pub fn row(&self, m: usize) -> &[MapEntry] {
        &self.entries[self.offsets[m]..self.offsets[m + 1]]
    }

    /// Surviving taps across all rows (kept partials / Oc).
    pub fn surviving_taps(&self) -> usize {
        self.entries.len()
    }

    /// Dropped taps across all rows.
    pub fn dropped_taps(&self) -> usize {
        self.problem.m() * self.problem.ks * self.problem.ks - self.entries.len()
    }
}

/// Per-output-row input schedule (Algorithm 1): which input rows, with
/// which filter row, contribute to output row `h`.
#[derive(Clone, Debug)]
pub struct RowSchedule {
    /// contributions[h] = (input_row, kh) pairs, ascending in input_row.
    pub contributions: Vec<Vec<(usize, usize)>>,
    /// Algorithm 1's `i_end_row[h]`: last input row needed for output row
    /// h, or -1 if none (possible only when Ks < S).
    pub i_end_row: Vec<i64>,
}

impl RowSchedule {
    /// Derive Algorithm 1's per-output-row input schedule for `p`.
    pub fn build(p: &TconvProblem) -> Self {
        let mut contributions = Vec::with_capacity(p.oh());
        let mut i_end_row = Vec::with_capacity(p.oh());
        for h in 0..p.oh() {
            let mut c = Vec::new();
            for ihr in 0..p.ih {
                let kh = h as i64 + p.pad_top() as i64 - (ihr * p.stride) as i64;
                if kh >= 0 && (kh as usize) < p.ks {
                    c.push((ihr, kh as usize));
                }
            }
            i_end_row.push(c.last().map_or(-1, |&(ihr, _)| ihr as i64));
            contributions.push(c);
        }
        Self { contributions, i_end_row }
    }

    /// Max contributing input rows for any output row: ceil(Ks / S) bound.
    pub fn max_rows(&self) -> usize {
        self.contributions.iter().map(Vec::len).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p_fig2() -> TconvProblem {
        TconvProblem::new(2, 2, 2, 3, 2, 1)
    }

    #[test]
    fn fig2_drop_counts() {
        let map = OutputMap::build(&p_fig2());
        // 4 rows x 9 taps = 36 total; paper: D_o = 40 = dropped_taps * Oc.
        assert_eq!(map.dropped_taps() * 2, 40);
        assert_eq!(map.surviving_taps(), 16);
    }

    #[test]
    fn fig2_row0_entries() {
        // Input pixel (0,0), pad 1: taps land at output (kh-1, kw-1);
        // survivors are kh,kw in {1,2} -> outputs (0,0),(0,1),(1,0),(1,1).
        let map = OutputMap::build(&p_fig2());
        let row0: Vec<(u32, u32)> = map.row(0).iter().map(|e| (e.col, e.out)).collect();
        assert_eq!(row0, vec![(4, 0), (5, 1), (7, 2), (8, 3)]);
    }

    #[test]
    fn entries_cover_every_output_when_ks_ge_stride() {
        for (ih, ic, ks, oc, s) in [(7, 8, 3, 4, 1), (5, 4, 5, 2, 2), (4, 4, 7, 3, 2)] {
            let p = TconvProblem::square(ih, ic, ks, oc, s);
            let map = OutputMap::build(&p);
            let mut covered = vec![false; p.oh() * p.ow()];
            for e in &map.entries {
                covered[e.out as usize] = true;
            }
            assert!(covered.iter().all(|&c| c), "{p}");
        }
    }

    #[test]
    fn omap_matches_bruteforce_contributions() {
        let p = TconvProblem::new(3, 5, 2, 4, 3, 2);
        let map = OutputMap::build(&p);
        let mut counts = vec![0u32; p.oh() * p.ow()];
        for e in &map.entries {
            counts[e.out as usize] += 1;
        }
        let mut brute = vec![0u32; p.oh() * p.ow()];
        for ih in 0..p.ih {
            for iw in 0..p.iw {
                for kh in 0..p.ks {
                    for kw in 0..p.ks {
                        let oh = (ih * p.stride + kh) as i64 - p.pad_top() as i64;
                        let ow = (iw * p.stride + kw) as i64 - p.pad_left() as i64;
                        if oh >= 0 && (oh as usize) < p.oh() && ow >= 0 && (ow as usize) < p.ow() {
                            brute[oh as usize * p.ow() + ow as usize] += 1;
                        }
                    }
                }
            }
        }
        assert_eq!(counts, brute);
    }

    #[test]
    fn row_schedule_matches_fig5_step_structure() {
        // S=1, Ks=3, Ih=4: interior output rows take 3 input rows.
        let p = TconvProblem::square(4, 2, 3, 2, 1);
        let sched = RowSchedule::build(&p);
        assert_eq!(sched.max_rows(), 3);
        assert_eq!(sched.contributions[0], vec![(0, 1), (1, 0)]); // pad_top = 1... h=0: kh = 0+1-ihr
        assert_eq!(sched.i_end_row, vec![1, 2, 3, 3]);
    }

    #[test]
    fn i_end_row_nondecreasing() {
        for (ih, ks, s) in [(7, 5, 2), (9, 3, 1), (11, 7, 2), (4, 2, 3)] {
            let p = TconvProblem::square(ih, 8, ks, 4, s);
            let sched = RowSchedule::build(&p);
            let mut last = -1;
            for &e in &sched.i_end_row {
                if e >= 0 {
                    assert!(e >= last, "{p}: {:?}", sched.i_end_row);
                    last = e;
                }
            }
        }
    }

    #[test]
    fn max_rows_bounded_by_ceil_ks_over_s() {
        for (ih, ks, s) in [(7, 5, 2), (9, 3, 1), (11, 7, 2), (5, 2, 3), (6, 4, 4)] {
            let p = TconvProblem::square(ih, 4, ks, 4, s);
            let sched = RowSchedule::build(&p);
            assert!(sched.max_rows() <= (ks + s - 1) / s, "{p}");
        }
    }
}
