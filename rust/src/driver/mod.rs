//! Host-side driver + delegate (the SECDA-TFLite integration layer).
//!
//! [`instructions`] implements Algorithm 1 (*Tiled MM2IM*): it walks the
//! layer in `filter_step = X` output-channel tiles, streams only the new
//! input rows each output row needs (`i_end_row`), and emits the micro-ISA
//! stream the accelerator consumes. [`delegate`] is the TFLite-delegate
//! analogue: it partitions a model graph, offloads TCONV layers to the
//! simulated accelerator and accounts the host-side overheads.

pub mod delegate;
pub mod instructions;

pub use delegate::{Delegate, LayerExecution};
pub use instructions::{build_layer_stream, layer_quant_stream, DRIVER_FIXED_OVERHEAD_S};
