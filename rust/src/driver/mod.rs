//! Host-side driver + delegate (the SECDA-TFLite integration layer).
//!
//! [`instructions`] implements Algorithm 1 (*Tiled MM2IM*): it walks the
//! layer in `filter_step = X` output-channel tiles, streams only the new
//! input rows each output row needs (`i_end_row`), and emits the micro-ISA
//! stream the accelerator consumes. The walk is split compile/execute:
//! [`instructions::compile_layer`] produces a reusable, input-independent
//! [`plan::CompiledPlan`] and [`plan::CompiledPlan::instantiate`] splices
//! a request's activations in. [`plan`] also provides the keyed, bounded
//! [`plan::PlanCache`] the serving layer shares across workers.
//! [`delegate`] is the TFLite-delegate analogue: it partitions a model
//! graph, offloads TCONV layers to a *persistent* simulated accelerator
//! (resolving streams through the plan cache when one is installed) and
//! accounts the host-side overheads. Same-layer batches go through
//! [`plan::CompiledPlan::instantiate_batch`] /
//! [`delegate::Delegate::run_tconv_quant_batch`], which emit one weight
//! prologue per tile for the whole batch. *Cross-graph* batches of
//! chain-mates (equal [`plan::GraphKey`]s — same shapes, different
//! weights) go through [`plan::CompiledPlan::instantiate_batch_multi`] /
//! [`delegate::Delegate::run_tconv_quant_batch_multi`], which share each
//! tile's `Configure` and pay one `LoadWeights` per (tile, variant).
//! [`persist`] makes the cache durable: versioned, checksummed,
//! fingerprint-validated snapshots so a restarted shard preloads its
//! compiled plans instead of recompiling the zoo.

pub mod delegate;
pub mod instructions;
pub mod persist;
pub mod plan;

pub use delegate::{Delegate, LayerExecution, TconvVariant};
pub use instructions::{
    build_layer_stream, compile_layer, layer_quant_stream, DRIVER_FIXED_OVERHEAD_S,
};
pub use persist::{PersistError, Snapshot, SnapshotHeader};
pub use plan::{CacheStats, CompiledPlan, GraphKey, GraphKeyBuilder, PlanCache, PlanKey};
