//! The TFLite-delegate analogue: routes TCONV layers to the simulated
//! MM2IM accelerator (with modeled end-to-end latency = driver overhead +
//! accelerator cycles) or to the CPU baseline (real numerics + modeled A9
//! latency). Non-TCONV layers always run on the CPU path.
//!
//! Every delegate executes on a *persistent* [`Accelerator`] instance
//! (`Arc<Mutex<_>>`): cloning a delegate, or constructing one with
//! [`Delegate::with_shared_accelerator`], shares the instance, which is
//! how the coordinator gives all workers of a shard one accelerator whose
//! BRAM/weight state survives across requests. Same-layer batches go
//! through [`Delegate::run_tconv_quant_batch`], which pays one weight
//! prologue per tile and one driver dispatch for the whole batch.

use crate::accel::isa::{Instr, OutMode};
use crate::accel::{Accelerator, AccelConfig, CycleReport, ExecError, FaultInjector};
use crate::cpu::{baseline, cost_model};
use crate::driver::instructions::{compile_layer, DRIVER_FIXED_OVERHEAD_S};
use crate::driver::plan::{CacheStats, CompiledPlan, PlanCache, PlanKey};
use crate::tconv::problem::TconvProblem;
use crate::tensor::quant::PerChannel;
use crate::tensor::Tensor;
use std::sync::{Arc, Mutex};

/// Where a layer ran and what it cost (modeled PYNQ-Z1 seconds).
#[derive(Clone, Debug)]
pub struct LayerExecution {
    /// Where the layer ran.
    pub device: Device,
    /// Modeled end-to-end seconds on the PYNQ-Z1 testbed.
    pub modeled_seconds: f64,
    /// Modeled energy in joules.
    pub modeled_energy_j: f64,
    /// Accelerator cycle report (accelerated layers only).
    pub report: Option<CycleReport>,
}

/// Execution device of one layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Device {
    /// The simulated MM2IM instance.
    Accelerator,
    /// The dual-thread A9 CPU baseline.
    Cpu {
        /// CPU threads the baseline ran with.
        threads: usize,
    },
}

/// One weight variant of a shared-geometry TCONV layer, as submitted to
/// [`Delegate::run_tconv_quant_batch_multi`]: the parameters that
/// differ between chain-mate graphs while the compiled plan's geometry
/// (and therefore every tile's `Configure`) stays shared.
#[derive(Clone, Copy, Debug)]
pub struct TconvVariant<'a> {
    /// Variant filter weights, `[oc, ks, ks, ic]`.
    pub w: &'a Tensor<i8>,
    /// Variant per-channel bias.
    pub bias: &'a [i32],
    /// Variant PPU requant parameters.
    pub requant: &'a PerChannel,
}

/// The delegate: owns the accelerator configuration, the CPU-thread
/// policy for non-offloaded work, and the persistent accelerator
/// instance layer streams execute on.
#[derive(Clone)]
pub struct Delegate {
    /// Target accelerator configuration.
    pub cfg: AccelConfig,
    /// CPU threads for non-offloaded layers.
    pub cpu_threads: usize,
    /// Offload TCONVs to the accelerator (false = CPU-only baseline runs).
    pub use_accelerator: bool,
    /// Shared compiled-plan cache. `None` compiles every layer stream per
    /// call (the pre-serving behavior); the coordinator installs one
    /// cache across all workers so a layer compiles once per process.
    pub plan_cache: Option<Arc<PlanCache>>,
    /// Persistent simulated instance; clones share it, which is what
    /// makes BRAM/weight state survive across requests on one shard.
    accel: Arc<Mutex<Accelerator>>,
}

impl std::fmt::Debug for Delegate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Delegate")
            .field("cfg", &self.cfg)
            .field("cpu_threads", &self.cpu_threads)
            .field("use_accelerator", &self.use_accelerator)
            .field("plan_cache", &self.plan_cache.is_some())
            .finish_non_exhaustive()
    }
}

impl Delegate {
    /// Delegate with its own private persistent accelerator and no plan
    /// cache.
    pub fn new(cfg: AccelConfig, cpu_threads: usize, use_accelerator: bool) -> Self {
        let accel = Arc::new(Mutex::new(Accelerator::new(cfg.clone())));
        Self { cfg, cpu_threads, use_accelerator, plan_cache: None, accel }
    }

    /// Delegate whose layer programs resolve through `cache` (shared
    /// across workers via `Arc`).
    pub fn with_cache(
        cfg: AccelConfig,
        cpu_threads: usize,
        use_accelerator: bool,
        cache: Arc<PlanCache>,
    ) -> Self {
        let accel = Arc::new(Mutex::new(Accelerator::new(cfg.clone())));
        Self { cfg, cpu_threads, use_accelerator, plan_cache: Some(cache), accel }
    }

    /// Delegate sharing both the plan cache and a persistent accelerator
    /// instance (the serving path: the coordinator builds one accelerator
    /// per shard and threads it through every worker's delegate). `accel`
    /// must have been built from `cfg` — cycle accounting assumes the
    /// instance and the config agree.
    pub fn with_shared_accelerator(
        cfg: AccelConfig,
        cpu_threads: usize,
        use_accelerator: bool,
        cache: Arc<PlanCache>,
        accel: Arc<Mutex<Accelerator>>,
    ) -> Self {
        Self { cfg, cpu_threads, use_accelerator, plan_cache: Some(cache), accel }
    }

    /// Build a persistent accelerator suitable for
    /// [`Delegate::with_shared_accelerator`].
    pub fn shared_accelerator(cfg: &AccelConfig) -> Arc<Mutex<Accelerator>> {
        Arc::new(Mutex::new(Accelerator::new(cfg.clone())))
    }

    /// Cache counters (zeros when no cache is installed).
    pub fn cache_stats(&self) -> CacheStats {
        self.plan_cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// Acquire the shared accelerator, recovering from lock poisoning: a
    /// worker that panicked mid-`lock` (injected shard death, or a real
    /// bug) must not wedge every other worker of the shard. Safe because
    /// faults fire only at stream boundaries — the instance is never
    /// mid-stream when a panic unwinds — but we still drop the residency
    /// shadow on recovery so the next stream's first `LoadWeights`
    /// transfers rather than trusting post-panic BRAM state.
    fn lock_accel(&self) -> std::sync::MutexGuard<'_, Accelerator> {
        match self.accel.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                self.accel.clear_poison();
                let mut g = poisoned.into_inner();
                g.clear_resident();
                g
            }
        }
    }

    /// Install a fault injector on this delegate's (possibly shared)
    /// accelerator instance. Serving chaos legs only.
    pub fn set_fault_injector(&self, injector: FaultInjector) {
        self.lock_accel().set_fault_injector(injector);
    }

    /// Recovery probe against the underlying accelerator: `true` when the
    /// instance can execute streams. Always true without an installed
    /// fault injector.
    pub fn probe(&self) -> bool {
        self.lock_accel().probe()
    }

    /// Signature of the filter set currently resident in this delegate's
    /// (possibly shared) accelerator BRAM — `None` before the first
    /// weight load. Blocks briefly on the instance lock; intended for
    /// observability and tests, not the dispatch hot path (the
    /// coordinator's placement scorer tracks a lock-free shadow instead).
    pub fn resident_signature(&self) -> Option<crate::accel::WeightSetSig> {
        self.lock_accel().resident_signature()
    }

    /// Resolve the layer's compiled plan: through the shared plan cache
    /// when installed (compile once per process), else by compiling
    /// inline. Both paths yield byte-identical plans.
    fn layer_plan(
        &self,
        p: &TconvProblem,
        w: &Tensor<i8>,
        bias: &[i32],
        requant: Option<&PerChannel>,
        out_mode: OutMode,
    ) -> Arc<CompiledPlan> {
        match &self.plan_cache {
            Some(cache) => {
                let key = PlanKey::new(p, out_mode, &self.cfg, w, bias, requant);
                cache.get_or_compile(key, || compile_layer(p, w, bias, requant, &self.cfg, out_mode))
            }
            None => Arc::new(compile_layer(p, w, bias, requant, &self.cfg, out_mode)),
        }
    }

    /// Resolve the layer's instruction stream for one input.
    fn layer_stream(
        &self,
        p: &TconvProblem,
        x: &Tensor<i8>,
        w: &Tensor<i8>,
        bias: &[i32],
        requant: Option<&PerChannel>,
        out_mode: OutMode,
    ) -> Vec<Instr> {
        self.layer_plan(p, w, bias, requant, out_mode).instantiate(x)
    }

    /// Execute one quantized TCONV layer: returns int8 output + execution
    /// record. Numerics are identical on both devices (§V-E: "we ensured
    /// that the accelerator output matches the CPU baseline output").
    ///
    /// `Err` only ever surfaces from the accelerator path, and in
    /// practice only under fault injection (serving chaos legs) — a
    /// malformed stream is a driver bug and still reports as
    /// [`ExecError::Stream`]. The CPU path is infallible.
    pub fn run_tconv_quant(
        &self,
        p: &TconvProblem,
        x: &Tensor<i8>,
        w: &Tensor<i8>,
        bias: &[i32],
        zp_in: i32,
        requant: &PerChannel,
    ) -> Result<(Tensor<i8>, LayerExecution), ExecError> {
        if self.use_accelerator {
            // Fold the input zero-point into an adjusted bias is only
            // valid per-output-pixel; the hardware handles zp via the
            // driver pre-offsetting the input (SECDA-TFLite's approach:
            // symmetric-input fast path). We pre-offset here.
            if zp_in == 0 {
                let stream = self.layer_stream(p, x, w, bias, Some(requant), OutMode::Int8);
                let result = self.lock_accel().run_stream(&stream)?;
                let t = result.report.seconds(&self.cfg) + DRIVER_FIXED_OVERHEAD_S;
                let e = crate::accel::energy::accel_energy_j(&result.report, &self.cfg);
                return Ok((
                    result.quant,
                    LayerExecution {
                        device: Device::Accelerator,
                        modeled_seconds: t,
                        modeled_energy_j: e,
                        report: Some(result.report),
                    },
                ));
            }
            // zp_in != 0: run CPU semantics for numerics but still model
            // accelerated timing via a zero-offset equivalent stream.
            let out = baseline::tconv_quantized(p, x, w, bias, zp_in, requant, self.cpu_threads);
            let stream = self.layer_stream(p, x, w, bias, Some(requant), OutMode::Int8);
            let result = self.lock_accel().run_stream(&stream)?;
            let t = result.report.seconds(&self.cfg) + DRIVER_FIXED_OVERHEAD_S;
            let e = crate::accel::energy::accel_energy_j(&result.report, &self.cfg);
            return Ok((
                out,
                LayerExecution {
                    device: Device::Accelerator,
                    modeled_seconds: t,
                    modeled_energy_j: e,
                    report: Some(result.report),
                },
            ));
        }

        let out = baseline::tconv_quantized(p, x, w, bias, zp_in, requant, self.cpu_threads);
        let t = cost_model::tconv_seconds(p, self.cpu_threads);
        Ok((
            out,
            LayerExecution {
                device: Device::Cpu { threads: self.cpu_threads },
                modeled_seconds: t,
                modeled_energy_j: crate::accel::energy::cpu_energy_j(t, self.cpu_threads),
                report: None,
            },
        ))
    }

    /// Execute one quantized TCONV layer for a whole same-layer batch:
    /// one weight prologue per tile serves every input (the GANAX-style
    /// weight-reuse batching), and the single driver dispatch overhead is
    /// amortized across the batch. Outputs are byte-identical to calling
    /// [`Delegate::run_tconv_quant`] per input with `zp_in = 0`.
    ///
    /// The returned [`LayerExecution`] covers the *whole batch* (one
    /// timeline, one cycle report); divide by `xs.len()` for the
    /// amortized per-request cost. Requires `use_accelerator` — CPU
    /// fallback gains nothing from batching, loop per request instead.
    pub fn run_tconv_quant_batch(
        &self,
        p: &TconvProblem,
        xs: &[&Tensor<i8>],
        w: &Tensor<i8>,
        bias: &[i32],
        requant: &PerChannel,
    ) -> Result<(Vec<Tensor<i8>>, LayerExecution), ExecError> {
        assert!(!xs.is_empty(), "empty batch");
        assert!(self.use_accelerator, "batched execution targets the accelerator");
        let plan = self.layer_plan(p, w, bias, Some(requant), OutMode::Int8);
        let stream = plan.instantiate_batch(xs);
        let result = self.lock_accel().run_batch(&stream)?;
        let t = result.report.seconds(&self.cfg) + DRIVER_FIXED_OVERHEAD_S;
        let e = crate::accel::energy::accel_energy_j(&result.report, &self.cfg);
        let outs: Vec<Tensor<i8>> = result.outputs.into_iter().map(|(_raw, q)| q).collect();
        Ok((
            outs,
            LayerExecution {
                device: Device::Accelerator,
                modeled_seconds: t,
                modeled_energy_j: e,
                report: Some(result.report),
            },
        ))
    }

    /// Execute one quantized TCONV layer for a batch that spans
    /// **multiple weight variants** of the same geometry (chain-mates:
    /// graphs with equal [`crate::driver::plan::GraphKey`]s). Each
    /// request names its variant; the stream shares every tile's
    /// `Configure` across the whole batch and pays one `LoadWeights`
    /// per (tile, variant) — `instantiate_batch_multi`'s cross-graph
    /// weight-reuse. Outputs come back in request order and are
    /// byte-identical to running each request through
    /// [`Delegate::run_tconv_quant`] against its own variant with
    /// `zp_in = 0`.
    ///
    /// The returned [`LayerExecution`] covers the whole mixed batch.
    /// Requires `use_accelerator`, like
    /// [`Delegate::run_tconv_quant_batch`] (which this degenerates to
    /// when `variants.len() == 1`).
    pub fn run_tconv_quant_batch_multi(
        &self,
        p: &TconvProblem,
        variants: &[TconvVariant<'_>],
        reqs: &[(usize, &Tensor<i8>)],
    ) -> Result<(Vec<Tensor<i8>>, LayerExecution), ExecError> {
        assert!(!reqs.is_empty(), "empty batch");
        assert!(!variants.is_empty(), "no variants");
        assert!(self.use_accelerator, "batched execution targets the accelerator");
        // One plan Arc per variant: reference identity is what lets the
        // splicer coalesce same-variant requests onto one weight load.
        let plans: Vec<Arc<CompiledPlan>> = variants
            .iter()
            .map(|v| self.layer_plan(p, v.w, v.bias, Some(v.requant), OutMode::Int8))
            .collect();
        let pairs: Vec<(&CompiledPlan, &Tensor<i8>)> = reqs
            .iter()
            .map(|&(v, x)| {
                assert!(v < variants.len(), "variant index {v} out of range");
                (plans[v].as_ref(), x)
            })
            .collect();
        // Hold the accelerator across residency query + execution so the
        // queried signature is still what's resident when the stream
        // runs; the resident variant's segment then leads each tile and
        // its first load elides.
        let mut accel = self.lock_accel();
        let stream = CompiledPlan::instantiate_batch_multi(&pairs, accel.resident_signature());
        let result = accel.run_batch(&stream)?;
        drop(accel);
        let t = result.report.seconds(&self.cfg) + DRIVER_FIXED_OVERHEAD_S;
        let e = crate::accel::energy::accel_energy_j(&result.report, &self.cfg);
        let outs: Vec<Tensor<i8>> = result.outputs.into_iter().map(|(_raw, q)| q).collect();
        Ok((
            outs,
            LayerExecution {
                device: Device::Accelerator,
                modeled_seconds: t,
                modeled_energy_j: e,
                report: Some(result.report),
            },
        ))
    }

    /// Raw-accumulator TCONV (testing / f32 pipelines).
    pub fn run_tconv_raw(
        &self,
        p: &TconvProblem,
        x: &Tensor<i8>,
        w: &Tensor<i8>,
        bias: &[i32],
    ) -> Result<(Tensor<i32>, LayerExecution), ExecError> {
        if self.use_accelerator {
            let stream = self.layer_stream(p, x, w, bias, None, OutMode::Raw32);
            let result = self.lock_accel().run_stream(&stream)?;
            let t = result.report.seconds(&self.cfg) + DRIVER_FIXED_OVERHEAD_S;
            let e = crate::accel::energy::accel_energy_j(&result.report, &self.cfg);
            Ok((
                result.raw,
                LayerExecution {
                    device: Device::Accelerator,
                    modeled_seconds: t,
                    modeled_energy_j: e,
                    report: Some(result.report),
                },
            ))
        } else {
            let out = baseline::tconv_i32(p, x, w, Some(bias), self.cpu_threads);
            let t = cost_model::tconv_seconds(p, self.cpu_threads);
            Ok((
                out,
                LayerExecution {
                    device: Device::Cpu { threads: self.cpu_threads },
                    modeled_seconds: t,
                    modeled_energy_j: crate::accel::energy::cpu_energy_j(t, self.cpu_threads),
                    report: None,
                },
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn case(p: &TconvProblem, seed: u64) -> (Tensor<i8>, Tensor<i8>, Vec<i32>) {
        let mut rng = Pcg32::new(seed);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let bias: Vec<i32> = (0..p.oc).map(|i| i as i32 * 3 - 5).collect();
        (x, w, bias)
    }

    #[test]
    fn accelerator_and_cpu_agree_bit_exactly_raw() {
        let p = TconvProblem::new(5, 5, 16, 5, 12, 2);
        let (x, w, bias) = case(&p, 3);
        let acc = Delegate::new(AccelConfig::default(), 2, true);
        let cpu = Delegate::new(AccelConfig::default(), 2, false);
        let (out_a, ex_a) = acc.run_tconv_raw(&p, &x, &w, &bias).unwrap();
        let (out_c, ex_c) = cpu.run_tconv_raw(&p, &x, &w, &bias).unwrap();
        assert_eq!(out_a.data(), out_c.data());
        assert_eq!(ex_a.device, Device::Accelerator);
        assert_eq!(ex_c.device, Device::Cpu { threads: 2 });
        assert!(ex_a.modeled_seconds > 0.0 && ex_c.modeled_seconds > 0.0);
    }

    #[test]
    fn accelerator_and_cpu_agree_bit_exactly_quantized() {
        let p = TconvProblem::new(4, 4, 8, 3, 6, 2);
        let (x, w, bias) = case(&p, 4);
        let out_q = crate::tensor::quant::QuantParams { scale: 0.05, zero_point: -4 };
        let requant = PerChannel::new(0.02, &vec![0.01; p.oc], out_q);
        let acc = Delegate::new(AccelConfig::default(), 2, true);
        let cpu = Delegate::new(AccelConfig::default(), 2, false);
        let (a, _) = acc.run_tconv_quant(&p, &x, &w, &bias, 0, &requant).unwrap();
        let (c, _) = cpu.run_tconv_quant(&p, &x, &w, &bias, 0, &requant).unwrap();
        assert_eq!(a.data(), c.data());
    }

    #[test]
    fn cached_plans_match_uncached_and_compile_once() {
        let p = TconvProblem::new(5, 5, 12, 3, 10, 2);
        let (x, w, bias) = case(&p, 8);
        let out_q = crate::tensor::quant::QuantParams { scale: 0.05, zero_point: 0 };
        let requant = PerChannel::new(0.02, &vec![0.01; p.oc], out_q);
        let cache = PlanCache::shared(8);
        let cached = Delegate::with_cache(AccelConfig::default(), 1, true, cache.clone());
        let uncached = Delegate::new(AccelConfig::default(), 1, true);

        for round in 0..3 {
            let (a, ex_a) = cached.run_tconv_quant(&p, &x, &w, &bias, 0, &requant).unwrap();
            let (b, ex_b) = uncached.run_tconv_quant(&p, &x, &w, &bias, 0, &requant).unwrap();
            assert_eq!(a.data(), b.data(), "round {round}");
            // Cycle model unaffected by where the stream came from.
            assert_eq!(ex_a.modeled_seconds, ex_b.modeled_seconds, "round {round}");
        }
        let s = cached.cache_stats();
        assert_eq!(s.misses, 1, "layer compiled exactly once");
        assert_eq!(s.hits, 2);
        // A cacheless delegate reports zeros.
        let u = uncached.cache_stats();
        assert_eq!((u.hits, u.misses, u.evictions), (0, 0, 0));
        // Raw mode is a distinct key, not a collision.
        let _ = cached.run_tconv_raw(&p, &x, &w, &bias).unwrap();
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn same_layer_batch_matches_per_request_and_amortizes() {
        let p = TconvProblem::new(5, 5, 8, 3, 6, 2); // one tile (Oc=6 <= X=8)
        let (_, w, bias) = case(&p, 9);
        let out_q = crate::tensor::quant::QuantParams { scale: 0.04, zero_point: 0 };
        let requant = PerChannel::new(0.02, &vec![0.01; p.oc], out_q);
        let mut rng = Pcg32::new(10);
        let xs: Vec<Tensor<i8>> = (0..3)
            .map(|_| Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng))
            .collect();
        let refs: Vec<&Tensor<i8>> = xs.iter().collect();

        let batched = Delegate::new(AccelConfig::default(), 1, true);
        let (outs, ex) = batched.run_tconv_quant_batch(&p, &refs, &w, &bias, &requant).unwrap();
        assert_eq!(outs.len(), 3);

        // Per-request on a *fresh* delegate each time: no resident reuse,
        // the pre-batching cost.
        let mut per_request_seconds = 0.0;
        for (k, x) in xs.iter().enumerate() {
            let single = Delegate::new(AccelConfig::default(), 1, true);
            let (q, e) = single.run_tconv_quant(&p, x, &w, &bias, 0, &requant).unwrap();
            assert_eq!(outs[k].data(), q.data(), "request {k}");
            per_request_seconds += e.modeled_seconds;
        }
        assert!(
            ex.modeled_seconds < per_request_seconds,
            "batch {} vs per-request {per_request_seconds}",
            ex.modeled_seconds
        );
        let report = ex.report.expect("batch report");
        assert_eq!(report.weight_loads, 1, "one LoadWeights for the whole batch");
    }

    /// Mixed-variant batches: interleaved requests over two weight sets
    /// of one geometry match per-request execution byte-for-byte while
    /// paying (tiles x variants) weight loads instead of
    /// (tiles x requests).
    #[test]
    fn multi_variant_batch_matches_per_request_and_elides_loads() {
        let p = TconvProblem::new(4, 4, 8, 3, 20, 2); // 3 tiles over X=8
        let (_, w_a, bias_a) = case(&p, 14);
        let (_, w_b, _) = case(&p, 15);
        let bias_b: Vec<i32> = (0..p.oc).map(|i| 7 - i as i32).collect();
        let out_q = crate::tensor::quant::QuantParams { scale: 0.04, zero_point: 0 };
        let requant = PerChannel::new(0.02, &vec![0.01; p.oc], out_q);
        let variants = [
            TconvVariant { w: &w_a, bias: &bias_a, requant: &requant },
            TconvVariant { w: &w_b, bias: &bias_b, requant: &requant },
        ];
        let mut rng = Pcg32::new(16);
        let xs: Vec<Tensor<i8>> = (0..4)
            .map(|_| Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng))
            .collect();
        // Interleaved: A, B, B, A.
        let reqs: Vec<(usize, &Tensor<i8>)> =
            vec![(0, &xs[0]), (1, &xs[1]), (1, &xs[2]), (0, &xs[3])];

        let cache = PlanCache::shared(8);
        let del = Delegate::with_cache(AccelConfig::default(), 1, true, cache);
        let (outs, ex) = del.run_tconv_quant_batch_multi(&p, &variants, &reqs).unwrap();
        assert_eq!(outs.len(), 4);
        let report = ex.report.expect("batch report");
        assert_eq!(report.weight_loads, 3 * 2, "tiles x variants");

        for (k, &(v, x)) in reqs.iter().enumerate() {
            let solo = Delegate::new(AccelConfig::default(), 1, true);
            let (q, _) = solo
                .run_tconv_quant(&p, x, variants[v].w, variants[v].bias, 0, variants[v].requant)
                .unwrap();
            assert_eq!(outs[k].data(), q.data(), "request {k}");
        }
    }

    #[test]
    fn driver_overhead_included_in_modeled_time() {
        let p = TconvProblem::new(2, 2, 4, 3, 2, 1); // tiny layer
        let (x, w, bias) = case(&p, 5);
        let acc = Delegate::new(AccelConfig::default(), 2, true);
        let (_, ex) = acc.run_tconv_raw(&p, &x, &w, &bias).unwrap();
        assert!(ex.modeled_seconds >= DRIVER_FIXED_OVERHEAD_S);
    }

    #[test]
    fn big_ic_layer_beats_cpu_small_layer_does_not_much() {
        // the paper's Fig. 6 dynamic in one test
        let big = TconvProblem::new(9, 9, 256, 5, 16, 1);
        let tiny = TconvProblem::new(2, 2, 4, 3, 2, 1);
        for (p, expect_speedup) in [(big, true), (tiny, false)] {
            let (x, w, bias) = case(&p, 6);
            let acc = Delegate::new(AccelConfig::default(), 2, true);
            let cpu = Delegate::new(AccelConfig::default(), 2, false);
            let (_, ex_a) = acc.run_tconv_raw(&p, &x, &w, &bias).unwrap();
            let (_, ex_c) = cpu.run_tconv_raw(&p, &x, &w, &bias).unwrap();
            let speedup = ex_c.modeled_seconds / ex_a.modeled_seconds;
            if expect_speedup {
                assert!(speedup > 1.5, "{p}: speedup {speedup}");
            } else {
                assert!(speedup < 1.5, "{p}: speedup {speedup}");
            }
        }
    }
}
