//! The TFLite-delegate analogue: routes TCONV layers to the simulated
//! MM2IM accelerator (with modeled end-to-end latency = driver overhead +
//! accelerator cycles) or to the CPU baseline (real numerics + modeled A9
//! latency). Non-TCONV layers always run on the CPU path.

use crate::accel::isa::{Instr, OutMode};
use crate::accel::{Accelerator, AccelConfig, CycleReport};
use crate::cpu::{baseline, cost_model};
use crate::driver::instructions::{build_layer_stream, compile_layer, DRIVER_FIXED_OVERHEAD_S};
use crate::driver::plan::{CacheStats, PlanCache, PlanKey};
use crate::tconv::problem::TconvProblem;
use crate::tensor::quant::PerChannel;
use crate::tensor::Tensor;
use std::sync::Arc;

/// Where a layer ran and what it cost (modeled PYNQ-Z1 seconds).
#[derive(Clone, Debug)]
pub struct LayerExecution {
    pub device: Device,
    /// Modeled end-to-end seconds on the PYNQ-Z1 testbed.
    pub modeled_seconds: f64,
    /// Modeled energy in joules.
    pub modeled_energy_j: f64,
    /// Accelerator cycle report (accelerated layers only).
    pub report: Option<CycleReport>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Device {
    Accelerator,
    Cpu { threads: usize },
}

/// The delegate: owns the accelerator configuration and the CPU-thread
/// policy for non-offloaded work.
#[derive(Clone, Debug)]
pub struct Delegate {
    pub cfg: AccelConfig,
    pub cpu_threads: usize,
    /// Offload TCONVs to the accelerator (false = CPU-only baseline runs).
    pub use_accelerator: bool,
    /// Shared compiled-plan cache. `None` compiles every layer stream per
    /// call (the pre-serving behavior); the coordinator installs one
    /// cache across all workers so a layer compiles once per process.
    pub plan_cache: Option<Arc<PlanCache>>,
}

impl Delegate {
    pub fn new(cfg: AccelConfig, cpu_threads: usize, use_accelerator: bool) -> Self {
        Self { cfg, cpu_threads, use_accelerator, plan_cache: None }
    }

    /// Delegate whose layer programs resolve through `cache` (shared
    /// across workers via `Arc`).
    pub fn with_cache(
        cfg: AccelConfig,
        cpu_threads: usize,
        use_accelerator: bool,
        cache: Arc<PlanCache>,
    ) -> Self {
        Self { cfg, cpu_threads, use_accelerator, plan_cache: Some(cache) }
    }

    /// Cache counters (zeros when no cache is installed).
    pub fn cache_stats(&self) -> CacheStats {
        self.plan_cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// Resolve the layer's instruction stream: through the shared plan
    /// cache when installed (compile once, splice input rows per call),
    /// else by compiling inline. Both paths emit byte-identical streams.
    fn layer_stream(
        &self,
        p: &TconvProblem,
        x: &Tensor<i8>,
        w: &Tensor<i8>,
        bias: &[i32],
        requant: Option<&PerChannel>,
        out_mode: OutMode,
    ) -> Vec<Instr> {
        match &self.plan_cache {
            Some(cache) => {
                let key = PlanKey::new(p, out_mode, &self.cfg, w, bias, requant);
                let plan = cache
                    .get_or_compile(key, || compile_layer(p, w, bias, requant, &self.cfg, out_mode));
                plan.instantiate(x)
            }
            None => build_layer_stream(p, x, w, bias, requant, &self.cfg, out_mode),
        }
    }

    /// Execute one quantized TCONV layer: returns int8 output + execution
    /// record. Numerics are identical on both devices (§V-E: "we ensured
    /// that the accelerator output matches the CPU baseline output").
    pub fn run_tconv_quant(
        &self,
        p: &TconvProblem,
        x: &Tensor<i8>,
        w: &Tensor<i8>,
        bias: &[i32],
        zp_in: i32,
        requant: &PerChannel,
    ) -> (Tensor<i8>, LayerExecution) {
        if self.use_accelerator {
            // Fold the input zero-point into an adjusted bias is only
            // valid per-output-pixel; the hardware handles zp via the
            // driver pre-offsetting the input (SECDA-TFLite's approach:
            // symmetric-input fast path). We pre-offset here.
            if zp_in == 0 {
                let stream = self.layer_stream(p, x, w, bias, Some(requant), OutMode::Int8);
                let result = Accelerator::new(self.cfg.clone())
                    .execute(&stream)
                    .expect("accelerator execution");
                let t = result.report.seconds(&self.cfg) + DRIVER_FIXED_OVERHEAD_S;
                let e = crate::accel::energy::accel_energy_j(&result.report, &self.cfg);
                return (
                    result.quant,
                    LayerExecution {
                        device: Device::Accelerator,
                        modeled_seconds: t,
                        modeled_energy_j: e,
                        report: Some(result.report),
                    },
                );
            }
            // zp_in != 0: run CPU semantics for numerics but still model
            // accelerated timing via a zero-offset equivalent stream.
            let out = baseline::tconv_quantized(p, x, w, bias, zp_in, requant, self.cpu_threads);
            let stream = self.layer_stream(p, x, w, bias, Some(requant), OutMode::Int8);
            let result = Accelerator::new(self.cfg.clone())
                .execute(&stream)
                .expect("accelerator execution");
            let t = result.report.seconds(&self.cfg) + DRIVER_FIXED_OVERHEAD_S;
            let e = crate::accel::energy::accel_energy_j(&result.report, &self.cfg);
            return (
                out,
                LayerExecution {
                    device: Device::Accelerator,
                    modeled_seconds: t,
                    modeled_energy_j: e,
                    report: Some(result.report),
                },
            );
        }

        let out = baseline::tconv_quantized(p, x, w, bias, zp_in, requant, self.cpu_threads);
        let t = cost_model::tconv_seconds(p, self.cpu_threads);
        (
            out,
            LayerExecution {
                device: Device::Cpu { threads: self.cpu_threads },
                modeled_seconds: t,
                modeled_energy_j: crate::accel::energy::cpu_energy_j(t, self.cpu_threads),
                report: None,
            },
        )
    }

    /// Raw-accumulator TCONV (testing / f32 pipelines).
    pub fn run_tconv_raw(
        &self,
        p: &TconvProblem,
        x: &Tensor<i8>,
        w: &Tensor<i8>,
        bias: &[i32],
    ) -> (Tensor<i32>, LayerExecution) {
        if self.use_accelerator {
            let stream = self.layer_stream(p, x, w, bias, None, OutMode::Raw32);
            let result = Accelerator::new(self.cfg.clone())
                .execute(&stream)
                .expect("accelerator execution");
            let t = result.report.seconds(&self.cfg) + DRIVER_FIXED_OVERHEAD_S;
            let e = crate::accel::energy::accel_energy_j(&result.report, &self.cfg);
            (
                result.raw,
                LayerExecution {
                    device: Device::Accelerator,
                    modeled_seconds: t,
                    modeled_energy_j: e,
                    report: Some(result.report),
                },
            )
        } else {
            let out = baseline::tconv_i32(p, x, w, Some(bias), self.cpu_threads);
            let t = cost_model::tconv_seconds(p, self.cpu_threads);
            (
                out,
                LayerExecution {
                    device: Device::Cpu { threads: self.cpu_threads },
                    modeled_seconds: t,
                    modeled_energy_j: crate::accel::energy::cpu_energy_j(t, self.cpu_threads),
                    report: None,
                },
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn case(p: &TconvProblem, seed: u64) -> (Tensor<i8>, Tensor<i8>, Vec<i32>) {
        let mut rng = Pcg32::new(seed);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let bias: Vec<i32> = (0..p.oc).map(|i| i as i32 * 3 - 5).collect();
        (x, w, bias)
    }

    #[test]
    fn accelerator_and_cpu_agree_bit_exactly_raw() {
        let p = TconvProblem::new(5, 5, 16, 5, 12, 2);
        let (x, w, bias) = case(&p, 3);
        let acc = Delegate::new(AccelConfig::default(), 2, true);
        let cpu = Delegate::new(AccelConfig::default(), 2, false);
        let (out_a, ex_a) = acc.run_tconv_raw(&p, &x, &w, &bias);
        let (out_c, ex_c) = cpu.run_tconv_raw(&p, &x, &w, &bias);
        assert_eq!(out_a.data(), out_c.data());
        assert_eq!(ex_a.device, Device::Accelerator);
        assert_eq!(ex_c.device, Device::Cpu { threads: 2 });
        assert!(ex_a.modeled_seconds > 0.0 && ex_c.modeled_seconds > 0.0);
    }

    #[test]
    fn accelerator_and_cpu_agree_bit_exactly_quantized() {
        let p = TconvProblem::new(4, 4, 8, 3, 6, 2);
        let (x, w, bias) = case(&p, 4);
        let out_q = crate::tensor::quant::QuantParams { scale: 0.05, zero_point: -4 };
        let requant = PerChannel::new(0.02, &vec![0.01; p.oc], out_q);
        let acc = Delegate::new(AccelConfig::default(), 2, true);
        let cpu = Delegate::new(AccelConfig::default(), 2, false);
        let (a, _) = acc.run_tconv_quant(&p, &x, &w, &bias, 0, &requant);
        let (c, _) = cpu.run_tconv_quant(&p, &x, &w, &bias, 0, &requant);
        assert_eq!(a.data(), c.data());
    }

    #[test]
    fn cached_plans_match_uncached_and_compile_once() {
        let p = TconvProblem::new(5, 5, 12, 3, 10, 2);
        let (x, w, bias) = case(&p, 8);
        let out_q = crate::tensor::quant::QuantParams { scale: 0.05, zero_point: 0 };
        let requant = PerChannel::new(0.02, &vec![0.01; p.oc], out_q);
        let cache = PlanCache::shared(8);
        let cached = Delegate::with_cache(AccelConfig::default(), 1, true, cache.clone());
        let uncached = Delegate::new(AccelConfig::default(), 1, true);

        for round in 0..3 {
            let (a, ex_a) = cached.run_tconv_quant(&p, &x, &w, &bias, 0, &requant);
            let (b, ex_b) = uncached.run_tconv_quant(&p, &x, &w, &bias, 0, &requant);
            assert_eq!(a.data(), b.data(), "round {round}");
            // Cycle model unaffected by where the stream came from.
            assert_eq!(ex_a.modeled_seconds, ex_b.modeled_seconds, "round {round}");
        }
        let s = cached.cache_stats();
        assert_eq!(s.misses, 1, "layer compiled exactly once");
        assert_eq!(s.hits, 2);
        // A cacheless delegate reports zeros.
        let u = uncached.cache_stats();
        assert_eq!((u.hits, u.misses, u.evictions), (0, 0, 0));
        // Raw mode is a distinct key, not a collision.
        let _ = cached.run_tconv_raw(&p, &x, &w, &bias);
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn driver_overhead_included_in_modeled_time() {
        let p = TconvProblem::new(2, 2, 4, 3, 2, 1); // tiny layer
        let (x, w, bias) = case(&p, 5);
        let acc = Delegate::new(AccelConfig::default(), 2, true);
        let (_, ex) = acc.run_tconv_raw(&p, &x, &w, &bias);
        assert!(ex.modeled_seconds >= DRIVER_FIXED_OVERHEAD_S);
    }

    #[test]
    fn big_ic_layer_beats_cpu_small_layer_does_not_much() {
        // the paper's Fig. 6 dynamic in one test
        let big = TconvProblem::new(9, 9, 256, 5, 16, 1);
        let tiny = TconvProblem::new(2, 2, 4, 3, 2, 1);
        for (p, expect_speedup) in [(big, true), (tiny, false)] {
            let (x, w, bias) = case(&p, 6);
            let acc = Delegate::new(AccelConfig::default(), 2, true);
            let cpu = Delegate::new(AccelConfig::default(), 2, false);
            let (_, ex_a) = acc.run_tconv_raw(&p, &x, &w, &bias);
            let (_, ex_c) = cpu.run_tconv_raw(&p, &x, &w, &bias);
            let speedup = ex_c.modeled_seconds / ex_a.modeled_seconds;
            if expect_speedup {
                assert!(speedup > 1.5, "{p}: speedup {speedup}");
            } else {
                assert!(speedup < 1.5, "{p}: speedup {speedup}");
            }
        }
    }
}
