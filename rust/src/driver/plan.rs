//! Compile/execute split for the host driver: a layer's Algorithm-1
//! program as a reusable artifact, plus the keyed cache that shares it
//! across serving workers.
//!
//! The paper's accelerator amortizes mapping work in hardware (maps are
//! generated once per row and broadcast, §IV-E); this module applies the
//! same idea one level up. Everything Algorithm 1 derives that does *not*
//! depend on the input activations — tile decomposition, filter payloads
//! (weights + bias + PPU requant), and the `i_end_row` streaming schedule
//! — is captured once as a [`CompiledPlan`]. Serving a request then only
//! splices the request's input rows into the plan
//! ([`CompiledPlan::instantiate`]), instead of re-walking the layer and
//! re-packing filter payloads per request.
//!
//! # Weight prologue vs row schedule
//!
//! Each [`PlanTile`] splits cleanly into a *weight prologue* (the
//! `Configure` + `LoadWeights` pair, input-independent and by far the
//! most expensive transfer of the tile) and a *row schedule* (the
//! [`RowOp`] list, which only needs a request's input rows spliced in).
//! [`CompiledPlan::instantiate`] replays prologue + schedule for one
//! input; [`CompiledPlan::instantiate_batch`] emits the prologue **once
//! per tile** and then splices every request's row schedule behind
//! `SelectOutput` markers — N same-layer requests pay one weight load per
//! tile instead of N (the GANAX/HUGE2-style weight-reuse batching the
//! serving layer schedules; see `coordinator`).
//!
//! ```
//! use mm2im::accel::isa::{Instr, OutMode};
//! use mm2im::accel::AccelConfig;
//! use mm2im::driver::compile_layer;
//! use mm2im::tconv::TconvProblem;
//! use mm2im::tensor::Tensor;
//! use mm2im::util::rng::Pcg32;
//!
//! let p = TconvProblem::new(4, 4, 8, 3, 20, 2); // 20 channels over X=8: 3 tiles
//! let mut rng = Pcg32::new(1);
//! let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
//! let xs: Vec<Tensor<i8>> = (0..4)
//!     .map(|_| Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng))
//!     .collect();
//! let plan = compile_layer(&p, &w, &vec![0; p.oc], None, &AccelConfig::default(), OutMode::Raw32);
//!
//! // Per-request: one LoadWeights per tile *per request* (4 * 3 = 12).
//! // Batched: one LoadWeights per tile for the whole batch (3).
//! let count = |s: &[Instr]| s.iter().filter(|i| matches!(i, Instr::LoadWeights(_))).count();
//! let per_request: usize = xs.iter().map(|x| count(&plan.instantiate(x))).sum();
//! let refs: Vec<&Tensor<i8>> = xs.iter().collect();
//! let batched = count(&plan.instantiate_batch(&refs));
//! assert_eq!(per_request, 4 * plan.tiles.len());
//! assert_eq!(batched, plan.tiles.len());
//! ```
//!
//! # Cache keying
//!
//! [`PlanKey`] identifies a plan by the [`TconvProblem`] geometry, the
//! [`OutMode`], a fingerprint of the full [`AccelConfig`] (any field that
//! could change the stream or its cycle accounting), and a fingerprint of
//! the layer parameters (weights, bias, requant). The parameter
//! fingerprint matters: two layers with identical geometry but different
//! weights — common inside one GAN — must not collide. [`PlanCache`] is a
//! bounded, LRU-evicting map shared across workers (`Arc<PlanCache>`);
//! compilation happens under the cache lock so each key is compiled
//! exactly once no matter how many workers race on a cold entry. The same
//! key doubles as the serving layer's *reuse-detection* handle: requests
//! whose layers resolve to equal keys can be batched onto one weight
//! prologue.

use crate::accel::config::AccelConfig;
use crate::accel::isa::{Instr, OutMode, RowSlice, TileConfig, WeightSet};
use crate::accel::WeightSetSig;
use crate::tconv::problem::TconvProblem;
use crate::telemetry::{Counter, Tree};
use crate::tensor::quant::PerChannel;
use crate::tensor::Tensor;
use crate::util::hash::Fnv;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

/// Input-independent row operation inside one output-channel tile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowOp {
    /// Stream input rows `[first_row, first_row + count)` to the Row
    /// Buffer (Algorithm 1's `SendInputRows`).
    SendRows {
        /// First input row of the burst.
        first_row: usize,
        /// Rows in the burst.
        count: usize,
    },
    /// Compute one output row on all active PMs (`ComputeOutRow`).
    Compute {
        /// Output row index.
        out_row: usize,
    },
    /// Drain one output row through the crossbar (`StoreOutRow`).
    Store {
        /// Output row index.
        out_row: usize,
    },
}

/// One `filter_step` tile of a compiled layer program: the weight
/// prologue (`config` + `weights`) plus the input-agnostic row schedule
/// (`ops`).
#[derive(Clone, Debug)]
pub struct PlanTile {
    /// Opcode-0x01 operands for this tile.
    pub config: TileConfig,
    /// Pre-packed opcode-0x02 payloads (weights, bias, requant) with
    /// their resident-set signature — both the packing *and* the
    /// signature hash are paid once at compile time; instantiation and
    /// execution only bump `Arc`s and compare signatures.
    pub weights: WeightSet,
    /// The Algorithm-1 row walk; input rows are spliced in at
    /// instantiation time.
    pub ops: Vec<RowOp>,
}

impl PlanTile {
    /// The tile's weight prologue: the `Configure`/`LoadWeights` pair a
    /// batched stream emits exactly once regardless of batch size. The
    /// clone is shallow — filter bytes are `Arc`-shared with the plan.
    pub fn prologue(&self) -> [Instr; 2] {
        [Instr::Configure(self.config.clone()), Instr::LoadWeights(self.weights.clone())]
    }
}

/// A TCONV layer's reusable program: the full Algorithm-1 walk minus the
/// input activations. Built by [`crate::driver::instructions::compile_layer`].
#[derive(Clone, Debug)]
pub struct CompiledPlan {
    /// Geometry the plan was compiled for.
    pub problem: TconvProblem,
    /// Output mode baked into every tile's `Configure`.
    pub out_mode: OutMode,
    /// One entry per `filter_step` output-channel tile.
    pub tiles: Vec<PlanTile>,
}

impl CompiledPlan {
    /// Instructions one instantiation emits (for capacity pre-allocation
    /// and serving metrics).
    pub fn instr_count(&self) -> usize {
        self.tiles.iter().map(|t| 2 + t.ops.len()).sum()
    }

    /// Instructions a batched instantiation over `requests` inputs emits:
    /// one prologue per tile, then per request one `SelectOutput` marker
    /// plus the spliced row schedule.
    pub fn batch_instr_count(&self, requests: usize) -> usize {
        self.tiles.iter().map(|t| 2 + requests * (1 + t.ops.len())).sum()
    }

    /// Splice a request's input tensor into the plan, yielding the exact
    /// stream `build_layer_stream` would produce for `x`.
    pub fn instantiate(&self, x: &Tensor<i8>) -> Vec<Instr> {
        let mut stream = Vec::with_capacity(self.instr_count());
        for tile in &self.tiles {
            stream.extend(tile.prologue());
            self.splice_rows(&mut stream, tile, x);
        }
        stream
    }

    /// Resident-set signature of tile `tile`'s weight prologue — exactly
    /// the signature `accel::Accelerator` stores as resident when the
    /// tile's `LoadWeights` executes, so driver-side code can predict
    /// the resident-skip without touching an instance. Computed once at
    /// compile time and stored in the tile (no rehash here).
    pub fn tile_weight_sig(&self, tile: usize) -> WeightSetSig {
        self.tiles[tile].weights.sig()
    }

    /// Signature of the *first* weight load a stream instantiated from
    /// this plan issues (tile 0). A shard whose accelerator's resident
    /// signature equals this skips the stream's opening weight transfer.
    pub fn first_weight_sig(&self) -> WeightSetSig {
        self.tile_weight_sig(0)
    }

    /// Signature of the *last* weight load the stream issues — i.e. what
    /// remains resident in PM BRAM after the stream completes.
    pub fn last_weight_sig(&self) -> WeightSetSig {
        self.tile_weight_sig(self.tiles.len() - 1)
    }

    /// Splice a whole same-layer batch into one stream: each tile's
    /// weight prologue is emitted exactly once, then every request's row
    /// schedule follows behind a `SelectOutput` marker (slot = position
    /// in `xs`). Executing the result with
    /// [`run_batch`](crate::accel::Accelerator::run_batch) yields outputs
    /// byte-identical to running [`CompiledPlan::instantiate`] per
    /// request — the only difference is N-1 elided weight loads per tile.
    pub fn instantiate_batch(&self, xs: &[&Tensor<i8>]) -> Vec<Instr> {
        assert!(!xs.is_empty(), "empty batch");
        let mut stream = Vec::with_capacity(self.batch_instr_count(xs.len()));
        for tile in &self.tiles {
            stream.extend(tile.prologue());
            for (slot, x) in xs.iter().enumerate() {
                stream.push(Instr::SelectOutput { slot });
                self.splice_rows(&mut stream, tile, x);
            }
        }
        stream
    }

    /// Splice a *mixed-variant* batch into one stream: requests may come
    /// from **different compiled plans**, as long as every plan shares
    /// the lead plan's geometry (`problem`, `out_mode`, tile
    /// decomposition — i.e. the weight-independent [`PlanKey`]
    /// projection a [`GraphKey`] chain digests), differing only in
    /// parameter values (weights / bias / requant). Per tile the stream
    /// emits **one** `Configure` — tile configs are weight-free, so
    /// chain-mates agree on them byte-for-byte (asserted) — then for
    /// each distinct plan, in order of first appearance in `reqs`, one
    /// `LoadWeights` followed by that plan's requests' `SelectOutput` +
    /// spliced row schedules. Slots equal each request's position in
    /// `reqs`, so [`run_batch`](crate::accel::Accelerator::run_batch)
    /// outputs line up with submission order regardless of how requests
    /// interleave variants.
    ///
    /// Weight loads per tile: *distinct plans*, not requests — the
    /// cross-graph generalization of [`CompiledPlan::instantiate_batch`]
    /// (which this degenerates to when all requests share one plan).
    /// Plans are distinguished by reference identity: resolve each
    /// variant through one [`PlanCache`] (or reuse one `Arc` per
    /// variant) so chain-mates of the same variant coalesce onto one
    /// weight load.
    ///
    /// `resident` is the signature of the filter set currently in PM
    /// BRAM ([`crate::accel::Accelerator::resident_signature`]), if
    /// known. When it matches a variant's first-tile weights, that
    /// variant's segment is rotated to the front of every tile so the
    /// accelerator's resident-skip elides its first `LoadWeights` —
    /// segment order is free (each request's rows follow its own
    /// `SelectOutput`, so outputs are slot-addressed and byte-identical
    /// under any segment permutation), and this residency-aware ordering
    /// is what lets chain batches *strictly* beat graph-identity
    /// grouping on performed weight loads under alternating traffic.
    pub fn instantiate_batch_multi(
        reqs: &[(&CompiledPlan, &Tensor<i8>)],
        resident: Option<WeightSetSig>,
    ) -> Vec<Instr> {
        assert!(!reqs.is_empty(), "empty batch");
        let lead = reqs[0].0;
        // Group request slots by plan identity, preserving the order of
        // first appearance (deterministic stream for a given submission
        // order).
        let mut groups: Vec<(&CompiledPlan, Vec<usize>)> = Vec::new();
        for (slot, (plan, _)) in reqs.iter().enumerate() {
            assert_eq!(plan.problem, lead.problem, "mixed-geometry batch");
            assert_eq!(plan.out_mode, lead.out_mode, "mixed-out-mode batch");
            assert_eq!(plan.tiles.len(), lead.tiles.len(), "tile decomposition diverged");
            match groups.iter_mut().find(|(g, _)| std::ptr::eq(*g, *plan)) {
                Some((_, slots)) => slots.push(slot),
                None => groups.push((plan, vec![slot])),
            }
        }
        // Residency-aware segment order: lead with the variant whose
        // first-tile weights are already resident, if any; the rest keep
        // first-appearance order.
        if let Some(sig) = resident {
            if let Some(pos) = groups.iter().position(|(p, _)| p.tile_weight_sig(0) == sig) {
                let hit = groups.remove(pos);
                groups.insert(0, hit);
            }
        }
        let cap: usize = lead
            .tiles
            .iter()
            .map(|t| 1 + groups.len() + reqs.len() * (1 + t.ops.len()))
            .sum();
        let mut stream = Vec::with_capacity(cap);
        for t in 0..lead.tiles.len() {
            stream.push(Instr::Configure(lead.tiles[t].config.clone()));
            for (plan, slots) in &groups {
                let tile = &plan.tiles[t];
                assert_eq!(
                    tile.config, lead.tiles[t].config,
                    "chain-mate tile configs must agree to share one Configure"
                );
                stream.push(Instr::LoadWeights(tile.weights.clone()));
                for &slot in slots {
                    stream.push(Instr::SelectOutput { slot });
                    plan.splice_rows(&mut stream, tile, reqs[slot].1);
                }
            }
        }
        stream
    }

    /// Append one request's instantiated row schedule for `tile`.
    /// Zero-copy: every `LoadInput` row is a [`RowSlice`] aliasing the
    /// request tensor's own buffer (an `Arc` bump per row, never a byte
    /// copy — the old path copied the whole input once per tile).
    fn splice_rows(&self, stream: &mut Vec<Instr>, tile: &PlanTile, x: &Tensor<i8>) {
        let p = &self.problem;
        assert_eq!(x.shape(), &[p.ih, p.iw, p.ic], "plan/input shape mismatch");
        let buf = x.shared_data();
        let row_bytes = p.iw * p.ic;
        for op in &tile.ops {
            match *op {
                RowOp::SendRows { first_row, count } => {
                    let rows: Vec<RowSlice> = (first_row..first_row + count)
                        .map(|r| RowSlice::new(Arc::clone(&buf), r * row_bytes, row_bytes))
                        .collect();
                    stream.push(Instr::LoadInput { first_row, rows });
                }
                RowOp::Compute { out_row } => stream.push(Instr::Schedule { out_row }),
                RowOp::Store { out_row } => stream.push(Instr::StoreOutput { out_row }),
            }
        }
    }
}

/// Identity of a compiled plan in the shared cache.
///
/// Parameters (weights, bias, requant) are identified by *two*
/// independent 64-bit FNV-1a digests (different bases), so an accidental
/// collision between two same-geometry layers needs a simultaneous
/// 128-bit match — negligible even across adversarially large model
/// zoos. The expensive part — the O(|w|) pass over the weight tensor —
/// is **memoized per tensor buffer** ([`Tensor::fingerprint`]): the
/// first lookup for a layer digests its weights once, and every later
/// lookup over the graph's lifetime folds the cached pair plus the cheap
/// O(Oc) bias/requant words. (This closes the ROADMAP item about
/// re-hashing the full weight tensor on every lookup.)
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Layer geometry the plan was compiled for.
    pub problem: TconvProblem,
    /// Output mode baked into the plan's `Configure` operands.
    pub out_mode: OutMode,
    /// [`AccelConfig::fingerprint`] of the target instance.
    pub cfg_fp: u64,
    /// First parameter digest (standard FNV-1a basis).
    pub params_fp: u64,
    /// Second parameter digest (alternate basis).
    pub params_fp2: u64,
}

impl PlanKey {
    /// Build the cache key for one layer execution: folds the memoized
    /// weight-tensor digest with the bias/requant words and fingerprints
    /// the target config. Cost after the first call for a given weight
    /// buffer: O(Oc), independent of |w|.
    pub fn new(
        p: &TconvProblem,
        out_mode: OutMode,
        cfg: &AccelConfig,
        w: &Tensor<i8>,
        bias: &[i32],
        requant: Option<&PerChannel>,
    ) -> Self {
        let (w_fp, w_fp2) = w.fingerprint();
        let mut fp = Fnv::new();
        let mut fp2 = Fnv::with_basis(Fnv::ALT_BASIS);
        fp.word(w_fp);
        fp2.word(w_fp2);
        let mut put_word = |v: u64| {
            fp.word(v);
            fp2.word(v);
        };
        for &b in bias {
            put_word(b as u32 as u64);
        }
        if let Some(r) = requant {
            for m in &r.mults {
                put_word(m.m as u32 as u64);
                put_word(m.shift as u32 as u64);
            }
            put_word(r.zp_out as u32 as u64);
        }
        Self {
            problem: *p,
            out_mode,
            cfg_fp: cfg.fingerprint(),
            params_fp: fp.finish(),
            params_fp2: fp2.finish(),
        }
    }

    /// Stable 64-bit digest of the whole key — geometry, mapper, output
    /// mode, config fingerprint, and both parameter digests. This is
    /// the label the telemetry tree files the plan's per-plan node
    /// under (`plans/<fingerprint-hex>/…`), so one plan keeps one node
    /// across servers and restarts.
    pub fn fingerprint(&self) -> u64 {
        let p = &self.problem;
        let mut fp = Fnv::new();
        for w in [p.ih, p.iw, p.ic, p.ks, p.oc, p.stride] {
            fp.word(w as u64);
        }
        fp.word(match p.mapper {
            crate::tconv::problem::MapperKind::Overlapped => 0,
            crate::tconv::problem::MapperKind::Segregated => 1,
        });
        fp.word(match self.out_mode {
            OutMode::Raw32 => 0,
            OutMode::Int8 => 1,
        });
        fp.word(self.cfg_fp);
        fp.word(self.params_fp);
        fp.word(self.params_fp2);
        fp.finish()
    }
}

/// Weight-independent identity of a graph's compiled layer chain.
///
/// Two graphs whose layers compile to the same `PlanKey` *sequence
/// modulo parameter fingerprints* — identical TCONV geometry (including
/// the [`MapperKind`](crate::tconv::problem::MapperKind)), output
/// modes, accelerator config, and non-TCONV structure — produce equal
/// `GraphKey`s even when their weights differ. The serving layer keys
/// batch grouping on this: chain-mates share every tile's `Configure`
/// and row schedule, so their requests can ride one weight-reuse batch
/// ([`CompiledPlan::instantiate_batch_multi`]) with one `LoadWeights`
/// per (tile, variant) instead of per (tile, request). Built once per
/// graph at server registration and memoized.
///
/// Like [`PlanKey`]'s parameter fingerprint, the digest is a pair of
/// independent 64-bit FNV-1a streams: an accidental chain collision
/// needs a simultaneous 128-bit match.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GraphKey {
    fp: u64,
    fp2: u64,
}

impl GraphKey {
    /// Start an incremental chain digest.
    pub fn builder() -> GraphKeyBuilder {
        GraphKeyBuilder { fp: Fnv::new(), fp2: Fnv::with_basis(Fnv::ALT_BASIS) }
    }
}

/// Incremental [`GraphKey`] digest: fold structural words for non-TCONV
/// layers and [`GraphKeyBuilder::chain_link`] for each compiled TCONV
/// layer, then [`GraphKeyBuilder::finish`].
#[derive(Debug)]
pub struct GraphKeyBuilder {
    fp: Fnv,
    fp2: Fnv,
}

impl GraphKeyBuilder {
    /// Fold one structural word into both digest streams.
    pub fn word(&mut self, v: u64) -> &mut Self {
        self.fp.word(v);
        self.fp2.word(v);
        self
    }

    /// Fold the weight-independent projection of one layer's [`PlanKey`]:
    /// the full `TconvProblem` geometry (mapper kind included), the
    /// output mode, and the config fingerprint — **not**
    /// `params_fp`/`params_fp2`, which is exactly what lets two
    /// same-shape graphs with different weights share a chain.
    pub fn chain_link(&mut self, key: &PlanKey) -> &mut Self {
        let p = &key.problem;
        for w in [p.ih, p.iw, p.ic, p.ks, p.oc, p.stride] {
            self.word(w as u64);
        }
        self.word(match p.mapper {
            crate::tconv::problem::MapperKind::Overlapped => 0,
            crate::tconv::problem::MapperKind::Segregated => 1,
        });
        self.word(match key.out_mode {
            OutMode::Raw32 => 0,
            OutMode::Int8 => 1,
        });
        self.word(key.cfg_fp);
        self
    }

    /// Finish the digest.
    pub fn finish(&self) -> GraphKey {
        GraphKey { fp: self.fp.finish(), fp2: self.fp2.finish() }
    }
}

/// Aggregate cache counters, snapshotted by [`PlanCache::stats`].
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Lookups served by a resident plan.
    pub hits: u64,
    /// Lookups that had to compile (includes re-compiles after eviction).
    pub misses: u64,
    /// Plans dropped by the LRU bound.
    pub evictions: u64,
}

impl CacheStats {
    /// Fraction of lookups served from the cache (0 when never queried).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Live handles into an attached telemetry tree (see
/// [`PlanCache::attach_telemetry`]).
#[derive(Debug)]
struct CacheTelem {
    tree: Arc<Tree>,
    hits: Counter,
    misses: Counter,
    evictions: Counter,
}

#[derive(Debug)]
struct CacheInner {
    map: HashMap<PlanKey, Arc<CompiledPlan>>,
    /// Recency order, front = least recently used.
    lru: VecDeque<PlanKey>,
    stats: CacheStats,
    telem: Option<CacheTelem>,
}

/// Bounded, shared compiled-plan cache. Clone the `Arc` into every
/// worker; hit/miss counters feed `ServeStats`.
#[derive(Debug)]
pub struct PlanCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
}

impl PlanCache {
    /// `capacity` is in plans (>= 1); a typical graph needs one per
    /// distinct TCONV layer.
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                lru: VecDeque::new(),
                stats: CacheStats::default(),
                telem: None,
            }),
            capacity: capacity.max(1),
        }
    }

    /// Convenience: a cache already wrapped for sharing across workers.
    pub fn shared(capacity: usize) -> Arc<Self> {
        Arc::new(Self::new(capacity))
    }

    /// Mirror the cache's counters into `tree`: aggregate totals under
    /// `cache/{hits,misses,evictions}` plus a per-plan
    /// `plans/<fingerprint-hex>/{hits,compiles}` node for every key
    /// subsequently looked up. Activity recorded *before* attachment is
    /// carried into the aggregate counters, so the tree's totals always
    /// equal [`PlanCache::stats`] — the invariant
    /// `ServeStats::from_snapshot` relies on. Attaching a new tree
    /// replaces the previous one (a cache outliving a server re-homes
    /// its counters on the next server's tree).
    pub fn attach_telemetry(&self, tree: &Arc<Tree>) {
        let mut inner = self.inner.lock().unwrap();
        let node = tree.node("cache");
        let telem = CacheTelem {
            hits: node.counter("hits"),
            misses: node.counter("misses"),
            evictions: node.counter("evictions"),
            tree: Arc::clone(tree),
        };
        telem.hits.add(inner.stats.hits);
        telem.misses.add(inner.stats.misses);
        telem.evictions.add(inner.stats.evictions);
        inner.telem = Some(telem);
    }

    /// Look up `key`, compiling and inserting on miss. The compile
    /// closure runs under the cache lock, so concurrent workers missing
    /// on the same cold key still compile it exactly once.
    pub fn get_or_compile(
        &self,
        key: PlanKey,
        compile: impl FnOnce() -> CompiledPlan,
    ) -> Arc<CompiledPlan> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(plan) = inner.map.get(&key).cloned() {
            inner.stats.hits += 1;
            if let Some(pos) = inner.lru.iter().position(|k| k == &key) {
                inner.lru.remove(pos);
                inner.lru.push_back(key);
            }
            if let Some(t) = &inner.telem {
                t.hits.inc();
                t.tree.counter(&format!("plans/{:#018x}/hits", key.fingerprint())).inc();
            }
            return plan;
        }
        inner.stats.misses += 1;
        if let Some(t) = &inner.telem {
            t.misses.inc();
            t.tree.counter(&format!("plans/{:#018x}/compiles", key.fingerprint())).inc();
        }
        let plan = Arc::new(compile());
        let mut evicted = 0u64;
        while inner.map.len() >= self.capacity {
            match inner.lru.pop_front() {
                Some(old) => {
                    inner.map.remove(&old);
                    inner.stats.evictions += 1;
                    evicted += 1;
                }
                None => break,
            }
        }
        if evicted > 0 {
            if let Some(t) = &inner.telem {
                t.evictions.add(evicted);
            }
        }
        inner.map.insert(key, plan.clone());
        inner.lru.push_back(key);
        plan
    }

    /// Snapshot every resident plan in recency order (front = least
    /// recently used), for the persistence layer
    /// ([`crate::driver::persist`]). `Arc` bumps only — no plan is
    /// cloned — and the counters are untouched.
    pub fn export(&self) -> Vec<(PlanKey, Arc<CompiledPlan>)> {
        let inner = self.inner.lock().unwrap();
        inner
            .lru
            .iter()
            .map(|k| (*k, Arc::clone(inner.map.get(k).expect("lru key resident"))))
            .collect()
    }

    /// Seed the cache with already-compiled plans (a snapshot reload).
    /// Entries are inserted in iteration order until the capacity bound;
    /// keys already resident and entries beyond capacity are skipped.
    /// Deliberately **not** counted as hits or misses — `CacheStats`
    /// keeps meaning "lookups", so a warm-restart run can assert
    /// `misses == 0` while serving entirely from preloaded plans.
    /// Returns the number of plans actually inserted.
    pub fn preload(
        &self,
        entries: impl IntoIterator<Item = (PlanKey, Arc<CompiledPlan>)>,
    ) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let mut inserted = 0;
        for (key, plan) in entries {
            if inner.map.len() >= self.capacity {
                break;
            }
            if inner.map.contains_key(&key) {
                continue;
            }
            inner.map.insert(key, plan);
            inner.lru.push_back(key);
            inserted += 1;
        }
        inserted
    }

    /// Snapshot of the hit/miss/eviction counters.
    pub fn stats(&self) -> CacheStats {
        self.inner.lock().unwrap().stats
    }

    /// Plans currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// True when no plan is resident.
    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::instructions::compile_layer;
    use crate::util::rng::Pcg32;

    fn case(p: &TconvProblem, seed: u64) -> (Tensor<i8>, Tensor<i8>, Vec<i32>) {
        let mut rng = Pcg32::new(seed);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let bias: Vec<i32> = (0..p.oc).map(|i| i as i32 - 3).collect();
        (x, w, bias)
    }

    #[test]
    fn instantiate_covers_all_tiles_and_rows() {
        let p = TconvProblem::new(4, 4, 8, 3, 20, 2);
        let (x, w, bias) = case(&p, 1);
        let cfg = AccelConfig::default();
        let plan = compile_layer(&p, &w, &bias, None, &cfg, OutMode::Raw32);
        assert_eq!(plan.tiles.len(), 3); // 20 channels over X=8 PMs
        let stream = plan.instantiate(&x);
        assert_eq!(stream.len(), plan.instr_count());
        let schedules = stream
            .iter()
            .filter(|i| matches!(i, Instr::Schedule { .. }))
            .count();
        assert_eq!(schedules, p.oh() * plan.tiles.len());
    }

    #[test]
    fn batched_instantiation_emits_one_prologue_per_tile() {
        use crate::accel::isa::Opcode;
        let p = TconvProblem::new(4, 4, 8, 3, 20, 2);
        let cfg = AccelConfig::default();
        let (_, w, bias) = case(&p, 4);
        let plan = compile_layer(&p, &w, &bias, None, &cfg, OutMode::Raw32);
        assert_eq!(plan.tiles.len(), 3);
        let mut rng = Pcg32::new(9);
        let xs: Vec<Tensor<i8>> = (0..4)
            .map(|_| Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng))
            .collect();
        let refs: Vec<&Tensor<i8>> = xs.iter().collect();
        let stream = plan.instantiate_batch(&refs);
        assert_eq!(stream.len(), plan.batch_instr_count(4));

        let count = |op: Opcode| stream.iter().filter(|i| i.opcode() == op).count();
        // One weight prologue per tile — not per (tile, request).
        assert_eq!(count(Opcode::Configure), plan.tiles.len());
        assert_eq!(count(Opcode::LoadWeights), plan.tiles.len());
        // One slot selection per (tile, request).
        assert_eq!(count(Opcode::SelectOutput), plan.tiles.len() * 4);
        // Full compute/store coverage for every request.
        assert_eq!(count(Opcode::Schedule), plan.tiles.len() * 4 * p.oh());
        assert_eq!(count(Opcode::StoreOutput), plan.tiles.len() * 4 * p.oh());
        // The tile prologue helper is exactly the stream's first two ops.
        let pro = plan.tiles[0].prologue();
        assert_eq!(stream[0].opcode(), pro[0].opcode());
        assert_eq!(stream[1].opcode(), pro[1].opcode());
    }

    /// Acceptance: instantiation performs zero input-tensor byte copies —
    /// every `LoadInput` row aliases the request tensor's own buffer,
    /// including across the per-request segments of a batched stream.
    #[test]
    fn instantiation_shares_input_rows_zero_copy() {
        let p = TconvProblem::new(4, 4, 8, 3, 20, 2);
        let cfg = AccelConfig::default();
        let (x, w, bias) = case(&p, 5);
        let plan = compile_layer(&p, &w, &bias, None, &cfg, OutMode::Raw32);
        let mut rng = Pcg32::new(6);
        let x2 = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let (buf, buf2) = (x.shared_data(), x2.shared_data());
        let stream = plan.instantiate_batch(&[&x, &x2]);
        let mut rows_checked = 0usize;
        let mut expect = &buf;
        for ins in &stream {
            match ins {
                Instr::SelectOutput { slot } => expect = if *slot == 0 { &buf } else { &buf2 },
                Instr::LoadInput { rows, .. } => {
                    for r in rows {
                        assert!(r.shares_buffer(expect), "input row copied instead of shared");
                        rows_checked += 1;
                    }
                }
                _ => {}
            }
        }
        // Every input row of every (tile, request) pair was inspected.
        assert_eq!(rows_checked, 2 * plan.tiles.len() * p.ih);
        // Single-request instantiation shares too.
        let single = plan.instantiate(&x);
        for ins in &single {
            if let Instr::LoadInput { rows, .. } = ins {
                assert!(rows.iter().all(|r| r.shares_buffer(&buf)));
            }
        }
    }

    #[test]
    fn keys_distinguish_problem_config_and_params() {
        let p1 = TconvProblem::new(4, 4, 8, 3, 6, 2);
        let p2 = TconvProblem::new(4, 4, 8, 3, 6, 1);
        let (_, w, bias) = case(&p1, 2);
        let cfg = AccelConfig::default();
        let base = PlanKey::new(&p1, OutMode::Raw32, &cfg, &w, &bias, None);
        assert_ne!(base, PlanKey::new(&p2, OutMode::Raw32, &cfg, &w, &bias, None));
        assert_ne!(base, PlanKey::new(&p1, OutMode::Int8, &cfg, &w, &bias, None));
        let mut cfg2 = AccelConfig::default();
        cfg2.x_pms = 4;
        assert_ne!(base, PlanKey::new(&p1, OutMode::Raw32, &cfg2, &w, &bias, None));
        let (_, w2, _) = case(&p1, 3);
        assert_ne!(base, PlanKey::new(&p1, OutMode::Raw32, &cfg, &w2, &bias, None));
        // And equal inputs agree.
        assert_eq!(base, PlanKey::new(&p1, OutMode::Raw32, &cfg, &w, &bias, None));
    }

    /// ROADMAP regression: key construction digests the weight tensor
    /// exactly once per buffer lifetime, no matter how many lookups hit
    /// it — and clones (e.g. a graph shared across workers) reuse the
    /// same memo.
    #[test]
    fn params_fp_hashes_weight_tensor_once_per_lifetime() {
        let p = TconvProblem::new(4, 4, 8, 3, 6, 2);
        let (_, w, bias) = case(&p, 11);
        let cfg = AccelConfig::default();
        assert_eq!(w.fingerprint_computes(), 0);
        let first = PlanKey::new(&p, OutMode::Raw32, &cfg, &w, &bias, None);
        for _ in 0..5 {
            assert_eq!(PlanKey::new(&p, OutMode::Raw32, &cfg, &w, &bias, None), first);
        }
        assert_eq!(w.fingerprint_computes(), 1, "one O(|w|) pass for six lookups");
        let shared = w.clone();
        assert_eq!(PlanKey::new(&p, OutMode::Raw32, &cfg, &shared, &bias, None), first);
        assert_eq!(shared.fingerprint_computes(), 1, "clone reuses the memo");
        // Mutated weights get a fresh digest and a distinct key.
        let mut w2 = w.clone();
        w2.data_mut()[0] = w2.data()[0].wrapping_add(1);
        assert_ne!(PlanKey::new(&p, OutMode::Raw32, &cfg, &w2, &bias, None), first);
        // The original's memo was not disturbed by the clone's mutation.
        assert_eq!(PlanKey::new(&p, OutMode::Raw32, &cfg, &w, &bias, None), first);
        assert_eq!(w.fingerprint_computes(), 1);
    }

    /// The plan-side weight signatures must predict the accelerator's
    /// resident-skip: the signature of tile 0 equals what the instance
    /// reports resident after loading tile 0, and for a multi-tile plan
    /// first != last.
    #[test]
    fn weight_sigs_match_accelerator_residency() {
        use crate::accel::Accelerator;
        let p = TconvProblem::new(4, 4, 8, 3, 20, 2); // 3 tiles over X=8
        let (x, w, bias) = case(&p, 12);
        let cfg = AccelConfig::default();
        let plan = compile_layer(&p, &w, &bias, None, &cfg, OutMode::Raw32);
        assert_eq!(plan.tiles.len(), 3);
        assert_ne!(plan.first_weight_sig(), plan.last_weight_sig());

        let mut acc = Accelerator::new(cfg);
        assert_eq!(acc.resident_signature(), None, "fresh instance");
        acc.run_stream(&plan.instantiate(&x)).unwrap();
        assert_eq!(
            acc.resident_signature(),
            Some(plan.last_weight_sig()),
            "after a full stream the last tile's filters are resident"
        );
    }

    #[test]
    fn cache_hit_after_insert_and_lru_eviction() {
        let cfg = AccelConfig::default();
        let cache = PlanCache::new(2);
        let probs = [
            TconvProblem::new(3, 3, 4, 3, 2, 1),
            TconvProblem::new(3, 3, 4, 3, 4, 1),
            TconvProblem::new(3, 3, 4, 3, 6, 1),
        ];
        let mut keys = Vec::new();
        for (i, p) in probs.iter().enumerate() {
            let (_, w, bias) = case(p, i as u64);
            let key = PlanKey::new(p, OutMode::Raw32, &cfg, &w, &bias, None);
            cache.get_or_compile(key, || compile_layer(p, &w, &bias, None, &cfg, OutMode::Raw32));
            keys.push((key, w, bias));
        }
        // 3 inserts into capacity 2: one eviction (of problem 0, the LRU).
        assert_eq!(cache.len(), 2);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (0, 3, 1));
        // Resident problems hit first (refreshing recency), then the
        // evicted one recompiles.
        for i in [1usize, 2, 0] {
            let p = &probs[i];
            let (key, w, bias) = &keys[i];
            let plan = cache
                .get_or_compile(*key, || compile_layer(p, w, bias, None, &cfg, OutMode::Raw32));
            assert_eq!(plan.problem, *p);
        }
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (2, 4, 2));
        assert!((s.hit_rate() - 2.0 / 6.0).abs() < 1e-12);
    }

    /// The persistence hooks: `export` snapshots plans in LRU order
    /// without touching counters, `preload` seeds a fresh cache without
    /// counting hits or misses, respects the capacity bound, skips
    /// already-resident keys, and a preloaded key serves its next lookup
    /// as a hit with no compile.
    #[test]
    fn export_preload_round_trip_keeps_counters_clean() {
        let cfg = AccelConfig::default();
        let cache = PlanCache::new(4);
        let probs = [
            TconvProblem::new(3, 3, 4, 3, 2, 1),
            TconvProblem::new(3, 3, 4, 3, 4, 1),
            TconvProblem::new(3, 3, 4, 3, 6, 1),
        ];
        let mut keys = Vec::new();
        for (i, p) in probs.iter().enumerate() {
            let (_, w, bias) = case(p, i as u64);
            let key = PlanKey::new(p, OutMode::Raw32, &cfg, &w, &bias, None);
            cache.get_or_compile(key, || compile_layer(p, &w, &bias, None, &cfg, OutMode::Raw32));
            keys.push(key);
        }
        let exported = cache.export();
        assert_eq!(exported.len(), 3);
        // LRU order: insertion order, nothing was re-touched.
        assert_eq!(exported.iter().map(|(k, _)| *k).collect::<Vec<_>>(), keys);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (0, 3), "export does not count as lookups");

        // Preload into a fresh, smaller cache: capacity bounds the
        // insert, duplicates are skipped, counters stay zero.
        let warm = PlanCache::new(2);
        assert_eq!(warm.preload(exported.clone()), 2);
        assert_eq!(warm.preload(exported.clone()), 0, "already resident");
        assert_eq!(warm.len(), 2);
        let s = warm.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (0, 0, 0));
        // A preloaded key is served without invoking the compiler.
        let plan =
            warm.get_or_compile(keys[0], || unreachable!("preloaded key must not recompile"));
        assert_eq!(plan.problem, probs[0]);
        let s = warm.stats();
        assert_eq!((s.hits, s.misses), (1, 0));
    }

    /// The mixed-variant splicer: interleaved requests over two weight
    /// variants of one geometry share each tile's `Configure`, pay one
    /// `LoadWeights` per (tile, variant), and execute byte-identically
    /// to per-request streams.
    #[test]
    fn multi_variant_batch_shares_configure_and_splits_weight_loads() {
        use crate::accel::isa::Opcode;
        use crate::accel::Accelerator;
        let p = TconvProblem::new(4, 4, 8, 3, 20, 2); // 3 tiles over X=8
        let cfg = AccelConfig::default();
        let (_, w_a, bias) = case(&p, 21);
        let (_, w_b, _) = case(&p, 22);
        let plan_a = compile_layer(&p, &w_a, &bias, None, &cfg, OutMode::Raw32);
        let plan_b = compile_layer(&p, &w_b, &bias, None, &cfg, OutMode::Raw32);
        assert_eq!(plan_a.tiles.len(), 3);

        let mut rng = Pcg32::new(23);
        let xs: Vec<Tensor<i8>> = (0..4)
            .map(|_| Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng))
            .collect();
        // Interleave variants: A, B, A, B.
        let reqs: Vec<(&CompiledPlan, &Tensor<i8>)> = vec![
            (&plan_a, &xs[0]),
            (&plan_b, &xs[1]),
            (&plan_a, &xs[2]),
            (&plan_b, &xs[3]),
        ];
        let stream = CompiledPlan::instantiate_batch_multi(&reqs, None);

        let count = |op: Opcode| stream.iter().filter(|i| i.opcode() == op).count();
        // One shared Configure per tile, one LoadWeights per (tile, variant).
        assert_eq!(count(Opcode::Configure), 3);
        assert_eq!(count(Opcode::LoadWeights), 3 * 2);
        assert_eq!(count(Opcode::SelectOutput), 3 * 4);

        let mut acc = Accelerator::new(cfg.clone());
        let result = acc.run_batch(&stream).unwrap();
        assert_eq!(result.outputs.len(), 4);
        assert_eq!(result.report.weight_loads, 3 * 2, "tiles x variants, not tiles x requests");

        // Byte-identical to per-request execution of each variant's plan.
        for (slot, (plan, x)) in reqs.iter().enumerate() {
            let mut solo = Accelerator::new(cfg.clone());
            let r = solo.run_stream(&plan.instantiate(x)).unwrap();
            assert_eq!(result.outputs[slot].0.data(), r.raw.data(), "slot {slot}");
        }

        // Degenerate case: all requests on one plan == instantiate_batch.
        let mono: Vec<(&CompiledPlan, &Tensor<i8>)> =
            xs.iter().map(|x| (&plan_a, x)).collect();
        let multi = CompiledPlan::instantiate_batch_multi(&mono, None);
        let refs: Vec<&Tensor<i8>> = xs.iter().collect();
        assert_eq!(multi.len(), plan_a.instantiate_batch(&refs).len());
        let loads = multi.iter().filter(|i| i.opcode() == Opcode::LoadWeights).count();
        assert_eq!(loads, plan_a.tiles.len());

        // Residency-aware segment order: telling the splicer B's weights
        // are resident rotates B's segment to the front of every tile,
        // and outputs stay byte-identical (slots are explicit).
        let sig_b = plan_b.tile_weight_sig(0);
        let reordered = CompiledPlan::instantiate_batch_multi(&reqs, Some(sig_b));
        let first_load_sig = reordered
            .iter()
            .find_map(|i| match i {
                Instr::LoadWeights(ws) => Some(ws.sig()),
                _ => None,
            })
            .expect("stream has loads");
        assert_eq!(first_load_sig, sig_b, "resident variant leads the stream");
        let mut acc2 = Accelerator::new(cfg.clone());
        let r2 = acc2.run_batch(&reordered).unwrap();
        for slot in 0..reqs.len() {
            assert_eq!(r2.outputs[slot].0.data(), result.outputs[slot].0.data(), "slot {slot}");
        }
    }

    #[test]
    #[should_panic(expected = "mixed-geometry batch")]
    fn multi_variant_batch_rejects_mixed_geometry() {
        let p1 = TconvProblem::new(4, 4, 8, 3, 6, 2);
        let p2 = TconvProblem::new(4, 4, 8, 3, 6, 1);
        let cfg = AccelConfig::default();
        let (x1, w1, b1) = case(&p1, 31);
        let (x2, w2, b2) = case(&p2, 32);
        let plan1 = compile_layer(&p1, &w1, &b1, None, &cfg, OutMode::Raw32);
        let plan2 = compile_layer(&p2, &w2, &b2, None, &cfg, OutMode::Raw32);
        let _ = CompiledPlan::instantiate_batch_multi(&[(&plan1, &x1), (&plan2, &x2)], None);
    }

    /// GraphKey chains are weight-blind but geometry/config/mode aware.
    #[test]
    fn graph_key_ignores_params_but_tracks_shape_mode_and_config() {
        let p = TconvProblem::new(4, 4, 8, 3, 6, 2);
        let cfg = AccelConfig::default();
        let (_, w1, bias) = case(&p, 41);
        let (_, w2, _) = case(&p, 42);
        let k1 = PlanKey::new(&p, OutMode::Int8, &cfg, &w1, &bias, None);
        let k2 = PlanKey::new(&p, OutMode::Int8, &cfg, &w2, &bias, None);
        assert_ne!(k1, k2, "params distinguish plan keys");
        let chain = |k: &PlanKey| GraphKey::builder().chain_link(k).finish();
        assert_eq!(chain(&k1), chain(&k2), "chains are weight-independent");

        // Geometry, mapper kind, out mode, and config all separate chains.
        let p_seg = p.with_mapper(crate::tconv::problem::MapperKind::Segregated);
        let k_seg = PlanKey::new(&p_seg, OutMode::Int8, &cfg, &w1, &bias, None);
        assert_ne!(chain(&k1), chain(&k_seg), "mapper kind is chain identity");
        let k_raw = PlanKey::new(&p, OutMode::Raw32, &cfg, &w1, &bias, None);
        assert_ne!(chain(&k1), chain(&k_raw));
        let mut cfg2 = AccelConfig::default();
        cfg2.x_pms = 4;
        let k_cfg = PlanKey::new(&p, OutMode::Int8, &cfg2, &w1, &bias, None);
        assert_ne!(chain(&k1), chain(&k_cfg));
        let p2 = TconvProblem::new(4, 4, 8, 3, 8, 2);
        let k_geo = PlanKey::new(&p2, OutMode::Int8, &cfg, &w1, &bias, None);
        assert_ne!(chain(&k1), chain(&k_geo));

        // Structural words participate: same links, different interleaved
        // words => different keys.
        let mut b1 = GraphKey::builder();
        b1.word(7).chain_link(&k1);
        let mut b2 = GraphKey::builder();
        b2.word(8).chain_link(&k1);
        assert_ne!(b1.finish(), b2.finish());
    }
}
