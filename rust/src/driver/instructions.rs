//! Algorithm 1 — *Tiled MM2IM*: the host driver's instruction generator.
//!
//! ```text
//! foreach c in 0..Oc step filter_step:        // one tile per PM set
//!     SendWeightFilters(c, filter_step)        // 0x01 + 0x02
//!     starting = 0
//!     foreach h in 0..Oh:
//!         rows_to_send = i_end_row[h] + 1 - starting
//!         if i_end_row[h] != starting - 1:
//!             SendInputRows(starting, rows_to_send)   // 0x04
//!         ComputeOutRow(h, c, filter_step)            // 0x08
//!         StoreOutRow(h, c, filter_step)              // 0x10
//!         starting = i_end_row[h] + 1
//! ```
//!
//! The weight/output-stationary property: filters are sent once per tile,
//! each input row crosses AXI exactly once per tile, and each output row
//! is stored exactly once.

use crate::accel::config::AccelConfig;
use crate::accel::isa::{FilterPayload, Instr, OutMode, TileConfig, WeightSet};
use crate::driver::plan::{CompiledPlan, PlanTile, RowOp};
use crate::tconv::maps::RowSchedule;
use crate::tconv::problem::TconvProblem;
use crate::tensor::quant::PerChannel;
use crate::tensor::Tensor;

/// Fixed host-side cost per offloaded layer: delegate dispatch, buffer
/// pinning, instruction generation, interrupt wait. Calibrated against
/// the paper's small-problem behaviour (FCN in Table II runs 0.22 ms on
/// *both* CPU and accelerator — i.e. the offload overhead matches the
/// CPU's own invoke overhead and tiny layers see ~1.0x).
pub const DRIVER_FIXED_OVERHEAD_S: f64 = 190e-6;

/// Extract the PM-local filter layout [(kh, kw, ic)] for channel `oc`.
fn filter_slice(p: &TconvProblem, w: &Tensor<i8>, oc: usize) -> Vec<i8> {
    let mut out = Vec::with_capacity(p.ks * p.ks * p.ic);
    for kh in 0..p.ks {
        for kw in 0..p.ks {
            for c in 0..p.ic {
                out.push(w.at4(oc, kh, kw, c));
            }
        }
    }
    out
}

/// Compile one TCONV layer into its reusable, input-independent program:
/// the tile decomposition, packed filter payloads, and the Algorithm-1
/// row-streaming schedule. Serving paths cache the result (keyed by
/// [`crate::driver::plan::PlanKey`]) and re-instantiate it per request.
///
/// `requant`: per-channel PPU parameters for `OutMode::Int8`; pass `None`
/// with `OutMode::Raw32` (identity requant installed).
pub fn compile_layer(
    p: &TconvProblem,
    w: &Tensor<i8>,
    bias: &[i32],
    requant: Option<&PerChannel>,
    cfg: &AccelConfig,
    out_mode: OutMode,
) -> CompiledPlan {
    assert_eq!(w.shape(), &[p.oc, p.ks, p.ks, p.ic]);
    assert_eq!(bias.len(), p.oc);

    let sched = RowSchedule::build(p);
    let mut tiles = Vec::new();

    let mut oc_base = 0;
    while oc_base < p.oc {
        let oc_count = cfg.x_pms.min(p.oc - oc_base);
        let config = TileConfig { problem: *p, oc_base, oc_count, out_mode };

        let filters: Vec<FilterPayload> = (0..oc_count)
            .map(|i| {
                let oc = oc_base + i;
                let (m, s, zp) = match requant {
                    Some(r) => (r.mults[oc].m, r.mults[oc].shift, r.zp_out),
                    None => (1 << 30, 1, 0), // identity
                };
                FilterPayload {
                    weights: filter_slice(p, w, oc).into(),
                    bias: bias[oc],
                    qmult_m: m,
                    qmult_shift: s,
                    zp_out: zp,
                }
            })
            .collect();
        // The resident-set signature is hashed here, once per tile per
        // compilation — execution compares signatures instead of
        // re-hashing weight bytes per stream.
        let weights = WeightSet::new(filters, p.ks, p.ic);

        // Inner loop of Algorithm 1 over output rows.
        let mut ops = Vec::with_capacity(3 * p.oh());
        let mut starting: i64 = 0;
        for h in 0..p.oh() {
            let end = sched.i_end_row[h];
            if end >= starting {
                ops.push(RowOp::SendRows {
                    first_row: starting as usize,
                    count: (end - starting + 1) as usize,
                });
                starting = end + 1;
            }
            ops.push(RowOp::Compute { out_row: h });
            ops.push(RowOp::Store { out_row: h });
        }
        tiles.push(PlanTile { config, weights, ops });
        oc_base += oc_count;
    }
    CompiledPlan { problem: *p, out_mode, tiles }
}

/// Build the full instruction stream for one TCONV layer: compile then
/// instantiate in one step (the uncached path; byte-identical to a cached
/// plan's [`CompiledPlan::instantiate`]).
pub fn build_layer_stream(
    p: &TconvProblem,
    x: &Tensor<i8>,
    w: &Tensor<i8>,
    bias: &[i32],
    requant: Option<&PerChannel>,
    cfg: &AccelConfig,
    out_mode: OutMode,
) -> Vec<Instr> {
    compile_layer(p, w, bias, requant, cfg, out_mode).instantiate(x)
}

/// Convenience: quantized layer stream with PPU requant installed.
pub fn layer_quant_stream(
    p: &TconvProblem,
    x: &Tensor<i8>,
    w: &Tensor<i8>,
    bias: &[i32],
    requant: &PerChannel,
    cfg: &AccelConfig,
) -> Vec<Instr> {
    build_layer_stream(p, x, w, bias, Some(requant), cfg, OutMode::Int8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::isa::Opcode;
    use crate::util::rng::Pcg32;

    fn stream_for(p: &TconvProblem, cfg: &AccelConfig) -> Vec<Instr> {
        let mut rng = Pcg32::new(5);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        build_layer_stream(p, &x, &w, &vec![0; p.oc], None, cfg, OutMode::Raw32)
    }

    #[test]
    fn tiles_cover_oc_exactly_once() {
        let p = TconvProblem::new(4, 4, 8, 3, 20, 2); // 20 channels, X=8 -> 8+8+4
        let stream = stream_for(&p, &AccelConfig::default());
        let tiles: Vec<(usize, usize)> = stream
            .iter()
            .filter_map(|i| match i {
                Instr::Configure(tc) => Some((tc.oc_base, tc.oc_count)),
                _ => None,
            })
            .collect();
        assert_eq!(tiles, vec![(0, 8), (8, 8), (16, 4)]);
    }

    #[test]
    fn each_input_row_sent_once_per_tile() {
        let p = TconvProblem::new(7, 7, 16, 5, 16, 2);
        let stream = stream_for(&p, &AccelConfig::default());
        let mut per_tile_rows: Vec<Vec<usize>> = Vec::new();
        for i in &stream {
            match i {
                Instr::Configure(_) => per_tile_rows.push(Vec::new()),
                Instr::LoadInput { first_row, rows } => {
                    let tile = per_tile_rows.last_mut().unwrap();
                    for k in 0..rows.len() {
                        tile.push(first_row + k);
                    }
                }
                _ => {}
            }
        }
        assert_eq!(per_tile_rows.len(), 2);
        for rows in per_tile_rows {
            let want: Vec<usize> = (0..p.ih).collect();
            assert_eq!(rows, want, "every row exactly once, in order");
        }
    }

    #[test]
    fn schedule_store_pairs_for_every_output_row() {
        let p = TconvProblem::new(3, 3, 4, 3, 2, 2);
        let stream = stream_for(&p, &AccelConfig::default());
        let scheds: Vec<usize> = stream
            .iter()
            .filter_map(|i| match i {
                Instr::Schedule { out_row } => Some(*out_row),
                _ => None,
            })
            .collect();
        let stores: Vec<usize> = stream
            .iter()
            .filter_map(|i| match i {
                Instr::StoreOutput { out_row } => Some(*out_row),
                _ => None,
            })
            .collect();
        let want: Vec<usize> = (0..p.oh()).collect();
        assert_eq!(scheds, want);
        assert_eq!(stores, want);
    }

    #[test]
    fn opcode_ordering_is_configure_weights_then_rows() {
        let p = TconvProblem::new(3, 3, 4, 3, 2, 1);
        let stream = stream_for(&p, &AccelConfig::default());
        let ops: Vec<Opcode> = stream.iter().map(|i| i.opcode()).collect();
        assert_eq!(ops[0], Opcode::Configure);
        assert_eq!(ops[1], Opcode::LoadWeights);
        assert!(matches!(ops[2], Opcode::LoadInput));
    }

    #[test]
    fn weight_bytes_sent_once_per_tile_weight_stationary() {
        let p = TconvProblem::new(7, 7, 32, 5, 16, 2);
        let stream = stream_for(&p, &AccelConfig::default());
        let weight_bytes: u64 = stream.iter().map(|i| match i {
            Instr::LoadWeights(_) => i.data_bytes(),
            _ => 0,
        }).sum();
        // exactly one copy of all filters
        assert_eq!(weight_bytes, p.weight_elems() as u64);
    }

    #[test]
    fn small_pm_array_still_covers() {
        let mut cfg = AccelConfig::default();
        cfg.x_pms = 3;
        let p = TconvProblem::new(3, 3, 4, 3, 7, 1);
        let stream = stream_for(&p, &cfg);
        let tiles: Vec<(usize, usize)> = stream
            .iter()
            .filter_map(|i| match i {
                Instr::Configure(tc) => Some((tc.oc_base, tc.oc_count)),
                _ => None,
            })
            .collect();
        assert_eq!(tiles, vec![(0, 3), (3, 3), (6, 1)]);
    }
}
