//! Versioned, fingerprint-validated on-disk snapshots of the compiled
//! plan cache — warm restarts without zoo recompilation.
//!
//! MM2IM's premise (paper §IV) is that a TCONV layer's Algorithm-1
//! program is input-independent: everything expensive — tile
//! decomposition, filter payload packing, requant folding, the
//! `i_end_row` schedule — is paid once at compile time and reused per
//! request. That made restart the one place the premise broke: a
//! restarted or newly-autoscaled shard recompiled the whole zoo (twice
//! over since kernel-segregated mapping doubled the plan population)
//! before serving its first request. This module closes the gap by
//! making the [`PlanCache`] contents a durable artifact: save on
//! drain, reload at startup, serve the first request with **zero**
//! compiles. Because the file is self-describing and validated, it
//! doubles as a fleet-wide plan-distribution artifact — one shard
//! compiles, every replica preloads.
//!
//! # Format
//!
//! Hand-rolled little-endian binary (no serde; `util::json` is a
//! parser, not a writer, and plans are bulk binary anyway):
//!
//! ```text
//! magic "MM2IMPLN" | format_version u32 | crate_version (u32 len + utf8)
//! cfg fingerprint set (u32 count + u64 each) | entry count u32
//! entries:
//!   PlanKey   — ih iw ic ks oc stride (u64 each), mapper u8, out_mode u8,
//!               cfg_fp params_fp params_fp2 (u64 each)
//!   payload_len u64 | checksum (dual-FNV u64 pair over key||payload)
//!   payload   — CompiledPlan: out_mode, tiles (oc_base/oc_count,
//!               WeightSetSig digest words + (ks, ic) layout,
//!               filter payloads, tagged RowOps)
//! ```
//!
//! # Validation: reject structurally, never serve a stale plan
//!
//! A snapshot is trusted only when every gate passes; any failure
//! rejects the **whole file** with a typed [`PersistError`] and the
//! caller falls back to a clean cold start (the coordinator's
//! `plan_store` path does exactly that):
//!
//! - magic + `FORMAT_VERSION` gate layout drift across releases;
//! - each entry's dual-FNV checksum spans the key *and* payload bytes,
//!   so a flipped byte can neither corrupt a plan nor re-home an intact
//!   plan under the wrong key;
//! - every [`WeightSet`] is rebuilt through [`WeightSet::new`] — the
//!   only constructor, so signatures are *recomputed from the decoded
//!   payloads*, never trusted from disk — and the recomputed signature
//!   must match the stored digest words ([`PersistError::SigMismatch`]
//!   otherwise);
//! - entry keys carry the same `cfg_fp`/`params_fp` fingerprints live
//!   lookups use. A snapshot from a different [`AccelConfig`] or stale
//!   weights can preload at most *dead* entries: live `PlanKey`s are
//!   derived from the fleet's actual config and weight tensors, so a
//!   mismatched entry is simply never hit and the layer recompiles —
//!   wrong cycles are structurally unreachable. (The coordinator
//!   additionally filters entries to the fleet's fingerprint set via
//!   [`Snapshot::retain_configs`] so dead entries don't occupy cache
//!   capacity.)

use crate::accel::isa::{FilterPayload, OutMode, TileConfig, WeightSet};
use crate::driver::plan::{CompiledPlan, PlanCache, PlanKey, PlanTile, RowOp};
use crate::tconv::problem::{MapperKind, TconvProblem};
use crate::util::hash::Fnv;
use std::path::Path;
use std::sync::Arc;

/// File magic: identifies an MM2IM plan snapshot.
pub const MAGIC: [u8; 8] = *b"MM2IMPLN";

/// Layout version of the snapshot format. Bump on any byte-layout
/// change; readers reject other versions outright (a snapshot is a
/// cache, so "reject and recompile" is always correct).
pub const FORMAT_VERSION: u32 = 1;

/// Why a snapshot was rejected. Every variant means "cold start" to the
/// serving layer; the CLI (`repro plans load`) surfaces them verbatim.
#[derive(Debug)]
pub enum PersistError {
    /// Filesystem-level failure (missing file, permissions, rename).
    Io(std::io::Error),
    /// The file does not start with [`MAGIC`] — not a plan snapshot.
    BadMagic,
    /// Written under a different [`FORMAT_VERSION`].
    UnsupportedVersion {
        /// Version found in the header.
        found: u32,
    },
    /// The buffer ended before the structure did (truncated file).
    Truncated {
        /// What was being read when the bytes ran out.
        context: &'static str,
    },
    /// A decoded value is structurally impossible (bad discriminant,
    /// length overflowing the platform, geometry mismatch).
    Corrupt {
        /// What failed to validate.
        context: &'static str,
    },
    /// An entry's stored checksum does not match its key+payload bytes.
    ChecksumMismatch {
        /// Zero-based index of the offending entry.
        entry: usize,
    },
    /// A weight set's signature, recomputed from the decoded payloads,
    /// does not match the digest words it was written with.
    SigMismatch {
        /// Zero-based index of the offending entry.
        entry: usize,
    },
}

impl std::fmt::Display for PersistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io(e) => write!(f, "io: {e}"),
            Self::BadMagic => write!(f, "not a plan snapshot (bad magic)"),
            Self::UnsupportedVersion { found } => {
                write!(f, "unsupported format version {found} (reader supports {FORMAT_VERSION})")
            }
            Self::Truncated { context } => write!(f, "truncated while reading {context}"),
            Self::Corrupt { context } => write!(f, "corrupt field: {context}"),
            Self::ChecksumMismatch { entry } => write!(f, "checksum mismatch at entry {entry}"),
            Self::SigMismatch { entry } => {
                write!(f, "weight-set signature mismatch at entry {entry}")
            }
        }
    }
}

impl std::error::Error for PersistError {}

impl From<std::io::Error> for PersistError {
    fn from(e: std::io::Error) -> Self {
        Self::Io(e)
    }
}

/// Decoded snapshot header — what `repro plans load` prints.
#[derive(Clone, Debug)]
pub struct SnapshotHeader {
    /// Layout version the file was written under.
    pub format_version: u32,
    /// `CARGO_PKG_VERSION` of the writer (informational; compatibility
    /// is governed by `format_version` and the fingerprints).
    pub crate_version: String,
    /// [`AccelConfig::fingerprint`](crate::accel::AccelConfig::fingerprint)
    /// set of the fleet the snapshot was saved from.
    pub cfg_fps: Vec<u64>,
    /// Entries in the file.
    pub entries: usize,
}

/// A fully decoded, fully validated snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// The file header.
    pub header: SnapshotHeader,
    /// Every plan, keyed exactly as the live cache keys it.
    pub entries: Vec<(PlanKey, Arc<CompiledPlan>)>,
}

impl Snapshot {
    /// Drop entries whose `cfg_fp` is not in `fps` — the loader-side
    /// guard that keeps a foreign fleet's plans from occupying cache
    /// capacity (they could never be *hit*; see module docs).
    pub fn retain_configs(mut self, fps: &[u64]) -> Self {
        self.entries.retain(|(k, _)| fps.contains(&k.cfg_fp));
        self
    }

    /// Preload `cache` with this snapshot's entries; returns plans
    /// inserted (see [`PlanCache::preload`] for the counter semantics).
    pub fn preload_into(self, cache: &PlanCache) -> usize {
        cache.preload(self.entries)
    }
}

// ---------------------------------------------------------------------
// Little-endian writer / bounds-checked reader
// ---------------------------------------------------------------------

#[derive(Default)]
struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn bytes(&mut self, len: usize, context: &'static str) -> Result<&'a [u8], PersistError> {
        if self.remaining() < len {
            return Err(PersistError::Truncated { context });
        }
        let s = &self.buf[self.pos..self.pos + len];
        self.pos += len;
        Ok(s)
    }

    fn u8(&mut self, context: &'static str) -> Result<u8, PersistError> {
        Ok(self.bytes(1, context)?[0])
    }

    fn u32(&mut self, context: &'static str) -> Result<u32, PersistError> {
        Ok(u32::from_le_bytes(self.bytes(4, context)?.try_into().unwrap()))
    }

    fn u64(&mut self, context: &'static str) -> Result<u64, PersistError> {
        Ok(u64::from_le_bytes(self.bytes(8, context)?.try_into().unwrap()))
    }

    fn i32(&mut self, context: &'static str) -> Result<i32, PersistError> {
        Ok(i32::from_le_bytes(self.bytes(4, context)?.try_into().unwrap()))
    }

    /// A u64 that must fit the platform's `usize` *and* bound a
    /// structure still to be read — so a corrupted length can neither
    /// wrap arithmetic nor trigger a pathological allocation.
    fn len(&mut self, context: &'static str) -> Result<usize, PersistError> {
        let v = self.u64(context)?;
        let v = usize::try_from(v).map_err(|_| PersistError::Corrupt { context })?;
        if v > self.remaining() {
            return Err(PersistError::Truncated { context });
        }
        Ok(v)
    }
}

/// Dual-basis FNV over an entry's key+payload bytes — the per-entry
/// corruption gate. Two independent 64-bit streams: an accidental pass
/// on corrupted bytes needs a simultaneous 128-bit collision.
fn checksum(key_bytes: &[u8], payload: &[u8]) -> (u64, u64) {
    let mut fp = Fnv::new();
    let mut fp2 = Fnv::with_basis(Fnv::ALT_BASIS);
    for &b in key_bytes.iter().chain(payload) {
        fp.byte(b);
        fp2.byte(b);
    }
    (fp.finish(), fp2.finish())
}

// ---------------------------------------------------------------------
// Field codecs
// ---------------------------------------------------------------------

fn put_problem(w: &mut Writer, p: &TconvProblem) {
    for v in [p.ih, p.iw, p.ic, p.ks, p.oc, p.stride] {
        w.u64(v as u64);
    }
    w.u8(match p.mapper {
        MapperKind::Overlapped => 0,
        MapperKind::Segregated => 1,
    });
}

fn get_problem(r: &mut Reader) -> Result<TconvProblem, PersistError> {
    let mut f = [0usize; 6];
    for v in f.iter_mut() {
        *v = usize::try_from(r.u64("problem geometry")?)
            .map_err(|_| PersistError::Corrupt { context: "problem geometry" })?;
        // `TconvProblem::new` asserts every dimension positive; gate it
        // here so a checksum-consistent but nonsensical file is a typed
        // rejection, never a panic.
        if *v == 0 {
            return Err(PersistError::Corrupt { context: "problem geometry" });
        }
    }
    let mapper = match r.u8("mapper kind")? {
        0 => MapperKind::Overlapped,
        1 => MapperKind::Segregated,
        _ => return Err(PersistError::Corrupt { context: "mapper kind" }),
    };
    Ok(TconvProblem::new(f[0], f[1], f[2], f[3], f[4], f[5]).with_mapper(mapper))
}

fn put_out_mode(w: &mut Writer, m: OutMode) {
    w.u8(match m {
        OutMode::Raw32 => 0,
        OutMode::Int8 => 1,
    });
}

fn get_out_mode(r: &mut Reader) -> Result<OutMode, PersistError> {
    match r.u8("out mode")? {
        0 => Ok(OutMode::Raw32),
        1 => Ok(OutMode::Int8),
        _ => Err(PersistError::Corrupt { context: "out mode" }),
    }
}

fn put_key(w: &mut Writer, k: &PlanKey) {
    put_problem(w, &k.problem);
    put_out_mode(w, k.out_mode);
    w.u64(k.cfg_fp);
    w.u64(k.params_fp);
    w.u64(k.params_fp2);
}

fn get_key(r: &mut Reader) -> Result<PlanKey, PersistError> {
    let problem = get_problem(r)?;
    let out_mode = get_out_mode(r)?;
    let cfg_fp = r.u64("cfg fingerprint")?;
    let params_fp = r.u64("params fingerprint")?;
    let params_fp2 = r.u64("params fingerprint 2")?;
    Ok(PlanKey { problem, out_mode, cfg_fp, params_fp, params_fp2 })
}

fn put_plan(w: &mut Writer, plan: &CompiledPlan) {
    put_out_mode(w, plan.out_mode);
    w.u32(plan.tiles.len() as u32);
    for tile in &plan.tiles {
        // Tile configs repeat the plan-level problem/mode by
        // construction (`compile_layer`); assert rather than store.
        assert_eq!(tile.config.problem, plan.problem, "tile problem diverged from plan");
        assert_eq!(tile.config.out_mode, plan.out_mode, "tile out mode diverged from plan");
        w.u64(tile.config.oc_base as u64);
        w.u64(tile.config.oc_count as u64);
        let sig = tile.weights.sig();
        let (fp, fp2) = sig.digest_words();
        let (ks, ic) = sig.layout();
        w.u64(fp);
        w.u64(fp2);
        w.u64(ks as u64);
        w.u64(ic as u64);
        w.u32(tile.weights.filters().len() as u32);
        for f in tile.weights.filters() {
            w.u64(f.weights.len() as u64);
            // i8 -> u8 is a bit-preserving cast; the reader reverses it.
            w.bytes(&f.weights.iter().map(|&b| b as u8).collect::<Vec<u8>>());
            w.i32(f.bias);
            w.i32(f.qmult_m);
            w.i32(f.qmult_shift);
            w.i32(f.zp_out);
        }
        w.u32(tile.ops.len() as u32);
        for op in &tile.ops {
            match *op {
                RowOp::SendRows { first_row, count } => {
                    w.u8(0);
                    w.u64(first_row as u64);
                    w.u64(count as u64);
                }
                RowOp::Compute { out_row } => {
                    w.u8(1);
                    w.u64(out_row as u64);
                }
                RowOp::Store { out_row } => {
                    w.u8(2);
                    w.u64(out_row as u64);
                }
            }
        }
    }
}

fn get_plan(r: &mut Reader, key: &PlanKey, entry: usize) -> Result<CompiledPlan, PersistError> {
    let out_mode = get_out_mode(r)?;
    if out_mode != key.out_mode {
        return Err(PersistError::Corrupt { context: "payload out mode disagrees with key" });
    }
    let tile_count = r.u32("tile count")? as usize;
    let mut tiles = Vec::with_capacity(tile_count.min(r.remaining()));
    for _ in 0..tile_count {
        let oc_base = usize::try_from(r.u64("tile oc_base")?)
            .map_err(|_| PersistError::Corrupt { context: "tile oc_base" })?;
        let oc_count = usize::try_from(r.u64("tile oc_count")?)
            .map_err(|_| PersistError::Corrupt { context: "tile oc_count" })?;
        let stored_fp = r.u64("weight sig fp")?;
        let stored_fp2 = r.u64("weight sig fp2")?;
        let ks = usize::try_from(r.u64("weight layout ks")?)
            .map_err(|_| PersistError::Corrupt { context: "weight layout ks" })?;
        let ic = usize::try_from(r.u64("weight layout ic")?)
            .map_err(|_| PersistError::Corrupt { context: "weight layout ic" })?;
        let filter_count = r.u32("filter count")? as usize;
        let mut filters = Vec::with_capacity(filter_count.min(r.remaining()));
        for _ in 0..filter_count {
            let wlen = r.len("filter weight bytes")?;
            let weights: Arc<[i8]> =
                r.bytes(wlen, "filter weights")?.iter().map(|&b| b as i8).collect();
            let bias = r.i32("filter bias")?;
            let qmult_m = r.i32("filter qmult_m")?;
            let qmult_shift = r.i32("filter qmult_shift")?;
            let zp_out = r.i32("filter zp_out")?;
            filters.push(FilterPayload { weights, bias, qmult_m, qmult_shift, zp_out });
        }
        // The one constructor: the signature is recomputed from the
        // decoded payloads, never deserialized — then checked against
        // the stored digest words as a belt-and-braces gate on top of
        // the entry checksum.
        let weights = WeightSet::new(filters, ks, ic);
        if weights.sig().digest_words() != (stored_fp, stored_fp2)
            || weights.sig().layout() != (ks, ic)
        {
            return Err(PersistError::SigMismatch { entry });
        }
        let op_count = r.u32("row op count")? as usize;
        let mut ops = Vec::with_capacity(op_count.min(r.remaining()));
        for _ in 0..op_count {
            let op = match r.u8("row op tag")? {
                0 => {
                    let first_row = usize::try_from(r.u64("send first_row")?)
                        .map_err(|_| PersistError::Corrupt { context: "send first_row" })?;
                    let count = usize::try_from(r.u64("send count")?)
                        .map_err(|_| PersistError::Corrupt { context: "send count" })?;
                    RowOp::SendRows { first_row, count }
                }
                1 => RowOp::Compute {
                    out_row: usize::try_from(r.u64("compute out_row")?)
                        .map_err(|_| PersistError::Corrupt { context: "compute out_row" })?,
                },
                2 => RowOp::Store {
                    out_row: usize::try_from(r.u64("store out_row")?)
                        .map_err(|_| PersistError::Corrupt { context: "store out_row" })?,
                },
                _ => return Err(PersistError::Corrupt { context: "row op tag" }),
            };
            ops.push(op);
        }
        let config =
            TileConfig { problem: key.problem, oc_base, oc_count, out_mode: key.out_mode };
        tiles.push(PlanTile { config, weights, ops });
    }
    Ok(CompiledPlan { problem: key.problem, out_mode: key.out_mode, tiles })
}

// ---------------------------------------------------------------------
// Public encode / decode / save / load
// ---------------------------------------------------------------------

/// Serialize `entries` (as produced by [`PlanCache::export`]) under the
/// fleet's config fingerprint set.
pub fn encode(entries: &[(PlanKey, Arc<CompiledPlan>)], cfg_fps: &[u64]) -> Vec<u8> {
    let mut w = Writer::default();
    w.bytes(&MAGIC);
    w.u32(FORMAT_VERSION);
    let version = env!("CARGO_PKG_VERSION").as_bytes();
    w.u32(version.len() as u32);
    w.bytes(version);
    w.u32(cfg_fps.len() as u32);
    for &fp in cfg_fps {
        w.u64(fp);
    }
    w.u32(entries.len() as u32);
    for (key, plan) in entries {
        let mut kw = Writer::default();
        put_key(&mut kw, key);
        let mut pw = Writer::default();
        put_plan(&mut pw, plan);
        let (fp, fp2) = checksum(&kw.buf, &pw.buf);
        w.bytes(&kw.buf);
        w.u64(pw.buf.len() as u64);
        w.u64(fp);
        w.u64(fp2);
        w.bytes(&pw.buf);
    }
    w.buf
}

/// Size of an encoded [`PlanKey`] — 6 geometry words, mapper and
/// out-mode discriminant bytes, 3 fingerprint words.
const KEY_BYTES: usize = 6 * 8 + 2 + 3 * 8;

/// Decode and fully validate a snapshot. Any failure rejects the whole
/// buffer — a partially trusted snapshot is worse than a cold start.
pub fn decode(bytes: &[u8]) -> Result<Snapshot, PersistError> {
    let mut r = Reader::new(bytes);
    if r.bytes(MAGIC.len(), "magic")? != MAGIC {
        return Err(PersistError::BadMagic);
    }
    let format_version = r.u32("format version")?;
    if format_version != FORMAT_VERSION {
        return Err(PersistError::UnsupportedVersion { found: format_version });
    }
    let vlen = r.u32("crate version length")? as usize;
    let crate_version = std::str::from_utf8(r.bytes(vlen, "crate version")?)
        .map_err(|_| PersistError::Corrupt { context: "crate version" })?
        .to_string();
    let fp_count = r.u32("cfg fingerprint count")? as usize;
    let mut cfg_fps = Vec::with_capacity(fp_count.min(r.remaining()));
    for _ in 0..fp_count {
        cfg_fps.push(r.u64("cfg fingerprint set")?);
    }
    let entry_count = r.u32("entry count")? as usize;
    let mut entries = Vec::with_capacity(entry_count.min(r.remaining()));
    for entry in 0..entry_count {
        let key_bytes: &[u8] = r.bytes(KEY_BYTES, "entry key")?;
        let payload_len = r.len("entry payload length")?;
        let stored_fp = r.u64("entry checksum fp")?;
        let stored_fp2 = r.u64("entry checksum fp2")?;
        let payload = r.bytes(payload_len, "entry payload")?;
        if checksum(key_bytes, payload) != (stored_fp, stored_fp2) {
            return Err(PersistError::ChecksumMismatch { entry });
        }
        let key = get_key(&mut Reader::new(key_bytes))?;
        let mut pr = Reader::new(payload);
        let plan = get_plan(&mut pr, &key, entry)?;
        if pr.remaining() != 0 {
            return Err(PersistError::Corrupt { context: "trailing payload bytes" });
        }
        entries.push((key, Arc::new(plan)));
    }
    if r.remaining() != 0 {
        return Err(PersistError::Corrupt { context: "trailing file bytes" });
    }
    let header = SnapshotHeader { format_version, crate_version, cfg_fps, entries: entry_count };
    Ok(Snapshot { header, entries })
}

/// Atomically write a snapshot to `path` (temp sibling + rename, so a
/// crash mid-flush can leave a stale snapshot but never a torn one).
pub fn save(
    path: &Path,
    entries: &[(PlanKey, Arc<CompiledPlan>)],
    cfg_fps: &[u64],
) -> Result<(), PersistError> {
    let bytes = encode(entries, cfg_fps);
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Read and fully validate the snapshot at `path`.
pub fn load(path: &Path) -> Result<Snapshot, PersistError> {
    decode(&std::fs::read(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::AccelConfig;
    use crate::driver::instructions::compile_layer;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg32;

    fn sample_entries() -> (Vec<(PlanKey, Arc<CompiledPlan>)>, u64) {
        let cfg = AccelConfig::default();
        let mut entries = Vec::new();
        for (i, p) in [
            TconvProblem::new(4, 4, 8, 3, 20, 2),
            TconvProblem::new(4, 4, 8, 3, 6, 1).with_mapper(MapperKind::Segregated),
        ]
        .iter()
        .enumerate()
        {
            let mut rng = Pcg32::new(100 + i as u64);
            let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
            let bias: Vec<i32> = (0..p.oc).map(|c| c as i32 - 2).collect();
            let key = PlanKey::new(p, OutMode::Raw32, &cfg, &w, &bias, None);
            let plan = compile_layer(p, &w, &bias, None, &cfg, OutMode::Raw32);
            entries.push((key, Arc::new(plan)));
        }
        (entries, cfg.fingerprint())
    }

    #[test]
    fn round_trip_preserves_keys_tiles_sigs_and_ops() {
        let (entries, cfg_fp) = sample_entries();
        let bytes = encode(&entries, &[cfg_fp]);
        let snap = decode(&bytes).expect("valid snapshot");
        assert_eq!(snap.header.format_version, FORMAT_VERSION);
        assert_eq!(snap.header.crate_version, env!("CARGO_PKG_VERSION"));
        assert_eq!(snap.header.cfg_fps, vec![cfg_fp]);
        assert_eq!(snap.entries.len(), entries.len());
        for ((k, plan), (dk, dplan)) in entries.iter().zip(&snap.entries) {
            assert_eq!(k, dk);
            assert_eq!(plan.problem, dplan.problem);
            assert_eq!(plan.out_mode, dplan.out_mode);
            assert_eq!(plan.tiles.len(), dplan.tiles.len());
            for (t, dt) in plan.tiles.iter().zip(&dplan.tiles) {
                assert_eq!(t.config, dt.config);
                assert_eq!(t.ops, dt.ops);
                assert_eq!(t.weights.sig(), dt.weights.sig());
                assert_eq!(t.weights.transfer_bytes(), dt.weights.transfer_bytes());
            }
        }
    }

    /// A reloaded plan must instantiate the byte-identical instruction
    /// stream and produce byte-identical accelerator output — the
    /// differential guarantee the warm-restart path rests on.
    #[test]
    fn reloaded_plan_executes_byte_identically() {
        use crate::accel::Accelerator;
        let (entries, cfg_fp) = sample_entries();
        let snap = decode(&encode(&entries, &[cfg_fp])).unwrap();
        let cfg = AccelConfig::default();
        for ((k, original), (_, reloaded)) in entries.iter().zip(&snap.entries) {
            let p = &k.problem;
            let mut rng = Pcg32::new(7);
            let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
            let a = Accelerator::new(cfg.clone()).run_stream(&original.instantiate(&x)).unwrap();
            let b = Accelerator::new(cfg.clone()).run_stream(&reloaded.instantiate(&x)).unwrap();
            assert_eq!(a.raw.data(), b.raw.data(), "outputs diverged after reload");
        }
    }

    #[test]
    fn rejects_bad_magic_version_truncation_and_flips() {
        let (entries, cfg_fp) = sample_entries();
        let bytes = encode(&entries, &[cfg_fp]);

        let mut bad_magic = bytes.clone();
        bad_magic[0] ^= 0xff;
        assert!(matches!(decode(&bad_magic), Err(PersistError::BadMagic)));

        let mut bad_version = bytes.clone();
        bad_version[8..12].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
        assert!(matches!(
            decode(&bad_version),
            Err(PersistError::UnsupportedVersion { found }) if found == FORMAT_VERSION + 1
        ));

        // Truncation anywhere — from the magic to one byte short.
        for cut in [3, MAGIC.len() + 2, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                matches!(decode(&bytes[..cut]), Err(PersistError::Truncated { .. })),
                "cut at {cut} must report truncation"
            );
        }

        // A flipped byte in the final entry's payload trips its checksum.
        let mut flipped = bytes.clone();
        let last = flipped.len() - 3;
        flipped[last] ^= 0x10;
        assert!(matches!(decode(&flipped), Err(PersistError::ChecksumMismatch { entry: 1 })));

        // A flipped byte in an entry's *key* region also trips the
        // checksum (it spans key||payload) — an intact plan can never be
        // re-homed under a corrupted key.
        let header = MAGIC.len() + 4 + (4 + env!("CARGO_PKG_VERSION").len()) + 4 + 8 + 4;
        let mut keyflip = bytes.clone();
        keyflip[header + 5] ^= 0x01; // inside entry 0's problem geometry
        assert!(matches!(decode(&keyflip), Err(PersistError::ChecksumMismatch { entry: 0 })));

        // A checksum-*consistent* file with impossible geometry (all-zero
        // dimensions, which `TconvProblem::new` would assert on) is a
        // typed rejection, never a panic: zero entry 0's first geometry
        // word and recompute its checksum so only the structural gate can
        // catch it.
        let mut zeroed = bytes.clone();
        zeroed[header..header + 8].fill(0);
        let len_at = header + KEY_BYTES;
        let payload_len =
            u64::from_le_bytes(zeroed[len_at..len_at + 8].try_into().unwrap()) as usize;
        let payload_at = len_at + 8 + 16;
        let (fp, fp2) = checksum(
            &zeroed[header..header + KEY_BYTES],
            &zeroed[payload_at..payload_at + payload_len],
        );
        zeroed[len_at + 8..len_at + 16].copy_from_slice(&fp.to_le_bytes());
        zeroed[len_at + 16..len_at + 24].copy_from_slice(&fp2.to_le_bytes());
        assert!(matches!(
            decode(&zeroed),
            Err(PersistError::Corrupt { context: "problem geometry" })
        ));

        // Trailing garbage is rejected, not ignored.
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(matches!(decode(&trailing), Err(PersistError::Corrupt { .. })));
    }

    #[test]
    fn retain_configs_filters_foreign_fleets() {
        let (entries, cfg_fp) = sample_entries();
        let snap = decode(&encode(&entries, &[cfg_fp])).unwrap();
        assert_eq!(snap.clone().retain_configs(&[cfg_fp]).entries.len(), entries.len());
        assert_eq!(snap.retain_configs(&[cfg_fp ^ 1]).entries.len(), 0);
    }

    #[test]
    fn save_load_round_trips_on_disk_and_missing_file_is_io() {
        let (entries, cfg_fp) = sample_entries();
        let name = format!("mm2im_persist_unit_{}.bin", std::process::id());
        let path = std::env::temp_dir().join(name);
        save(&path, &entries, &[cfg_fp]).unwrap();
        let snap = load(&path).unwrap();
        assert_eq!(snap.entries.len(), entries.len());
        let cache = PlanCache::new(8);
        assert_eq!(snap.preload_into(&cache), entries.len());
        assert_eq!(cache.len(), entries.len());
        std::fs::remove_file(&path).unwrap();
        assert!(matches!(load(&path), Err(PersistError::Io(_))));
    }
}
