//! Analytical performance model (§III-C) — Eq. 3 + Eq. 4 in closed form.
//!
//! Estimates accelerator latency for a TCONV problem from the problem
//! geometry and the [`AccelConfig`] cost constants *without executing
//! anything*: this is the model the paper used to guide design choices
//! (third key insight: it exposed the output-map transfer as up to 35% of
//! T_total, motivating the MM2IM Mapper). §V-F validates it within 10%
//! of the real (simulated) accelerator; `rust/benches/perf_model_validation.rs`
//! regenerates that result.

use crate::accel::axi::{instr_cycles, transfer_cycles};
use crate::accel::config::AccelConfig;
use crate::tconv::maps::RowSchedule;
use crate::tconv::problem::TconvProblem;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Eq. 3/4 component estimates, in cycles.
#[derive(Clone, Copy, Debug, Default)]
pub struct Estimate {
    /// CU dot-product cycles (Eq. 3).
    pub t_cu_compute: u64,
    /// CU input-load cycles (Eq. 3).
    pub t_cu_load: u64,
    /// CU partial-store cycles (Eq. 3).
    pub t_cu_store: u64,
    /// Accumulation Unit cycles (Eq. 3).
    pub t_au: u64,
    /// PPU cycles (Eq. 3).
    pub t_ppu: u64,
    /// Mapper generation cycles.
    pub t_mapper: u64,
    /// Weight transfer cycles (Eq. 4).
    pub t_weights: u64,
    /// Input transfer cycles (Eq. 4).
    pub t_inputs: u64,
    /// Output transfer cycles (Eq. 4).
    pub t_outputs: u64,
    /// omap transfer cycles (mapper-disabled only, Eq. 4).
    pub t_omap: u64,
    /// Instruction stream cycles.
    pub t_instr: u64,
    /// Modeled total with the overlap policy applied.
    pub t_total: u64,
}

impl Estimate {
    /// T_PM of Eq. 3.
    pub fn t_pm(&self) -> u64 {
        self.t_cu_compute + self.t_cu_load + self.t_cu_store + self.t_au + self.t_ppu
    }

    /// T_Data of Eq. 4.
    pub fn t_data(&self) -> u64 {
        self.t_weights + self.t_inputs + self.t_outputs + self.t_omap
    }

    /// The paper's summed view: T_total = T_PM + T_Data (+ decode).
    pub fn t_summed(&self) -> u64 {
        self.t_pm() + self.t_data() + self.t_instr + self.t_mapper
    }

    /// Estimated wall-clock seconds at `cfg`'s fabric clock.
    pub fn seconds(&self, cfg: &AccelConfig) -> f64 {
        cfg.seconds(self.t_total)
    }

    /// Fraction of the summed latency spent transferring omap data —
    /// meaningful in the mapper-disabled configuration (§III-C insight).
    pub fn omap_share(&self) -> f64 {
        self.t_omap as f64 / self.t_summed().max(1) as f64
    }
}

/// Width-axis survivors for one (input row) pass: |{(iw, kw) in bounds}|,
/// and the count of pixels with at least one survivor.
fn width_survivors(p: &TconvProblem) -> (u64, u64) {
    let pad = p.pad_left() as i64;
    let ow = p.ow() as i64;
    let mut taps = 0u64;
    let mut pixels = 0u64;
    for iw in 0..p.iw as i64 {
        let base = iw * p.stride as i64 - pad;
        let lo = (-base).max(0);
        let hi = (ow - base).min(p.ks as i64);
        if hi > lo {
            taps += (hi - lo) as u64;
            pixels += 1;
        }
    }
    (taps, pixels)
}

/// Analytical estimate for one TCONV layer on the accelerator.
pub fn estimate(p: &TconvProblem, cfg: &AccelConfig) -> Estimate {
    let sched = RowSchedule::build(p);
    let (w_taps, w_pixels) = width_survivors(p);
    let beats = cfg.dot_cycles(p.ic);
    let dot = cfg.cu_pipeline_latency + beats; // mirrors pm::compute_pass
    let tiles = p.oc.div_ceil(cfg.x_pms);

    let mut e = Estimate::default();

    // ---- per-tile weight load (never overlapped) ---------------------------
    for t in 0..tiles {
        let oc_count = cfg.x_pms.min(p.oc - t * cfg.x_pms);
        let bytes = (oc_count * (p.ks * p.ks * p.ic + 16)) as u64;
        e.t_weights += transfer_cycles(bytes, cfg);
    }

    // ---- per-row compute (lockstep PM array) -------------------------------
    let mut compute_per_tile = 0u64;
    let mut io_per_tile = 0u64;
    let mut mapper_per_tile = 0u64;
    let mut omap_per_tile = 0u64;
    let mut loads_per_tile = 0u64; // LoadInput instruction count
    let mut row_times = vec![0u64; p.oh()]; // per-row timeline charge
    let row_bytes = (p.iw * p.ic) as u64;
    let mut starting: i64 = 0;
    for h in 0..p.oh() {
        let passes = sched.contributions[h].len() as u64;
        let cu_pass = if cfg.cu_reload_input_per_tap {
            w_taps * (dot + beats)
        } else {
            w_taps * dot + w_pixels * beats
        };
        let mapper_pass = p.mapper.mapper_walk_slots(p.iw, p.ks, p.stride, w_taps as usize)
            * cfg.mapper_cycles_per_tap;
        let row_time = if cfg.mapper_enabled {
            mapper_per_tile += passes * mapper_pass;
            passes * cu_pass.max(mapper_pass)
        } else {
            let omap_c = transfer_cycles(w_taps * 4, cfg);
            omap_per_tile += passes * omap_c;
            passes * (cu_pass + omap_c)
        };
        let ppu = p.ow() as u64 * cfg.ppu_cycles_per_output + cfg.fifo_drain_cycles;
        compute_per_tile += row_time + ppu;
        row_times[h] = row_time + ppu;
        let tiles64 = tiles as u64;
        e.t_cu_compute += tiles64 * passes * w_taps * dot;
        e.t_cu_load +=
            tiles64 * passes * if cfg.cu_reload_input_per_tap { w_taps * beats } else { w_pixels * beats };
        e.t_cu_store += tiles64 * passes * w_taps;
        e.t_au += tiles64 * passes * w_taps;
        e.t_ppu += tiles64 * ppu;

        // input rows sent before this output row (Algorithm 1)
        let end = sched.i_end_row[h];
        if end >= starting {
            let rows = (end - starting + 1) as u64;
            io_per_tile += transfer_cycles(rows * row_bytes, cfg);
            loads_per_tile += 1;
            starting = end + 1;
        }
        // output store per row
        io_per_tile += transfer_cycles((cfg.x_pms.min(p.oc) * p.ow()) as u64, cfg);
    }
    let _ = io_per_tile;

    // ---- instruction stream ------------------------------------------------
    // Per tile: Configure (9+1 words) + LoadWeights (1 + 4*oc words) +
    // per output row Schedule (2 words) + StoreOutput (2 words) +
    // `loads_per_tile` LoadInput instructions whose operand words total
    // 3 per instruction plus one length word per sent row (Ih rows/tile).
    let mut instr = 0u64;
    for t in 0..tiles {
        let oc_count = cfg.x_pms.min(p.oc - t * cfg.x_pms) as u64;
        instr += instr_cycles(10, cfg) + instr_cycles(1 + 4 * oc_count, cfg);
        instr += p.oh() as u64 * 2 * instr_cycles(2, cfg);
        instr += loads_per_tile * cfg.instr_decode_cycles + 3 * loads_per_tile + p.ih as u64;
    }
    e.t_instr = instr;

    e.t_mapper = mapper_per_tile * tiles as u64;
    e.t_omap = omap_per_tile * tiles as u64;

    // ---- data transfers (inputs resent per tile; outputs once) ------------
    let mut in_cycles = 0u64;
    let mut starting: i64 = 0;
    for h in 0..p.oh() {
        let end = sched.i_end_row[h];
        if end >= starting {
            in_cycles += transfer_cycles((end - starting + 1) as u64 * row_bytes, cfg);
            starting = end + 1;
        }
    }
    let mut out_cycles = 0u64;
    for t in 0..tiles {
        let oc_count = cfg.x_pms.min(p.oc - t * cfg.x_pms);
        out_cycles += p.oh() as u64 * transfer_cycles((oc_count * p.ow()) as u64, cfg);
    }
    e.t_inputs = in_cycles * tiles as u64;
    e.t_outputs = out_cycles;

    // ---- overlap policy (mirrors sim::advance, per-row budget) -------------
    // Each Schedule replenishes the overlap budget with its row time;
    // the following LoadInput/StoreOutput hide inside it. Replay the
    // per-tile row walk to bound hiding per row rather than globally.
    let compute_total = compute_per_tile * tiles as u64;
    let io_total = e.t_inputs + e.t_outputs;
    let hidden = if cfg.overlap_axi_compute {
        let mut hidden = 0u64;
        for t in 0..tiles {
            let oc_count = cfg.x_pms.min(p.oc - t * cfg.x_pms);
            let store_h = transfer_cycles((oc_count * p.ow()) as u64, cfg);
            let mut starting: i64 = 0;
            let mut budget = 0u64; // no compute before the first Schedule
            for h in 0..p.oh() {
                // LoadInput(h) spends what is left of Schedule(h-1)'s budget
                let end = sched.i_end_row[h];
                if end >= starting {
                    let in_h = transfer_cycles((end - starting + 1) as u64 * row_bytes, cfg);
                    // budget is replenished below before its next read,
                    // so only the hidden tally needs the subtraction.
                    hidden += in_h.min(budget);
                    starting = end + 1;
                }
                // Schedule(h) replenishes, StoreOutput(h) spends; the
                // next LoadInput reads what is left.
                let hide = store_h.min(row_times[h]);
                hidden += hide;
                budget = row_times[h] - hide;
            }
        }
        hidden
    } else {
        0
    };
    e.t_total = e.t_weights + compute_total + e.t_omap + e.t_instr + io_total - hidden;
    e
}

/// Modeled end-to-end seconds (accelerator + host driver overhead).
pub fn estimate_seconds(p: &TconvProblem, cfg: &AccelConfig) -> f64 {
    estimate(p, cfg).seconds(cfg) + crate::driver::instructions::DRIVER_FIXED_OVERHEAD_S
}

/// Memoized [`estimate`] queries, keyed by `(problem, config
/// fingerprint)` — the cost-relevant projection of a
/// [`crate::driver::plan::PlanKey`] (weights never change the cycle
/// estimate, so the parameter digests are deliberately not part of the
/// key). The serving layer queries an estimate for every
/// `(graph TCONV layer, shard config)` pair while precomputing its
/// placement table at server start; this cache makes each distinct
/// `(layer geometry, backend config)` pair pay the analytical walk
/// exactly once per table build, however many graphs and shards share
/// it. (The dispatch path itself only reads the precomputed table.)
#[derive(Debug, Default)]
pub struct EstimateCache {
    inner: Mutex<HashMap<(TconvProblem, u64), Estimate>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl EstimateCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// The estimate for `p` on `cfg`, computed at most once per distinct
    /// `(problem, config)` pair.
    pub fn get(&self, p: &TconvProblem, cfg: &AccelConfig) -> Estimate {
        let key = (*p, cfg.fingerprint());
        if let Some(e) = self.inner.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *e;
        }
        // Compute outside the lock: racing workers may both compute, but
        // the value is deterministic so last-write-wins is harmless.
        let e = estimate(p, cfg);
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.inner.lock().unwrap().insert(key, e);
        e
    }

    /// Modeled end-to-end seconds on `cfg` (accelerator total at the
    /// config's clock + fixed driver dispatch overhead) — the placement
    /// scorer's per-layer input.
    pub fn modeled_seconds(&self, p: &TconvProblem, cfg: &AccelConfig) -> f64 {
        self.get(p, cfg).seconds(cfg) + crate::driver::instructions::DRIVER_FIXED_OVERHEAD_S
    }

    /// `(hits, misses)` counters since construction.
    pub fn counters(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Distinct `(problem, config)` pairs currently memoized.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// True when nothing has been memoized yet.
    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::isa::OutMode;
    use crate::accel::Accelerator;
    use crate::driver::instructions::build_layer_stream;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg32;

    fn simulate(p: &TconvProblem, cfg: &AccelConfig) -> u64 {
        let mut rng = Pcg32::new(9);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let stream = build_layer_stream(p, &x, &w, &vec![0; p.oc], None, cfg, OutMode::Raw32);
        Accelerator::new(cfg.clone()).execute(&stream).unwrap().report.total_cycles
    }

    /// §V-F: "the model estimates the actual performance within 10%".
    #[test]
    fn within_ten_percent_of_simulator() {
        let cfg = AccelConfig::default();
        for p in [
            TconvProblem::square(7, 32, 3, 16, 1),
            TconvProblem::square(9, 64, 5, 32, 2),
            TconvProblem::square(11, 128, 7, 64, 2),
            TconvProblem::square(7, 256, 5, 16, 1),
            TconvProblem::square(4, 1024, 5, 64, 2),
            TconvProblem::square(11, 256, 3, 64, 1),
        ] {
            let sim = simulate(&p, &cfg) as f64;
            let est = estimate(&p, &cfg).t_total as f64;
            let err = (est - sim).abs() / sim;
            assert!(err < 0.10, "{p}: sim {sim} est {est} err {:.1}%", err * 100.0);
        }
    }

    #[test]
    fn mapper_ablation_omap_share_significant_for_small_ic() {
        // §III-C: "up to 35% of T_total ... due to transferring output
        // mapping data". The share peaks on small-Ic problems where the
        // dot product is cheapest relative to the map stream; with our
        // calibrated AXI model the max over the sweep lands lower (the
        // ablation bench prints the full distribution).
        let mut cfg = AccelConfig::default();
        cfg.mapper_enabled = false;
        let small_ic = estimate(&TconvProblem::square(11, 16, 5, 64, 1), &cfg);
        assert!(small_ic.t_omap > 0);
        let share = small_ic.omap_share();
        assert!(share > 0.05 && share < 0.45, "omap share {share}");
        // and it must shrink as Ic grows
        let big_ic = estimate(&TconvProblem::square(11, 256, 5, 64, 1), &cfg);
        assert!(big_ic.omap_share() < share);
    }

    #[test]
    fn estimate_monotone_in_workload() {
        let cfg = AccelConfig::default();
        let small = estimate(&TconvProblem::square(7, 32, 3, 16, 1), &cfg).t_total;
        let big = estimate(&TconvProblem::square(11, 256, 7, 64, 2), &cfg).t_total;
        assert!(big > small * 5);
    }

    /// Placement-scorer sanity: growing any single problem dimension
    /// strictly grows the modeled total (more rows, deeper dot products,
    /// more tiles, or more taps all cost cycles). A scorer ranking shards
    /// by these estimates must never see a bigger problem score cheaper
    /// on the same config.
    #[test]
    fn estimate_monotone_per_axis() {
        let cfg = AccelConfig::default();
        let base = estimate(&TconvProblem::square(7, 32, 3, 16, 2), &cfg).t_total;
        let grow = [
            TconvProblem::square(9, 32, 3, 16, 2),  // taller input
            TconvProblem::square(7, 64, 3, 16, 2),  // deeper dot product
            TconvProblem::square(7, 32, 3, 32, 2),  // more output channels
            TconvProblem::square(7, 32, 5, 16, 2),  // bigger kernel
        ];
        for p in grow {
            let t = estimate(&p, &cfg).t_total;
            assert!(t > base, "{p}: {t} vs base {base}");
        }
    }

    /// Golden values for three Table-II configurations on the default
    /// (paper) config, pinning every scorer input: T_PM (Eq. 3), T_Data
    /// (Eq. 4), the summed view, and the overlap-aware total the
    /// placement scorer converts to seconds. Any change to the cost
    /// model must consciously update these.
    #[test]
    fn golden_values_on_paper_configurations() {
        let cfg = AccelConfig::default();
        // (problem, t_pm, t_data, t_summed, t_total)
        let goldens = [
            // DCGAN_1 (Table II row 1)
            (TconvProblem::square(4, 1024, 5, 512, 2), 2_601_728, 3_602_432, 6_237_376, 5_928_768),
            // StyleTransfer_1
            (TconvProblem::square(64, 128, 3, 64, 2), 8_442_080, 1_428_224, 10_180_472, 7_911_272),
            // FSRCNN
            (TconvProblem::square(32, 32, 9, 2, 2), 1_245_248, 17_688, 1_344_044, 1_093_668),
        ];
        for (p, t_pm, t_data, t_summed, t_total) in goldens {
            let e = estimate(&p, &cfg);
            assert_eq!(e.t_pm(), t_pm, "{p} t_pm");
            assert_eq!(e.t_data(), t_data, "{p} t_data");
            assert_eq!(e.t_summed(), t_summed, "{p} t_summed");
            assert_eq!(e.t_total, t_total, "{p} t_total");
        }
    }

    #[test]
    fn estimate_cache_memoizes_per_problem_and_config() {
        let cache = EstimateCache::new();
        assert!(cache.is_empty());
        let p1 = TconvProblem::square(7, 32, 3, 16, 2);
        let p2 = TconvProblem::square(9, 64, 5, 32, 2);
        let a = AccelConfig::default();
        let mut b = AccelConfig::default();
        b.x_pms = 4;
        b.uf = 32;

        let direct = estimate(&p1, &a);
        let cached = cache.get(&p1, &a);
        assert_eq!(cached.t_total, direct.t_total, "cache is transparent");
        for _ in 0..3 {
            assert_eq!(cache.get(&p1, &a).t_total, direct.t_total);
        }
        // Distinct problem or config = distinct entry.
        let _ = cache.get(&p2, &a);
        let _ = cache.get(&p1, &b);
        assert_eq!(cache.len(), 3);
        let (hits, misses) = cache.counters();
        assert_eq!(misses, 3, "one analytical walk per distinct pair");
        assert_eq!(hits, 3);
        // Seconds view includes the fixed driver overhead.
        let s = cache.modeled_seconds(&p1, &a);
        assert!((s - estimate_seconds(&p1, &a)).abs() < 1e-15);
    }

    #[test]
    fn components_sum_to_summed_view() {
        let cfg = AccelConfig::default();
        let e = estimate(&TconvProblem::square(9, 64, 5, 32, 2), &cfg);
        assert_eq!(
            e.t_summed(),
            e.t_pm() + e.t_weights + e.t_inputs + e.t_outputs + e.t_omap + e.t_instr + e.t_mapper
        );
        // The summed (paper Eq. 3+4) view and the overlap-aware total are
        // close but not ordered in general: cu_store/au are pipelined out
        // of the timeline while max(cu, mapper) can exceed their sum.
        let ratio = e.t_total as f64 / e.t_summed() as f64;
        assert!(ratio > 0.5 && ratio < 1.5, "ratio {ratio}");
    }
}
