//! Analytical performance model (§III-C) — Eq. 3 + Eq. 4 in closed form.
//!
//! Estimates accelerator latency for a TCONV problem from the problem
//! geometry and the [`AccelConfig`] cost constants *without executing
//! anything*: this is the model the paper used to guide design choices
//! (third key insight: it exposed the output-map transfer as up to 35% of
//! T_total, motivating the MM2IM Mapper). §V-F validates it within 10%
//! of the real (simulated) accelerator; `rust/benches/perf_model_validation.rs`
//! regenerates that result.

use crate::accel::axi::{instr_cycles, transfer_cycles};
use crate::accel::config::AccelConfig;
use crate::tconv::maps::RowSchedule;
use crate::tconv::problem::TconvProblem;

/// Eq. 3/4 component estimates, in cycles.
#[derive(Clone, Copy, Debug, Default)]
pub struct Estimate {
    /// CU dot-product cycles (Eq. 3).
    pub t_cu_compute: u64,
    /// CU input-load cycles (Eq. 3).
    pub t_cu_load: u64,
    /// CU partial-store cycles (Eq. 3).
    pub t_cu_store: u64,
    /// Accumulation Unit cycles (Eq. 3).
    pub t_au: u64,
    /// PPU cycles (Eq. 3).
    pub t_ppu: u64,
    /// Mapper generation cycles.
    pub t_mapper: u64,
    /// Weight transfer cycles (Eq. 4).
    pub t_weights: u64,
    /// Input transfer cycles (Eq. 4).
    pub t_inputs: u64,
    /// Output transfer cycles (Eq. 4).
    pub t_outputs: u64,
    /// omap transfer cycles (mapper-disabled only, Eq. 4).
    pub t_omap: u64,
    /// Instruction stream cycles.
    pub t_instr: u64,
    /// Modeled total with the overlap policy applied.
    pub t_total: u64,
}

impl Estimate {
    /// T_PM of Eq. 3.
    pub fn t_pm(&self) -> u64 {
        self.t_cu_compute + self.t_cu_load + self.t_cu_store + self.t_au + self.t_ppu
    }

    /// T_Data of Eq. 4.
    pub fn t_data(&self) -> u64 {
        self.t_weights + self.t_inputs + self.t_outputs + self.t_omap
    }

    /// The paper's summed view: T_total = T_PM + T_Data (+ decode).
    pub fn t_summed(&self) -> u64 {
        self.t_pm() + self.t_data() + self.t_instr + self.t_mapper
    }

    /// Estimated wall-clock seconds at `cfg`'s fabric clock.
    pub fn seconds(&self, cfg: &AccelConfig) -> f64 {
        cfg.seconds(self.t_total)
    }

    /// Fraction of the summed latency spent transferring omap data —
    /// meaningful in the mapper-disabled configuration (§III-C insight).
    pub fn omap_share(&self) -> f64 {
        self.t_omap as f64 / self.t_summed().max(1) as f64
    }
}

/// Width-axis survivors for one (input row) pass: |{(iw, kw) in bounds}|,
/// and the count of pixels with at least one survivor.
fn width_survivors(p: &TconvProblem) -> (u64, u64) {
    let pad = p.pad_left() as i64;
    let ow = p.ow() as i64;
    let mut taps = 0u64;
    let mut pixels = 0u64;
    for iw in 0..p.iw as i64 {
        let base = iw * p.stride as i64 - pad;
        let lo = (-base).max(0);
        let hi = (ow - base).min(p.ks as i64);
        if hi > lo {
            taps += (hi - lo) as u64;
            pixels += 1;
        }
    }
    (taps, pixels)
}

/// Analytical estimate for one TCONV layer on the accelerator.
pub fn estimate(p: &TconvProblem, cfg: &AccelConfig) -> Estimate {
    let sched = RowSchedule::build(p);
    let (w_taps, w_pixels) = width_survivors(p);
    let beats = cfg.dot_cycles(p.ic);
    let dot = cfg.cu_pipeline_latency + beats; // mirrors pm::compute_pass
    let tiles = (p.oc + cfg.x_pms - 1) / cfg.x_pms;

    let mut e = Estimate::default();

    // ---- per-tile weight load (never overlapped) ---------------------------
    for t in 0..tiles {
        let oc_count = cfg.x_pms.min(p.oc - t * cfg.x_pms);
        let bytes = (oc_count * (p.ks * p.ks * p.ic + 16)) as u64;
        e.t_weights += transfer_cycles(bytes, cfg);
    }

    // ---- per-row compute (lockstep PM array) -------------------------------
    let mut compute_per_tile = 0u64;
    let mut io_per_tile = 0u64;
    let mut mapper_per_tile = 0u64;
    let mut omap_per_tile = 0u64;
    let mut loads_per_tile = 0u64; // LoadInput instruction count
    let mut row_times = vec![0u64; p.oh()]; // per-row timeline charge
    let row_bytes = (p.iw * p.ic) as u64;
    let mut starting: i64 = 0;
    for h in 0..p.oh() {
        let passes = sched.contributions[h].len() as u64;
        let cu_pass = if cfg.cu_reload_input_per_tap {
            w_taps * (dot + beats)
        } else {
            w_taps * dot + w_pixels * beats
        };
        let mapper_pass = (p.iw * p.ks) as u64 * cfg.mapper_cycles_per_tap;
        let row_time = if cfg.mapper_enabled {
            mapper_per_tile += passes * mapper_pass;
            passes * cu_pass.max(mapper_pass)
        } else {
            let omap_c = transfer_cycles(w_taps * 4, cfg);
            omap_per_tile += passes * omap_c;
            passes * (cu_pass + omap_c)
        };
        let ppu = p.ow() as u64 * cfg.ppu_cycles_per_output + cfg.fifo_drain_cycles;
        compute_per_tile += row_time + ppu;
        row_times[h] = row_time + ppu;
        let tiles64 = tiles as u64;
        e.t_cu_compute += tiles64 * passes * w_taps * dot;
        e.t_cu_load +=
            tiles64 * passes * if cfg.cu_reload_input_per_tap { w_taps * beats } else { w_pixels * beats };
        e.t_cu_store += tiles64 * passes * w_taps;
        e.t_au += tiles64 * passes * w_taps;
        e.t_ppu += tiles64 * ppu;

        // input rows sent before this output row (Algorithm 1)
        let end = sched.i_end_row[h];
        if end >= starting {
            let rows = (end - starting + 1) as u64;
            io_per_tile += transfer_cycles(rows * row_bytes, cfg);
            loads_per_tile += 1;
            starting = end + 1;
        }
        // output store per row
        io_per_tile += transfer_cycles((cfg.x_pms.min(p.oc) * p.ow()) as u64, cfg);
    }
    let _ = io_per_tile;

    // ---- instruction stream ------------------------------------------------
    // Per tile: Configure (9+1 words) + LoadWeights (1 + 4*oc words) +
    // per output row Schedule (2 words) + StoreOutput (2 words) +
    // `loads_per_tile` LoadInput instructions whose operand words total
    // 3 per instruction plus one length word per sent row (Ih rows/tile).
    let mut instr = 0u64;
    for t in 0..tiles {
        let oc_count = cfg.x_pms.min(p.oc - t * cfg.x_pms) as u64;
        instr += instr_cycles(10, cfg) + instr_cycles(1 + 4 * oc_count, cfg);
        instr += p.oh() as u64 * 2 * instr_cycles(2, cfg);
        instr += loads_per_tile * cfg.instr_decode_cycles + 3 * loads_per_tile + p.ih as u64;
    }
    e.t_instr = instr;

    e.t_mapper = mapper_per_tile * tiles as u64;
    e.t_omap = omap_per_tile * tiles as u64;

    // ---- data transfers (inputs resent per tile; outputs once) ------------
    let mut in_cycles = 0u64;
    let mut starting: i64 = 0;
    for h in 0..p.oh() {
        let end = sched.i_end_row[h];
        if end >= starting {
            in_cycles += transfer_cycles((end - starting + 1) as u64 * row_bytes, cfg);
            starting = end + 1;
        }
    }
    let mut out_cycles = 0u64;
    for t in 0..tiles {
        let oc_count = cfg.x_pms.min(p.oc - t * cfg.x_pms);
        out_cycles += p.oh() as u64 * transfer_cycles((oc_count * p.ow()) as u64, cfg);
    }
    e.t_inputs = in_cycles * tiles as u64;
    e.t_outputs = out_cycles;

    // ---- overlap policy (mirrors sim::advance, per-row budget) -------------
    // Each Schedule replenishes the overlap budget with its row time;
    // the following LoadInput/StoreOutput hide inside it. Replay the
    // per-tile row walk to bound hiding per row rather than globally.
    let compute_total = compute_per_tile * tiles as u64;
    let io_total = e.t_inputs + e.t_outputs;
    let hidden = if cfg.overlap_axi_compute {
        let mut hidden = 0u64;
        for t in 0..tiles {
            let oc_count = cfg.x_pms.min(p.oc - t * cfg.x_pms);
            let store_h = transfer_cycles((oc_count * p.ow()) as u64, cfg);
            let mut starting: i64 = 0;
            let mut budget = 0u64; // no compute before the first Schedule
            for h in 0..p.oh() {
                // LoadInput(h) spends what is left of Schedule(h-1)'s budget
                let end = sched.i_end_row[h];
                if end >= starting {
                    let in_h = transfer_cycles((end - starting + 1) as u64 * row_bytes, cfg);
                    // budget is replenished below before its next read,
                    // so only the hidden tally needs the subtraction.
                    hidden += in_h.min(budget);
                    starting = end + 1;
                }
                // Schedule(h) replenishes, StoreOutput(h) spends; the
                // next LoadInput reads what is left.
                let hide = store_h.min(row_times[h]);
                hidden += hide;
                budget = row_times[h] - hide;
            }
        }
        hidden
    } else {
        0
    };
    e.t_total = e.t_weights + compute_total + e.t_omap + e.t_instr + io_total - hidden;
    e
}

/// Modeled end-to-end seconds (accelerator + host driver overhead).
pub fn estimate_seconds(p: &TconvProblem, cfg: &AccelConfig) -> f64 {
    estimate(p, cfg).seconds(cfg) + crate::driver::instructions::DRIVER_FIXED_OVERHEAD_S
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::isa::OutMode;
    use crate::accel::Accelerator;
    use crate::driver::instructions::build_layer_stream;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg32;

    fn simulate(p: &TconvProblem, cfg: &AccelConfig) -> u64 {
        let mut rng = Pcg32::new(9);
        let x = Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        let stream = build_layer_stream(p, &x, &w, &vec![0; p.oc], None, cfg, OutMode::Raw32);
        Accelerator::new(cfg.clone()).execute(&stream).unwrap().report.total_cycles
    }

    /// §V-F: "the model estimates the actual performance within 10%".
    #[test]
    fn within_ten_percent_of_simulator() {
        let cfg = AccelConfig::default();
        for p in [
            TconvProblem::square(7, 32, 3, 16, 1),
            TconvProblem::square(9, 64, 5, 32, 2),
            TconvProblem::square(11, 128, 7, 64, 2),
            TconvProblem::square(7, 256, 5, 16, 1),
            TconvProblem::square(4, 1024, 5, 64, 2),
            TconvProblem::square(11, 256, 3, 64, 1),
        ] {
            let sim = simulate(&p, &cfg) as f64;
            let est = estimate(&p, &cfg).t_total as f64;
            let err = (est - sim).abs() / sim;
            assert!(err < 0.10, "{p}: sim {sim} est {est} err {:.1}%", err * 100.0);
        }
    }

    #[test]
    fn mapper_ablation_omap_share_significant_for_small_ic() {
        // §III-C: "up to 35% of T_total ... due to transferring output
        // mapping data". The share peaks on small-Ic problems where the
        // dot product is cheapest relative to the map stream; with our
        // calibrated AXI model the max over the sweep lands lower (the
        // ablation bench prints the full distribution).
        let mut cfg = AccelConfig::default();
        cfg.mapper_enabled = false;
        let small_ic = estimate(&TconvProblem::square(11, 16, 5, 64, 1), &cfg);
        assert!(small_ic.t_omap > 0);
        let share = small_ic.omap_share();
        assert!(share > 0.05 && share < 0.45, "omap share {share}");
        // and it must shrink as Ic grows
        let big_ic = estimate(&TconvProblem::square(11, 256, 5, 64, 1), &cfg);
        assert!(big_ic.omap_share() < share);
    }

    #[test]
    fn estimate_monotone_in_workload() {
        let cfg = AccelConfig::default();
        let small = estimate(&TconvProblem::square(7, 32, 3, 16, 1), &cfg).t_total;
        let big = estimate(&TconvProblem::square(11, 256, 7, 64, 2), &cfg).t_total;
        assert!(big > small * 5);
    }

    #[test]
    fn components_sum_to_summed_view() {
        let cfg = AccelConfig::default();
        let e = estimate(&TconvProblem::square(9, 64, 5, 32, 2), &cfg);
        assert_eq!(
            e.t_summed(),
            e.t_pm() + e.t_weights + e.t_inputs + e.t_outputs + e.t_omap + e.t_instr + e.t_mapper
        );
        // The summed (paper Eq. 3+4) view and the overlap-aware total are
        // close but not ordered in general: cu_store/au are pipelined out
        // of the timeline while max(cu, mapper) can exceed their sum.
        let ratio = e.t_total as f64 / e.t_summed() as f64;
        assert!(ratio > 0.5 && ratio < 1.5, "ratio {ratio}");
    }
}
