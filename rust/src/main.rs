//! `repro` — CLI for the MM2IM reproduction.
//!
//! Commands:
//!   info                       architecture, resource model, peak numbers
//!   layer    --ih --ic --ks --oc --stride [--iw]   run one TCONV problem
//!   sweep    [--limit N]       the 261-problem §V-B sweep (Figs. 6/7)
//!   dcgan    [--seed S]        end-to-end DCGAN generator (Table IV)
//!   pix2pix  [--size N --width W]  end-to-end pix2pix (Table IV)
//!   validate [--artifacts DIR] PJRT artifact vs rust-native numerics
//!   serve    [--requests N --shards S --workers-per-shard W --queue Q
//!             --batch B --plan-store PATH --expect-warm
//!             --fault-spec SPEC --stats-json PATH]
//!                            sharded, batched inference service with a
//!                            shared compiled-plan cache; --plan-store
//!                            persists compiled plans across runs,
//!                            --expect-warm asserts the reload compiled
//!                            nothing (the CI warm-restart leg),
//!                            --fault-spec injects seeded faults (e.g.
//!                            "seed=7,transient=0.2,kill=1@3") to
//!                            exercise retry/quarantine supervision, and
//!                            --stats-json dumps the run's final
//!                            telemetry snapshot as stable JSON
//!   stats    <dump.json>     pretty-print a --stats-json telemetry dump
//!                            and run the built-in triage rules over it;
//!                            exits 1 when an error-severity rule fires,
//!                            2 when the dump is unreadable
//!   plans    <save|load|inspect> --path PATH [--model pix2pix|dcgan
//!             --size N --width W --seed S]
//!                            compile a model's plans and save them as a
//!                            snapshot / validate + print a snapshot's
//!                            header / list its entries
//!
//! Shared flags: --x N, --uf N (architecture scaling), --no-mapper,
//! --no-skip (ablations).

use mm2im::accel::{resources, AccelConfig};
use mm2im::bench::{run_problem, sweep261};
use mm2im::coordinator;
use mm2im::driver::{persist, Delegate, PlanCache};
use mm2im::model::executor::{Executor, RunConfig};
use mm2im::model::{float_ref, zoo};
use mm2im::runtime::{Manifest, PjrtRuntime};
use mm2im::tconv::TconvProblem;
use mm2im::telemetry::{triage, Snapshot};
use mm2im::tensor::Tensor;
use mm2im::util::cli::Args;
use mm2im::util::rng::Pcg32;
use mm2im::util::stats;
use mm2im::util::table::{f2, ms, pct, Table};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("info") => info(),
        Some("layer") => layer(&args),
        Some("sweep") => sweep(&args),
        Some("dcgan") => dcgan(&args),
        Some("pix2pix") => pix2pix(&args),
        Some("validate") => validate(&args),
        Some("serve") => serve(&args),
        Some("plans") => plans(&args),
        Some("stats") => stats_cmd(&args),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown command '{cmd}'\n");
            }
            eprintln!(
                "usage: repro <info|layer|sweep|dcgan|pix2pix|validate|serve|plans|stats> \
                 [--options]"
            );
            eprintln!("see module docs in rust/src/main.rs for per-command flags");
            std::process::exit(if other.is_some() { 2 } else { 0 });
        }
    }
}

fn cfg_from(args: &Args) -> AccelConfig {
    let mut cfg = AccelConfig::default();
    cfg.x_pms = args.usize_or("x", cfg.x_pms);
    cfg.uf = args.usize_or("uf", cfg.uf);
    if args.flag("no-mapper") {
        cfg.mapper_enabled = false;
    }
    if args.flag("no-skip") {
        cfg.cmap_skip_enabled = false;
    }
    cfg
}

fn info() {
    let cfg = AccelConfig::default();
    let r = resources::estimate(&cfg);
    println!("MM2IM accelerator (simulated PYNQ-Z1 instantiation)");
    println!("  PMs (X)            : {}", cfg.x_pms);
    println!("  Unroll factor (UF) : {}", cfg.uf);
    println!("  Clock              : {} MHz", cfg.freq_hz / 1e6);
    println!(
        "  Peak               : {} MACs/cycle = {:.1} GOPs",
        cfg.peak_macs_per_cycle(),
        cfg.peak_gops()
    );
    println!("  DSP                : {} ({:.0}%)", r.dsp, r.dsp_pct());
    println!("  LUT                : {} ({:.0}%)", r.lut, r.lut_pct());
    println!("  FF                 : {} ({:.0}%)", r.ff, r.ff_pct());
    println!("  BRAM               : {:.1} Mb ({:.0}%)", r.bram_bits as f64 / 1e6, r.bram_pct());
    println!("  GOPs/DSP (peak)    : {:.2}", cfg.peak_gops() / r.dsp as f64);
}

fn layer(args: &Args) {
    let ih = args.usize_or("ih", 7);
    let p = TconvProblem::new(
        ih,
        args.usize_or("iw", ih),
        args.usize_or("ic", 32),
        args.usize_or("ks", 5),
        args.usize_or("oc", 16),
        args.usize_or("stride", 2),
    );
    let cfg = cfg_from(args);
    let r = run_problem(&p, &cfg, args.u64_or("seed", 1));
    println!("{p}: M={} N={} K={} ({} MACs)", p.m(), p.n(), p.k(), p.macs());
    println!("  drop rate          : {} (D_o = {})", pct(r.drop.d_r), r.drop.d_o);
    println!(
        "  accelerator        : {} ms ({} GOPs, util {})",
        ms(r.acc_seconds),
        f2(r.gops),
        pct(r.utilization)
    );
    println!("  cpu 1T / 2T        : {} / {} ms", ms(r.cpu1_seconds), ms(r.cpu2_seconds));
    println!("  speedup vs 1T / 2T : {}x / {}x", f2(r.speedup_1t()), f2(r.speedup_2t()));
    println!("  GOPs/W             : {}", f2(r.gops_per_watt));
    println!("  cycles             : {} (summed-view {})", r.report.total_cycles, r.report.summed_view());
}

fn sweep(args: &Args) {
    let cfg = cfg_from(args);
    let entries = sweep261();
    let limit = args.usize_or("limit", entries.len());
    let mut speedups = Vec::new();
    let mut t = Table::new(
        "261-problem sweep (Fig. 6/7 data)",
        &["problem", "drop", "acc ms", "cpu2T ms", "speedup"],
    );
    for e in entries.iter().take(limit) {
        let r = run_problem(&e.problem, &cfg, 1);
        speedups.push(r.speedup_2t());
        t.row(&[
            e.problem.to_string(),
            pct(r.drop.d_r),
            ms(r.acc_seconds),
            ms(r.cpu2_seconds),
            f2(r.speedup_2t()),
        ]);
    }
    t.print();
    println!(
        "\nmean speedup {:.2}x | geomean {:.2}x | median {:.2}x (paper: avg 1.9x)",
        stats::mean(&speedups),
        stats::geomean(&speedups),
        stats::median(&speedups)
    );
}

fn dcgan(args: &Args) {
    let g = zoo::dcgan_tf(args.u64_or("seed", 0));
    let cfg = cfg_from(args);
    run_model(&g, &cfg, args);
}

fn pix2pix(args: &Args) {
    let g = zoo::pix2pix(args.usize_or("size", 64), args.usize_or("width", 16), args.u64_or("seed", 0));
    let cfg = cfg_from(args);
    run_model(&g, &cfg, args);
}

fn run_model(g: &mm2im::model::Graph, cfg: &AccelConfig, args: &Args) {
    let mut rng = Pcg32::new(args.u64_or("input-seed", 7));
    let input = Tensor::<i8>::random(&g.input_shape, &mut rng);
    let t0 = Instant::now();
    let exec = Executor::new(Delegate::new(cfg.clone(), 2, true));
    let run = exec.run(g, &input);
    println!(
        "{}: output {:?} (host wall {:.2}s)",
        g.name,
        run.output.shape(),
        t0.elapsed().as_secs_f64()
    );
    let mut t = Table::new(
        &format!("{} modeled on PYNQ-Z1 (Table IV rows)", g.name),
        &["configuration", "TCONV ms", "overall ms", "energy J"],
    );
    for (label, rc) in [
        ("CPU 1T", RunConfig::Cpu { threads: 1 }),
        ("ACC + CPU 1T", RunConfig::AccPlusCpu { threads: 1 }),
        ("CPU 2T", RunConfig::Cpu { threads: 2 }),
        ("ACC + CPU 2T", RunConfig::AccPlusCpu { threads: 2 }),
    ] {
        let tb = run.modeled(rc, cfg);
        t.row(&[label.into(), ms(tb.tconv_s), ms(tb.total_s()), format!("{:.3}", tb.energy_j)]);
    }
    t.print();
}

fn validate(args: &Args) {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(mm2im::runtime::manifest::default_dir);
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("cannot load manifest: {e}");
            std::process::exit(1);
        }
    };
    let rt = match PjrtRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot validate: {e}");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", rt.platform());
    let mut rng = Pcg32::new(args.u64_or("seed", 11));

    for meta in manifest.tconv_artifacts() {
        let mm2im::runtime::ArtifactKind::Tconv { name, problem: p } = &meta.kind else {
            continue;
        };
        let exe = rt.load(&manifest.path_of(meta), 1).expect("load");
        let x = Tensor::random_normal(&[p.ih, p.iw, p.ic], 1.0, &mut rng);
        let w = Tensor::random_normal(&[p.oc, p.ks, p.ks, p.ic], 0.1, &mut rng);
        let b = Tensor::random_normal(&[p.oc], 0.1, &mut rng);
        let got = &exe.run_f32(&[x.clone(), w.clone(), b.clone()]).expect("run")[0];
        let want = mm2im::tconv::reference::direct_f32(p, &x, &w, Some(b.data()));
        let diff = got.max_abs_diff(&want);
        println!(
            "  {name} {p}: max |pjrt - rust| = {diff:.2e} {}",
            if diff < 1e-3 { "OK" } else { "MISMATCH" }
        );
        assert!(diff < 1e-3);
    }

    if let Some(meta) = manifest.dcgan() {
        let exe = rt.load(&manifest.path_of(meta), 1).expect("load dcgan");
        let params = float_ref::random_params(&mut rng, 0.02);
        let z = Tensor::random_normal(&[float_ref::LATENT], 1.0, &mut rng);
        let mut argv = vec![z.clone()];
        argv.extend(params.iter().cloned());
        let got = &exe.run_f32(&argv).expect("run dcgan")[0];
        let want = float_ref::dcgan_forward(z.data(), &params);
        let diff = got.clone().reshape(&[28, 28, 1]).max_abs_diff(&want);
        println!(
            "  dcgan_gen: max |pjrt - rust| = {diff:.2e} {}",
            if diff < 1e-3 { "OK" } else { "MISMATCH" }
        );
        assert!(diff < 1e-3);
    }
    println!("validate: all artifacts match rust-native numerics");
}

fn serve(args: &Args) {
    let size = args.usize_or("size", 16);
    let width = args.usize_or("width", 4);
    let g = Arc::new(zoo::pix2pix(size, width, 0));
    let n = args.usize_or("requests", 8);
    let shards = args.usize_or("shards", 2);
    let workers_per_shard = args.usize_or("workers-per-shard", 1);
    let workers = shards.max(1) * workers_per_shard.max(1);
    let mut builder = coordinator::Server::builder()
        .graph(g)
        .shards(shards)
        .workers_per_shard(workers_per_shard)
        .queue_capacity(args.usize_or("queue", 16))
        .max_batch(args.usize_or("batch", 4))
        .accel(cfg_from(args));
    if let Some(path) = args.get("plan-store") {
        builder = builder.plan_store(path);
    }
    if let Some(spec) = args.get("fault-spec") {
        match mm2im::accel::FaultSpec::parse(spec) {
            Ok(spec) => builder = builder.fault_plan(mm2im::accel::FaultPlan::new(spec)),
            Err(e) => {
                eprintln!("invalid --fault-spec: {e}");
                std::process::exit(2);
            }
        }
    }
    let mut server = builder
        .start()
        .unwrap_or_else(|e| {
            eprintln!("cannot start server: {e}");
            std::process::exit(1);
        });
    // Mixed-class traffic: every 4th request is latency-sensitive.
    for seed in 0..n as u64 {
        let req = coordinator::Request::seed(seed).priority(if seed % 4 == 0 {
            coordinator::Priority::High
        } else {
            coordinator::Priority::Normal
        });
        server.submit(req).expect("seeded requests always validate");
    }
    // Keep a handle on the server's telemetry tree: it outlives
    // `finish`, so the final snapshot (uptime set, health resynced) can
    // be dumped after the summary prints.
    let telem = server.telemetry();
    let (responses, stats) = server.finish();
    assert_eq!(responses.len(), n);
    println!(
        "served {} requests on {shards} shards / {workers} workers: {:.1} req/s",
        stats.requests, stats.throughput_rps
    );
    println!(
        "  latency p50 / p95 : {:.1} / {:.1} ms (host wall, incl. queue)",
        stats.p50_latency_s * 1e3,
        stats.p95_latency_s * 1e3
    );
    for c in mm2im::bench::harness::latency_by_class(&responses) {
        println!(
            "    class {:<6}    : {} requests, p50 {:.1} ms, p95 {:.1} ms",
            c.priority.label(),
            c.requests,
            c.p50_s * 1e3,
            c.p95_s * 1e3
        );
    }
    println!(
        "  mean wall / modeled: {:.1} / {:.1} ms",
        stats.wall_mean_s * 1e3,
        stats.modeled_mean_s * 1e3
    );
    println!(
        "  plan cache        : {:.0}% hit rate ({} hits / {} compiles, {} preloaded)",
        stats.cache_hit_rate() * 100.0,
        stats.cache_hits,
        stats.cache_misses,
        stats.plans_preloaded
    );
    println!(
        "  batching          : {} batches, {:.2} mean batch size",
        stats.batches, stats.mean_batch_size
    );
    println!(
        "  weight loads      : {:.0}% amortized ({} performed / {} per-request equiv)",
        stats.weight_load_hit_rate() * 100.0,
        stats.weight_loads,
        stats.weight_loads_equiv
    );
    for (i, (u, r)) in stats.shard_utilization.iter().zip(&stats.shard_requests).enumerate() {
        println!("  shard {i}           : {:.0}% utilized, {r} requests", u * 100.0);
    }
    if stats.exec_failures > 0 || stats.requests_failed > 0 || !stats.worker_failures.is_empty() {
        println!(
            "  supervision       : {} exec failures, {} retries, {} requests failed",
            stats.exec_failures, stats.retries, stats.requests_failed
        );
        println!(
            "  shard health      : {} quarantine events, {} probes, {} recoveries; final {:?}",
            stats.shards_quarantined, stats.probes, stats.probe_recoveries, stats.shard_health
        );
        for e in &stats.worker_failures {
            println!("  worker failure    : {e}");
        }
    }
    if args.flag("expect-warm") {
        // CI warm-restart leg: a snapshot-preloaded server must serve its
        // whole run without compiling a single plan.
        if stats.plans_preloaded == 0 || stats.cache_misses != 0 {
            eprintln!(
                "expect-warm FAILED: {} plans preloaded, {} compiles (wanted >0 / 0)",
                stats.plans_preloaded, stats.cache_misses
            );
            std::process::exit(1);
        }
        println!("  warm restart      : OK (zero plan compiles after snapshot preload)");
    }
    if let Some(path) = args.get("stats-json") {
        let snap = telem.snapshot();
        if let Err(e) = std::fs::write(path, snap.to_json()) {
            eprintln!("cannot write --stats-json {path}: {e}");
            std::process::exit(2);
        }
        println!("  telemetry         : wrote {} ({} metrics)", path, snap.iter().count());
    }
}

/// `repro stats <dump.json>` — rebuild a snapshot from a `serve
/// --stats-json` dump, pretty-print the projected summary, and run the
/// built-in triage rules. Exit codes: 2 when the dump cannot be read or
/// parsed, 1 when an error-severity rule fires, 0 otherwise (warnings
/// and missing-path verdicts print but do not fail the command).
fn stats_cmd(args: &Args) {
    let Some(path) = args.positional.first() else {
        eprintln!("usage: repro stats <dump.json>");
        std::process::exit(2);
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {path}: {e}");
            std::process::exit(2);
        }
    };
    let snap = match Snapshot::from_json(&text) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{path} is not a telemetry dump: {e}");
            std::process::exit(2);
        }
    };
    println!("telemetry dump {path}: {} metrics", snap.iter().count());
    match coordinator::ServeStats::from_snapshot(&snap) {
        Ok(stats) => {
            println!(
                "  requests          : {} served / {} submitted ({} cancelled, {} expired, {} failed)",
                stats.requests,
                stats.submitted,
                stats.cancelled,
                stats.deadline_expired,
                stats.requests_failed
            );
            println!(
                "  latency p50 / p95 : {:.1} / {:.1} ms ({:.1} req/s)",
                stats.p50_latency_s * 1e3,
                stats.p95_latency_s * 1e3,
                stats.throughput_rps
            );
            println!(
                "  plan cache        : {:.0}% hit rate ({} hits / {} compiles, {} preloaded)",
                stats.cache_hit_rate() * 100.0,
                stats.cache_hits,
                stats.cache_misses,
                stats.plans_preloaded
            );
            println!(
                "  batching          : {} batches, {:.2} mean batch size, {} cross-graph",
                stats.batches, stats.mean_batch_size, stats.cross_graph_batches
            );
            println!(
                "  weight loads      : {:.0}% amortized ({} performed / {} per-request equiv)",
                stats.weight_load_hit_rate() * 100.0,
                stats.weight_loads,
                stats.weight_loads_equiv
            );
            for (i, (u, r)) in
                stats.shard_utilization.iter().zip(&stats.shard_requests).enumerate()
            {
                println!(
                    "  shard {i}           : {:.0}% utilized, {r} requests, {:?}",
                    u * 100.0,
                    stats.shard_health[i]
                );
            }
            if stats.exec_failures > 0 || !stats.worker_failures.is_empty() {
                println!(
                    "  supervision       : {} exec failures, {} retries, {} quarantine events",
                    stats.exec_failures, stats.retries, stats.shards_quarantined
                );
                for e in &stats.worker_failures {
                    println!("  worker failure    : {e}");
                }
            }
        }
        // A hand-trimmed or non-serve dump still triages; the projection
        // is a convenience, not a gate.
        Err(e) => println!("  (no serve summary: {e})"),
    }
    println!("triage:");
    let report = triage::evaluate(&triage::default_rules(), &snap);
    print!("{report}");
    if report.worst() == Some(triage::Severity::Error) {
        std::process::exit(1);
    }
}

/// `repro plans <save|load|inspect> --path PATH` — build, validate, or dump
/// a compiled-plan snapshot (`driver::persist` format).
fn plans(args: &Args) {
    let verb = args.positional.first().map(String::as_str);
    let path = std::path::PathBuf::from(args.get_or("path", "plans.mm2im"));
    match verb {
        Some("save") => {
            let g = match args.get_or("model", "pix2pix") {
                "pix2pix" => zoo::pix2pix(
                    args.usize_or("size", 16),
                    args.usize_or("width", 4),
                    args.u64_or("seed", 0),
                ),
                "dcgan" => zoo::dcgan_tf(args.u64_or("seed", 0)),
                other => {
                    eprintln!("unknown --model '{other}' (expected pix2pix or dcgan)");
                    std::process::exit(2);
                }
            };
            let cfg = cfg_from(args);
            let cache = PlanCache::shared(args.usize_or("cache", 64));
            let exec = Executor::new(Delegate::with_cache(cfg.clone(), 1, true, cache.clone()));
            let mut rng = Pcg32::new(args.u64_or("input-seed", 7));
            let input = Tensor::<i8>::random(&g.input_shape, &mut rng);
            exec.run(&g, &input);
            let entries = cache.export();
            if let Err(e) = persist::save(&path, &entries, &[cfg.fingerprint()]) {
                eprintln!("cannot save snapshot to {}: {e}", path.display());
                std::process::exit(1);
            }
            println!(
                "saved {} compiled plans for {} (cfg fp {:#018x}) to {}",
                entries.len(),
                g.name,
                cfg.fingerprint(),
                path.display()
            );
        }
        Some("load") => {
            let snap = load_snapshot_or_exit(&path);
            print_header(&snap.header, &path);
            println!("validation: OK (magic, version, checksums, weight-set signatures)");
        }
        Some("inspect") => {
            let snap = load_snapshot_or_exit(&path);
            print_header(&snap.header, &path);
            let mut t = Table::new(
                "snapshot entries",
                &["problem", "out", "cfg fp", "tiles", "instrs", "weight bytes"],
            );
            for (key, plan) in &snap.entries {
                let weight_bytes: u64 =
                    plan.tiles.iter().map(|t| t.weights.transfer_bytes()).sum();
                t.row(&[
                    key.problem.to_string(),
                    format!("{:?}", key.out_mode),
                    format!("{:#018x}", key.cfg_fp),
                    plan.tiles.len().to_string(),
                    plan.instr_count().to_string(),
                    weight_bytes.to_string(),
                ]);
            }
            t.print();
        }
        other => {
            if let Some(v) = other {
                eprintln!("unknown plans verb '{v}'\n");
            }
            eprintln!("usage: repro plans <save|load|inspect> --path PATH [--model pix2pix|dcgan]");
            std::process::exit(2);
        }
    }
}

fn load_snapshot_or_exit(path: &std::path::Path) -> persist::Snapshot {
    match persist::load(path) {
        Ok(snap) => snap,
        Err(e) => {
            eprintln!("cannot load snapshot {}: {e}", path.display());
            eprintln!("(a server pointed at this path would fall back to a cold start)");
            std::process::exit(1);
        }
    }
}

fn print_header(h: &persist::SnapshotHeader, path: &std::path::Path) {
    println!("snapshot {}", path.display());
    println!("  format version : {}", h.format_version);
    println!("  crate version  : {}", h.crate_version);
    println!(
        "  config fps     : [{}]",
        h.cfg_fps.iter().map(|f| format!("{f:#018x}")).collect::<Vec<_>>().join(", ")
    );
    println!("  entries        : {}", h.entries);
}
