//! Output Crossbar (§IV-C): merges the per-PM output-row streams and
//! assembles them into the NHWC output tensor on the way to main memory.

use crate::tconv::problem::TconvProblem;
use crate::tensor::Tensor;

/// Collects completed rows from each PM and writes them at
/// [h, :, oc_base + pm] of the layer output.
pub struct Crossbar {
    raw: Tensor<i32>,
    quant: Tensor<i8>,
    p: TconvProblem,
    rows_stored: usize,
}

impl Crossbar {
    /// Empty output assembly for one layer (or one batch slot).
    pub fn new(p: &TconvProblem) -> Self {
        Self {
            raw: Tensor::zeros(&[p.oh(), p.ow(), p.oc]),
            quant: Tensor::zeros(&[p.oh(), p.ow(), p.oc]),
            p: *p,
            rows_stored: 0,
        }
    }

    /// Store one PM's completed output row for channel `oc`. Writes
    /// through one `data_mut()` borrow per tensor per row — the
    /// copy-on-write uniqueness check is paid twice per row, not per
    /// element (the crossbar's tensors are never shared while
    /// assembling, so it never actually copies).
    pub fn store_row(&mut self, h: usize, oc: usize, raw: &[i32], quant: &[i8]) {
        let (ow_total, oc_total) = (self.p.ow(), self.p.oc);
        assert_eq!(raw.len(), ow_total);
        assert_eq!(quant.len(), ow_total);
        assert!(h < self.p.oh() && oc < oc_total, "store ({h}, {oc}) out of range");
        let base = h * ow_total * oc_total + oc;
        let rdst = self.raw.data_mut();
        for (i, &v) in raw.iter().enumerate() {
            rdst[base + i * oc_total] = v;
        }
        let qdst = self.quant.data_mut();
        for (i, &v) in quant.iter().enumerate() {
            qdst[base + i * oc_total] = v;
        }
        self.rows_stored += 1;
    }

    /// (row, channel) stores performed so far; a complete layer needs
    /// `Oh * Oc`.
    pub fn rows_stored(&self) -> usize {
        self.rows_stored
    }

    /// Problem this crossbar assembles.
    pub fn problem(&self) -> TconvProblem {
        self.p
    }

    /// Bytes sent to main memory for one row-store burst of `pms` PMs.
    pub fn store_bytes(&self, pms: usize, int8: bool) -> u64 {
        let per = if int8 { 1 } else { 4 };
        (pms * self.p.ow() * per) as u64
    }

    /// Consume into the assembled (raw int32, requantized int8) tensors.
    pub fn into_outputs(self) -> (Tensor<i32>, Tensor<i8>) {
        (self.raw, self.quant)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assembles_rows_into_nhwc() {
        let p = TconvProblem::new(2, 2, 1, 2, 3, 2);
        let mut cb = Crossbar::new(&p);
        cb.store_row(1, 2, &[10, 20, 30, 40], &[1, 2, 3, 4]);
        let (raw, quant) = cb.into_outputs();
        assert_eq!(raw.at3(1, 0, 2), 10);
        assert_eq!(raw.at3(1, 3, 2), 40);
        assert_eq!(quant.at3(1, 2, 2), 3);
        assert_eq!(raw.at3(0, 0, 0), 0);
    }

    #[test]
    fn store_bytes_by_mode() {
        let p = TconvProblem::new(2, 4, 1, 2, 8, 2);
        let cb = Crossbar::new(&p);
        assert_eq!(cb.store_bytes(8, true), 8 * 8);
        assert_eq!(cb.store_bytes(8, false), 8 * 8 * 4);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn bounds_checked() {
        let p = TconvProblem::new(2, 2, 1, 2, 3, 2);
        let mut cb = Crossbar::new(&p);
        cb.store_row(4, 0, &[0; 4], &[0; 4]);
    }
}
