//! AXI-Stream + DMA transfer model (Fig. 3's connection to main memory).
//!
//! Every bulk transfer costs a DMA descriptor setup plus payload beats at
//! `axi_bytes_per_cycle`. Instruction words ride the same stream one word
//! per beat after decode.

use super::config::AccelConfig;

/// Cycles to move `bytes` of bulk data over the data stream.
pub fn transfer_cycles(bytes: u64, cfg: &AccelConfig) -> u64 {
    if bytes == 0 {
        return 0;
    }
    cfg.dma_setup_cycles + bytes.div_ceil(cfg.axi_bytes_per_cycle as u64)
}

/// Cycles for an instruction's words (decode + one beat per word).
pub fn instr_cycles(words: u64, cfg: &AccelConfig) -> u64 {
    cfg.instr_decode_cycles + words
}

/// Running tally of bytes by direction (for Eq. 4's T_Data and the
/// bandwidth section of the report).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AxiTraffic {
    /// Filter payload bytes (opcode 0x02).
    pub weight_bytes: u64,
    /// Input row bytes (opcode 0x04).
    pub input_bytes: u64,
    /// Output row bytes (opcode 0x10).
    pub output_bytes: u64,
    /// omap bytes (mapper-disabled ablation only).
    pub omap_bytes: u64,
    /// Instruction words decoded.
    pub instr_words: u64,
}

impl AxiTraffic {
    /// Every byte that crossed the stream (instruction words count 4 B).
    pub fn total_bytes(&self) -> u64 {
        self.weight_bytes + self.input_bytes + self.output_bytes + self.omap_bytes
            + self.instr_words * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_bytes_free() {
        assert_eq!(transfer_cycles(0, &AccelConfig::default()), 0);
    }

    #[test]
    fn beats_round_up() {
        let cfg = AccelConfig::default(); // 4 B/cycle, 64 setup
        assert_eq!(transfer_cycles(1, &cfg), 64 + 1);
        assert_eq!(transfer_cycles(4, &cfg), 64 + 1);
        assert_eq!(transfer_cycles(5, &cfg), 64 + 2);
        assert_eq!(transfer_cycles(4096, &cfg), 64 + 1024);
    }

    #[test]
    fn traffic_totals() {
        let t = AxiTraffic {
            weight_bytes: 100,
            input_bytes: 50,
            output_bytes: 25,
            omap_bytes: 0,
            instr_words: 10,
        };
        assert_eq!(t.total_bytes(), 215);
    }
}
