//! Cycle accounting across the accelerator (the simulator's answer to the
//! paper's Eq. 3/4 decomposition, with the overlap policy on top).

use super::axi::AxiTraffic;
use super::config::AccelConfig;
use super::pm::PmCycles;

/// Per-component cycle tallies of one executed stream (layer or batch).
/// `PartialEq` so the engine differential net can assert the fused and
/// scalar paths produce *identical* reports, not just equal totals —
/// see the manual impl below for the one deliberate exclusion.
#[derive(Clone, Debug, Default)]
pub struct CycleReport {
    /// Summed per-PM component charges (max over PMs per pass, since the
    /// array runs in lockstep on the same maps).
    pub pm: PmCycles,
    /// Mapper generation cycles (overlapped with compute when possible).
    pub mapper: u64,
    /// AXI cycles moving filter payloads.
    pub axi_weights: u64,
    /// AXI cycles moving input rows.
    pub axi_inputs: u64,
    /// AXI cycles draining output rows.
    pub axi_outputs: u64,
    /// AXI cycles fetching omaps (mapper-disabled ablation only).
    pub axi_omap: u64,
    /// Instruction decode + word-stream cycles.
    pub instr: u64,
    /// Byte tallies.
    pub traffic: AxiTraffic,
    /// Final modeled executione time (with overlap policy applied).
    pub total_cycles: u64,
    /// Effectual / skipped MAC counts (utilization + ablation metrics).
    pub effectual_macs: u64,
    /// MACs the cmap-skip ablation would have wasted.
    pub wasted_macs: u64,
    /// `LoadWeights` instructions that actually moved filter payloads
    /// over AXI.
    pub weight_loads: u64,
    /// `LoadWeights` instructions elided because the identical filter set
    /// was already resident in PM BRAM (weight-stationary reuse across
    /// streams on a persistent instance; see `sim::Accelerator`).
    pub weight_loads_skipped: u64,
    /// `LoadWeights` transfers whose *host-side* operand repack was
    /// skipped because the fused engine still held the set's packed GEMM
    /// operands in its LRU (multi-tile layers reload BRAM every stream,
    /// but the pack survives). Zero modeled cycles — a host-throughput
    /// counter only, which is why [`CycleReport`]'s `PartialEq` excludes
    /// it (the scalar oracle never packs at all).
    pub repacks_skipped: u64,
}

impl PartialEq for CycleReport {
    /// Every modeled field; `repacks_skipped` is deliberately excluded —
    /// it tallies a host-side pack-cache optimization that costs zero
    /// modeled cycles and has no scalar-path equivalent, so the fused ==
    /// scalar report identity the differential net asserts must not
    /// depend on it.
    fn eq(&self, other: &Self) -> bool {
        self.pm == other.pm
            && self.mapper == other.mapper
            && self.axi_weights == other.axi_weights
            && self.axi_inputs == other.axi_inputs
            && self.axi_outputs == other.axi_outputs
            && self.axi_omap == other.axi_omap
            && self.instr == other.instr
            && self.traffic == other.traffic
            && self.total_cycles == other.total_cycles
            && self.effectual_macs == other.effectual_macs
            && self.wasted_macs == other.wasted_macs
            && self.weight_loads == other.weight_loads
            && self.weight_loads_skipped == other.weight_loads_skipped
    }
}

impl Eq for CycleReport {}

impl CycleReport {
    /// Modeled wall-clock seconds at `cfg`'s fabric clock.
    pub fn seconds(&self, cfg: &AccelConfig) -> f64 {
        cfg.seconds(self.total_cycles)
    }

    /// Achieved GOPs counting *algorithm* ops (the paper counts the full
    /// IOM M*N*K work as the layer's OPs, so skipped MACs still count as
    /// delivered work — that is exactly how skipping wins speedup).
    pub fn achieved_gops(&self, algorithm_macs: u64, cfg: &AccelConfig) -> f64 {
        2.0 * algorithm_macs as f64 / self.seconds(cfg) / 1e9
    }

    /// MAC-array utilization: effectual MACs / (peak MACs * cycles).
    pub fn utilization(&self, cfg: &AccelConfig) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        self.effectual_macs as f64
            / (cfg.peak_macs_per_cycle() as f64 * self.total_cycles as f64)
    }

    /// The paper's summed Eq. 3 + Eq. 4 view (no overlap) — what the
    /// analytical `perf_model` predicts; kept for §V-F validation.
    pub fn summed_view(&self) -> u64 {
        self.pm.t_pm()
            + self.mapper
            + self.axi_weights
            + self.axi_inputs
            + self.axi_outputs
            + self.axi_omap
            + self.instr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seconds_and_gops() {
        let cfg = AccelConfig::default();
        let mut r = CycleReport::default();
        r.total_cycles = 200_000; // 1 ms at 200 MHz
        assert!((r.seconds(&cfg) - 1e-3).abs() < 1e-12);
        // 1e6 MACs in 1ms = 2 GOPs
        assert!((r.achieved_gops(1_000_000, &cfg) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn utilization_bounds() {
        let cfg = AccelConfig::default();
        let mut r = CycleReport::default();
        r.total_cycles = 1000;
        r.effectual_macs = 128 * 1000; // saturated
        assert!((r.utilization(&cfg) - 1.0).abs() < 1e-12);
        r.effectual_macs = 0;
        assert_eq!(r.utilization(&cfg), 0.0);
    }

    #[test]
    fn repacks_skipped_excluded_from_equality() {
        let mut a = CycleReport::default();
        a.total_cycles = 123;
        let mut b = a.clone();
        b.repacks_skipped = 7;
        assert_eq!(a, b, "host-side pack-cache hits must not break report identity");
        b.total_cycles += 1;
        assert_ne!(a, b, "modeled fields still compare");
    }

    #[test]
    fn summed_view_adds_components() {
        let mut r = CycleReport::default();
        r.pm = PmCycles { cu_compute: 10, cu_load: 5, cu_store: 2, au: 2, ppu: 1 };
        r.mapper = 3;
        r.axi_weights = 7;
        r.instr = 2;
        assert_eq!(r.summed_view(), 32);
    }
}
