//! Fused tile-level GEMM + col2IM execution engine — the host-side fast
//! path for `Schedule` passes (`AccelConfig::exec_engine`, the default).
//!
//! The paper's core claim is that TCONV is best computed as a tiled
//! MatMul followed by a col2IM scatter; the legacy simulator path
//! nevertheless executed each pass as per-tap scalar dot products, one
//! length-`Ic` dot per (tap, PM). This module restructures exactly that
//! work into dense, regular kernels (the same restructuring
//! Kernel-Segregated Transpose Convolution and HUGE2 exploit on edge
//! CPUs/FPGAs):
//!
//! * **Pack** — at `LoadWeights`, the tile's `oc_count` resident filters
//!   are repacked once from per-PM `(kh, kw, ic)` order into per-`(kh,
//!   kw)` blocks of shape `[oc_count, Ic]` (each row one PM's filter
//!   column). The pack is skipped entirely when the resident-weight skip
//!   fires, and — because the engine keeps an LRU of the last
//!   [`PACKED_LRU`] packed sets keyed by `WeightSetSig` — also when a
//!   transfer re-delivers a recently packed set, which is every tile of
//!   a multi-tile layer from its second stream on
//!   (`CycleReport::repacks_skipped`).
//! * **GEMM** — a pass (fixed `kh`) walks the cached width-tap map once,
//!   grouped by `kw`. Each group's surviving input pixels form a
//!   *contiguous* `[n, Ic]` slice of the broadcast row (the mapper's
//!   survivors for one `kw` are an integer interval of `iw`), so the
//!   whole PM array × tap group is one `cpu::gemm::gemm_i8_i32_nt` call
//!   — no gather, no per-tap bounds math.
//! * **col2IM scatter** — the `[tap, pm]` product block accumulates into
//!   each PM's `out_row` at `ow0 + j*stride` (the cached omap restricted
//!   to the group), coalescing overlapping sums in the accumulator
//!   exactly like the hardware out muxer. i32 addition is associative,
//!   so the result is bit-identical to the scalar path.
//!
//! Cycle charges are computed *analytically* in closed form from the
//! tile's tap census (`taps`, `distinct pixels`, `Iw*Ks` candidates) —
//! the same totals the scalar path tallies per tap, so `CycleReport` is
//! identical by construction. `rust/tests/engine_differential.rs` locks
//! both equivalences (outputs and reports) down across the sweep sample,
//! the ablation configs, and batched streams.

use std::sync::Mutex;

use super::config::AccelConfig;
use super::isa::{FilterPayload, WeightSetSig};
use super::mapper::WidthTap;
use super::pm::{PmCycles, ProcessingModule};
use crate::cpu::gemm::gemm_i8_i32_nt;
use crate::cpu::threadpool::ThreadPool;
use crate::tconv::problem::TconvProblem;

/// Packed filter sets the engine keeps resident, keyed by
/// [`WeightSetSig`]. The accelerator's resident-skip tracks only the
/// *last* loaded set, so a multi-tile layer reloads every tile's filters
/// on each stream — but the host-side pack is pure bookkeeping, so the
/// engine keeps an LRU of recent packs and skips the repack whenever a
/// `LoadWeights` transfer re-delivers a set it already packed
/// (`CycleReport::repacks_skipped` counts these; zero modeled cycles
/// either way).
pub const PACKED_LRU: usize = 8;

/// One `kw`'s surviving taps within a pass: a contiguous run of input
/// pixels `[iw0, iw0 + n)` scattering to output columns `ow0 + j*stride`.
#[derive(Clone, Copy, Debug)]
struct TapGroup {
    kw: usize,
    iw0: usize,
    n: usize,
    ow0: usize,
}

/// Row-invariant per-tile state: the kw tap groups and the tap census
/// the analytic cycle charges are derived from.
#[derive(Clone, Debug)]
struct EngineTile {
    groups: Vec<TapGroup>,
    /// Surviving taps per pass (`cached_taps.len()`).
    taps: u64,
    /// Input pixels with >= 1 surviving tap (cu_load census for the
    /// `cu_reload_input_per_tap = false` configuration).
    distinct_pixels: u64,
    /// Candidate taps per pass, the cmap-skip ablation's wasted-work
    /// census: `Iw * Ks` for the Overlapped walk, `taps` for the
    /// Segregated one (`MapperKind::candidate_taps`).
    candidate_taps: u64,
    stride: usize,
}

/// One filter set's packed GEMM operands, identified by its
/// [`WeightSetSig`] (the same identity the accelerator's resident-skip
/// compares).
#[derive(Clone, Debug)]
struct PackedSet {
    sig: WeightSetSig,
    /// Per-(kh, kw) packed operand, laid out
    /// `[(kh*ks + kw) * ocn * ic + p * ic + c]`.
    data: Vec<i8>,
    ks: usize,
    ic: usize,
    ocn: usize,
}

/// The fused execution engine owned by one `Accelerator` instance.
///
/// Packed filter operands persist across streams in a small LRU keyed by
/// [`WeightSetSig`] ([`PACKED_LRU`] sets), so multi-tile layers — whose
/// per-tile `LoadWeights` always transfer again because the BRAM
/// resident-skip tracks only the last set — still skip the host-side
/// repack on every stream after the first. Tap groups are per-tile state
/// rebuilt at `Configure`.
#[derive(Debug, Default)]
pub struct Engine {
    /// Packed filter sets, most recently used at the back.
    packed: Vec<PackedSet>,
    /// Index into `packed` of the set the current tile computes with.
    current: Option<usize>,
    tile: Option<EngineTile>,
    /// GEMM output scratch, `[max group n, ocn]`, recycled across passes.
    scratch: Vec<i32>,
    /// Persistent worker pool for the parallel pass path, built lazily
    /// to `AccelConfig::host_threads - 1` OS threads (the pass-issuing
    /// thread participates as one more lane). `None` until a pass
    /// actually goes parallel.
    pool: Option<ThreadPool>,
    /// Per-lane GEMM scratch for the parallel path (each lane locks its
    /// own slot — never contended, the Mutex only satisfies `Sync`).
    par_scratch: Vec<Mutex<Vec<i32>>>,
}

impl Engine {
    /// Fresh engine: nothing packed, no tile configured.
    pub(crate) fn new() -> Self {
        Self::default()
    }

    /// Drop per-tile state ahead of a new stream. Packed filters are
    /// deliberately kept — they belong to the resident filter set, which
    /// survives stream resets on a persistent instance.
    pub(crate) fn reset_tile(&mut self) {
        self.tile = None;
    }

    /// Latch one tile's row-invariant tap census (called at `Configure`
    /// with the simulator's cached width-tap map).
    pub(crate) fn configure(&mut self, p: &TconvProblem, oc_count: usize, taps: &[WidthTap]) {
        let mut groups: Vec<TapGroup> = Vec::with_capacity(p.ks);
        let mut seen = vec![false; p.iw];
        for t in taps {
            seen[t.iw as usize] = true;
            let kw = t.kw as usize;
            match groups.iter_mut().find(|g| g.kw == kw) {
                Some(g) => {
                    // The mapper emits kw groups as integer iw intervals
                    // in ascending order — the contiguity the one-slice
                    // GEMM operand depends on. Checked once per tile.
                    assert_eq!(t.iw as usize, g.iw0 + g.n, "non-contiguous tap group");
                    g.n += 1;
                }
                None => groups.push(TapGroup {
                    kw,
                    iw0: t.iw as usize,
                    n: 1,
                    ow0: t.ow as usize,
                }),
            }
        }
        let max_n = groups.iter().map(|g| g.n).max().unwrap_or(0);
        self.scratch.clear();
        self.scratch.resize(max_n * oc_count, 0);
        self.tile = Some(EngineTile {
            groups,
            taps: taps.len() as u64,
            distinct_pixels: seen.iter().filter(|&&b| b).count() as u64,
            candidate_taps: p.mapper.candidate_taps(p.iw, p.ks, taps.len()),
            stride: p.stride,
        });
    }

    /// Make `filters` the current packed operand set. Called only when
    /// `LoadWeights` actually transfers (a resident-skip keeps the
    /// previous pack, which is the same bytes). Returns `true` when the
    /// repack was *skipped* because the set — identified by `sig`, the
    /// same signature the resident-skip compares — was still in the
    /// engine's LRU of [`PACKED_LRU`] packed sets; the caller counts
    /// these in `CycleReport::repacks_skipped`.
    pub(crate) fn load_filters(
        &mut self,
        filters: &[FilterPayload],
        ks: usize,
        ic: usize,
        sig: WeightSetSig,
    ) -> bool {
        if let Some(pos) = self.packed.iter().position(|s| s.sig == sig) {
            // LRU hit: same payload bytes (sig is a dual-128-bit digest
            // over them), so the existing pack is valid — refresh its
            // recency and point the tile at it.
            let set = self.packed.remove(pos);
            self.packed.push(set);
            self.current = Some(self.packed.len() - 1);
            return true;
        }
        let ocn = filters.len();
        let mut data = vec![0i8; ks * ks * ocn * ic];
        for khkw in 0..ks * ks {
            let base = khkw * ocn * ic;
            for (p, f) in filters.iter().enumerate() {
                data[base + p * ic..base + (p + 1) * ic]
                    .copy_from_slice(&f.weights[khkw * ic..(khkw + 1) * ic]);
            }
        }
        if self.packed.len() == PACKED_LRU {
            self.packed.remove(0);
        }
        self.packed.push(PackedSet { sig, data, ks, ic, ocn });
        self.current = Some(self.packed.len() - 1);
        false
    }

    /// Execute one (output row, input row) pass for the whole PM array:
    /// per-kw-group GEMMs plus the col2IM scatter into each PM's
    /// `out_row`, with the pass's cycle charges returned in closed form
    /// (one PM's lockstep tally, exactly like the scalar path). Also
    /// credits the PMs' effectual/skipped MAC counters the way the
    /// scalar path does, so the report drain downstream is unchanged.
    ///
    /// When `AccelConfig::host_threads` asks for more than one lane and
    /// the pass is big enough (`AccelConfig::host_parallel_min_macs`),
    /// the PM array is split into contiguous chunks fanned out over the
    /// persistent [`ThreadPool`]. Each chunk computes its own PMs' slice
    /// of every group GEMM and scatters into accumulators only it owns,
    /// so outputs are bit-identical to the serial path regardless of
    /// worker scheduling — and the charges are computed analytically
    /// outside the parallel region, so `CycleReport` cannot even in
    /// principle depend on the thread count.
    pub(crate) fn compute_pass(
        &mut self,
        input_row: &[i8],
        kh: usize,
        pms: &mut [ProcessingModule],
        cfg: &AccelConfig,
    ) -> PmCycles {
        let (pass_macs, ocn) = {
            let tile = self.tile.as_ref().expect("engine pass before Configure");
            let set = &self.packed[self.current.expect("engine pass before LoadWeights")];
            (tile.taps * (set.ocn * set.ic) as u64, set.ocn)
        };
        let mut lanes = cfg.resolved_host_threads().min(ocn.max(1));
        if pass_macs < cfg.host_parallel_min_macs {
            lanes = 1;
        }
        if lanes > 1 {
            self.ensure_lanes(lanes);
            return self.compute_pass_parallel(input_row, kh, pms, cfg, lanes);
        }

        let tile = self.tile.as_ref().expect("engine pass before Configure");
        let set = &self.packed[self.current.expect("engine pass before LoadWeights")];
        let ic = set.ic;
        debug_assert_eq!(pms.len(), ocn, "PM slice must match the packed filter set");
        debug_assert_eq!(input_row.len() % ic.max(1), 0);

        for g in &tile.groups {
            let b0 = (kh * set.ks + g.kw) * ocn * ic;
            let b = &set.data[b0..b0 + ocn * ic];
            let a = &input_row[g.iw0 * ic..(g.iw0 + g.n) * ic];
            let c = &mut self.scratch[..g.n * ocn];
            c.fill(0);
            gemm_i8_i32_nt(g.n, ocn, ic, a, b, c);
            for (p, pm) in pms.iter_mut().enumerate() {
                let row = pm.row_accum_mut();
                for (j, chunk) in c.chunks_exact(ocn).enumerate() {
                    row[g.ow0 + j * tile.stride] += chunk[p];
                }
            }
        }
        charge_pass(tile, ic, pms, cfg)
    }

    /// Size the pool and per-lane scratch for `lanes` execution lanes
    /// (the issuing thread plus `lanes - 1` pooled OS workers).
    fn ensure_lanes(&mut self, lanes: usize) {
        let workers = lanes - 1;
        if self.pool.as_ref().map(ThreadPool::workers) != Some(workers) {
            self.pool = Some(ThreadPool::new(workers));
        }
        if self.par_scratch.len() < lanes {
            self.par_scratch.resize_with(lanes, Mutex::default);
        }
    }

    /// The parallel pass body: PM chunks fan out over the pool; chunk
    /// `ci` computes columns `[ci * chunk, ci * chunk + take)` of every
    /// group GEMM against the packed operand's matching row block (the
    /// packed layout keeps one (kh, kw) block's PM rows contiguous, so
    /// a chunk's B operand is a contiguous sub-slice).
    fn compute_pass_parallel(
        &mut self,
        input_row: &[i8],
        kh: usize,
        pms: &mut [ProcessingModule],
        cfg: &AccelConfig,
        lanes: usize,
    ) -> PmCycles {
        let tile = self.tile.as_ref().expect("engine pass before Configure");
        let set = &self.packed[self.current.expect("engine pass before LoadWeights")];
        let (ic, ocn) = (set.ic, set.ocn);
        debug_assert_eq!(pms.len(), ocn, "PM slice must match the packed filter set");
        debug_assert_eq!(input_row.len() % ic.max(1), 0);

        let chunk = ocn.div_ceil(lanes);
        // Pre-split the PM array into disjoint chunks behind Mutexes so
        // the shared `Fn` closure can reach mutable state safely; each
        // chunk is locked exactly once, by the lane that owns it.
        let pm_chunks: Vec<Mutex<(usize, &mut [ProcessingModule])>> = pms
            .chunks_mut(chunk)
            .enumerate()
            .map(|(ci, s)| Mutex::new((ci * chunk, s)))
            .collect();
        let (groups, stride) = (&tile.groups, tile.stride);
        let (data, ks) = (&set.data, set.ks);
        let par_scratch = &self.par_scratch;
        let pool = self.pool.as_ref().expect("ensure_lanes builds the pool first");
        pool.run(pm_chunks.len(), &|ci| {
            let mut guard = pm_chunks[ci].lock().unwrap();
            let (pm0, pm_chunk) = &mut *guard;
            let take = pm_chunk.len();
            let mut scr = par_scratch[ci].lock().unwrap();
            for g in groups {
                let b0 = (kh * ks + g.kw) * ocn * ic + *pm0 * ic;
                let b = &data[b0..b0 + take * ic];
                let a = &input_row[g.iw0 * ic..(g.iw0 + g.n) * ic];
                if scr.len() < g.n * take {
                    scr.resize(g.n * take, 0);
                }
                let c = &mut scr[..g.n * take];
                c.fill(0);
                gemm_i8_i32_nt(g.n, take, ic, a, b, c);
                for (p, pm) in pm_chunk.iter_mut().enumerate() {
                    let row = pm.row_accum_mut();
                    for (j, crow) in c.chunks_exact(take).enumerate() {
                        row[g.ow0 + j * stride] += crow[p];
                    }
                }
            }
        });
        drop(pm_chunks); // release the chunk borrows before re-borrowing pms
        charge_pass(tile, ic, pms, cfg)
    }
}

/// Analytic lockstep charges: closed form over the tap census,
/// term-for-term what `compute_pass_taps` tallies per tap. Shared by
/// the serial and parallel pass paths — always computed on the issuing
/// thread, which is what keeps `CycleReport` independent of
/// `host_threads` by construction.
fn charge_pass(
    tile: &EngineTile,
    ic: usize,
    pms: &mut [ProcessingModule],
    cfg: &AccelConfig,
) -> PmCycles {
    let dot = cfg.cu_pipeline_latency + cfg.dot_cycles(ic);
    let load = cfg.dot_cycles(ic);
    let taps = tile.taps;
    let mut cyc = PmCycles {
        cu_compute: taps * dot,
        cu_load: if cfg.cu_reload_input_per_tap {
            taps * load
        } else {
            tile.distinct_pixels * load
        },
        cu_store: taps,
        au: taps,
        ppu: 0,
    };
    for pm in pms.iter_mut() {
        pm.effectual_macs += taps * ic as u64;
    }
    if !cfg.cmap_skip_enabled {
        let wasted = tile.candidate_taps - taps;
        cyc.cu_compute += wasted * dot;
        if cfg.cu_reload_input_per_tap {
            cyc.cu_load += wasted * load;
        }
        cyc.cu_store += wasted;
        cyc.au += wasted;
        for pm in pms.iter_mut() {
            pm.skipped_macs += wasted * ic as u64;
        }
    }
    cyc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::config::ExecEngine;
    use crate::accel::mapper::Mapper;
    use crate::util::rng::Pcg32;

    fn payloads(p: &TconvProblem, w: &crate::tensor::Tensor<i8>, n: usize) -> Vec<FilterPayload> {
        (0..n)
            .map(|oc| {
                let mut weights = Vec::with_capacity(p.ks * p.ks * p.ic);
                for kh in 0..p.ks {
                    for kw in 0..p.ks {
                        for c in 0..p.ic {
                            weights.push(w.at4(oc, kh, kw, c));
                        }
                    }
                }
                FilterPayload {
                    weights: weights.into(),
                    bias: 0,
                    qmult_m: 1 << 30,
                    qmult_shift: 1,
                    zp_out: 0,
                }
            })
            .collect()
    }

    /// Engine pass == scalar pass on the same PM array: accumulators and
    /// cycle charges, across strides and kernel/channel shapes.
    #[test]
    fn engine_pass_matches_scalar_pass() {
        for (p, seed) in [
            (TconvProblem::new(5, 4, 16, 5, 3, 2), 1u64),
            (TconvProblem::new(4, 6, 8, 3, 2, 1), 2),
            (TconvProblem::new(3, 3, 32, 2, 4, 3), 3), // Ks < S
            (TconvProblem::new(1, 1, 21, 4, 4, 4), 4), // FCN-like
        ] {
            let mut rng = Pcg32::new(seed);
            let x = crate::tensor::Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
            let w = crate::tensor::Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
            let cfg = AccelConfig::default();
            let mapper = Mapper::configure(&p);
            let taps = mapper.row_maps(0, 0, &cfg).taps;
            let filters = payloads(&p, &w, p.oc);

            let mut engine = Engine::new();
            engine.configure(&p, p.oc, &taps);
            let fresh =
                engine.load_filters(&filters, p.ks, p.ic, WeightSetSig::of(&filters, p.ks, p.ic));
            assert!(!fresh, "first load must pack");
            let mut fused: Vec<ProcessingModule> =
                (0..p.oc).map(|_| ProcessingModule::new()).collect();
            let mut scalar: Vec<ProcessingModule> =
                (0..p.oc).map(|_| ProcessingModule::new()).collect();
            for (pm, f) in fused.iter_mut().chain(scalar.iter_mut()).zip(
                filters.iter().chain(filters.iter()),
            ) {
                pm.load_filter(f, p.ks, p.ic);
            }

            for h in 0..p.oh() {
                for pm in fused.iter_mut().chain(scalar.iter_mut()) {
                    pm.begin_row(p.ow());
                }
                for (ihr, kh) in mapper.contributing_rows(h) {
                    let row = &x.data()[ihr * p.iw * p.ic..(ihr + 1) * p.iw * p.ic];
                    let a = engine.compute_pass(row, kh, &mut fused, &cfg);
                    let mut b = PmCycles::default();
                    let candidates = p.mapper.candidate_taps(p.iw, p.ks, taps.len());
                    for pm in scalar.iter_mut() {
                        b = pm.compute_pass_taps(row, &taps, kh, candidates, &cfg);
                    }
                    assert_eq!(a, b, "{p} h={h} kh={kh}: cycle charges diverge");
                }
                for (i, (f, s)) in fused.iter_mut().zip(scalar.iter_mut()).enumerate() {
                    let (fr, fq, fppu) = f.finish_row(&cfg);
                    let (sr, sq, sppu) = s.finish_row(&cfg);
                    assert_eq!(fr, sr, "{p} h={h} pm={i}: raw rows diverge");
                    assert_eq!(fq, sq, "{p} h={h} pm={i}: quant rows diverge");
                    assert_eq!(fppu, sppu);
                }
            }
            for (f, s) in fused.iter().zip(scalar.iter()) {
                assert_eq!(f.effectual_macs, s.effectual_macs, "{p}: MAC census diverges");
            }
        }
    }

    /// The ablation censuses (distinct pixels, candidate taps) agree
    /// with the scalar tallies under both non-default configurations.
    #[test]
    fn engine_ablation_charges_match_scalar() {
        let p = TconvProblem::new(4, 5, 16, 5, 2, 2);
        let mut rng = Pcg32::new(9);
        let x = crate::tensor::Tensor::<i8>::random(&[p.ih, p.iw, p.ic], &mut rng);
        let w = crate::tensor::Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
        for cfg in [
            AccelConfig { cu_reload_input_per_tap: false, ..AccelConfig::default() },
            AccelConfig { cmap_skip_enabled: false, ..AccelConfig::default() },
        ] {
            let mapper = Mapper::configure(&p);
            let taps = mapper.row_maps(0, 0, &cfg).taps;
            let filters = payloads(&p, &w, p.oc);
            let mut engine = Engine::new();
            engine.configure(&p, p.oc, &taps);
            engine.load_filters(&filters, p.ks, p.ic, WeightSetSig::of(&filters, p.ks, p.ic));
            let mut fused: Vec<ProcessingModule> =
                (0..p.oc).map(|_| ProcessingModule::new()).collect();
            let mut scalar = ProcessingModule::new();
            for pm in fused.iter_mut() {
                pm.load_filter(&filters[0], p.ks, p.ic);
            }
            scalar.load_filter(&filters[0], p.ks, p.ic);

            let (ihr, kh) = mapper.contributing_rows(0)[0];
            let row = &x.data()[ihr * p.iw * p.ic..(ihr + 1) * p.iw * p.ic];
            for pm in fused.iter_mut() {
                pm.begin_row(p.ow());
            }
            scalar.begin_row(p.ow());
            let a = engine.compute_pass(row, kh, &mut fused, &cfg);
            let candidates = p.mapper.candidate_taps(p.iw, p.ks, taps.len());
            let b = scalar.compute_pass_taps(row, &taps, kh, candidates, &cfg);
            assert_eq!(a, b, "ablation charges diverge");
            assert_eq!(fused[0].skipped_macs, scalar.skipped_macs);
        }
        // Exercised configs must really be the fused default otherwise.
        assert_eq!(AccelConfig::default().exec_engine, ExecEngine::Fused);
    }

    /// The packed-operand LRU: reloading a recently packed set skips the
    /// repack, distinct sets pack fresh, and eviction at capacity forces
    /// a repack of the oldest set — numerics unaffected throughout
    /// (asserted by the differential net; here we pin the bookkeeping).
    #[test]
    fn packed_lru_skips_repacks_and_evicts_oldest() {
        let p = TconvProblem::new(3, 3, 8, 3, 2, 2);
        let mut rng = Pcg32::new(17);
        let sets: Vec<(Vec<FilterPayload>, WeightSetSig)> = (0..PACKED_LRU + 1)
            .map(|_| {
                let w = crate::tensor::Tensor::<i8>::random(&[p.oc, p.ks, p.ks, p.ic], &mut rng);
                let f = payloads(&p, &w, p.oc);
                let sig = WeightSetSig::of(&f, p.ks, p.ic);
                (f, sig)
            })
            .collect();
        let mut engine = Engine::new();
        // First loads pack; immediate reloads hit the LRU.
        for (f, sig) in sets.iter().take(2) {
            assert!(!engine.load_filters(f, p.ks, p.ic, *sig), "first load packs");
            assert!(engine.load_filters(f, p.ks, p.ic, *sig), "reload skips the repack");
        }
        // Alternating between two resident sets keeps hitting.
        assert!(engine.load_filters(&sets[0].0, p.ks, p.ic, sets[0].1));
        assert!(engine.load_filters(&sets[1].0, p.ks, p.ic, sets[1].1));
        // Fill past capacity: set 0 (the least recently used after the
        // alternation is set... fill order makes sets[0] oldest once all
        // others load) eventually evicts and must repack.
        for (f, sig) in sets.iter().skip(1) {
            engine.load_filters(f, p.ks, p.ic, *sig);
        }
        assert_eq!(engine.packed.len(), PACKED_LRU, "capacity bounded");
        assert!(
            !engine.load_filters(&sets[0].0, p.ks, p.ic, sets[0].1),
            "evicted set must repack"
        );
    }
}
