//! FPGA resource model — regenerates the "Ours" column of Table III as a
//! function of the architecture parameters (X, UF, buffer sizes).
//!
//! Anchors (paper, PYNQ-Z1 XC7Z020: 220 DSP, 53.2K LUT, 106.4K FF,
//! 140 BRAM36 = 4.9 Mb):
//!   49 DSP (22%), 42K LUT (79%), 49K FF (46%), 99% BRAM.
//!
//! Model rationale:
//! * DSP: int8 MACs pack 8 ops per 3 DSP48E1 (two 8-bit multiplies per
//!   DSP via the 27x18 pre-adder trick) -> 128 MACs ≈ 48, +1 in the PPU.
//! * LUT/FF: per-module linear costs fitted to the anchor.
//! * BRAM: row buffer + per-PM filter/output buffers + FIFOs at the
//!   paper's sizing for the largest supported layer.

use super::config::AccelConfig;

/// XC7Z020 (PYNQ-Z1) DSP48E1 slice count.
pub const Z7020_DSP: u32 = 220;
/// XC7Z020 LUT count.
pub const Z7020_LUT: u32 = 53_200;
/// XC7Z020 flip-flop count.
pub const Z7020_FF: u32 = 106_400;
/// XC7Z020 BRAM capacity in bits (140 BRAM36 = 4.9 Mb).
pub const Z7020_BRAM_BITS: u64 = 140 * 36 * 1024;

/// Estimated FPGA resource footprint of one configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ResourceUsage {
    /// DSP48E1 slices.
    pub dsp: u32,
    /// Lookup tables.
    pub lut: u32,
    /// Flip-flops.
    pub ff: u32,
    /// Block RAM bits.
    pub bram_bits: u64,
}

impl ResourceUsage {
    /// DSP usage as a percentage of the XC7Z020.
    pub fn dsp_pct(&self) -> f64 {
        self.dsp as f64 / Z7020_DSP as f64 * 100.0
    }

    /// LUT usage as a percentage of the XC7Z020.
    pub fn lut_pct(&self) -> f64 {
        self.lut as f64 / Z7020_LUT as f64 * 100.0
    }

    /// Flip-flop usage as a percentage of the XC7Z020.
    pub fn ff_pct(&self) -> f64 {
        self.ff as f64 / Z7020_FF as f64 * 100.0
    }

    /// BRAM usage as a percentage of the XC7Z020.
    pub fn bram_pct(&self) -> f64 {
        self.bram_bits as f64 / Z7020_BRAM_BITS as f64 * 100.0
    }

    /// True when every resource fits the XC7Z020.
    pub fn fits(&self) -> bool {
        self.dsp <= Z7020_DSP
            && self.lut <= Z7020_LUT
            && self.ff <= Z7020_FF
            && self.bram_bits <= Z7020_BRAM_BITS
    }
}

/// Largest-layer sizing assumptions behind the BRAM budget (the paper
/// dimensions buffers for its evaluation set: Ic,max=1024, Ks,max=9,
/// row width Iw,max*Ic,max = 8 KB).
pub const MAX_IC: usize = 1024;
/// Largest supported kernel size.
pub const MAX_KS: usize = 9;
/// Largest supported input-row footprint (Iw,max * Ic,max bytes).
pub const MAX_ROW_BYTES: usize = 8 * 1024;
/// Largest supported output width.
pub const MAX_OW: usize = 512;

/// Estimate the Table III resource footprint of `cfg`.
pub fn estimate(cfg: &AccelConfig) -> ResourceUsage {
    let macs = (cfg.x_pms * cfg.uf) as u32;
    // 3 DSP48E1 per 8 int8 MACs (dual-mult packing), + 1 for the PPU.
    let dsp = (macs * 3 + 7) / 8 + 1;

    // Fitted linear LUT/FF model (anchor: X=8, UF=16 -> 42K LUT, 49K FF).
    let lut = 6_000 // decoder + scheduler + crossbar + AXI plumbing
        + 2_500 // MM2IM mapper
        + cfg.x_pms as u32 * 2_900 // CU control + cmap check + muxer
        + macs * 85; // PE array datapath
    let ff = 7_000 + 2_000 + cfg.x_pms as u32 * 3_200 + macs * 115;

    // BRAM bits: row buffer + per-PM (double-buffered filter buffer +
    // out row + FIFO). The filter buffer is sized for the largest
    // evaluated filter slice (DCGAN_1: 5*5*1024 = 25.6 KB), doubled so
    // the Weight Data Loader can stream the next tile's filters while
    // the current tile computes.
    let row_buffer = (cfg.row_buffer_rows * MAX_ROW_BYTES) as u64 * 8;
    let filter_slice_bytes = (5 * 5 * MAX_IC) as u64;
    let filter_buf = 2 * filter_slice_bytes * 8;
    let out_buf = (MAX_OW * 4) as u64 * 8;
    let fifo = (2 * 1024) as u64 * 8;
    let bram_bits = row_buffer + cfg.x_pms as u64 * (filter_buf + out_buf + fifo);

    ResourceUsage { dsp, lut, ff, bram_bits }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_instantiation_matches_table3_ours_column() {
        let r = estimate(&AccelConfig::default());
        assert_eq!(r.dsp, 49, "paper: 49 DSP");
        assert!((r.dsp_pct() - 22.0).abs() < 1.5, "paper: 22% ({:.1}%)", r.dsp_pct());
        assert!((r.lut as f64 - 42_000.0).abs() < 4_000.0, "paper: 42K LUT (got {})", r.lut);
        assert!((r.ff as f64 - 49_000.0).abs() < 5_000.0, "paper: 49K FF (got {})", r.ff);
        assert!(r.bram_pct() > 85.0 && r.bram_pct() <= 100.0, "paper: 99% BRAM ({:.1}%)", r.bram_pct());
        assert!(r.fits());
    }

    #[test]
    fn scaling_x_scales_resources() {
        let small = estimate(&AccelConfig { x_pms: 2, ..AccelConfig::default() });
        let big = estimate(&AccelConfig { x_pms: 16, ..AccelConfig::default() });
        assert!(small.dsp < big.dsp);
        assert!(small.lut < big.lut);
        assert!(small.bram_bits < big.bram_bits);
        // X=16 at UF=16 blows the BRAM budget -> the paper's X=8 choice.
        assert!(!big.fits());
    }

    #[test]
    fn uf_scales_dsp() {
        let a = estimate(&AccelConfig { uf: 8, ..AccelConfig::default() });
        let b = estimate(&AccelConfig { uf: 32, ..AccelConfig::default() });
        assert!(a.dsp < b.dsp);
    }
}
